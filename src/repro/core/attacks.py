"""Adversarial primitives from the paper's robustness studies — pure,
jittable state transforms. The schedulable, composable layer on top
(`ThreatModel` / `Attack` / `instrument_program`) lives in
`core.adversary` (DESIGN.md §9); these functions are the registry
entries behind `adversary.resolve_attack`.

§4.7 LSH-cheating attack: attackers controlling half of a target's
potential neighbors forge their published LSH codes to match the
target's code (maximal apparent similarity) while their actual models
are garbage — aiming to be selected and poison the target's distillation
aggregate.

§4.8 poison attack: a fraction of clients re-initialize their model
parameters every 3 rounds after a 50-round honest warm-up, injecting
noise into the network.

Commit-and-reveal attack (for §3.6 tests): a client reveals a ranking
different from the one it committed to.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.protocol import FedState


def attack_active(round_idx, start_round: int = 0, every: int = 1):
    """Scan-safe schedule predicate: active from `start_round`, every
    `every` rounds. Works with traced round indices (inside `jit` /
    `lax.scan` segments) as well as Python ints — gate with `lax.cond`
    or `jnp.where`, never a host `if`."""
    r = jnp.asarray(round_idx)
    return (r >= start_round) & (jnp.mod(r - start_round, every) == 0)


def forge_lsh_codes(state: FedState, attacker_mask, target_id: int
                    ) -> FedState:
    """Attackers republish the target's LSH code as their own (Eq. 5
    forgery). attacker_mask: (M,) bool."""
    forged = jnp.where(attacker_mask[:, None], state.codes[target_id][None],
                       state.codes)
    return state._replace(codes=forged)


def corrupt_params(state: FedState, attacker_mask, init_fn, key) -> FedState:
    """Replace attackers' params with fresh random re-initializations."""
    m = attacker_mask.shape[0]
    fresh = jax.vmap(init_fn)(jax.random.split(key, m))

    def mix(old, new):
        mask = attacker_mask.reshape((m,) + (1,) * (old.ndim - 1))
        return jnp.where(mask, new.astype(old.dtype), old)

    return state._replace(params=jax.tree.map(mix, state.params, fresh))


def poison_step(state: FedState, attacker_mask, init_fn, key, round_idx,
                *, start_round: int = 50, every: int = 3) -> FedState:
    """§4.8: periodic re-initialization after warm-up. Gated with
    `lax.cond` on `attack_active` so it stays correct when `round_idx`
    is traced (a host `if` silently mis-gates under `jit`/`scan`)."""
    return jax.lax.cond(
        attack_active(round_idx, start_round, every),
        lambda s: corrupt_params(s, attacker_mask, init_fn, key),
        lambda s: s, state)


def lie_in_reveal(state: FedState, liar_mask) -> FedState:
    """Reveal a ranking that GUARANTEED differs from the committed one —
    rotate the order and perturb the top entry (a random shuffle can be
    the identity with probability 1/n!, which would not be a lie). The
    §3.6 check must flag these reporters."""
    lied = jnp.roll(state.rankings, 1, axis=1)
    lied = lied.at[:, 0].add(1)          # differs even for width-1 rankings
    new = jnp.where(liar_mask[:, None], lied, state.rankings)
    return state._replace(rankings=new)
