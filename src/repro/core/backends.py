"""Backend resolution for kernel-backed protocol subsystems.

`FedConfig` carries one backend field per kernel-backed subsystem
(`selection_backend`, `exchange_backend`); both accept the same three
values and resolve through this single helper so the string validation
lives in exactly one place (DESIGN.md §4, §7):

  "kernel" -> the Pallas kernel path (interpret-mode off-TPU — the
              correctness path, not a CPU speedup),
  "oracle" -> the bit-exact pure-jnp twin,
  "auto"   -> kernel on TPU, oracle elsewhere.

This module deliberately imports only jax. `repro.core` modules import
it directly; `repro.kernels.ops.resolve_backend` delegates here via a
function-level import (`repro.core.__init__` pulls in the whole
protocol, so a module-level import from the kernels package would be a
cycle).
"""
from __future__ import annotations

import jax

BACKENDS = ("auto", "kernel", "oracle")


def interpret() -> bool:
    """Pallas kernels run in interpret mode everywhere but TPU."""
    return jax.default_backend() != "tpu"


def resolve(backend: str) -> str:
    """Validate and resolve a backend string to "kernel" or "oracle"."""
    if backend == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "oracle"
    if backend not in ("kernel", "oracle"):
        raise ValueError(
            f"unknown backend: {backend!r} (expected one of {BACKENDS})")
    return backend
