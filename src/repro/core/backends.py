"""Backend & tiling resolution for kernel-backed protocol subsystems.

`FedConfig` carries one backend field per kernel-backed subsystem
(`selection_backend`, `exchange_backend`); both accept the same three
values and resolve through this single helper so the string validation
lives in exactly one place (DESIGN.md §4, §7):

  "kernel" -> the Pallas kernel path (interpret-mode off-TPU — the
              correctness path, not a CPU speedup),
  "oracle" -> the bit-exact pure-jnp twin,
  "auto"   -> kernel on TPU, oracle elsewhere.

Each kernel-backed subsystem additionally carries a *tiling* field
(`selection_tiling`, `exchange_tiling`) resolved by `resolve_tiling`
(DESIGN.md §10):

  "oneshot" -> the original kernels that hold their full working set
               per program (bit-exact defaults; VMEM is O(problem)),
  "tiled"   -> the VMEM-tiled streaming kernels (selection: column-
               tiled two-pass top-N, bit-exact; exchange: R/C-tiled
               online-softmax, tolerance-bounded — see §10),
  "auto"    -> oneshot while the per-program working set fits the VMEM
               budget, tiled beyond it — an explicit estimate
               (`selection_vmem_bytes` / `exchange_vmem_bytes`)
               instead of an OOM at lowering time.

This module deliberately imports only jax. `repro.core` modules import
it directly; `repro.kernels.ops.resolve_backend` delegates here via a
function-level import (`repro.core.__init__` pulls in the whole
protocol, so a module-level import from the kernels package would be a
cycle).
"""
from __future__ import annotations

import jax

BACKENDS = ("auto", "kernel", "oracle")
TILINGS = ("auto", "oneshot", "tiled")
# selection additionally accepts "ann" (DESIGN.md §11): the
# sub-quadratic LSH-bucket candidate index. Exchange has no ANN
# analogue, so plain `resolve` keeps rejecting it.
SELECTION_BACKENDS = BACKENDS + ("ann",)

# "auto" hands selection to the ANN path only when the exact kernel's
# FLOPs exceed the candidate path's by this ratio AND the federation
# is past the floor — below it the exact kernels are comfortably
# VMEM/FLOP-bounded and stay bit-exact for free.
ANN_AUTO_MIN_M = 4096
ANN_AUTO_MIN_RATIO = 4.0

# TPU v5e VMEM is ~16 MiB/core; the budget leaves headroom for the
# compiler's own double-buffering and spills (DESIGN.md §10).
VMEM_LIMIT_BYTES = 16 * 2 ** 20
VMEM_BUDGET_BYTES = int(VMEM_LIMIT_BYTES * 0.75)


def interpret() -> bool:
    """Pallas kernels run in interpret mode everywhere but TPU."""
    return jax.default_backend() != "tpu"


def _reject(field: str, value, accepted) -> ValueError:
    """The one rejection formatter for every backend/tiling string
    (DESIGN.md §12): the message always names the offending FIELD, the
    offending value, and the accepted set, in this exact shape — the
    property test in tests/test_analysis.py asserts on it."""
    return ValueError(
        f"unknown {field}: {value!r} (expected one of {tuple(accepted)})")


def resolve(backend: str) -> str:
    """Validate and resolve a backend string to "kernel" or "oracle"."""
    if backend == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "oracle"
    if backend not in ("kernel", "oracle"):
        raise _reject("backend", backend, BACKENDS)
    return backend


# ---------------------------------------------------------------------------
# per-program VMEM estimates (DESIGN.md §10 carries the derivations)
# ---------------------------------------------------------------------------
def selection_vmem_bytes(m: int, bits_tot: int, *, block_m: int = 8) -> int:
    """One-shot `fused_select` working set per program: unpacked +-1
    row/column codes ((BM + M) * bits) + the (BM, M) weight block, f32,
    plus the packed uint32 inputs."""
    words = bits_tot // 32
    unpacked = (block_m + m) * bits_tot * 4
    weights = block_m * m * 4
    packed = (block_m + m) * words * 4
    return unpacked + weights + packed


def selection_tiled_vmem_bytes(bits_tot: int, *, block_m: int = 128,
                               block_k: int = 512, nsel: int = 16) -> int:
    """Column-tiled `fused_select_tiled` working set per program:
    O(tile), independent of M — unpacked (BM + BK) codes, the (BM, BK)
    weight tile, and the (BM, N) running top-N scratch."""
    unpacked = (block_m + block_k) * bits_tot * 4
    weights = block_m * block_k * 4
    scratch = 2 * block_m * max(nsel, 1) * 4
    return unpacked + weights + scratch


def exchange_vmem_bytes(n: int, r: int, c: int, *, block_m: int = 4) -> int:
    """One-shot `fused_exchange` working set per program: the
    (BM, N, R, C) neighbor-logit tile plus the (BM, R, C) own tile and
    the (BM, R, C) target output, f32."""
    return block_m * (n + 2) * r * c * 4


def exchange_tiled_vmem_bytes(n: int, *, block_m: int = 4, block_r: int = 8,
                              block_c: int = 512) -> int:
    """Streamed `fused_exchange_streamed` working set per program:
    O(tile) — the (BM, N, BR, BC) neighbor tile, the (BM, BR, BC) own
    tile, and the online-softmax scratch (4 arrays of (BM, N, BR) plus
    2 of (BM, BR))."""
    tiles = block_m * (n + 1) * block_r * block_c * 4
    scratch = (4 * block_m * n * block_r + 2 * block_m * block_r) * 4
    return tiles + scratch


def ann_vmem_bytes(bits_tot: int, *, block_m: int = 8,
                   block_k: int = 256, nsel: int = 16) -> int:
    """`fused_select_ann` working set per program: unpacked +-1 row
    codes (BM * bits) and candidate codes (BM * BK * bits), the
    (BM, BK) weight tile, and the (BM, N) running top-N scratch."""
    unpacked = (block_m + block_m * block_k) * bits_tot * 4
    weights = block_m * block_k * 4
    scratch = 2 * block_m * max(nsel, 1) * 4
    return unpacked + weights + scratch


# Introspection hook for the static-analysis gate (DESIGN.md §12):
# every estimator that a kernel contract can declare by name. The
# `repro.analysis` kernel-contract checker cross-validates each one
# against the VMEM bytes implied by the kernel's actual BlockSpecs, so
# a kernel retune that forgets this file fails CI instead of silently
# skewing resolve_tiling's "auto" decision.
VMEM_ESTIMATORS = {
    "selection_vmem_bytes": selection_vmem_bytes,
    "selection_tiled_vmem_bytes": selection_tiled_vmem_bytes,
    "exchange_vmem_bytes": exchange_vmem_bytes,
    "exchange_tiled_vmem_bytes": exchange_tiled_vmem_bytes,
    "ann_vmem_bytes": ann_vmem_bytes,
}


# ---------------------------------------------------------------------------
# per-round FLOP estimates — the "auto" exact-vs-ann decision (§11)
# ---------------------------------------------------------------------------
def selection_flops(m: int, bits_tot: int) -> float:
    """Exact selection prices every pair: one M x M +-1 Gram matmul,
    2 * M^2 * bits FLOPs per round (tiling changes VMEM, not FLOPs)."""
    return 2.0 * m * m * bits_tot


def ann_selection_flops(m: int, bits_tot: int, k: int) -> float:
    """ANN selection prices only candidates: 2 * M * K * bits, with
    K = (probes + 1) * bucket_cap + teaser (core.ann.candidate_count)."""
    return 2.0 * m * k * bits_tot


def resolve_selection(backend: str, m: int, *, exact_flops: float,
                      ann_flops: float) -> str:
    """Resolve a selection backend to "kernel", "oracle", or "ann".

    "ann" is explicit opt-in at any M. "auto" additionally routes to
    the ANN path once the federation is big enough that the exact
    Gram is ANN_AUTO_MIN_RATIO x the candidate path's FLOPs AND
    m >= ANN_AUTO_MIN_M — below either threshold "auto" keeps the
    bit-exact §10 kernels (approximation is never silent at small M).
    """
    if backend == "ann":
        return "ann"
    if backend == "auto":
        if m >= ANN_AUTO_MIN_M and exact_flops >= ANN_AUTO_MIN_RATIO * \
                ann_flops:
            return "ann"
        return resolve("auto")
    if backend not in ("kernel", "oracle"):
        raise _reject("selection backend", backend, SELECTION_BACKENDS)
    return backend


def resolve_tiling(tiling: str, est_oneshot_bytes: int, *,
                   budget_bytes: int = None) -> str:
    """Validate and resolve a tiling string to "oneshot" or "tiled".

    "auto" compares the one-shot kernel's per-program VMEM estimate
    against the budget — the explicit form of the decision that used to
    be an OOM at M ~ 10^4 clients / vocab-scale reference sets."""
    if tiling == "auto":
        budget = VMEM_BUDGET_BYTES if budget_bytes is None else budget_bytes
        return "oneshot" if est_oneshot_bytes <= budget else "tiled"
    if tiling not in ("oneshot", "tiled"):
        raise _reject("tiling", tiling, TILINGS)
    return tiling
