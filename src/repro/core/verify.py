"""Trust-free verification mechanisms.

§3.5 LSH-code verification: during P2P exchange, client i compares its
own reference-set outputs f(theta_i, X_i^ref) with each neighbor's
f(theta_j, X_i^ref) via KL divergence. Neighbors whose output similarity
ranks in the LOWER HALF are excluded from distillation — a forged LSH
code cannot fake logits on a reference set the attacker has never seen.

§3.6 ranking verification: commit-and-reveal (chain.py holds the SHA-256
path; the in-graph FNV fast path is here).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.chain import fnv1a_commit


def kl_divergence(logits_p, logits_q, axis: int = -1):
    """KL(softmax(p) || softmax(q)), summed over classes, mean over batch."""
    logp = jax.nn.log_softmax(logits_p, axis=axis)
    logq = jax.nn.log_softmax(logits_q, axis=axis)
    kl = jnp.sum(jnp.exp(logp) * (logp - logq), axis=axis)
    return jnp.mean(kl, axis=-1)


def lsh_verification_mask(own_logits, neighbor_logits, neighbor_mask):
    """§3.5 filter. own_logits: (R, C); neighbor_logits: (N, R, C);
    neighbor_mask: (N,) bool (selected neighbors).

    Returns (N,) bool — True for neighbors that PASS (upper half by
    output similarity). Invalid neighbors always fail.
    """
    kls = jax.vmap(lambda nl: kl_divergence(own_logits, nl))(
        neighbor_logits)                                   # (N,)
    kls = jnp.where(neighbor_mask, kls, jnp.inf)
    n_valid = jnp.sum(neighbor_mask.astype(jnp.int32))
    keep = (n_valid + 1) // 2                              # upper half
    order = jnp.argsort(kls)                               # ascending KL
    rank_of = jnp.argsort(order)                           # rank per entry
    return (rank_of < keep) & neighbor_mask


def verify_rankings_fnv(revealed, commitments, salt=0):
    """In-graph commit check. revealed: (M, N) int32; commitments: (M,)
    uint32 from last round. Returns (M,) bool reporter mask."""
    return fnv1a_commit(revealed, salt) == commitments
