"""Baseline methods from WPFed §4.2, sharing the FedState/data API so
Table 2 / Fig. 5 comparisons are apples-to-apples.

SILO    (Lian et al. 17):  purely local training, no collaboration.
FedMD   (Li & Wang 19):    distillation toward the all-client consensus
                           on a SHARED reference set, no selection.
ProxyFL (Kalra et al. 23): uniform random gossip — each round every
                           client distills from a few random peers
                           (proxy-model exchange reduces, in logit space,
                           to peer-output distillation).
KD-PDFL (Jeong & K. 23):   similarity-only selection — neighbors chosen
                           by output-KL similarity via knowledge
                           distillation, no rank score, no verification.

Each baseline is expressed as a `core.rounds.RoundProgram` (DESIGN.md
§8): the global round is the method's classic per-round body, and the
gossip epoch reuses the method's selection cache where one exists —
ProxyFL keeps its random peer draw, KD-PDFL its KL-similar neighbor
ids (turning its O(M^2) all-pairs forwards into O(M*N) per epoch).
SILO and FedMD have nothing to re-select (purely local / all-client
consensus), so their gossip epoch IS the global body. The classic
`make_*_round` constructors are adapters over the programs.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.paper_models import FedConfig
from repro.core import verify
from repro.core.protocol import FedState, batched_local_update
from repro.core.rounds import RoundProgram, program_round
from repro.optim.optimizers import Optimizer


def _update_round(apply_fn, optimizer, fed: FedConfig, state: FedState,
                  data_per, target, has_target, rng, rng_upd
                  ) -> Tuple[FedState, Dict]:
    """Shared tail of every baseline round: per-client fold_in keys,
    batched local updates on (target, has_target), state advance."""
    m = fed.num_clients
    upd_keys = jax.vmap(
        lambda i: jax.random.fold_in(rng_upd, i))(jnp.arange(m))
    params, opt_state, tm = batched_local_update(
        apply_fn, optimizer, fed, state.params, state.opt_state,
        data_per, target, has_target, upd_keys)
    metrics = {"mean_loss": jnp.mean(tm["loss"])}
    return state._replace(params=params, opt_state=opt_state, rng=rng,
                          round=state.round + 1), metrics


def _own_data_per(data):
    return {k: data[k] for k in ("x_train", "y_train", "x_ref", "y_ref")}


def silo_program(apply_fn, optimizer, fed: FedConfig) -> RoundProgram:
    m = fed.num_clients

    def round_body(state: FedState, data):
        rng, rng_upd = jax.random.split(state.rng)
        # zero distillation target, has_target=False -> pure local CE
        dummy = jnp.zeros_like(
            jax.vmap(apply_fn)(state.params, data["x_ref"]))
        state, metrics = _update_round(
            apply_fn, optimizer, fed, state, _own_data_per(data),
            dummy, jnp.zeros((m,), bool), rng, rng_upd)
        return state, (), metrics

    # purely local: nothing to re-select, every epoch is the full body
    return RoundProgram("silo", round_body,
                        lambda state, data, cache: round_body(state, data))


def fedmd_program(apply_fn, optimizer, fed: FedConfig,
                  shared_ref_x) -> RoundProgram:
    """Consensus distillation on one shared reference set."""
    m = fed.num_clients

    def round_body(state: FedState, data):
        rng, rng_upd = jax.random.split(state.rng)
        logits = jax.vmap(apply_fn, in_axes=(0, None))(
            state.params, shared_ref_x)                    # (M,R,C)
        consensus = jnp.mean(logits, axis=0)               # (R,C)
        data_per = {k: data[k] for k in ("x_train", "y_train")}
        data_per["x_ref"] = jnp.broadcast_to(
            shared_ref_x[None], (m,) + shared_ref_x.shape)
        data_per["y_ref"] = jnp.zeros((m, shared_ref_x.shape[0]), jnp.int32)
        state, metrics = _update_round(
            apply_fn, optimizer, fed, state, data_per,
            jnp.broadcast_to(consensus[None], logits.shape),
            jnp.ones((m,), bool), rng, rng_upd)
        return state, (), metrics

    # the consensus must track the drifting params, so every epoch
    # recomputes it: no reusable selection cache
    return RoundProgram("fedmd", round_body,
                        lambda state, data, cache: round_body(state, data))


def proxyfl_program(apply_fn, optimizer, fed: FedConfig,
                    num_peers: int = 3) -> RoundProgram:
    """Uniform random gossip distillation; the cache is the peer draw."""
    m = fed.num_clients

    def _distill_from(state: FedState, data, ids, rng, rng_upd):
        nb_params = jax.tree.map(lambda p: p[ids], state.params)
        y_web = jax.vmap(jax.vmap(apply_fn, in_axes=(0, None)))(
            nb_params, data["x_ref"])                      # (M,P,R,C)
        target = jnp.mean(y_web, axis=1)
        return _update_round(apply_fn, optimizer, fed, state,
                             _own_data_per(data), target,
                             jnp.ones((m,), bool), rng, rng_upd)

    def global_round(state: FedState, data):
        rng, rng_pick, rng_upd = jax.random.split(state.rng, 3)
        ids = jax.vmap(
            lambda k: jax.random.choice(k, m, (num_peers,), replace=False)
        )(jnp.stack(list(jax.random.split(rng_pick, m))))   # (M,P)
        state, metrics = _distill_from(state, data, ids, rng, rng_upd)
        return state, ids, metrics

    def gossip_round(state: FedState, data, ids):
        rng, rng_upd = jax.random.split(state.rng)
        state, metrics = _distill_from(state, data, ids, rng, rng_upd)
        return state, ids, metrics

    return RoundProgram("proxyfl", global_round, gossip_round)


def kdpdfl_program(apply_fn, optimizer, fed: FedConfig) -> RoundProgram:
    """Similarity-only selection: top-N by output-KL on own ref set.
    The global round pays the O(M^2) all-pairs forwards; gossip epochs
    reuse the cached neighbor ids at O(M*N)."""
    m = fed.num_clients
    n = min(fed.num_neighbors, m - 1)

    def global_round(state: FedState, data):
        rng, rng_upd = jax.random.split(state.rng)
        # all-pairs outputs on each client's own reference set
        y_all = jax.vmap(                                   # over i (ref set)
            jax.vmap(apply_fn, in_axes=(0, None))           # over j (model)
        )(jax.tree.map(
            lambda p: jnp.broadcast_to(p[None], (m,) + p.shape),
            state.params), data["x_ref"])                  # (M,M,R,C)
        own = jax.vmap(apply_fn)(state.params, data["x_ref"])
        kls = jax.vmap(lambda o, ys: jax.vmap(
            lambda y: verify.kl_divergence(o, y))(ys))(own, y_all)  # (M,M)
        kls = jnp.where(jnp.eye(m, dtype=bool), jnp.inf, kls)
        _, ids = jax.lax.top_k(-kls, n)                     # most similar
        picked = jnp.take_along_axis(
            y_all, ids[:, :, None, None], axis=1)           # (M,N,R,C)
        target = jnp.mean(picked, axis=1)
        state, metrics = _update_round(
            apply_fn, optimizer, fed, state, _own_data_per(data),
            target, jnp.ones((m,), bool), rng, rng_upd)
        return state, ids, metrics

    def gossip_round(state: FedState, data, ids):
        rng, rng_upd = jax.random.split(state.rng)
        nb_params = jax.tree.map(lambda p: p[ids], state.params)
        y_nb = jax.vmap(jax.vmap(apply_fn, in_axes=(0, None)))(
            nb_params, data["x_ref"])                      # (M,N,R,C)
        target = jnp.mean(y_nb, axis=1)
        state, metrics = _update_round(
            apply_fn, optimizer, fed, state, _own_data_per(data),
            target, jnp.ones((m,), bool), rng, rng_upd)
        return state, ids, metrics

    return RoundProgram("kdpdfl", global_round, gossip_round)


# ---------------------------------------------------------------------------
# classic per-round adapters
# ---------------------------------------------------------------------------
def make_silo_round(apply_fn, optimizer, fed: FedConfig):
    return program_round(silo_program(apply_fn, optimizer, fed))


def make_fedmd_round(apply_fn, optimizer, fed: FedConfig, shared_ref_x):
    return program_round(fedmd_program(apply_fn, optimizer, fed,
                                       shared_ref_x))


def make_proxyfl_round(apply_fn, optimizer, fed: FedConfig,
                       num_peers: int = 3):
    return program_round(proxyfl_program(apply_fn, optimizer, fed,
                                         num_peers=num_peers))


def make_kdpdfl_round(apply_fn, optimizer, fed: FedConfig):
    return program_round(kdpdfl_program(apply_fn, optimizer, fed))


BASELINES = {
    "silo": make_silo_round,
    "fedmd": make_fedmd_round,
    "proxyfl": make_proxyfl_round,
    "kdpdfl": make_kdpdfl_round,
}

BASELINE_PROGRAMS = {
    "silo": silo_program,
    "fedmd": fedmd_program,
    "proxyfl": proxyfl_program,
    "kdpdfl": kdpdfl_program,
}
