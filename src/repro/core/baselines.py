"""Baseline methods from WPFed §4.2, sharing the FedState/data API so
Table 2 / Fig. 5 comparisons are apples-to-apples.

SILO    (Lian et al. 17):  purely local training, no collaboration.
FedMD   (Li & Wang 19):    distillation toward the all-client consensus
                           on a SHARED reference set, no selection.
ProxyFL (Kalra et al. 23): uniform random gossip — each round every
                           client distills from a few random peers
                           (proxy-model exchange reduces, in logit space,
                           to peer-output distillation).
KD-PDFL (Jeong & K. 23):   similarity-only selection — neighbors chosen
                           by output-KL similarity via knowledge
                           distillation, no rank score, no verification.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.paper_models import FedConfig
from repro.core import distill, verify
from repro.core.protocol import FedState, batched_local_update
from repro.optim.optimizers import Optimizer


def _no_target(data):
    ref_shape = data["x_ref"].shape            # (M, R, ...)
    return None


def make_silo_round(apply_fn, optimizer, fed: FedConfig):
    m = fed.num_clients

    def round_fn(state: FedState, data):
        rng, rng_upd = jax.random.split(state.rng)
        upd_keys = jax.vmap(
            lambda i: jax.random.fold_in(rng_upd, i))(jnp.arange(m))
        # zero distillation target, has_target=False -> pure local CE
        dummy = jnp.zeros_like(
            jax.vmap(apply_fn)(state.params, data["x_ref"]))
        data_per = {k: data[k] for k in
                    ("x_train", "y_train", "x_ref", "y_ref")}
        params, opt_state, tm = batched_local_update(
            apply_fn, optimizer, fed, state.params, state.opt_state, data_per, dummy,
          jnp.zeros((m,), bool), upd_keys)
        metrics = {"mean_loss": jnp.mean(tm["loss"])}
        return state._replace(params=params, opt_state=opt_state, rng=rng,
                              round=state.round + 1), metrics

    return round_fn


def make_fedmd_round(apply_fn, optimizer, fed: FedConfig, shared_ref_x):
    """Consensus distillation on one shared reference set."""
    m = fed.num_clients

    def round_fn(state: FedState, data):
        rng, rng_upd = jax.random.split(state.rng)
        logits = jax.vmap(apply_fn, in_axes=(0, None))(
            state.params, shared_ref_x)                    # (M,R,C)
        consensus = jnp.mean(logits, axis=0)               # (R,C)
        upd_keys = jax.vmap(
            lambda i: jax.random.fold_in(rng_upd, i))(jnp.arange(m))
        data_per = {k: data[k] for k in ("x_train", "y_train")}
        data_per["x_ref"] = jnp.broadcast_to(
            shared_ref_x[None], (m,) + shared_ref_x.shape)
        data_per["y_ref"] = jnp.zeros((m, shared_ref_x.shape[0]), jnp.int32)
        params, opt_state, tm = batched_local_update(
            apply_fn, optimizer, fed, state.params, state.opt_state, data_per,
          jnp.broadcast_to(consensus[None], logits.shape),
          jnp.ones((m,), bool), upd_keys)
        metrics = {"mean_loss": jnp.mean(tm["loss"])}
        return state._replace(params=params, opt_state=opt_state, rng=rng,
                              round=state.round + 1), metrics

    return round_fn


def make_proxyfl_round(apply_fn, optimizer, fed: FedConfig,
                       num_peers: int = 3):
    """Uniform random gossip distillation."""
    m = fed.num_clients

    def round_fn(state: FedState, data):
        rng, rng_pick, rng_upd = jax.random.split(state.rng, 3)
        ids = jax.vmap(
            lambda k: jax.random.choice(k, m, (num_peers,), replace=False)
        )(jnp.stack(list(jax.random.split(rng_pick, m))))   # (M,P)
        nb_params = jax.tree.map(lambda p: p[ids], state.params)
        y_web = jax.vmap(jax.vmap(apply_fn, in_axes=(0, None)))(
            nb_params, data["x_ref"])                      # (M,P,R,C)
        target = jnp.mean(y_web, axis=1)
        upd_keys = jax.vmap(
            lambda i: jax.random.fold_in(rng_upd, i))(jnp.arange(m))
        data_per = {k: data[k] for k in
                    ("x_train", "y_train", "x_ref", "y_ref")}
        params, opt_state, tm = batched_local_update(
            apply_fn, optimizer, fed, state.params, state.opt_state, data_per, target,
          jnp.ones((m,), bool), upd_keys)
        metrics = {"mean_loss": jnp.mean(tm["loss"])}
        return state._replace(params=params, opt_state=opt_state, rng=rng,
                              round=state.round + 1), metrics

    return round_fn


def make_kdpdfl_round(apply_fn, optimizer, fed: FedConfig):
    """Similarity-only selection: top-N by output-KL on own ref set."""
    m = fed.num_clients
    n = min(fed.num_neighbors, m - 1)

    def round_fn(state: FedState, data):
        rng, rng_upd = jax.random.split(state.rng)
        # all-pairs outputs on each client's own reference set
        y_all = jax.vmap(                                   # over i (ref set)
            jax.vmap(apply_fn, in_axes=(0, None))           # over j (model)
        )(jax.tree.map(
            lambda p: jnp.broadcast_to(p[None], (m,) + p.shape),
            state.params), data["x_ref"])                  # (M,M,R,C)
        own = jax.vmap(apply_fn)(state.params, data["x_ref"])
        kls = jax.vmap(lambda o, ys: jax.vmap(
            lambda y: verify.kl_divergence(o, y))(ys))(own, y_all)  # (M,M)
        kls = jnp.where(jnp.eye(m, dtype=bool), jnp.inf, kls)
        _, ids = jax.lax.top_k(-kls, n)                     # most similar
        picked = jnp.take_along_axis(
            y_all, ids[:, :, None, None], axis=1)           # (M,N,R,C)
        target = jnp.mean(picked, axis=1)
        upd_keys = jax.vmap(
            lambda i: jax.random.fold_in(rng_upd, i))(jnp.arange(m))
        data_per = {k: data[k] for k in
                    ("x_train", "y_train", "x_ref", "y_ref")}
        params, opt_state, tm = batched_local_update(
            apply_fn, optimizer, fed, state.params, state.opt_state, data_per, target,
          jnp.ones((m,), bool), upd_keys)
        metrics = {"mean_loss": jnp.mean(tm["loss"])}
        return state._replace(params=params, opt_state=opt_state, rng=rng,
                              round=state.round + 1), metrics

    return round_fn


BASELINES = {
    "silo": make_silo_round,
    "fedmd": make_fedmd_round,
    "proxyfl": make_proxyfl_round,
    "kdpdfl": make_kdpdfl_round,
}
