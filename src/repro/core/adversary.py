"""First-class adversary API: typed, in-graph threat models composed
with the round-program engine (DESIGN.md §9).

The paper's robustness claims (§4.7 LSH-cheating, §4.8 poison) are
claims about (method x schedule x threat-model) combinations, so the
adversary is a subsystem like selection/exchange/rounds rather than a
per-experiment host loop:

  Attack        one scheduled behaviour — a pure jittable transform
                `(state, attacker_mask, round_idx, key) -> state` plus
                `start_round`/`every` gating. The gate is evaluated
                in-graph (`attacks.attack_active` under `lax.cond`),
                never with a host `if`, so attacks fire correctly for
                traced round indices — including the gossip epochs that
                run under `make_segment_fn`'s `lax.scan`.
  ThreatModel   a named attacker mask + a list of Attacks + a base PRNG
                key. Per-attack, per-round keys derive as
                `attack_key(key, attack_index, round_idx)`.
  resolve_attack  the one-place name/argument validator over the
                `core.attacks` primitives (the `repro.core.backends`
                pattern): "forge_codes", "corrupt", "poison" (§4.8
                defaults start_round=50, every=3), "lie_in_reveal".
  instrument_program  splices a ThreatModel into BOTH round bodies of a
                `core.rounds.RoundProgram` — attacks mutate state
                before each global round AND each gossip epoch, exactly
                where the legacy host hook ran — and augments the
                round metrics with in-graph threat telemetry
                (attacker admission rate, honest-vs-attacker ranking
                scores) wherever the base metrics expose the needed
                arrays. The instrumented program is still a program:
                it compiles into `make_segment_fn` segments, runs under
                sharding, and goes through `run_rounds` like every
                clean method.

`Schedule(1)` through an instrumented program is bit-exact with the
legacy per-round host loop (eager attack hook + jitted round) — pinned
in tests/test_adversary.py against a verbatim copy of that loop.

Module-level imports stay acyclic: `core.rounds` imports no siblings,
and `core.attacks` pulls only `core.protocol` (for FedState typing).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import attacks as _attacks
from repro.core.rounds import RoundProgram


class Attack(NamedTuple):
    """One scheduled adversarial behaviour."""
    name: str
    transform: Callable  # (state, attacker_mask, round_idx, key) -> state
    start_round: int = 0
    every: int = 1


class ThreatModel(NamedTuple):
    """Who attacks (mask), how (attacks), and with what randomness."""
    name: str
    attacker_mask: jnp.ndarray   # (M,) bool
    attacks: Tuple[Attack, ...]
    key: jnp.ndarray             # base PRNG key (see attack_key)


ATTACKS = ("forge_codes", "corrupt", "poison", "lie_in_reveal")
_NEEDS_INIT = ("corrupt", "poison")
_DEFAULT_SCHEDULE = {"poison": (50, 3)}   # §4.8: warm-up 50, re-init /3


def resolve_attack(name: str, *, start_round: Optional[int] = None,
                   every: Optional[int] = None, init_fn=None,
                   target_id: Optional[int] = None) -> Attack:
    """One-place attack construction + validation (the
    `repro.core.backends.resolve` pattern — benchmarks, examples and
    the launcher all build attacks here, so the name/argument checking
    lives in exactly one spot).

      "forge_codes"    §4.7 LSH forgery toward `target_id` (required)
      "corrupt"        replace attacker params with fresh re-inits
                       (`init_fn` required)
      "poison"         "corrupt" with the §4.8 schedule defaults
                       (start_round=50, every=3) unless overridden
      "lie_in_reveal"  §3.6 reveal that differs from the commitment
    """
    if name not in ATTACKS:
        raise ValueError(
            f"unknown attack: {name!r} (expected one of {ATTACKS})")
    d_start, d_every = _DEFAULT_SCHEDULE.get(name, (0, 1))
    start_round = d_start if start_round is None else start_round
    every = d_every if every is None else every
    if start_round < 0:
        raise ValueError(f"start_round must be >= 0, got {start_round}")
    if every < 1:
        raise ValueError(f"every must be >= 1, got {every}")
    if name in _NEEDS_INIT and init_fn is None:
        raise ValueError(f"attack {name!r} requires init_fn=")
    if name == "forge_codes" and target_id is None:
        raise ValueError("attack 'forge_codes' requires target_id=")

    if name == "forge_codes":
        def transform(state, mask, round_idx, key):
            return _attacks.forge_lsh_codes(state, mask, target_id)
    elif name in _NEEDS_INIT:
        def transform(state, mask, round_idx, key):
            return _attacks.corrupt_params(state, mask, init_fn, key)
    else:  # lie_in_reveal
        def transform(state, mask, round_idx, key):
            return _attacks.lie_in_reveal(state, mask)
    return Attack(name, transform, start_round, every)


def attacker_mask_tail(num_clients: int, frac: float) -> jnp.ndarray:
    """The experiments' convention (Fig. 4/5): the LAST
    int(M * frac) clients are the attackers."""
    n_bad = int(num_clients * frac)
    if not 0 < n_bad < num_clients:
        raise ValueError(
            f"attacker_frac={frac} yields {n_bad} attackers out of "
            f"{num_clients} clients (need 0 < attackers < clients)")
    return jnp.arange(num_clients) >= (num_clients - n_bad)


def threat_model(attack_list: Sequence[Attack], attacker_mask, *,
                 key=None, name: str = "threat") -> ThreatModel:
    """Validated ThreatModel constructor."""
    atks = tuple(attack_list)
    if not atks:
        raise ValueError("a ThreatModel needs at least one Attack")
    for a in atks:
        if not isinstance(a, Attack):
            raise TypeError(f"expected Attack, got {type(a).__name__} "
                            "(build attacks via resolve_attack)")
    attacker_mask = jnp.asarray(attacker_mask)
    if attacker_mask.ndim != 1 or \
            not jnp.issubdtype(attacker_mask.dtype, jnp.bool_):
        raise ValueError("attacker_mask must be a 1-D bool mask, got "
                         f"{attacker_mask.dtype}{attacker_mask.shape}")
    key = jax.random.PRNGKey(0) if key is None else key
    return ThreatModel(name, attacker_mask, atks, key)


def attack_key(key, attack_index, round_idx):
    """Per-(attack, round) key schedule: fold the attack's index, then
    the round, into the ThreatModel's base key."""
    return jax.random.fold_in(jax.random.fold_in(key, attack_index),
                              round_idx)


def apply_attacks(state, tm: ThreatModel, round_idx=None):
    """Apply every scheduled attack to `state` in ThreatModel order —
    fully in-graph: each attack runs under `lax.cond` on its
    `attack_active` gate, so the composition jits, scans, and shards.
    `round_idx` defaults to `state.round` (traced inside segments)."""
    r = state.round if round_idx is None else round_idx
    for i, atk in enumerate(tm.attacks):
        k = attack_key(tm.key, i, r)
        state = jax.lax.cond(
            _attacks.attack_active(r, atk.start_round, atk.every),
            lambda s, a=atk, kk=k: a.transform(s, tm.attacker_mask, r, kk),
            lambda s: s, state)
    return state


def _threat_metrics(metrics, attacker_mask):
    """In-graph threat telemetry derived from whatever per-round arrays
    the base program already reports (WPFed's `_round_metrics` exposes
    ranking_scores / neighbor_ids / valid_mask; baselines without a
    selection stage simply gain nothing):

      rank_score_honest / rank_score_attacker   Eq. 7 crowd scores by
          cohort — Fig. 5's "the crowd down-ranks poisoned clients".
      attacker_admission_rate   fraction of honest clients' VALID
          distillation slots held by attackers — Fig. 4/5's admission
          metric, the quantity the §3.5 filter collapses.
    """
    out = dict(metrics)
    honest = ~attacker_mask
    if "ranking_scores" in metrics:
        s = metrics["ranking_scores"]
        hf = honest.astype(s.dtype)
        af = attacker_mask.astype(s.dtype)
        out["rank_score_honest"] = (jnp.sum(s * hf)
                                    / jnp.maximum(jnp.sum(hf), 1))
        out["rank_score_attacker"] = (jnp.sum(s * af)
                                      / jnp.maximum(jnp.sum(af), 1))
    if "neighbor_ids" in metrics and "valid_mask" in metrics:
        ids, valid = metrics["neighbor_ids"], metrics["valid_mask"]
        att_sel = jnp.take(attacker_mask, ids)              # (M, N) bool
        admitted = (jnp.sum((att_sel & valid).astype(jnp.float32), axis=1)
                    / jnp.maximum(
                        jnp.sum(valid.astype(jnp.float32), axis=1), 1.0))
        hf = honest.astype(jnp.float32)
        out["attacker_admission_rate"] = (
            jnp.sum(admitted * hf) / jnp.maximum(jnp.sum(hf), 1.0))
    return out


def instrument_program(program: RoundProgram,
                       tm: ThreatModel) -> RoundProgram:
    """Splice a ThreatModel into a RoundProgram: attacks mutate state
    immediately before each global round AND each gossip epoch (the
    same point where the legacy host hook ran), and the per-round
    metrics gain the in-graph threat telemetry. The result is an
    ordinary program — `make_segment_fn` compiles it (gossip attacks
    run under the segment's `lax.scan`), `run_rounds` drives it, and
    the dryrun lowers it under sharding like any clean method."""

    def global_round(state, data):
        state = apply_attacks(state, tm)
        state, cache, metrics = program.global_round(state, data)
        return state, cache, _threat_metrics(metrics, tm.attacker_mask)

    gossip_round = None
    if program.gossip_round is not None:
        def gossip_round(state, data, cache):
            state = apply_attacks(state, tm)
            state, cache, metrics = program.gossip_round(state, data, cache)
            return state, cache, _threat_metrics(metrics, tm.attacker_mask)

    return RoundProgram(f"{program.name}+{tm.name}", global_round,
                        gossip_round)


# ---------------------------------------------------------------------------
# named threat-model presets (CLI / examples / benchmarks)
# ---------------------------------------------------------------------------
THREATS = ("lsh_cheat", "poison", "lie_in_reveal")


def resolve_threat(name: str, *, num_clients: int, attacker_frac: float = 0.5,
                   init_fn=None, key=None, start_round: Optional[int] = None,
                   every: Optional[int] = None,
                   target_id: int = 0) -> ThreatModel:
    """The paper's named threat models, in one validated place
    (launch/fed.py `--attack`, examples, benchmarks):

      "lsh_cheat"      §4.7 — corrupt params + forge LSH codes toward
                       `target_id`, every round from `start_round`
      "poison"         §4.8 — periodic re-initialization (registry
                       defaults start_round=50, every=3)
      "lie_in_reveal"  §3.6 — reveal a ranking differing from the
                       commitment

    Attackers are the last int(M * attacker_frac) clients
    (`attacker_mask_tail`).
    """
    if name not in THREATS:
        raise ValueError(
            f"unknown threat model: {name!r} (expected one of {THREATS})")
    mask = attacker_mask_tail(num_clients, attacker_frac)
    if name == "lsh_cheat":
        atks = [resolve_attack("corrupt", init_fn=init_fn,
                               start_round=start_round, every=every),
                resolve_attack("forge_codes", target_id=target_id,
                               start_round=start_round, every=every)]
    elif name == "poison":
        atks = [resolve_attack("poison", init_fn=init_fn,
                               start_round=start_round, every=every)]
    else:
        atks = [resolve_attack("lie_in_reveal", start_round=start_round,
                               every=every)]
    return threat_model(atks, mask, key=key, name=name)
