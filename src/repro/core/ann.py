"""LSH codes as a real ANN index (sub-quadratic selection, DESIGN.md §11).

The exact selection path prices every candidate pair: O(M^2 * bits)
FLOPs per round even after VMEM tiling (§10). But the published LSH
codes ALREADY encode proximity (Eq. 5-6) — close models agree on most
bits — so they can drive a bucketed candidate index the way
"Find Your Friends" restricts collaborator search to a sparse graph:

  1. *Prefix bucketing.* A per-round seeded permutation of the code's
     bit positions picks `prefix_bits` bits; clients sharing that
     prefix land in the same bucket (B = 2^prefix_bits buckets).
  2. *Multi-probe.* Each client also probes the buckets reached by
     flipping one prefix bit at a time (up to `probes` flips) — the
     standard multi-probe LSH recall knob: near-neighbors that
     straddle a bucket boundary differ in few prefix bits.
  3. *Score teaser.* Eq. 8 weights are s_j * exp(-gamma d/bits), so a
     globally high-ranked client can out-weigh a nearby one; distance
     buckets alone cannot see that. Every candidate set therefore
     also includes the global top-`teaser` ranking scores (one
     lax.top_k over M — O(M log M), not O(M^2)).

Exact Hamming -> Eq. 8 weights are then computed ONLY on the
candidate set (kernels.selection.fused_select_ann or the jnp twin
ref.ann_select_ref), and the per-bucket partial top-N merge reuses
the §10 knockout merge.

Everything here is pure jnp with STATIC shapes: buckets are laid out
as a padded (B, cap) table (stable sort by bucket id -> rank within
bucket -> scatter; overflow beyond `cap` is dropped from the
*candidate* side only — every client still queries with its own code).
Invalid slots (padding, empty probe buckets, teaser duplicates) carry
the sentinel id M, which the selection kernels mask to -inf exactly
like padded columns. The permutation seed is threaded from
`state.round` — the SAME per-round seed discipline as the LSH
projection itself (protocol.announce_phase), so reselection is
reproducible and scan-safe with a traced round index, and every peer
can recompute the bucketing from public information (the trust story
is unchanged: candidates come from codes everyone can verify).

Degenerate-bucket fallback: with `prefix_bits=0` there is ONE bucket
whose capacity is forced to M, so the candidate set is every client
in ascending id order and the ANN path is bit-exact against
`fused_select` / `fused_select_ref` (pinned in tests). The same holds
for all-identical codes at any prefix length: the shared bucket keeps
the first `cap` ids and the teaser covers the score order, which is
all the exact top-N can contain.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# Knuth multiplicative hash constants — the same counter-hash family
# as kernels.lsh_projection.rademacher_block, so the bucket
# permutation is "seeded like the projection" in mechanism, not just
# in spirit.
_K1 = 2654435761
_K2 = 40503
_K3 = 2246822519

MAX_PREFIX_BITS = 16        # 2^16 buckets bounds the table scatter


class AnnCandidates(NamedTuple):
    """Static-shape candidate layout for one round of ANN selection."""
    ids: jnp.ndarray       # (M, K) int32 candidate ids; invalid = M
    bucket: jnp.ndarray    # (M,) int32 bucket id per client
    counts: jnp.ndarray    # (B,) int32 bucket occupancy (pre-cap)
    dropped: jnp.ndarray   # () int32 clients beyond cap (candidate side)


def effective_prefix_bits(prefix_bits: int, bits_tot: int) -> int:
    """Static clamp: cannot take more prefix bits than the code has,
    and the bucket table is bounded at 2^MAX_PREFIX_BITS rows."""
    return max(0, min(prefix_bits, bits_tot, MAX_PREFIX_BITS))


def effective_probes(probes: int, prefix_bits: int) -> int:
    """Static clamp: single-bit probes can flip at most every prefix
    bit once (prefix_bits=0 leaves only the home bucket)."""
    return max(0, min(probes, prefix_bits))


def bucket_cap(m: int, prefix_bits: int, num_neighbors: int) -> int:
    """Static per-bucket candidate capacity: 4x the uniform occupancy
    but never fewer than N+1 ids (a full bucket must be able to serve
    a whole top-N by itself), never more than M. The 4x multiplier is
    measured, not guessed: clustered codes concentrate whole clusters
    into single buckets (occupancy ~ M/n_clusters, not M/B), and at 2x
    the overflow drops cost ~10 recall points on the benchmark sweep
    (BENCH_selection.json records `dropped_candidates` so the effect
    stays observable). prefix_bits=0 forces cap=M — the one-bucket
    exact fallback."""
    n_buckets = 1 << effective_prefix_bits(prefix_bits, 1 << 30)
    uniform = -(-m // n_buckets)                       # ceil(M / B)
    return min(m, max(num_neighbors + 1, 4 * uniform))


def teaser_count(m: int, num_neighbors: int) -> int:
    """Static size of the global top-score candidate tile."""
    return min(m, max(2 * num_neighbors, 16))


def candidate_count(m: int, prefix_bits: int, probes: int,
                    num_neighbors: int, bits_tot: int = 1 << 30) -> int:
    """Static K: candidates per client = (probes + 1) bucket tiles of
    `cap` plus the score teaser. The FLOP estimators in
    core.backends price the ANN path with this K."""
    pb = effective_prefix_bits(prefix_bits, bits_tot)
    np_ = effective_probes(probes, pb)
    return ((np_ + 1) * bucket_cap(m, pb, num_neighbors)
            + teaser_count(m, num_neighbors))


def prefix_bit_indices(bits_tot: int, prefix_bits: int, seed):
    """Seeded permutation of code bit positions; the first
    `prefix_bits` form the bucket prefix. `seed` may be a traced
    scalar (state.round) — the hash is pure uint32 arithmetic and the
    argsort is shape-static, so this is jit/scan-safe with NO host
    RNG anywhere on the ANN path."""
    i = jnp.arange(bits_tot, dtype=jnp.uint32)
    s = jnp.asarray(seed).astype(jnp.uint32)
    h = i * jnp.uint32(_K1) ^ (i * jnp.uint32(_K2) + s * jnp.uint32(_K3))
    h = h ^ (h >> jnp.uint32(15))
    h = h * jnp.uint32(_K3)
    h = h ^ (h >> jnp.uint32(13))
    order = jnp.argsort(h)                   # ties break by bit index
    return order[:prefix_bits].astype(jnp.int32)


def bucket_ids(codes, bit_idx):
    """Extract the (traced) prefix bit positions from packed uint32
    codes -> (M,) int32 bucket ids in [0, 2^prefix_bits)."""
    m = codes.shape[0]
    pb = bit_idx.shape[0]
    if pb == 0:
        return jnp.zeros((m,), jnp.int32)
    words = jnp.take(codes, bit_idx // 32, axis=1)       # (M, pb)
    bits = (words >> (bit_idx % 32).astype(jnp.uint32)) & jnp.uint32(1)
    weights = (jnp.uint32(1) << jnp.arange(pb, dtype=jnp.uint32))[None, :]
    return jnp.sum(bits * weights, axis=1).astype(jnp.int32)


def probe_masks(prefix_bits: int, probes: int):
    """Static XOR mask sequence: home bucket first, then single-bit
    flips of prefix bit 0, 1, ... (the prefix bits are already a
    seeded permutation of code positions, so the flip order is seeded
    too). Probed buckets are pairwise distinct, so no candidate can
    appear in two bucket tiles."""
    np_ = effective_probes(probes, prefix_bits)
    return jnp.asarray([0] + [1 << t for t in range(np_)], jnp.int32)


def build_bucket_table(bucket, m: int, n_buckets: int, cap: int):
    """Padded (B, cap) table of client ids per bucket.

    Stable sort by bucket id keeps ids ASCENDING within a bucket —
    the invariant the knockout merge needs to reproduce lax.top_k's
    first-max tie-breaking in the one-bucket exact fallback. Returns
    (table (B, cap) int32 padded with sentinel M, counts (B,) int32
    true occupancy, rank (M,) int32 position of each client within
    its bucket — rank >= cap means the client was dropped as a
    CANDIDATE by overflow, though it still queries normally)."""
    order = jnp.argsort(bucket, stable=True).astype(jnp.int32)
    sb = bucket[order]
    first = jnp.searchsorted(sb, sb, side="left")
    rank_sorted = (jnp.arange(m, dtype=jnp.int32)
                   - first.astype(jnp.int32))
    slot = sb * cap + rank_sorted
    ok = rank_sorted < cap
    flat = jnp.full((n_buckets * cap + 1,), m, jnp.int32)
    flat = flat.at[jnp.where(ok, slot, n_buckets * cap)].set(order)
    table = flat[:-1].reshape(n_buckets, cap)
    counts = jnp.zeros((n_buckets,), jnp.int32).at[bucket].add(1)
    rank = jnp.zeros((m,), jnp.int32).at[order].set(rank_sorted)
    return table, counts, rank


def ann_candidates(codes, scores, *, seed, prefix_bits: int, probes: int,
                   num_neighbors: int) -> AnnCandidates:
    """One round of candidate generation: seeded prefix bucketing +
    multi-probe + score teaser -> (M, K) candidate ids with sentinel
    M in every invalid slot (bucket padding, teaser duplicates).

    Valid entries in a row are pairwise DISTINCT: probed buckets are
    distinct and partition clients, and teaser entries already present
    in a probed bucket tile (probed AND rank < cap) are masked to the
    sentinel. Self ids are left in (the selection kernels self-mask
    exactly like the exact path). All shapes are static; `seed` may be
    traced."""
    m, w = codes.shape
    bits_tot = w * 32
    pb = effective_prefix_bits(prefix_bits, bits_tot)
    n_buckets = 1 << pb
    cap = bucket_cap(m, pb, num_neighbors)
    masks = probe_masks(pb, probes)

    bit_idx = prefix_bit_indices(bits_tot, pb, seed)
    bucket = bucket_ids(codes, bit_idx)
    table, counts, rank = build_bucket_table(bucket, m, n_buckets, cap)

    probed = bucket[:, None] ^ masks[None, :]            # (M, P+1)
    cand = table[probed].reshape(m, -1)                  # (M, (P+1)*cap)

    t = teaser_count(m, num_neighbors)
    _, top_ids = jax.lax.top_k(scores.astype(jnp.float32), t)
    top_ids = top_ids.astype(jnp.int32)
    tb = bucket[top_ids]                                 # (T,)
    in_probe = jnp.any(tb[None, :, None] == probed[:, None, :], axis=-1)
    dup = in_probe & (rank[top_ids] < cap)[None, :]      # already a cand
    teaser = jnp.where(dup, jnp.int32(m),
                       jnp.broadcast_to(top_ids[None, :], (m, t)))
    ids = jnp.concatenate([cand, teaser], axis=1)
    dropped = jnp.sum(jnp.maximum(counts - cap, 0))
    return AnnCandidates(ids, bucket, counts, dropped)


def occupancy_stats(c: AnnCandidates) -> dict:  # analysis: host-ok
    """Host-side candidate-set accounting for benchmarks: speedups
    must be attributable to a measured candidate count, not asserted.
    (Whole-function `host-ok`: every extraction here is the point.)"""
    import numpy as np
    counts = np.asarray(c.counts)
    nonempty = counts[counts > 0]
    return {
        "k": int(c.ids.shape[1]),
        "buckets": int(counts.size),
        "nonempty_buckets": int(nonempty.size),
        "mean_occupancy": round(float(nonempty.mean()), 2) if
        nonempty.size else 0.0,
        "max_occupancy": int(counts.max()) if counts.size else 0,
        "dropped_candidates": int(c.dropped),
    }
