"""Blockchain announcement layer (WPFed §2.2, §3.6).

Two tiers, by design:

1. **Host ledger** (this module): an append-only hash-chained block list
   with SHA-256 commitments — the auditable record. One block per round
   holds every client's announcement a_i = {lsh_i, C_i} plus last
   round's reveals. ``verify_chain`` re-hashes the whole chain;
   ``verify_reveal`` checks commit-and-reveal (Eq. 9-10).

2. **In-graph commitments** (``fnv1a_commit``): a JAX-traceable 64-bit
   FNV-1a hash over ranking integers so the *protocol step itself*
   (jit/vmap'd across clients) can verify reveals without host sync.
   SHA-256 remains the on-chain binding commitment; the FNV path is the
   fast-path filter inside the training loop. Both are computed over the
   same canonical serialization, and tests pin them to each other's
   accept/reject decisions.
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.analysis.privacy import declassifier


# ---------------------------------------------------------------------------
# canonical serialization + commitments
# ---------------------------------------------------------------------------
def canonical_ranking_bytes(ranking) -> bytes:
    """Rankings are int vectors (neighbor ids, best first; -1 padding)."""
    # analysis: host-ok — the ledger hashes host bytes by design (§8)
    arr = np.asarray(ranking, np.int64)
    return arr.tobytes() + arr.shape.__repr__().encode()


def sha256_commit(ranking, salt: int = 0) -> str:
    h = hashlib.sha256()
    h.update(salt.to_bytes(8, "little", signed=False))
    h.update(canonical_ranking_bytes(ranking))
    return h.hexdigest()


_FNV_OFFSET = np.uint64(14695981039346656037)
_FNV_PRIME = np.uint64(1099511628211)


@declassifier(
    name="commitment", paper_eq="Eq. 9-10 (§3.6 commit-and-reveal)",
    justification="a one-way hash of an already-releasable ranking "
                  "vector: binding for the reveal check, disclosing "
                  "nothing beyond the ranking it commits to")
def fnv1a_commit(ranking, salt=0):
    """JAX-traceable commitment over the same canonical int sequence.

    ranking: (..., N) int32 -> (...,) uint64-as-uint32-pair packed into
    a single uint32 (upper xor lower) — collision-resistant enough for
    the in-graph fast path; the binding commitment is SHA-256 on chain.
    """
    r = jnp.asarray(ranking).astype(jnp.uint32)
    salt = jnp.asarray(salt, jnp.uint32)
    h = jnp.full(r.shape[:-1], 2166136261, jnp.uint32) ^ salt

    def body(h, x):
        # FNV-1a over the 4 bytes of each int
        for shift in (0, 8, 16, 24):
            byte = (x >> jnp.uint32(shift)) & jnp.uint32(0xFF)
            h = (h ^ byte) * jnp.uint32(16777619)
        return h

    for idx in range(r.shape[-1]):
        h = body(h, r[..., idx])
    return h


# ---------------------------------------------------------------------------
# host ledger
# ---------------------------------------------------------------------------
@dataclass
class Block:
    index: int
    prev_hash: str
    payload: Dict[str, Any]            # round announcements + reveals
    # 0.0 = "unstamped" (genesis); publish_round stamps wall-clock time
    timestamp: float = field(default_factory=lambda: 0.0)
    hash: str = ""

    def compute_hash(self) -> str:
        h = hashlib.sha256()
        h.update(self.prev_hash.encode())
        h.update(str(self.index).encode())
        h.update(repr(self.timestamp).encode())
        h.update(json.dumps(self.payload, sort_keys=True,
                            default=str).encode())
        return h.hexdigest()


class Blockchain:
    """Append-only announcement ledger shared by all clients."""

    def __init__(self):
        genesis = Block(0, "0" * 64, {"genesis": True})
        genesis.hash = genesis.compute_hash()
        self.blocks: List[Block] = [genesis]

    def publish_round(self, round_idx: int,
                      announcements: Dict[int, Dict[str, Any]],
                      reveals: Optional[Dict[int, Any]] = None) -> Block:
        """announcements: client_id -> {"lsh": hex, "commit": sha256hex}
        reveals: client_id -> ranking list (for round_idx - 1)."""
        payload = {
            "round": round_idx,
            "announcements": {str(k): v for k, v in announcements.items()},
            "reveals": {str(k): list(map(int, v))
                        for k, v in (reveals or {}).items()},
        }
        blk = Block(len(self.blocks), self.blocks[-1].hash, payload,
                    timestamp=time.time())
        blk.hash = blk.compute_hash()
        self.blocks.append(blk)
        return blk

    def verify_chain(self) -> bool:
        for i in range(1, len(self.blocks)):
            b = self.blocks[i]
            if b.prev_hash != self.blocks[i - 1].hash:
                return False
            if b.hash != b.compute_hash():
                return False
        return True

    def head_round(self) -> int:  # analysis: host-ok — int() on ledger JSON payloads, not device values
        """Highest round index on chain; -1 for a genesis-only ledger.
        The resume path compares this against the checkpoint's round
        counter to catch silent ledger rollback (transport.py)."""
        for b in reversed(self.blocks):
            r = b.payload.get("round")
            if r is not None:
                return int(r)
        return -1

    def round_block(self, round_idx: int) -> Optional[Block]:
        for b in reversed(self.blocks):
            if b.payload.get("round") == round_idx:
                return b
        return None

    # -- durable form (the service's kill/resume path, DESIGN.md §13) --
    def to_json(self) -> str:
        """Full ledger as canonical JSON. The stored hashes are the
        ORIGINAL ones — verify_chain recomputes over the deserialized
        payloads, so a tampered file fails verification after load
        instead of laundering fresh hashes."""
        return json.dumps([{
            "index": b.index, "prev_hash": b.prev_hash,
            "payload": b.payload, "timestamp": b.timestamp,
            "hash": b.hash,
        } for b in self.blocks], sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "Blockchain":
        chain = cls.__new__(cls)
        chain.blocks = [
            Block(d["index"], d["prev_hash"], d["payload"],
                  timestamp=d["timestamp"], hash=d["hash"])
            for d in json.loads(text)]
        if not chain.blocks:
            raise ValueError("serialized chain has no genesis block")
        return chain


def verify_reveal(commitment_hex: str, revealed_ranking, salt: int = 0) -> bool:
    """Eq. (10): recompute the hash of the revealed ranking."""
    return sha256_commit(revealed_ranking, salt) == commitment_hex


def lsh_code_hex(code) -> str:
    # analysis: host-ok — announcement serialization for the host ledger
    return np.asarray(code, np.uint32).tobytes().hex()


def save_chain(path: str, chain: Blockchain) -> str:
    """Atomically persist the ledger (tmp + os.replace, the
    checkpoint.store discipline: a crash mid-write never truncates the
    previous good file)."""
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as fh:
        fh.write(chain.to_json())
    os.replace(tmp, path)
    return path


def load_chain(path: str) -> Blockchain:
    """Restore a persisted ledger. Integrity is the caller's call to
    `verify_chain()` — the service driver refuses to resume without it."""
    with open(path, "r", encoding="utf-8") as fh:
        return Blockchain.from_json(fh.read())
