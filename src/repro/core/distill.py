"""P2P knowledge distillation (WPFed §3.1, Eq. 2-4, Alg. 1 l.19).

The combined per-client objective:

    L_i = alpha * CE(f(theta_i, X_loc), Y_loc)
        + (1 - alpha) * || f(theta_i, X_ref) - mean_j Yhat_j ||^2

where Yhat_j = f(theta_j, X_i^ref) are the (stop-gradient) neighbor
outputs that passed LSH verification.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels)
                    .astype(jnp.float32))


def aggregate_neighbor_outputs(neighbor_logits, valid_mask):
    """mean over valid neighbors. neighbor_logits: (N, R, C); mask (N,).

    Falls back to zeros-weight (no distillation signal) when no neighbor
    passes verification — the local loss term then dominates.
    """
    w = valid_mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(w), 1.0)
    agg = jnp.einsum("n,nrc->rc", w, neighbor_logits) / denom
    has_any = jnp.sum(w) > 0
    return agg, has_any


def combined_loss(apply_fn, params, batch, ref_x, target_ref_logits,
                  has_target, alpha: float):
    """Alg. 1 line 19. batch: {"x","y"} local minibatch."""
    local_logits = apply_fn(params, batch["x"])
    l_loc = cross_entropy(local_logits, batch["y"])
    own_ref = apply_fn(params, ref_x)
    l_ref = jnp.mean(jnp.square(own_ref
                                - jax.lax.stop_gradient(target_ref_logits)))
    l_ref = jnp.where(has_target, l_ref, 0.0)
    return alpha * l_loc + (1 - alpha) * l_ref, (l_loc, l_ref)
