"""The paper's primary contribution: the WPFed trust-free personalized
decentralized learning protocol (LSH similarity, crowd-sourced ranking,
weighted neighbor selection, all-in-one exchange, verification,
blockchain announcements)."""
from repro.core.exchange import (  # noqa: F401
    ExchangeResult,
    all_in_one_exchange,
)
from repro.core.rounds import (  # noqa: F401
    RoundProgram,
    Schedule,
    make_program,
    make_segment_fn,
    program_round,
    resolve_schedule,
    run_rounds,
)
from repro.core.protocol import (  # noqa: F401
    Announcement,
    FedState,
    SelectResult,
    announce_phase,
    evaluate,
    exchange_phase,
    init_state,
    make_wpfed_round,
    select_phase,
    update_phase,
    wpfed_program,
)
from repro.core.adversary import (  # noqa: F401
    Attack,
    ThreatModel,
    apply_attacks,
    attacker_mask_tail,
    instrument_program,
    resolve_attack,
    resolve_threat,
    threat_model,
)
