"""The paper's primary contribution: the WPFed trust-free personalized
decentralized learning protocol (LSH similarity, crowd-sourced ranking,
weighted neighbor selection, verification, blockchain announcements)."""
from repro.core.protocol import (  # noqa: F401
    FedState,
    evaluate,
    init_state,
    make_wpfed_round,
)
