"""Performance rankings and crowd-sourced ranking scores (WPFed §3.3).

R_i ranks client i's neighbors in ascending distillation loss l_ij
(best-performing first). The global ranking score (Eq. 7):

    s_j = |{R_k : j in top-K of R_k}| / |{R_k : j in R_k}|

Rankings are fixed-width int32 vectors of neighbor ids padded with -1,
so everything vmaps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis.privacy import declassifier


@declassifier(
    name="rank-reveal", paper_eq="R_i (§3.3, revealed per §3.6)",
    justification="the revealed ranking is an ORDER over public "
                  "neighbor ids — the underlying distillation losses "
                  "are discarded, only their argsort is disclosed")
def make_ranking(neighbor_ids, losses, valid_mask=None):
    """Sort neighbor ids by ascending loss. (N,) -> (N,) int32, -1 pad.

    valid_mask: neighbors to include (e.g. only actually-contacted
    peers); invalid entries sink to the end as -1.
    """
    losses = jnp.asarray(losses, jnp.float32)
    if valid_mask is None:
        valid_mask = jnp.ones_like(losses, bool)
    keyed = jnp.where(valid_mask, losses, jnp.inf)
    order = jnp.argsort(keyed)
    ranked = jnp.take(neighbor_ids, order)
    ok = jnp.take(valid_mask, order)
    return jnp.where(ok, ranked, -1).astype(jnp.int32)


def dedupe_reporter_mask(rankings, reporter_mask):
    """Collapse duplicate revealed ranking vectors to ONE vote.

    Two reporters revealing the exact same ranking vector contribute no
    independent evidence to Eq. 7 — systematically so under
    `ref_mode="public"`, where every selector evaluates a neighbor on
    the same reference set and sees the same l_ij (DESIGN.md §7
    caveat), and adversarially so when colluding attackers copy
    rankings to boost mutual scores. Keeps the FIRST reporter of each
    distinct vector among the currently-unmasked reporters; O(M^2 N)
    compares, jittable.
    """
    same = jnp.all(rankings[:, None, :] == rankings[None, :, :], axis=-1)
    m = rankings.shape[0]
    earlier = jnp.arange(m)[None, :] < jnp.arange(m)[:, None]   # k < i
    dup = jnp.any(same & earlier & reporter_mask[None, :], axis=1)
    return reporter_mask & ~dup


@declassifier(
    name="rank-scores", paper_eq="Eq. 7 (§3.3)",
    justification="crowd-sourced tally over already-revealed rankings: "
                  "a count ratio of public votes, computable by every "
                  "peer from the chain alone")
def ranking_scores(rankings, num_clients: int, top_k: int,
                   reporter_mask=None, *, dedupe: bool = False):
    """Eq. (7). rankings: (M, N) int32 (-1 = absent).

    reporter_mask: (M,) bool — rankings from clients that failed
    commit-and-reveal verification are excluded entirely (§3.6).
    dedupe: drop duplicate ranking vectors before scoring (see
    `dedupe_reporter_mask`; recommended under ref_mode="public").
    Returns (num_clients,) f32 scores in [0, 1]; clients never ranked by
    anyone get score 0 (no evidence of quality — consistent with the
    paper's trust-free stance).
    """
    m, n = rankings.shape
    if reporter_mask is None:
        reporter_mask = jnp.ones((m,), bool)
    if dedupe:
        reporter_mask = dedupe_reporter_mask(rankings, reporter_mask)
    onehot = jax.nn.one_hot(jnp.where(rankings >= 0, rankings, num_clients),
                            num_clients + 1, dtype=jnp.float32)[..., :-1]
    rep = reporter_mask[:, None, None].astype(jnp.float32)
    appears = jnp.sum(onehot * rep, axis=(0, 1))              # (C,)
    in_topk = jnp.sum(onehot[:, :top_k, :] * rep, axis=(0, 1))
    return in_topk / jnp.maximum(appears, 1.0)
