"""Personalized neighbor selection (WPFed §3.4, Eq. 8).

w_ij = s_j * exp(-gamma * d_ij); each client takes the top-N weights
(excluding itself). Ablation switches reproduce Table 3:
  use_lsh=False  -> w_ij = s_j            ("w/o LSH")
  use_rank=False -> w_ij = exp(-gamma d)  ("w/o Rank")
  both False     -> uniform random selection ("w/o LSH & Rank")
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selection_weights(scores, dist_norm, gamma: float, *,
                      use_lsh: bool = True, use_rank: bool = True,
                      rng=None):
    """scores: (M,) f32; dist_norm: (M, M) f32 in [0,1] -> (M, M) f32."""
    m = dist_norm.shape[0]
    if use_rank:
        w = jnp.broadcast_to(scores[None, :], (m, m))
    else:
        w = jnp.ones((m, m), jnp.float32)
    if use_lsh:
        w = w * jnp.exp(-gamma * dist_norm)
    if not use_rank and not use_lsh:
        assert rng is not None, "random selection needs an rng key"
        w = jax.random.uniform(rng, (m, m))
    return jnp.where(jnp.eye(m, dtype=bool), -jnp.inf, w)


def select_neighbors(weights, num_neighbors: int):
    """Top-N per row. weights: (M, M) -> ids (M, N) int32, mask (M, N)."""
    n = min(num_neighbors, weights.shape[1] - 1)
    top_w, top_i = jax.lax.top_k(weights, n)
    mask = jnp.isfinite(top_w)
    return top_i.astype(jnp.int32), mask
