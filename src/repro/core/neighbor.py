"""Personalized neighbor selection (WPFed §3.4, Eq. 6-8).

`select_partners` is the single protocol entry point: published LSH
codes + crowd-sourced ranking scores -> per-client top-N partner ids.
It owns the backend switch (DESIGN.md §4):

  "kernel" -> fused Pallas kernel (Hamming -> Eq. 8 weights -> top-N in
              one pass; interpret-mode off-TPU),
  "oracle" -> the bit-exact fused jnp twin (ref.fused_select_ref),
  "auto"   -> kernel on TPU, oracle elsewhere.

The unfused pieces (`selection_weights`, `select_neighbors`) remain the
semantic reference — tests assert the fused paths match their
composition bit-exactly. Ablation switches reproduce Table 3:
  use_lsh=False  -> w_ij = s_j            ("w/o LSH")
  use_rank=False -> w_ij = exp(-gamma d)  ("w/o Rank")
  both False     -> uniform random selection ("w/o LSH & Rank")
The both-off random ablation draws from an rng and always runs the jnp
path (no kernel involvement regardless of backend).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ann, backends
from repro.kernels import ops, ref
from repro.kernels.selection import (fused_select, fused_select_ann,
                                     fused_select_tiled)


def selection_weights(scores, dist_norm, gamma: float, *,
                      use_lsh: bool = True, use_rank: bool = True,
                      rng=None):
    """scores: (M,) f32; dist_norm: (M, M) f32 in [0,1] -> (M, M) f32."""
    m = dist_norm.shape[0]
    if use_rank:
        w = jnp.broadcast_to(scores[None, :], (m, m))
    else:
        w = jnp.ones((m, m), jnp.float32)
    if use_lsh:
        w = w * jnp.exp(-gamma * dist_norm)
    if not use_rank and not use_lsh:
        assert rng is not None, "random selection needs an rng key"
        w = jax.random.uniform(rng, (m, m))
    return jnp.where(jnp.eye(m, dtype=bool), -jnp.inf, w)


def select_neighbors(weights, num_neighbors: int):
    """Top-N per row. weights: (M, M) -> ids (M, N) int32, mask (M, N)."""
    n = min(num_neighbors, weights.shape[1] - 1)
    top_w, top_i = jax.lax.top_k(weights, n)
    mask = jnp.isfinite(top_w)
    return top_i.astype(jnp.int32), mask


def select_partners(codes, scores, fed, *, rng=None, backend=None,
                    tiling=None, seed=0, active=None):
    """Eq. 6-8 + top-N in one call: the WPFed partner-selection step.

    codes: (M, W) uint32 published LSH codes; scores: (M,) f32 ranking
    scores (Eq. 7, reporter-filtered by the caller); fed: FedConfig
    (consumes num_neighbors, gamma, lsh_bits, use_lsh, use_rank,
    selection_backend, selection_tiling, ann_prefix_bits, ann_probes).
    rng is required only for the random ablation (use_lsh=False,
    use_rank=False). `backend` / `tiling` override
    fed.selection_backend / fed.selection_tiling when given. `seed`
    (may be a traced scalar — protocol.select_phase passes
    state.round) seeds the ANN bucket permutation; the exact paths
    ignore it.

    The kernel path picks one-shot vs column-tiled from the explicit
    VMEM estimate (`backends.resolve_tiling`, DESIGN.md §10); both are
    bit-exact against the oracle, so the choice never moves results.
    The oracle is the jnp twin either way (CPU memory is not
    VMEM-bounded).

    The "ann" path (DESIGN.md §11) restricts the exact Eq. 6-8
    weighting to LSH-bucket candidate sets — O(M*K*bits) instead of
    O(M^2*bits). "auto" opts into it only past the FLOP thresholds in
    `backends.resolve_selection`, so approximation is never silent at
    small M.

    `active` (M,) bool excludes departed clients (the service layer's
    churn-as-masking, DESIGN.md §13) by forcing their score column to
    -inf BEFORE backend dispatch: -inf survives the Eq. 8 multiply in
    every backend (oracle / kernel / tiled / ann — IEEE -inf times a
    positive finite weight stays -inf) and `isfinite(top_w)` already
    masks it out of the result, so no backend needs a mask argument.
    Requires use_rank=True — with Eq. 8 ignoring scores there is no
    column to carry the exclusion (and the ablations model a fixed
    cohort anyway).

    Returns (ids (M, N) int32, sel_mask (M, N) bool). With N <= M-1
    every selected id is a real, non-self client and the mask is all
    True; the mask exists for degenerate M <= 1 federations (and, on
    the ann path, for rows whose candidate set ran dry — the score
    teaser makes that impossible for M >= 2).
    """
    m = codes.shape[0]
    n = min(fed.num_neighbors, m - 1)
    if active is not None:
        if not fed.use_rank:
            raise ValueError(
                "select_partners(active=...) requires use_rank=True: "
                "membership exclusion rides the Eq. 8 score column "
                "(DESIGN.md §13)")
        scores = jnp.where(active, scores, -jnp.inf)
    if not fed.use_lsh and not fed.use_rank:
        w = selection_weights(scores, jnp.zeros((m, m), jnp.float32),
                              fed.gamma, use_lsh=False, use_rank=False,
                              rng=rng)
        return select_neighbors(w, n)
    bits_tot = codes.shape[1] * 32
    k = ann.candidate_count(m, fed.ann_prefix_bits, fed.ann_probes, n,
                            bits_tot)
    resolved = backends.resolve_selection(
        backend or fed.selection_backend, m,
        exact_flops=backends.selection_flops(m, bits_tot),
        ann_flops=backends.ann_selection_flops(m, bits_tot, k))
    if resolved == "ann":
        # tiling strings stay validated even though the ann kernel has
        # exactly one (streaming) layout
        backends.resolve_tiling(tiling or fed.selection_tiling, 0)
        cand = ann.ann_candidates(
            codes, scores, seed=seed, prefix_bits=fed.ann_prefix_bits,
            probes=fed.ann_probes, num_neighbors=n)
        if backends.resolve("auto") == "kernel":
            ids, top_w = fused_select_ann(
                codes, scores, cand.ids, bits=fed.lsh_bits,
                gamma=fed.gamma, num_neighbors=n, use_lsh=fed.use_lsh,
                use_rank=fed.use_rank, interpret=backends.interpret())
        else:
            ids, top_w = ref.ann_select_ref(
                codes, scores, cand.ids, bits=fed.lsh_bits,
                gamma=fed.gamma, num_neighbors=n, use_lsh=fed.use_lsh,
                use_rank=fed.use_rank)
        return ids, jnp.isfinite(top_w)
    if resolved == "kernel":
        bits_tot = codes.shape[1] * 32
        resolved_tiling = backends.resolve_tiling(
            tiling or fed.selection_tiling,
            backends.selection_vmem_bytes(m, bits_tot))
        select_fn = (fused_select_tiled if resolved_tiling == "tiled"
                     else fused_select)
        ids, top_w = select_fn(
            codes, scores, bits=fed.lsh_bits, gamma=fed.gamma,
            num_neighbors=n, use_lsh=fed.use_lsh, use_rank=fed.use_rank,
            interpret=backends.interpret())
    else:
        backends.resolve_tiling(tiling or fed.selection_tiling, 0)
        ids, top_w = ref.fused_select_ref(
            codes, scores, bits=fed.lsh_bits, gamma=fed.gamma,
            num_neighbors=n, use_lsh=fed.use_lsh, use_rank=fed.use_rank)
    return ids, jnp.isfinite(top_w)
