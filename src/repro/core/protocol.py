"""The WPFed round (Algorithm 1), fully jit-able and vmapped over the
client axis. One call = one federation iteration for all M clients:

  1. verify last round's ranking reveals against commitments (§3.6)
  2. LSH distances (Eq. 6) + ranking scores (Eq. 7) -> weights (Eq. 8)
  3. top-N personalized neighbor selection
  4. P2P reference-set logit exchange (the collective-friendly form of
     the paper's point-to-point sends — DESIGN.md §3)
  5. per-neighbor loss (Eq. 3) + LSH verification filter (§3.5)
  6. local model update on the combined objective (Alg. 1 l.19)
  7. new LSH codes, rankings, commitments -> next announcement

Client models are homogeneous pytrees stacked on a leading (M,) axis;
`launch/fed.py` shards that axis across the mesh for TPU-scale runs.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.paper_models import FedConfig
from repro.core import distill, lsh, neighbor, ranking, verify
from repro.core.chain import fnv1a_commit
from repro.optim.optimizers import Optimizer, apply_updates


class FedState(NamedTuple):
    params: Any          # stacked (M, ...)
    opt_state: Any       # stacked (M, ...)
    codes: jnp.ndarray   # (M, W) uint32 — published LSH codes
    rankings: jnp.ndarray     # (M, N) int32 — this round's reveals
    commitments: jnp.ndarray  # (M,) uint32 — commitments to `rankings`
    rng: jnp.ndarray
    round: jnp.ndarray   # scalar int32


def init_state(apply_fn, init_fn, optimizer: Optimizer, fed: FedConfig,
               key) -> FedState:
    """init_fn(key) -> one client's params."""
    m = fed.num_clients
    keys = jnp.stack(list(jax.random.split(key, m)))
    params = jax.vmap(init_fn)(keys)
    opt_state = jax.vmap(optimizer.init)(params)
    # round-0 codes use the round-0 LSH seed (see round_fn step 7)
    codes = lsh.stacked_lsh_codes(params, seed=0, bits=fed.lsh_bits,
                                  backend=fed.selection_backend)
    n = min(fed.num_neighbors, m - 1)
    rankings = -jnp.ones((m, n), jnp.int32)
    commitments = fnv1a_commit(rankings, salt=0)
    return FedState(params, opt_state, codes, rankings, commitments,
                    jax.random.fold_in(key, 1), jnp.zeros((), jnp.int32))


def _local_update(apply_fn, optimizer, fed: FedConfig, params, opt_state,
                  data_i, target_ref, has_target, rng):
    """`local_steps` minibatch steps on the combined loss for ONE client."""
    n_local = data_i["x_train"].shape[0]
    mb = min(fed.local_batch, n_local)

    def step(carry, key):
        p, s = carry
        idx = jax.random.randint(key, (mb,), 0, n_local)
        batch = {"x": data_i["x_train"][idx], "y": data_i["y_train"][idx]}
        (loss, (l_loc, l_ref)), grads = jax.value_and_grad(
            lambda q: distill.combined_loss(
                apply_fn, q, batch, data_i["x_ref"], target_ref,
                has_target, fed.alpha), has_aux=True)(p)
        updates, s = optimizer.update(grads, s, p)
        return (apply_updates(p, updates), s), (loss, l_loc, l_ref)

    keys = jnp.stack(list(jax.random.split(rng, fed.local_steps)))
    (params, opt_state), (losses, l_locs, l_refs) = jax.lax.scan(
        step, (params, opt_state), keys)
    return params, opt_state, {"loss": losses[-1], "local_loss": l_locs[-1],
                               "ref_loss": l_refs[-1]}


def batched_local_update(apply_fn, optimizer, fed: FedConfig, params,
                         opt_state, data_per, target_ref, has_target, keys):
    """Per-client local updates over the stacked (M, ...) axis.

    Uses ``lax.map`` rather than ``vmap``: vmapping convolutions over
    per-client *weights* forces XLA-CPU onto a grouped-conv path whose
    gradients are ~25x slower (measured); sequential per-client bodies
    keep the fast path. On TPU the client axis is sharded by
    launch/fed.py, so the inner loop stays short there too.
    """
    def one(args):
        p, s, d, t, h, k = args
        return _local_update(apply_fn, optimizer, fed, p, s, d, t, h, k)

    return jax.lax.map(one, (params, opt_state, data_per, target_ref,
                             has_target, keys))


def make_wpfed_round(apply_fn: Callable, optimizer: Optimizer,
                     fed: FedConfig):
    """Returns round_fn(state, data) -> (state, metrics). `data` is the
    stacked federated dataset dict (see data.federated.stacked)."""
    m = fed.num_clients

    def round_fn(state: FedState, data: Dict[str, jnp.ndarray]
                 ) -> Tuple[FedState, Dict[str, jnp.ndarray]]:
        rng, rng_sel, rng_upd = jax.random.split(state.rng, 3)

        # --- 1. §3.6 reveal verification --------------------------------
        if fed.rank_verification:
            reporter_mask = verify.verify_rankings_fnv(
                state.rankings, state.commitments)
        else:
            reporter_mask = jnp.ones((m,), bool)

        # --- 2-3. neighbor selection (Eq. 6-8, fused; DESIGN.md §4) ------
        scores = ranking.ranking_scores(
            jnp.where(reporter_mask[:, None], state.rankings, -1),
            m, fed.top_k)
        ids, sel_mask = neighbor.select_partners(
            state.codes, scores, fed,
            rng=rng_sel if not (fed.use_lsh or fed.use_rank) else None)

        # --- 4. P2P logit exchange on personal reference sets ------------
        nb_params = jax.tree.map(lambda p: p[ids], state.params)  # (M,N,...)
        y_web = jax.vmap(                                   # over clients i
            jax.vmap(apply_fn, in_axes=(0, None))           # over neighbors j
        )(nb_params, data["x_ref"])                         # (M,N,R,C)
        own_ref = jax.vmap(apply_fn)(state.params, data["x_ref"])  # (M,R,C)

        # --- 5. Eq. (3) losses + §3.5 LSH verification --------------------
        l_ij = jax.vmap(lambda yl, y: jax.vmap(
            lambda l: distill.cross_entropy(l, y))(yl))(
            y_web, data["y_ref"])                           # (M,N)
        if fed.lsh_verification:
            valid = jax.vmap(verify.lsh_verification_mask)(
                own_ref, y_web, sel_mask)
        else:
            valid = sel_mask

        # --- 6. model update (Alg. 1 l.19) --------------------------------
        target_ref, has_target = jax.vmap(
            distill.aggregate_neighbor_outputs)(y_web, valid)
        upd_keys = jax.vmap(
            lambda i: jax.random.fold_in(rng_upd, i))(jnp.arange(m))
        data_per = {k: data[k] for k in
                    ("x_train", "y_train", "x_ref", "y_ref")}
        params, opt_state, train_metrics = batched_local_update(
            apply_fn, optimizer, fed, state.params, state.opt_state,
            data_per, target_ref, has_target, upd_keys)

        # --- 7. announcements for the next round --------------------------
        # Codes consumed in round r+1 hash with the shared per-round
        # seed r+1: every client projects with the SAME Rademacher
        # matrix (distances stay comparable) and the projection rotates
        # each round, so a §3.4 attacker cannot precompute a code that
        # stays close to a victim across rounds (regression-tested).
        codes = lsh.stacked_lsh_codes(params, seed=state.round + 1,
                                      bits=fed.lsh_bits,
                                      backend=fed.selection_backend)
        new_rankings = jax.vmap(ranking.make_ranking)(ids, l_ij, sel_mask)
        commitments = fnv1a_commit(new_rankings, salt=0)

        metrics = {
            "round": state.round,
            "mean_loss": jnp.mean(train_metrics["loss"]),
            "mean_local_loss": jnp.mean(train_metrics["local_loss"]),
            "mean_ref_loss": jnp.mean(train_metrics["ref_loss"]),
            "mean_neighbor_loss": jnp.mean(
                jnp.where(sel_mask, l_ij, 0.0)),
            "valid_neighbor_frac": jnp.mean(valid.astype(jnp.float32)),
            "honest_reporter_frac": jnp.mean(
                reporter_mask.astype(jnp.float32)),
            "neighbor_ids": ids,
            "valid_mask": valid,
            "ranking_scores": scores,
        }
        new_state = FedState(params, opt_state, codes, new_rankings,
                             commitments, rng, state.round + 1)
        return new_state, metrics

    return round_fn


def evaluate(apply_fn, state: FedState, data, honest_mask=None):
    """Per-client test accuracy; mean over honest clients if mask given."""
    logits = jax.vmap(apply_fn)(state.params, data["x_test"])
    acc = jax.vmap(distill.accuracy)(logits, data["y_test"])
    if honest_mask is not None:
        mean = (jnp.sum(acc * honest_mask)
                / jnp.maximum(jnp.sum(honest_mask), 1.0))
    else:
        mean = jnp.mean(acc)
    return {"per_client_acc": acc, "mean_acc": mean}
