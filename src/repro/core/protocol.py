"""The WPFed round (Algorithm 1), fully jit-able and vmapped over the
client axis, decomposed into four typed phase functions so variant
rounds (async/gossip epochs, public-reference serving) can reuse the
phases instead of forking a monolith (DESIGN.md §7):

  select_phase    §3.6 reveal verification + Eq. 6-8 fused neighbor
                  selection (steps 1-3)
  exchange_phase  the all-in-one reference-set exchange: P2P logit
                  gather + Eq. 3 losses + §3.5 verification + the
                  distillation target, in one kernel-backed pass
                  (steps 4-6a; core.exchange / DESIGN.md §3, §7)
  update_phase    local model updates on the combined objective
                  (Alg. 1 l.19, step 6b)
  announce_phase  new LSH codes, rankings, commitments (step 7)

`wpfed_program` composes them into a `core.rounds.RoundProgram`: the
global round (all four phases — one federation iteration for all M
clients) plus the gossip epoch (exchange + update against the cached
`SelectResult`, DESIGN.md §8). `make_wpfed_round` is the classic sync
adapter over that program. Client models are homogeneous pytrees
stacked on a leading (M,) axis; `launch/fed.py` shards that axis
across the mesh for TPU-scale runs.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.privacy import sink
from repro.configs.paper_models import FedConfig
from repro.core import distill, lsh, neighbor, ranking, verify
from repro.core.chain import fnv1a_commit
from repro.core.exchange import (ExchangeResult, all_in_one_exchange,
                                 public_ref_logits)
from repro.core.rounds import RoundProgram, program_round
from repro.optim.optimizers import Optimizer, apply_updates

REF_MODES = ("personal", "public")


class FedState(NamedTuple):
    params: Any          # stacked (M, ...)
    opt_state: Any       # stacked (M, ...)
    codes: jnp.ndarray   # (M, W) uint32 — published LSH codes
    rankings: jnp.ndarray     # (M, N) int32 — this round's reveals
    commitments: jnp.ndarray  # (M,) uint32 — commitments to `rankings`
    rng: jnp.ndarray
    round: jnp.ndarray   # scalar int32


class SelectResult(NamedTuple):
    """Output of select_phase: who talks to whom this round."""
    ids: jnp.ndarray            # (M, N) int32 — selected partner ids
    sel_mask: jnp.ndarray       # (M, N) bool — real (non-padded) slots
    scores: jnp.ndarray         # (M,) f32 — Eq. 7 ranking scores
    reporter_mask: jnp.ndarray  # (M,) bool — §3.6 honest reporters


class Announcement(NamedTuple):
    """Output of announce_phase: next round's published state."""
    codes: jnp.ndarray        # (M, W) uint32
    rankings: jnp.ndarray     # (M, N) int32
    commitments: jnp.ndarray  # (M,) uint32


def init_state(apply_fn, init_fn, optimizer: Optimizer, fed: FedConfig,
               key) -> FedState:
    """init_fn(key) -> one client's params."""
    m = fed.num_clients
    keys = jnp.stack(list(jax.random.split(key, m)))
    params = jax.vmap(init_fn)(keys)
    opt_state = jax.vmap(optimizer.init)(params)
    # round-0 codes use the round-0 LSH seed (see announce_phase)
    codes = lsh.stacked_lsh_codes(params, seed=0, bits=fed.lsh_bits,
                                  backend=fed.selection_backend)
    n = min(fed.num_neighbors, m - 1)
    rankings = -jnp.ones((m, n), jnp.int32)
    commitments = fnv1a_commit(rankings, salt=0)
    return FedState(params, opt_state, codes, rankings, commitments,
                    jax.random.fold_in(key, 1), jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# phases
# ---------------------------------------------------------------------------
def select_phase(state: FedState, fed: FedConfig, *,
                 rng=None, active=None, score_scale=None) -> SelectResult:
    """Steps 1-3: §3.6 reveal verification -> Eq. 7 ranking scores ->
    fused Eq. 6-8 top-N partner selection (DESIGN.md §4). `rng` is
    consumed only by the random-selection ablation (use_lsh=False,
    use_rank=False). The ANN bucket permutation (selection_backend
    "ann", DESIGN.md §11) is seeded from state.round — the same
    per-round discipline as the LSH projection seed in announce_phase,
    so reselection is reproducible, scan-safe, and recomputable by
    every peer from public information.

    The service layer (DESIGN.md §13) threads two optional masks:
    `active` (M,) bool drops departed clients from BOTH sides of the
    round — their stale rankings stop counting as Eq. 7 evidence
    (reporter_mask &= active) and they never enter any peer's top-N
    (neighbor.select_partners forces their score column to -inf);
    `score_scale` (M,) f32 multiplies the Eq. 7 scores — the staleness
    discount for re-joiners whose published codes are periods old.
    Both default to no-ops, keeping the classic sync round bit-exact."""
    m = fed.num_clients
    if fed.rank_verification:
        reporter_mask = verify.verify_rankings_fnv(
            state.rankings, state.commitments)
    else:
        reporter_mask = jnp.ones((m,), bool)
    if active is not None:
        reporter_mask = reporter_mask & active
    scores = ranking.ranking_scores(
        jnp.where(reporter_mask[:, None], state.rankings, -1),
        m, fed.top_k, dedupe=fed.dedupe_rankings)
    if score_scale is not None:
        scores = scores * score_scale
    ids, sel_mask = neighbor.select_partners(
        state.codes, scores, fed,
        rng=rng if not (fed.use_lsh or fed.use_rank) else None,
        seed=state.round, active=active)
    return SelectResult(ids, sel_mask, scores, reporter_mask)


def exchange_phase(apply_fn: Callable, fed: FedConfig, params,
                   data: Dict[str, jnp.ndarray],
                   sel: SelectResult) -> ExchangeResult:
    """Steps 4-6a: evaluate reference sets and run the all-in-one
    exchange (knowledge transfer + quality evaluation + similarity
    verification in one pass — core.exchange, DESIGN.md §7).

    ref_mode="personal": neighbors answer each client's OWN reference
    set, so the logit web needs M*N forwards over gathered neighbor
    params (the collective-friendly form of the paper's point-to-point
    sends, DESIGN.md §3).

    ref_mode="public": every client evaluates the SHARED reference set
    (row 0 of data["x_ref"] — the abstract's public reference dataset)
    exactly once; the (M, N, R, C) logit web is then a pure gather of
    those M outputs. M forwards instead of M*N and no neighbor-param
    gather, which is what makes large-M federations affordable.
    """
    if fed.ref_mode not in REF_MODES:
        raise ValueError(f"unknown ref_mode: {fed.ref_mode!r} "
                         f"(expected one of {REF_MODES})")
    m = fed.num_clients
    if fed.ref_mode == "public":
        x_shared = data["x_ref"][0]
        own_ref = jax.vmap(apply_fn, in_axes=(0, None))(
            params, x_shared)                           # (M, R, C)
        y_web = public_ref_logits(own_ref[sel.ids])     # (M, N, R, C) gather
        y_ref = jnp.broadcast_to(data["y_ref"][0][None],
                                 (m,) + data["y_ref"].shape[1:])
    else:
        nb_params = jax.tree.map(lambda p: p[sel.ids], params)  # (M, N, ...)
        y_web = public_ref_logits(
            jax.vmap(                                   # over clients i
                jax.vmap(apply_fn, in_axes=(0, None))   # over neighbors j
            )(nb_params, data["x_ref"]))                # (M, N, R, C)
        own_ref = jax.vmap(apply_fn)(params, data["x_ref"])     # (M, R, C)
        y_ref = data["y_ref"]
    return all_in_one_exchange(own_ref, y_web, y_ref, sel.sel_mask, fed)


def update_phase(apply_fn: Callable, optimizer: Optimizer, fed: FedConfig,
                 params, opt_state, data: Dict[str, jnp.ndarray],
                 exch: ExchangeResult, rng, participate=None):
    """Step 6b: per-client local updates on the combined objective
    (Alg. 1 l.19), distilling toward the exchange's aggregated target.
    Returns (params, opt_state, train_metrics).

    `participate` (M,) bool freezes non-participants: their params AND
    optimizer state come back bitwise unchanged (the service layer's
    departed clients and exhausted per-client gossip budgets,
    DESIGN.md §13). The update still computes for every padded slot —
    static shapes — and is then masked out, so `None` (everyone
    participates) stays bit-exact with the pre-service round."""
    m = fed.num_clients
    upd_keys = jax.vmap(
        lambda i: jax.random.fold_in(rng, i))(jnp.arange(m))
    data_per = {k: data[k] for k in
                ("x_train", "y_train", "x_ref", "y_ref")}
    if fed.ref_mode == "public":        # distill on the shared set
        # broadcast x_ref AND y_ref so the pair stays consistent for
        # any consumer (only x_ref is read by _local_update today)
        for k in ("x_ref", "y_ref"):
            data_per[k] = jnp.broadcast_to(data[k][0][None],
                                           data[k].shape)
    new_params, new_opt, train_metrics = batched_local_update(
        apply_fn, optimizer, fed, params, opt_state, data_per,
        exch.target_ref, exch.has_target, upd_keys)
    if participate is not None:
        def keep(new, old):
            mask = participate.reshape((m,) + (1,) * (new.ndim - 1))
            return jnp.where(mask, new, old)

        new_params = jax.tree.map(keep, new_params, params)
        new_opt = jax.tree.map(keep, new_opt, opt_state)
    return new_params, new_opt, train_metrics


def announce_phase(fed: FedConfig, params, sel: SelectResult,
                   exch: ExchangeResult, round_idx) -> Announcement:
    """Step 7: announcements for the next round.

    Codes consumed in round r+1 hash with the shared per-round seed
    r+1: every client projects with the SAME Rademacher matrix
    (distances stay comparable) and the projection rotates each round,
    so a §3.4 attacker cannot precompute a code that stays close to a
    victim across rounds (regression-tested)."""
    codes = lsh.stacked_lsh_codes(params, seed=round_idx + 1,
                                  bits=fed.lsh_bits,
                                  backend=fed.selection_backend)
    rankings = jax.vmap(ranking.make_ranking)(sel.ids, exch.l_ij,
                                              sel.sel_mask)
    # the round's disclosure point: every field crossing to the chain
    # must arrive declassified (repro.analysis.taint proves it)
    return sink("chain-announcement",
                Announcement(codes, rankings,
                             fnv1a_commit(rankings, salt=0)))


# ---------------------------------------------------------------------------
# local updates (shared with core.baselines)
# ---------------------------------------------------------------------------
def _local_update(apply_fn, optimizer, fed: FedConfig, params, opt_state,
                  data_i, target_ref, has_target, rng):
    """`local_steps` minibatch steps on the combined loss for ONE client."""
    n_local = data_i["x_train"].shape[0]
    mb = min(fed.local_batch, n_local)

    def step(carry, key):
        p, s = carry
        idx = jax.random.randint(key, (mb,), 0, n_local)
        batch = {"x": data_i["x_train"][idx], "y": data_i["y_train"][idx]}
        (loss, (l_loc, l_ref)), grads = jax.value_and_grad(
            lambda q: distill.combined_loss(
                apply_fn, q, batch, data_i["x_ref"], target_ref,
                has_target, fed.alpha), has_aux=True)(p)
        updates, s = optimizer.update(grads, s, p)
        return (apply_updates(p, updates), s), (loss, l_loc, l_ref)

    keys = jnp.stack(list(jax.random.split(rng, fed.local_steps)))
    (params, opt_state), (losses, l_locs, l_refs) = jax.lax.scan(
        step, (params, opt_state), keys)
    return params, opt_state, {"loss": losses[-1], "local_loss": l_locs[-1],
                               "ref_loss": l_refs[-1]}


def batched_local_update(apply_fn, optimizer, fed: FedConfig, params,
                         opt_state, data_per, target_ref, has_target, keys):
    """Per-client local updates over the stacked (M, ...) axis.

    Uses ``lax.map`` rather than ``vmap``: vmapping convolutions over
    per-client *weights* forces XLA-CPU onto a grouped-conv path whose
    gradients are ~25x slower (measured); sequential per-client bodies
    keep the fast path. On TPU the client axis is sharded by
    launch/fed.py, so the inner loop stays short there too.
    """
    def one(args):
        p, s, d, t, h, k = args
        return _local_update(apply_fn, optimizer, fed, p, s, d, t, h, k)

    return jax.lax.map(one, (params, opt_state, data_per, target_ref,
                             has_target, keys))


# ---------------------------------------------------------------------------
# the composed round program
# ---------------------------------------------------------------------------
def _round_metrics(sel: SelectResult, exch: ExchangeResult, train_metrics,
                   round_idx) -> Dict[str, jnp.ndarray]:
    """Per-round metrics shared by the global round and gossip epochs
    (identical structure so a reselection period stacks under scan)."""
    n_sel = jnp.sum(sel.sel_mask.astype(jnp.float32))
    return {
        "round": round_idx,
        "mean_loss": jnp.mean(train_metrics["loss"]),
        "mean_local_loss": jnp.mean(train_metrics["local_loss"]),
        "mean_ref_loss": jnp.mean(train_metrics["ref_loss"]),
        # mean over the SELECTED slots only (padding slots would
        # otherwise dilute the average with zeros)
        "mean_neighbor_loss": (
            jnp.sum(jnp.where(sel.sel_mask, exch.l_ij, 0.0))
            / jnp.maximum(n_sel, 1.0)),
        "valid_neighbor_frac": jnp.mean(
            exch.valid_mask.astype(jnp.float32)),
        "honest_reporter_frac": jnp.mean(
            sel.reporter_mask.astype(jnp.float32)),
        "neighbor_ids": sel.ids,
        "valid_mask": exch.valid_mask,
        "ranking_scores": sel.scores,
    }


def wpfed_program(apply_fn: Callable, optimizer: Optimizer,
                  fed: FedConfig) -> RoundProgram:
    """WPFed as a round program (DESIGN.md §8).

    global_round is Algorithm 1 verbatim — all four phases; its cache
    is the round's `SelectResult`. gossip_round is the cheap epoch
    between reselections: exchange + update against the CACHED
    selection, with codes / rankings / commitments frozen (no
    announce_phase, no LSH re-code), so a reselection period costs one
    global round plus G-1 exchange/update epochs.
    """

    def global_round(state: FedState, data: Dict[str, jnp.ndarray]
                     ) -> Tuple[FedState, SelectResult, Dict]:
        rng, rng_sel, rng_upd = jax.random.split(state.rng, 3)

        sel = select_phase(state, fed, rng=rng_sel)
        exch = exchange_phase(apply_fn, fed, state.params, data, sel)
        params, opt_state, train_metrics = update_phase(
            apply_fn, optimizer, fed, state.params, state.opt_state,
            data, exch, rng_upd)
        ann = announce_phase(fed, params, sel, exch, state.round)

        metrics = _round_metrics(sel, exch, train_metrics, state.round)
        new_state = FedState(params, opt_state, ann.codes, ann.rankings,
                             ann.commitments, rng, state.round + 1)
        return new_state, sel, metrics

    def gossip_round(state: FedState, data: Dict[str, jnp.ndarray],
                     sel: SelectResult
                     ) -> Tuple[FedState, SelectResult, Dict]:
        rng, rng_upd = jax.random.split(state.rng)
        exch = exchange_phase(apply_fn, fed, state.params, data, sel)
        params, opt_state, train_metrics = update_phase(
            apply_fn, optimizer, fed, state.params, state.opt_state,
            data, exch, rng_upd)
        metrics = _round_metrics(sel, exch, train_metrics, state.round)
        new_state = state._replace(params=params, opt_state=opt_state,
                                   rng=rng, round=state.round + 1)
        return new_state, sel, metrics

    return RoundProgram("wpfed", global_round, gossip_round)


def make_wpfed_round(apply_fn: Callable, optimizer: Optimizer,
                     fed: FedConfig):
    """Classic sync API: round_fn(state, data) -> (state, metrics) —
    the adapter over `wpfed_program`'s global round. `data` is the
    stacked federated dataset dict (see data.federated.stacked)."""
    return program_round(wpfed_program(apply_fn, optimizer, fed))


def evaluate(apply_fn, state: FedState, data, honest_mask=None):
    """Per-client test accuracy; mean over honest clients if mask given."""
    logits = jax.vmap(apply_fn)(state.params, data["x_test"])
    acc = jax.vmap(distill.accuracy)(logits, data["y_test"])
    if honest_mask is not None:
        mean = (jnp.sum(acc * honest_mask)
                / jnp.maximum(jnp.sum(honest_mask), 1.0))
    else:
        mean = jnp.mean(acc)
    return {"per_client_acc": acc, "mean_acc": mean}
