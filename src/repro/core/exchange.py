"""All-in-one reference-set exchange (WPFed Eq. 3 + §3.5 + Alg. 1's
distillation target — the paper's headline "single exchange" protocol).

The paper's contribution is that ONE reference-set logit exchange
simultaneously (1) transfers knowledge (the distillation target),
(2) evaluates model quality (the per-neighbor CE losses that feed the
Eq. 7 rankings), and (3) verifies similarity (§3.5's output-KL
upper-half filter). `all_in_one_exchange` is the single protocol entry
point for all three, mirroring `core.neighbor.select_partners` for the
selection subsystem (DESIGN.md §7):

  "kernel" -> fused Pallas kernel (one shared neighbor log-softmax
              while the (N, R, C) tile is in VMEM; interpret off-TPU),
  "oracle" -> the bit-exact jnp twin (ref.all_in_one_exchange_ref),
  "auto"   -> kernel on TPU, oracle elsewhere.

`FedConfig.exchange_tiling` layers the VMEM regime on top (DESIGN.md
§10): "oneshot" is the bit-exact default above; "tiled" streams
R/C-tiled blocks with an online softmax (vocab-scale reference sets —
tolerance-bounded, §3.5 mask preserved); "auto" picks from the
explicit per-program VMEM estimate (`backends.exchange_vmem_bytes`)
instead of OOMing. On the oracle backend "tiled" selects the streaming
jnp twin (`ref.streamed_exchange_ref`) — the CPU path for shapes the
one-shot oracle cannot materialize.

The unfused pieces (`distill.cross_entropy`,
`verify.lsh_verification_mask`, `distill.aggregate_neighbor_outputs`)
remain the semantic reference — tests assert both one-shot fused paths
match their composition bit-exactly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.analysis.privacy import declassifier
from repro.core import backends
from repro.kernels import ref
from repro.kernels.exchange import fused_exchange, fused_exchange_streamed


@declassifier(
    name="public-ref-logits", paper_eq="Eq. 2-3 (§3.1 logit exchange)",
    justification="the paper's designated exchange artifact: neighbor "
                  "outputs on the (public or mutually shared) reference "
                  "set — the knowledge-transfer channel the protocol "
                  "defines as releasable in place of raw parameters")
def public_ref_logits(neighbor_logits):
    """Mark a (M, N, R, C) neighbor-logit web as the exchanged artifact.

    `core.protocol.exchange_phase` routes every logit web through this
    identity before it enters the exchange: the taint verifier treats
    the gathered logits as disclosed-by-design (DESIGN.md §14), so the
    rest of the round is proven clean DOWNSTREAM of exactly this one
    sanctioned release."""
    return neighbor_logits


class ExchangeResult(NamedTuple):
    """Everything one reference-set exchange yields, for all M clients."""
    l_ij: jnp.ndarray        # (M, N) f32 — Eq. 3 CE of neighbor j on X_i^ref
    valid_mask: jnp.ndarray  # (M, N) bool — §3.5 survivors (selected & upper half)
    target_ref: jnp.ndarray  # (M, R, C) f32 — masked mean of valid neighbor logits
    has_target: jnp.ndarray  # (M,) bool — any neighbor passed (else zeros target)


def all_in_one_exchange(own_logits, neighbor_logits, y_ref, sel_mask, fed,
                        *, backend: str | None = None,
                        tiling: str | None = None) -> ExchangeResult:
    """Distill + evaluate + verify in one pass over the exchanged logits.

    own_logits: (M, R, C) — each client's outputs on its reference set;
    neighbor_logits: (M, N, R, C) — the selected neighbors' outputs on
    that same set (gathered, DESIGN.md §3); y_ref: (M, R) int labels;
    sel_mask: (M, N) bool selected slots; fed: FedConfig (consumes
    lsh_verification, exchange_backend and exchange_tiling).
    `backend` / `tiling` override the FedConfig fields when given.

    The tiling regime resolves from the explicit one-shot VMEM
    estimate (`backends.resolve_tiling`, DESIGN.md §10): shapes whose
    (BM, N, R, C) tile fits the budget keep the bit-exact one-shot
    path; beyond it the streamed R/C-tiled path runs (tolerance-bounded
    l_ij/target, identical §3.5 mask off exact kl ties).

    With fed.lsh_verification=False the §3.5 filter is skipped and
    valid_mask == sel_mask (the "w/o verification" ablation).
    """
    m, n = sel_mask.shape
    if n == 0:                         # degenerate M <= 1 federation
        r, c = own_logits.shape[-2:]
        return ExchangeResult(
            jnp.zeros((m, 0), jnp.float32), jnp.zeros((m, 0), bool),
            jnp.zeros((m, r, c), jnp.float32), jnp.zeros((m,), bool))
    r, c = neighbor_logits.shape[-2:]
    resolved = backends.resolve(backend or fed.exchange_backend)
    resolved_tiling = backends.resolve_tiling(
        tiling or fed.exchange_tiling,
        backends.exchange_vmem_bytes(n, r, c))
    if resolved == "kernel":
        exchange_fn = (fused_exchange_streamed
                       if resolved_tiling == "tiled" else fused_exchange)
        out = exchange_fn(own_logits, neighbor_logits, y_ref, sel_mask,
                          lsh_verification=fed.lsh_verification,
                          interpret=backends.interpret())
    elif resolved_tiling == "tiled":
        out = ref.streamed_exchange_ref(
            own_logits, neighbor_logits, y_ref, sel_mask,
            lsh_verification=fed.lsh_verification)
    else:
        out = ref.all_in_one_exchange_ref(
            own_logits, neighbor_logits, y_ref, sel_mask,
            lsh_verification=fed.lsh_verification)
    return ExchangeResult(*out)
