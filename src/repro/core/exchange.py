"""All-in-one reference-set exchange (WPFed Eq. 3 + §3.5 + Alg. 1's
distillation target — the paper's headline "single exchange" protocol).

The paper's contribution is that ONE reference-set logit exchange
simultaneously (1) transfers knowledge (the distillation target),
(2) evaluates model quality (the per-neighbor CE losses that feed the
Eq. 7 rankings), and (3) verifies similarity (§3.5's output-KL
upper-half filter). `all_in_one_exchange` is the single protocol entry
point for all three, mirroring `core.neighbor.select_partners` for the
selection subsystem (DESIGN.md §7):

  "kernel" -> fused Pallas kernel (one shared neighbor log-softmax
              while the (N, R, C) tile is in VMEM; interpret off-TPU),
  "oracle" -> the bit-exact jnp twin (ref.all_in_one_exchange_ref),
  "auto"   -> kernel on TPU, oracle elsewhere.

The unfused pieces (`distill.cross_entropy`,
`verify.lsh_verification_mask`, `distill.aggregate_neighbor_outputs`)
remain the semantic reference — tests assert both fused paths match
their composition bit-exactly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import backends
from repro.kernels import ref
from repro.kernels.exchange import fused_exchange


class ExchangeResult(NamedTuple):
    """Everything one reference-set exchange yields, for all M clients."""
    l_ij: jnp.ndarray        # (M, N) f32 — Eq. 3 CE of neighbor j on X_i^ref
    valid_mask: jnp.ndarray  # (M, N) bool — §3.5 survivors (selected & upper half)
    target_ref: jnp.ndarray  # (M, R, C) f32 — masked mean of valid neighbor logits
    has_target: jnp.ndarray  # (M,) bool — any neighbor passed (else zeros target)


def all_in_one_exchange(own_logits, neighbor_logits, y_ref, sel_mask, fed,
                        *, backend: str | None = None) -> ExchangeResult:
    """Distill + evaluate + verify in one pass over the exchanged logits.

    own_logits: (M, R, C) — each client's outputs on its reference set;
    neighbor_logits: (M, N, R, C) — the selected neighbors' outputs on
    that same set (gathered, DESIGN.md §3); y_ref: (M, R) int labels;
    sel_mask: (M, N) bool selected slots; fed: FedConfig (consumes
    lsh_verification and exchange_backend). `backend` overrides
    fed.exchange_backend when given.

    With fed.lsh_verification=False the §3.5 filter is skipped and
    valid_mask == sel_mask (the "w/o verification" ablation).
    """
    m, n = sel_mask.shape
    if n == 0:                         # degenerate M <= 1 federation
        r, c = own_logits.shape[-2:]
        return ExchangeResult(
            jnp.zeros((m, 0), jnp.float32), jnp.zeros((m, 0), bool),
            jnp.zeros((m, r, c), jnp.float32), jnp.zeros((m,), bool))
    resolved = backends.resolve(backend or fed.exchange_backend)
    if resolved == "kernel":
        out = fused_exchange(own_logits, neighbor_logits, y_ref, sel_mask,
                             lsh_verification=fed.lsh_verification,
                             interpret=backends.interpret())
    else:
        out = ref.all_in_one_exchange_ref(
            own_logits, neighbor_logits, y_ref, sel_mask,
            lsh_verification=fed.lsh_verification)
    return ExchangeResult(*out)
