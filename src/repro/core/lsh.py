"""LSH similarity layer (WPFed §3.2, Eq. 5-6).

Wraps the Pallas kernels (repro.kernels) with protocol-level APIs:
per-client and batched codes from parameter pytrees, plus the unfused
all-pairs distance matrix / normalized distance kept as the semantic
reference for the fused selection path (the round itself goes through
core.neighbor.select_partners, which fuses Eq. 6-8 — DESIGN.md §4).

Normalization note (DESIGN.md §1): the paper's optimal gamma = 1.0 over
a search space {0.01..1000} implies d is O(1); raw Hamming distances are
O(bits), so we use the bit-fraction d/bits. A sharded-model extension
(beyond-paper, DESIGN.md §3) computes partial projection sums per
parameter shard and psums them — the full parameter vector never
materializes on one device.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis.privacy import declassifier
from repro.core import backends
from repro.kernels import ops


def client_lsh_code(params, seed: int, bits: int = 256,
                    use_kernel: bool = True):
    """Eq. (5): packed uint32 code for one client's parameter pytree."""
    return ops.lsh_code(params, seed, bits=bits, use_kernel=use_kernel)


@declassifier(
    name="lsh-code", paper_eq="Eq. 5-6 (§3.2)",
    justification="sign-quantized random projection: each bit keeps one "
                  "sign of a Rademacher projection of the flattened "
                  "params — a locality hash for distance comparison, "
                  "not an invertible encoding of the model")
def stacked_lsh_codes(stacked_params, seed, bits: int = 256,
                      backend: str = "auto"):
    """Codes for vmap-stacked client params (M, ...) — the per-round
    federation path. The client axis flows through the natively batched
    projection kernel (2D grid over client-block x chunk; DESIGN.md §4)
    rather than a vmap of the single-client kernel, which has no
    batching rule and used to silently fall back to the per-client
    oracle. `seed` is the shared per-round LSH seed (all clients must
    hash with the same projection for distances to be comparable); it
    may be a traced scalar. Oracle backend is bit-exact at the code
    level (tested)."""
    flat2d = ops.flatten_params_batched(stacked_params)
    # "ann" only changes SELECTION (candidate generation, §11); the
    # projection itself has no approximate variant, so it resolves as
    # "auto" there.
    use_kernel = backends.resolve(
        "auto" if backend == "ann" else backend) == "kernel"
    return ops.batched_lsh_codes(flat2d, seed, bits=bits,
                                 use_kernel=use_kernel)


def sharded_lsh_code(local_shard_flat, seed: int, bits: int, axis_name: str):
    """Beyond-paper: LSH of a *sharded* parameter vector inside
    shard_map — each device projects its local shard chunk-offset by its
    axis index, partial sums are psum'd, then packed. Linearity of the
    projection makes this exact: sum over shards == projection of concat.
    """
    idx = jax.lax.axis_index(axis_name)
    # offset the chunk index so each shard hashes with its global offset
    n = local_shard_flat.shape[0]
    offset = idx * n
    from repro.kernels.lsh_projection import rademacher_block
    r = rademacher_block(offset, n, bits, seed)
    partial = jnp.dot(local_shard_flat.astype(jnp.float32), r)
    total = jax.lax.psum(partial, axis_name)
    return ops.pack_bits(total)


def distance_matrix(codes, *, use_kernel: bool = True):
    """Eq. (6) all-pairs: (M, W) uint32 -> (M, M) int32."""
    return ops.hamming_matrix(codes, use_kernel=use_kernel)


def normalized_distance(dist, bits: int):
    return dist.astype(jnp.float32) / float(bits)
