"""Round-program engine: ONE schedule API for the sync WPFed round,
gossip epochs, and all baselines (DESIGN.md §8).

A federation method is a `RoundProgram` — two typed round bodies over
`FedState` (or any state pytree with `.round`):

  global_round(state, data) -> (state, cache, metrics)
      the full (expensive) composition — for WPFed: §3.6 reveal
      verification + LSH re-code + fused top-N re-selection, the
      all-in-one exchange, local updates, and the next announcement.
      `cache` is the program's selection cache (for WPFed the
      `SelectResult`; peer ids for the gossip baselines), threaded
      into the gossip epochs that follow.
  gossip_round(state, data, cache) -> (state, cache, metrics)
      a cheap epoch that REUSES the cached selection: exchange +
      update only — no re-code, no ranking/commitment announcement.
      This is the ProxyFL-style peer epoch (Kalra et al. 23) / P4
      peer-to-peer round (Maheri et al. 24) between global
      re-selections.

`Schedule(reselect_every=G)` partitions the round axis into
reselection periods: one global round followed by G-1 gossip epochs.
`make_segment_fn` compiles a whole period into ONE XLA program (the
gossip epochs run under `jax.lax.scan`), and `run_rounds` drives
segments with host sync only once per reselection — the `on_reselect`
callback is where `core.chain.Blockchain` publishing lives
(launch/fed.py, examples/wpfed_federation.py). This replaces the
per-round Python loops that previously forked per method.

`Schedule(reselect_every=1)` reproduces the classic sync protocol
bit-exactly for WPFed and every baseline (regression-tested in
tests/test_rounds_engine.py).

This module deliberately imports no `repro.core` siblings at module
level: `core.protocol` / `core.baselines` import `RoundProgram` from
here, and `make_program` resolves them via function-level imports
(the `repro.core.backends` pattern).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import io_callback

from repro.analysis.privacy import declassifier, sink


class RoundProgram(NamedTuple):
    """A federation method as a (global round, gossip epoch) pair."""
    name: str
    global_round: Callable  # (state, data) -> (state, cache, metrics)
    gossip_round: Optional[Callable] = None  # (state, data, cache) -> same


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Reselection schedule: run the global round every
    `reselect_every` rounds, gossip epochs in between. 1 == the
    paper's fully synchronous protocol."""
    reselect_every: int = 1

    def __post_init__(self):
        if self.reselect_every < 1:
            raise ValueError(
                f"reselect_every must be >= 1, got {self.reselect_every}")

    def segments(self, rounds: int):
        """Yield (start_round, length) per reselection period."""
        r0 = 0
        while r0 < rounds:
            yield r0, min(self.reselect_every, rounds - r0)
            r0 += self.reselect_every


SCHEDULES = ("sync", "gossip")


def resolve_schedule(name: str = "sync", reselect_every: int = 0) -> Schedule:
    """One-place schedule validation (the repro.core.backends pattern —
    launch/fed.py, examples and benchmarks all construct schedules
    here, so the string/argument checking lives in exactly one spot).

      "sync"   -> Schedule(1), the per-round protocol; an explicit
                  reselect_every other than 0/1 is an error, not
                  silently ignored.
      "gossip" -> Schedule(reselect_every or 4).
    """
    if name not in SCHEDULES:
        raise ValueError(
            f"unknown schedule: {name!r} (expected one of {SCHEDULES})")
    if name == "sync":
        if reselect_every not in (0, 1):
            raise ValueError(
                "schedule 'sync' re-selects every round; pass "
                "schedule='gossip' to use reselect_every="
                f"{reselect_every}")
        return Schedule(1)
    return Schedule(reselect_every or 4)


def program_round(program: RoundProgram) -> Callable:
    """Adapt a program's global round to the classic
    `round_fn(state, data) -> (state, metrics)` signature
    (make_wpfed_round and the make_*_round baselines are this adapter
    over their programs)."""

    def round_fn(state, data):
        state, _cache, metrics = program.global_round(state, data)
        return state, metrics

    return round_fn


@declassifier(
    name="round-telemetry", paper_eq="§4 (reported per-round metrics)",
    justification="federation-level scalar aggregates only (means and "
                  "fractions over the client axis) — the declassifier "
                  "refuses any non-scalar leaf, so no per-client vector "
                  "or model-derived array can ride this channel")
def release_round_telemetry(scalars: Dict[str, Any]) -> Dict[str, Any]:
    """The ONLY gate through which round metrics may reach the host tap.

    Raises on any non-scalar leaf: the justification above is enforced
    structurally, not by reviewer diligence."""
    for k, v in scalars.items():
        if getattr(v, "ndim", None) != 0:
            raise ValueError(
                f"round-telemetry releases scalars only; {k!r} has "
                f"shape {getattr(v, 'shape', None)!r}")
    return scalars


def _stream_metrics(metrics_tap: Callable, m: Dict[str, Any]) -> None:
    """Emit one round's scalar metrics to the host from INSIDE a
    compiled segment via an ordered `io_callback` (DESIGN.md §13): a
    continuous-service operator sees rounds as they complete instead of
    once per reselection period. Ordered so taps arrive in round order;
    non-scalar metrics (neighbor_ids, masks) stay on device."""
    scalars = {k: jnp.asarray(v) for k, v in m.items()}
    scalars = {k: v for k, v in scalars.items() if v.ndim == 0}
    # declassify (scalar aggregates, enforced above) THEN mark the
    # disclosure: the io_callback below carries only released values
    scalars = sink("metrics-tap", release_round_telemetry(scalars))

    def tap(s):  # analysis: host-ok — io_callback target runs on host
        metrics_tap({k: v.item() for k, v in s.items()})

    io_callback(tap, None, scalars, ordered=True)


def make_segment_fn(program: RoundProgram, length: int, *,
                    eval_fn: Optional[Callable] = None,
                    metrics_tap: Optional[Callable] = None) -> Callable:
    """Compile-ready body for one reselection period of `length`
    rounds: the global round, then length-1 gossip epochs under
    `jax.lax.scan` threading (state, cache). Returns
    segment_fn(state, data) -> (state, metrics) with every metric
    stacked on a leading (length,) round axis.

    `eval_fn(state, data) -> dict` (jittable) is merged into each
    round's metrics — this keeps per-round evaluation inside the
    compiled segment instead of forcing a host sync per round.

    `metrics_tap(scalars: dict) -> None` (host function) additionally
    receives each round's scalar metrics mid-segment through an
    ordered `io_callback` — the service driver's live progress stream
    (`_stream_metrics`). Omitting it keeps the segment callback-free.
    """
    if length < 1:
        raise ValueError(f"segment length must be >= 1, got {length}")
    if length > 1 and program.gossip_round is None:
        raise ValueError(
            f"program {program.name!r} has no gossip_round; "
            "only Schedule(reselect_every=1) can run it")

    def seg_fn(state, data):
        state, cache, m0 = program.global_round(state, data)
        if eval_fn is not None:
            m0 = {**m0, **eval_fn(state, data)}
        if metrics_tap is not None:
            _stream_metrics(metrics_tap, m0)
        if length == 1:
            # no scan: the segment IS the classic sync round
            # (bit-exactness with the pre-engine round is regression-
            # tested; keep this path free of extra graph structure)
            return state, jax.tree.map(lambda a: jnp.asarray(a)[None], m0)

        def body(carry, _):
            st, ca = carry
            st, ca, m = program.gossip_round(st, data, ca)
            if eval_fn is not None:
                m = {**m, **eval_fn(st, data)}
            if metrics_tap is not None:
                _stream_metrics(metrics_tap, m)
            return (st, ca), m

        (state, _cache), ms = jax.lax.scan(
            body, (state, cache), None, length=length - 1)
        metrics = jax.tree.map(
            lambda a, b: jnp.concatenate([jnp.asarray(a)[None], b], axis=0),
            m0, ms)
        return state, metrics

    return seg_fn


def extract_history(metrics, r0, length):  # analysis: host-ok (see below)
    """Stacked per-round segment metrics -> one plain-Python dict per
    round (scalar metrics only, plus the absolute "round" index).
    Intentional host extraction: callers run it once per reselection
    period, after `jax.block_until_ready` (run_rounds here, the
    continuous service driver in `repro.service.driver`)."""
    history: List[Dict[str, Any]] = []
    for i in range(length):
        entry: Dict[str, Any] = {}
        for k, v in metrics.items():
            if getattr(v, "ndim", None) == 1:  # per-round scalar
                is_int = jnp.issubdtype(v.dtype, jnp.integer)
                entry[k] = int(v[i]) if is_int else float(v[i])
        entry["round"] = r0 + i
        history.append(entry)
    return history


def run_rounds(program: RoundProgram, state, data, *, rounds: int,
               schedule: Optional[Schedule] = None,
               eval_fn: Optional[Callable] = None,
               on_reselect: Optional[Callable] = None,
               log: Optional[Callable] = None
               ) -> Tuple[Any, List[Dict[str, Any]]]:
    """Drive `rounds` federation rounds under `schedule`.

    One jit-compiled segment per reselection period (compiled once per
    distinct length — at most two: full periods + a shorter tail);
    `on_reselect(start_round, state)` runs on host after each period
    with the period's announcements in `state` (codes / rankings /
    commitments are frozen across its gossip epochs), which is where
    the host `Blockchain` ledger publishes.

    Returns (final_state, history): one dict per round holding every
    scalar metric (plus `eval_fn` outputs) as a Python number and the
    absolute "round" index.
    """
    schedule = schedule or Schedule()
    seg_fns: Dict[int, Callable] = {}
    history: List[Dict[str, Any]] = []
    for r0, length in schedule.segments(rounds):
        if length not in seg_fns:
            seg_fns[length] = jax.jit(
                make_segment_fn(program, length, eval_fn=eval_fn))
        t0 = time.time()
        state, metrics = seg_fns[length](state, data)
        jax.block_until_ready(metrics)
        dt = time.time() - t0
        if on_reselect is not None:
            on_reselect(r0, state)
        history.extend(extract_history(metrics, r0, length))
        if log is not None:
            last = history[-1]
            parts = [f"{k} {last[k]:.4f}" for k in ("acc", "mean_loss")
                     if k in last]
            log(f"round {last['round']:3d} " + " ".join(parts)
                + f" ({dt:.1f}s/{length}r)")
    return state, history


PROGRAMS = ("wpfed", "silo", "fedmd", "proxyfl", "kdpdfl")


def make_program(method: str, apply_fn, optimizer, fed,
                 **kwargs) -> RoundProgram:
    """One-place program construction for every method name
    (`benchmarks.common` and the launchers resolve through here).
    `fedmd` requires shared_ref_x=...; `proxyfl` accepts num_peers=."""
    # function-level imports: protocol/baselines import RoundProgram
    # from this module (see the module docstring)
    from repro.core import baselines, protocol
    makers = {"wpfed": protocol.wpfed_program,
              **baselines.BASELINE_PROGRAMS}
    if method not in makers:
        raise KeyError(
            f"unknown method: {method!r} (expected one of {PROGRAMS})")
    return makers[method](apply_fn, optimizer, fed, **kwargs)
