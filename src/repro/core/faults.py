"""Deterministic fault injection for the federation service
(DESIGN.md §15).

TPFed's premise is operation over open, trust-averse networks — so the
service must be testable UNDER network reality: lossy links,
stragglers, corrupted bytes, flaky publishes, crash-restarts, forked
ledger views. This module makes those faults a first-class, seeded,
replayable dimension:

  * A `FaultPlan` is a typed description of the fault regime (per-kind
    rates plus scheduled crash/fork events). It contains NO mutable
    state and draws on NO global RNG.
  * Every fault decision is a pure function of
    `(plan.seed, kind, period, client, attempt)` through a splitmix64
    counter hash (`fault_u01`) — the same plan replays the same faults
    bit-for-bit, in the original process, in a resumed process, and in
    a regression test. `random` never appears.
  * `period_faults` precomputes one period's complete verdict set (who
    straggles, whose announcement drops / delays / duplicates /
    corrupts, how many publish/fetch attempts fail) so the driver can
    stream the period's fault counters through the existing
    `io_callback` metric channel BEFORE the segment runs, and the
    transport applies exactly the same verdicts afterwards — one
    source of truth, no divergence possible.
  * A `FaultTrace` records the events a transport actually injected;
    the chaos soak asserts two runs of the same plan produce identical
    traces (scripts/chaos_smoke.py).

The injection *site* is `repro.service.transport.BulletinTransport` —
faults model the client <-> bulletin-board link, never the in-graph
protocol math (which stays bit-reproducible by construction).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

# fault kinds, in hash-stream order (the index salts the counter hash,
# so every kind draws from an independent deterministic stream)
FAULT_KINDS = ("drop", "delay", "duplicate", "corrupt", "straggle",
               "publish_fail", "fetch_fail", "backoff")
_KIND_INDEX = {k: i for i, k in enumerate(FAULT_KINDS)}

_MASK64 = (1 << 64) - 1
_GOLDEN = 0x9E3779B97F4A7C15


def _splitmix64(x: int) -> int:
    """One splitmix64 output step (pure int math, host-side)."""
    x = (x + _GOLDEN) & _MASK64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK64
    return (x ^ (x >> 31)) & _MASK64


def fault_u01(seed: int, kind: str, period: int, client: int = 0,
              attempt: int = 0) -> float:
    """Uniform [0, 1) draw, a pure function of its arguments.

    This is the ONLY randomness source in the fault layer: replaying a
    plan replays its faults exactly (kill/resume included)."""
    h = seed & _MASK64
    for word in (_KIND_INDEX[kind], period, client, attempt):
        h = _splitmix64(h ^ ((word + 1) * _GOLDEN & _MASK64))
    return h / 2.0 ** 64


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """One seeded fault regime for a service run.

    Rates are per-period, per-client probabilities on the client ->
    bulletin-board link (publish_fail / fetch_fail are per ATTEMPT on
    the board itself). `crash_periods` kills the driver mid-period
    (after the compiled segment, before any durable effect) at each
    listed period; `fork_at >= 0` writes a competing rolled-back
    ledger view next to chain.json after that period's checkpoint.

    A plan is "eventually delivering" when every rate is < 1: each
    client's announcement lands with probability 1 in the limit, and
    bounded retry eventually clears every publish/fetch. The chaos
    soak's convergence invariant assumes that regime; rate = 1.0 is
    legal (unit tests force faults with it) but fail-stop."""
    seed: int = 0
    drop: float = 0.0          # announcement lost in transit
    delay: float = 0.0         # lands after the selection deadline
    duplicate: float = 0.0     # delivered twice (board must dedupe)
    corrupt: float = 0.0       # bytes flipped in transit (checksum)
    straggle: float = 0.0      # client misses the round deadline
    publish_fail: float = 0.0  # one publish attempt fails
    fetch_fail: float = 0.0    # one fetch attempt fails
    crash_periods: Tuple[int, ...] = ()
    fork_at: int = -1

    def __post_init__(self):
        for name in ("drop", "delay", "duplicate", "corrupt", "straggle",
                     "publish_fail", "fetch_fail"):
            rate = getattr(self, name)
            if not 0.0 <= rate <= 1.0:
                raise ValueError(
                    f"fault rate {name}={rate} outside [0, 1]")
        if any(p < 0 for p in self.crash_periods):
            raise ValueError(
                f"crash_periods must be >= 0, got {self.crash_periods}")

    def eventually_delivering(self) -> bool:
        return all(getattr(self, n) < 1.0
                   for n in ("drop", "delay", "corrupt", "straggle",
                             "publish_fail", "fetch_fail"))


class PeriodFaults:
    """One period's complete, precomputed fault verdicts (see module
    docstring: computed before the segment, applied after)."""

    def __init__(self, stragglers, drop, delay, duplicate, corrupt,
                 publish_failures: int, fetch_failures: int,
                 crash: bool):
        self.stragglers = stragglers  # (M,) bool — miss the deadline
        self.drop = drop              # (M,) bool — announcement lost
        self.delay = delay            # (M,) bool — lands late (stale)
        self.duplicate = duplicate    # (M,) bool — delivered twice
        self.corrupt = corrupt        # (M,) bool — bytes flipped
        self.publish_failures = publish_failures  # leading bad attempts
        self.fetch_failures = fetch_failures
        self.crash = crash            # kill the driver this period

    def any_delivery_fault(self) -> bool:
        return bool(self.drop.any() or self.delay.any()
                    or self.duplicate.any() or self.corrupt.any())


def leading_failures(plan: FaultPlan, kind: str, period: int,
                     max_attempts: int) -> int:
    """How many attempts fail before the first success (capped —
    `max_attempts` failures means the retry budget exhausts)."""
    n = 0
    rate = getattr(plan, kind)
    while n < max_attempts and \
            fault_u01(plan.seed, kind, period, attempt=n) < rate:
        n += 1
    return n


def period_faults(plan: FaultPlan, period: int, num_clients: int,
                  max_attempts: int) -> PeriodFaults:  # analysis: host-ok — deterministic host-side fault verdicts, no device values
    """All of one period's fault verdicts, reproducibly.

    Per client the in-flight faults are mutually exclusive with
    precedence drop > corrupt > delay (a dropped announcement cannot
    also be corrupted); duplication is orthogonal (a delivered copy may
    arrive twice). Stragglers are decided first and independently — a
    straggling client announces nothing, so its link faults are moot."""
    def draw(kind):  # analysis: host-ok — np.array over pure-int hash draws, no device values
        rate = getattr(plan, kind)
        return np.array([fault_u01(plan.seed, kind, period, client=i)
                         < rate for i in range(num_clients)], dtype=bool)

    straggle = draw("straggle")
    drop = draw("drop")
    corrupt = draw("corrupt") & ~drop
    delay = draw("delay") & ~drop & ~corrupt
    duplicate = draw("duplicate") & ~drop & ~corrupt
    return PeriodFaults(
        stragglers=straggle, drop=drop, delay=delay, duplicate=duplicate,
        corrupt=corrupt,
        publish_failures=leading_failures(plan, "publish_fail", period,
                                          max_attempts),
        fetch_failures=leading_failures(plan, "fetch_fail", period,
                                        max_attempts),
        crash=period in plan.crash_periods)


def fault_scalars(pf: PeriodFaults, announcing) -> Dict[str, float]:  # analysis: host-ok — host counters for the metric stream
    """The period's fault counters as flat scalars — what the driver
    streams through the io_callback metric channel and attaches to the
    period's history entry (and BENCH/chaos JSON). Link faults count
    only on ANNOUNCING clients: a fault verdict on an inactive or
    straggling slot injects nothing."""
    announcing = np.asarray(announcing, bool)
    return {
        "fault_stragglers": float((pf.stragglers & announcing).sum()),
        "fault_dropped": float((pf.drop & announcing
                                & ~pf.stragglers).sum()),
        "fault_delayed": float((pf.delay & announcing
                                & ~pf.stragglers).sum()),
        "fault_corrupt": float((pf.corrupt & announcing
                                & ~pf.stragglers).sum()),
        "fault_duplicates": float((pf.duplicate & announcing
                                   & ~pf.stragglers).sum()),
        "fault_publish_retries": float(pf.publish_failures),
        "fault_fetch_retries": float(pf.fetch_failures),
        "degraded_round": float(
            bool((pf.stragglers & announcing).any()
                 or ((pf.drop | pf.delay | pf.corrupt) & announcing
                     & ~pf.stragglers).any()
                 or pf.publish_failures or pf.fetch_failures)),
    }


class FaultTrace:
    """Append-only record of the faults a transport actually injected.

    `events` is the reproducibility artifact: two runs of the same
    FaultPlan must produce identical event lists (asserted by
    scripts/chaos_smoke.py and tests/test_faults.py)."""

    def __init__(self):
        self.events: List[Tuple[int, str, int]] = []
        self.counters: Dict[str, int] = {}

    def record(self, period: int, kind: str, who: int = -1) -> None:
        self.events.append((period, kind, who))
        self.counters[kind] = self.counters.get(kind, 0) + 1

    def snapshot(self) -> Dict[str, int]:
        return dict(self.counters)


_SPEC_RATES = ("drop", "delay", "duplicate", "corrupt", "straggle",
               "publish_fail", "fetch_fail")


def parse_fault_spec(spec: str) -> FaultPlan:
    """Parse the CLI fault spec, e.g.
    "seed=7,drop=0.1,straggle=0.2,publish_fail=0.3,crash=2,fork=1"
    -> FaultPlan(seed=7, drop=0.1, ..., crash_periods=(2,), fork_at=1).
    `crash` may repeat for multiple scheduled crash-restarts."""
    kwargs: Dict[str, object] = {}
    crashes: List[int] = []
    for item in filter(None, (s.strip() for s in spec.split(","))):
        if "=" not in item:
            raise ValueError(f"bad fault spec item {item!r} (want key=value)")
        key, _, value = item.partition("=")
        key = key.strip()
        # analysis: host-ok — int()/float() on CLI strings, not device values
        if key == "seed":
            kwargs["seed"] = int(value)
        elif key == "crash":
            crashes.append(int(value))
        elif key == "fork":
            kwargs["fork_at"] = int(value)
        elif key in _SPEC_RATES:
            kwargs[key] = float(value)
        else:
            raise ValueError(
                f"unknown fault spec key {key!r} (expected seed, "
                f"crash, fork, or one of {_SPEC_RATES})")
    if crashes:
        kwargs["crash_periods"] = tuple(crashes)
    return FaultPlan(**kwargs)
