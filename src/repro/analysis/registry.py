"""Kernel-contract registry (DESIGN.md §12).

Every Pallas kernel wrapper in `repro.kernels` registers a contract
entry via the `@kernel_contract(...)` decorator (applied ABOVE the
`jax.jit` partial, so the entry holds the public wrapper). The entry
is pure metadata — the decorator returns the function unchanged — and
records everything `repro.analysis.kernel_contracts` needs to verify
the kernel mechanically:

  * `sites`            how many `pl.pallas_call` sites the wrapper
                       launches (the completeness guard in
                       tests/test_analysis.py greps the kernel files
                       and asserts the per-module totals match);
  * `oracle`           the jnp twin's name in `kernels/ref.py`;
  * `estimator`        the VMEM estimator's name in
                       `core.backends.VMEM_ESTIMATORS` (None for
                       kernels whose budget is docstring-only), plus
                       `estimator_kwargs(point)` mapping a
                       representative shape point to its arguments;
  * `exactness`        "bit_exact" | "tolerance" — the testing class
                       the kernel's docstring claims;
  * `out_revisit`      per-site grid axes that may legally revisit an
                       output block (accumulation axes: the lsh chunk
                       axis, the §10 column-tile axis, flash's KV
                       axis). Any OTHER revisit is an output race;
  * `points`           representative shape points (≥ 3 for
                       estimator-backed kernels), with
                       `make_args(point)` building abstract
                       (ShapeDtypeStruct) arguments;
  * `vmem_extra`       bytes of kernel-internal intermediates beyond
                       the blocks themselves (unpacked ±1 codes,
                       weight tiles), computed FROM the captured
                       block shapes so estimator drift is caught in
                       either direction;
  * `slack`            relative tolerance for estimator truthfulness.

Capture ("abstract interpretation" layer 0): `capture_sites` runs the
un-jitted wrapper under `jax.eval_shape` with `pl.pallas_call`
monkey-patched to record (grid, in_specs, out_specs, out_shape,
scratch_shapes, operands) and return zeros of the declared out_shape.
No kernel body executes, no array memory is allocated, and the real
jit cache is never touched (the un-jitted function is traced inside
eval_shape's own scope).

This module is import-light on purpose (stdlib only at module level):
kernel modules import it at import time, so it must not pull in jax or
any `repro` sibling.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

EXACTNESS_CLASSES = ("bit_exact", "tolerance")

# name -> KernelEntry; populated at kernel-module import time
REGISTRY: Dict[str, "KernelEntry"] = {}


@dataclasses.dataclass(frozen=True)
class KernelEntry:
    name: str
    fn: Callable
    module: str
    sites: int
    oracle: Optional[str]
    estimator: Any            # str (backends name) | callable | None
    exactness: str
    out_revisit: Tuple[Tuple[int, ...], ...]   # per site
    points: Tuple[dict, ...]
    make_args: Callable       # point -> (args, kwargs)
    estimator_kwargs: Optional[Callable]       # point -> dict
    vmem_extra: Optional[Callable]             # (site, point) -> int
    slack: float


def _normalize_revisit(out_revisit, sites: int) -> Tuple[Tuple[int, ...], ...]:
    """Single-site entries may declare a flat tuple of axes; multi-site
    entries must declare one tuple per site."""
    rv = tuple(out_revisit)
    if sites == 1 and all(isinstance(a, int) for a in rv):
        return (rv,)
    if len(rv) != sites or not all(
            isinstance(s, (tuple, list)) for s in rv):
        raise ValueError(
            f"out_revisit must be one tuple of axes per site "
            f"({sites} sites), got {out_revisit!r}")
    return tuple(tuple(s) for s in rv)


def kernel_contract(*, name: str, sites: int, oracle: Optional[str],
                    estimator, exactness: str, out_revisit=(),
                    points: Sequence[dict] = (),
                    make_args: Optional[Callable] = None,
                    estimator_kwargs: Optional[Callable] = None,
                    vmem_extra: Optional[Callable] = None,
                    slack: float = 0.10):
    """Register a kernel wrapper's contract; returns the fn unchanged."""
    if exactness not in EXACTNESS_CLASSES:
        raise ValueError(f"unknown exactness: {exactness!r} "
                         f"(expected one of {EXACTNESS_CLASSES})")
    if make_args is None:
        raise ValueError(f"kernel_contract({name!r}) needs make_args=")
    revisit = _normalize_revisit(out_revisit, sites)

    def deco(fn):
        REGISTRY[name] = KernelEntry(
            name=name, fn=fn, module=fn.__module__, sites=sites,
            oracle=oracle, estimator=estimator, exactness=exactness,
            out_revisit=revisit, points=tuple(points),
            make_args=make_args, estimator_kwargs=estimator_kwargs,
            vmem_extra=vmem_extra, slack=slack)
        return fn

    return deco


class capture_registrations:
    """Context manager: record entries registered while it is active
    (used to check fixture modules in isolation from the HEAD
    registry)."""

    def __enter__(self) -> List[KernelEntry]:
        self._before = set(REGISTRY)
        self._new: List[KernelEntry] = []
        return self._new

    def __exit__(self, *exc):
        for k in set(REGISTRY) - self._before:
            self._new.append(REGISTRY.pop(k))
        return False


# ---------------------------------------------------------------------------
# pallas_call capture
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CapturedSite:
    """One recorded `pl.pallas_call` launch (all specs normalized to
    lists; operands recorded as ShapeDtypeStructs at call time)."""
    kernel_fn: Any
    grid: Tuple[int, ...]
    in_specs: list
    out_specs: list
    out_shapes: list
    scratch_shapes: list
    operands: list = dataclasses.field(default_factory=list)
    interpret: bool = False


def _aslist(x):
    if x is None:
        return []
    return list(x) if isinstance(x, (list, tuple)) else [x]


def unjitted(fn):
    """The pre-jit function (jax.jit wrappers carry __wrapped__)."""
    return getattr(fn, "__wrapped__", fn)


def capture_sites(entry: KernelEntry, point: dict) -> List[CapturedSite]:
    """Run `entry.fn` (un-jitted, under jax.eval_shape) at `point` with
    pallas_call monkey-patched; returns the recorded launch sites in
    call order."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    captured: List[CapturedSite] = []
    real = pl.pallas_call

    def fake_pallas_call(kernel, *fa, grid=None, in_specs=None,
                         out_specs=None, out_shape=None,
                         scratch_shapes=(), interpret=False, **fk):
        site = CapturedSite(
            kernel_fn=kernel,
            grid=(grid,) if isinstance(grid, int) else tuple(grid or ()),
            in_specs=_aslist(in_specs), out_specs=_aslist(out_specs),
            out_shapes=_aslist(out_shape),
            scratch_shapes=_aslist(scratch_shapes),
            interpret=bool(interpret))

        def runner(*ops):
            site.operands = [
                jax.ShapeDtypeStruct(jnp.shape(o), jnp.result_type(o))
                for o in ops]
            captured.append(site)
            return jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype), out_shape)

        return runner

    pl.pallas_call = fake_pallas_call
    try:
        args, kwargs = entry.make_args(point)
        jax.eval_shape(
            functools.partial(unjitted(entry.fn), **kwargs), *args)
    finally:
        pl.pallas_call = real
    return captured
