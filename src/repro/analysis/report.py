"""Finding record + report formatting for `repro.analysis` (stdlib only)."""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

# rule id -> one-line description (kept in sync with DESIGN.md §12)
RULES = {
    "tile-gap": "output-tile coverage gap: some output block is never "
                "written by any grid point",
    "tile-race": "output-tile write race: two grid points outside the "
                 "declared revisit axes write the same output block",
    "tile-oob": "index map addresses a block outside the output array",
    "block-mismatch": "block shape / arity inconsistency between "
                      "BlockSpecs, operands, and the kernel body",
    "site-count": "number of pallas_call sites differs from the "
                  "registry declaration",
    "oracle-missing": "declared jnp oracle twin not found in kernels/ref.py",
    "estimator-missing": "declared VMEM estimator not registered in "
                         "core.backends.VMEM_ESTIMATORS",
    "estimator-drift": "registered VMEM estimator disagrees with the "
                       "BlockSpec-implied bytes beyond the declared slack",
    "traced-host-cast": "host cast (int/float/.item()/np.*) on a value "
                        "reachable from traced args inside a traced context",
    "host-if": "Python `if` on a traced value inside a traced context",
    "unseeded-key": "constant PRNG key inside a traced context "
                    "(round-independent randomness)",
    "host-sync": "host-side numpy/scalar extraction of device values "
                 "(needs an `# analysis: host-ok` justification)",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str
    severity: str = "error"  # "error" | "warning"

    def location(self) -> str:
        return f"{self.path}:{self.line}"

    def __str__(self) -> str:
        return f"{self.location()}: [{self.rule}] {self.message}"


def render_text(findings: List[Finding]) -> str:
    if not findings:
        return "repro.analysis: clean (0 findings)"
    lines = [str(f) for f in findings]
    by_rule: Dict[str, int] = {}
    for f in findings:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    summary = ", ".join(f"{r}={n}" for r, n in sorted(by_rule.items()))
    lines.append(f"repro.analysis: {len(findings)} finding(s) ({summary})")
    return "\n".join(lines)


def render_json(findings: List[Finding], *, strict: bool,
                checked_entries: Optional[List[str]] = None,
                linted_paths: Optional[List[str]] = None) -> str:
    """`--json` payload: rule -> count -> locations, diffable across
    PRs (benchmarks/ANALYSIS_report.json)."""
    rules: Dict[str, Dict] = {}
    for f in sorted(findings, key=lambda f: (f.rule, f.path, f.line)):
        r = rules.setdefault(f.rule, {"count": 0, "locations": []})
        r["count"] += 1
        r["locations"].append(f"{f.location()} {f.message}")
    return json.dumps({
        "clean": not findings,
        "strict": strict,
        "total": len(findings),
        "rules": rules,
        "kernel_entries": checked_entries or [],
        "linted_paths": linted_paths or [],
    }, indent=1)
