"""Finding record + report formatting for `repro.analysis` (stdlib only)."""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

# bump when the JSON payload's shape changes, so CI diffs of
# benchmarks/ANALYSIS_report.json across runs are meaningful
SCHEMA_VERSION = 2

# rule id -> one-line description (kept in sync with DESIGN.md §12/§14)
RULES = {
    "tile-gap": "output-tile coverage gap: some output block is never "
                "written by any grid point",
    "tile-race": "output-tile write race: two grid points outside the "
                 "declared revisit axes write the same output block",
    "tile-oob": "index map addresses a block outside the output array",
    "block-mismatch": "block shape / arity inconsistency between "
                      "BlockSpecs, operands, and the kernel body",
    "site-count": "number of pallas_call sites differs from the "
                  "registry declaration",
    "oracle-missing": "declared jnp oracle twin not found in kernels/ref.py",
    "estimator-missing": "declared VMEM estimator not registered in "
                         "core.backends.VMEM_ESTIMATORS",
    "estimator-drift": "registered VMEM estimator disagrees with the "
                       "BlockSpec-implied bytes beyond the declared slack",
    "traced-host-cast": "host cast (int/float/.item()/np.*) on a value "
                        "reachable from traced args inside a traced context",
    "host-if": "Python `if` on a traced value inside a traced context",
    "unseeded-key": "constant PRNG key inside a traced context "
                    "(round-independent randomness)",
    "host-sync": "host-side numpy/scalar extraction of device values "
                 "(needs an `# analysis: host-ok` justification)",
    "unregistered-kernel": "pallas_call site(s) in a module whose "
                           "registered kernel contracts declare a "
                           "different site count (a kernel dodging "
                           "contract registration)",
    "host-ok-drift": "the `# analysis: host-ok` exemption inventory "
                     "changed without updating analysis/exemptions.py "
                     "(new host escapes must be deliberate)",
    "taint-sink": "a value tainted by a private source (client params, "
                  "optimizer state, local batches) reaches a declared "
                  "disclosure sink with no declassifier on the path",
    "taint-callback": "an io_callback/pure_callback operand is tainted "
                      "by a private source — device data crossing to "
                      "the host undeclassified",
    "taint-trace-error": "a taint analysis target failed to trace "
                         "(the disclosure boundary for that entry "
                         "point is UNVERIFIED)",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str
    severity: str = "error"  # "error" | "warning"

    def location(self) -> str:
        return f"{self.path}:{self.line}"

    def __str__(self) -> str:
        return f"{self.location()}: [{self.rule}] {self.message}"


def render_text(findings: List[Finding]) -> str:
    if not findings:
        return "repro.analysis: clean (0 findings)"
    lines = [str(f) for f in findings]
    by_rule: Dict[str, int] = {}
    for f in findings:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    summary = ", ".join(f"{r}={n}" for r, n in sorted(by_rule.items()))
    lines.append(f"repro.analysis: {len(findings)} finding(s) ({summary})")
    return "\n".join(lines)


def render_json(findings: List[Finding], *, strict: bool,
                checked_entries: Optional[List[str]] = None,
                linted_paths: Optional[List[str]] = None,
                taint_targets: Optional[List[str]] = None,
                host_ok: Optional[List] = None,
                wall_time_s: Optional[float] = None) -> str:
    """`--json` payload (benchmarks/ANALYSIS_report.json).

    Deterministic by construction so CI diffs are meaningful: the flat
    `findings` list is sorted (path, line, rule, message), every other
    list is sorted, keys are sorted, and `schema_version` stamps the
    shape. `host_ok` is the exemption inventory [(path, line, why)];
    `taint_targets` the verified jaxpr entry points; `wall_time_s` the
    whole analysis pass (ci.sh records it)."""
    ordered = sorted(findings,
                     key=lambda f: (f.path, f.line, f.rule, f.message))
    rules: Dict[str, Dict] = {}
    for f in ordered:
        r = rules.setdefault(f.rule, {"count": 0, "locations": []})
        r["count"] += 1
        r["locations"].append(f"{f.location()} {f.message}")
    payload = {
        "schema_version": SCHEMA_VERSION,
        "clean": not findings,
        "strict": strict,
        "total": len(findings),
        "findings": [dataclasses.asdict(f) for f in ordered],
        "rules": rules,
        "kernel_entries": sorted(checked_entries or []),
        "linted_paths": sorted(linted_paths or []),
        "taint_targets": sorted(taint_targets or []),
        "host_ok": {
            "count": len(host_ok or []),
            "sites": sorted(f"{p}:{ln} {why}"
                            for p, ln, why in (host_ok or []))},
    }
    if wall_time_s is not None:
        payload["wall_time_s"] = round(float(wall_time_s), 3)
    return json.dumps(payload, indent=1, sort_keys=True)
