"""Static-analysis subsystem: kernel contracts + trace-safety lint +
privacy-taint verification.

Three layers (DESIGN.md §12, §14), one CLI (`python -m repro.analysis`):

  * `registry` / `kernel_contracts` — a contract registry entry per
    Pallas kernel (wrapper fn, jnp oracle twin in `kernels/ref.py`,
    VMEM estimator in `core/backends.py`, exactness class) and an
    abstract interpreter over each pallas_call site's grid +
    BlockSpecs: output-tile coverage, undeclared output revisits
    (write races), block/arity consistency, estimator truthfulness at
    representative shapes, and the src/repro-wide completeness walk
    (no pallas_call site may dodge registration).
  * `trace_lint` — AST lint over `core/`, `kernels/`, `launch/`,
    `service/`, `train/`, `checkpoint/` for host-side casts on traced
    values, Python `if` on traced booleans, constant PRNG keys in
    traced code, and host-sync call patterns (exempted case-by-case
    via `# analysis: host-ok`; the exemption inventory is pinned in
    `exemptions.py`).
  * `privacy` / `taint` — the trust-free disclosure boundary as a
    machine-checked dataflow property: `@declassifier`-registered
    functions (LSH codes, rankings, commitments, reference-set logits,
    scalar telemetry) are the ONLY paths by which values derived from
    private sources (client params, optimizer state, local batches)
    may reach a declared `sink(...)` — proven over the jaxprs of every
    protocol phase, round program, service segment, and the serving
    forward.

This package deliberately keeps `registry` and `privacy` import-light
(stdlib only) so protocol and kernel modules can attach their
registrations at import time without a cycle; everything heavier (jax,
the checkers) lives behind function-level imports in the sibling
modules.
"""
from repro.analysis.privacy import (DECLASSIFIERS, SINKS,  # noqa: F401
                                    declassifier, sink)
from repro.analysis.registry import REGISTRY, kernel_contract  # noqa: F401
from repro.analysis.report import Finding  # noqa: F401
