"""Static-analysis subsystem: kernel contracts + trace-safety lint.

Two layers (DESIGN.md §12), one CLI (`python -m repro.analysis`):

  * `registry` / `kernel_contracts` — a contract registry entry per
    Pallas kernel (wrapper fn, jnp oracle twin in `kernels/ref.py`,
    VMEM estimator in `core/backends.py`, exactness class) and an
    abstract interpreter over each pallas_call site's grid +
    BlockSpecs: output-tile coverage, undeclared output revisits
    (write races), block/arity consistency, and estimator
    truthfulness at representative shapes.
  * `trace_lint` — AST lint over `core/`, `kernels/`, `launch/` for
    host-side casts on traced values, Python `if` on traced booleans,
    constant PRNG keys in traced code, and host-sync call patterns
    (exempted case-by-case via `# analysis: host-ok`).

This package deliberately keeps `registry` import-light (stdlib only)
so the kernel modules can attach their contract entries at import time
without a cycle; everything heavier (jax, the checkers) lives behind
function-level imports in the sibling modules.
"""
from repro.analysis.registry import REGISTRY, kernel_contract  # noqa: F401
from repro.analysis.report import Finding  # noqa: F401
