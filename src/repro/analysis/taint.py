"""Layer 3: jaxpr-level privacy-taint dataflow analysis (DESIGN.md §14).

Proves the paper's trust-free disclosure boundary as a machine-checked
property of the actual computation graphs: private sources (the local
parameter pytree, local data batches, optimizer state) are tainted at
the avals of each analysis target's signature, taint propagates
structurally through every eqn of `jax.make_jaxpr`'s output —
including `scan` / `while` / `cond` / `pjit` sub-jaxprs (carry
fixpoints, branch unions, predicate implicit flows), `pallas_call`
(conservatively: all inputs flow to all outputs), and `io_callback`
operands — and only the registered `@declassifier` functions
(`repro.analysis.privacy`) clear it. A tainted value reaching a
declared `sink(...)` is a `taint-sink` finding; a tainted `io_callback`
operand is a `taint-callback` finding; a target that fails to trace is
a `taint-trace-error`.

The lattice is the powerset of source labels ({client-params,
opt-state, client-data}) ordered by inclusion; every transfer function
below is a monotone union, so the scan/while carry fixpoints converge
in at most |labels| passes. Fixpoint iterations run with finding
emission off and are followed by one final emitting pass, so each
violation is reported exactly once.

Analysis targets are jaxprs of the real protocol entry points:
`head_targets()` covers every WPFed phase, the wpfed/baseline round
programs, a metrics-tapped compiled segment (scan + ordered
io_callback), the adversary-instrumented round, the continuous-service
round/segment (ledger publish path), and the PersonalizedServer
forward — traced over a tiny 4-client federation on the oracle
backends (the taint semantics are backend-invariant; oracle jaxprs are
small and pallas-free). Fixtures register their own targets via
`taint_target(...)`, captured in isolation by `capture_targets`.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Callable, Dict, List, Sequence, Tuple

from repro.analysis import privacy
from repro.analysis.report import Finding

# canonical private-source labels (DESIGN.md §14 table)
SRC_PARAMS = "client-params"
SRC_OPT = "opt-state"
SRC_DATA = "client-data"
SOURCES = (SRC_PARAMS, SRC_OPT, SRC_DATA)

EMPTY: frozenset = frozenset()

# callback primitives whose operands cross to the host
_CALLBACK_PRIMS = ("io_callback", "pure_callback", "debug_callback")


# ---------------------------------------------------------------------------
# marker primitives (bound by repro.analysis.privacy while tracing)
# ---------------------------------------------------------------------------
def _make_marker(prim_name: str):
    from jax.extend.core import Primitive
    from jax.interpreters import ad, batching

    prim = Primitive(prim_name)
    prim.def_impl(lambda x, **_: x)
    prim.def_abstract_eval(lambda x, **_: x)
    # identity rules so markers survive vmap (declassifiers run under
    # jax.vmap — make_ranking) and autodiff without special-casing
    batching.primitive_batchers[prim] = \
        lambda args, dims, **params: (prim.bind(args[0], **params),
                                      dims[0])
    ad.defjvp(prim, lambda g, x, **params: g)
    ad.primitive_transposes[prim] = lambda ct, x, **params: [ct]
    return prim


taint_declassify_p = _make_marker("taint_declassify")
taint_sink_p = _make_marker("taint_sink")


def declassify_value(value, name: str):
    """Bind the declassify marker on every array leaf of `value`."""
    import jax
    import jax.numpy as jnp
    return jax.tree.map(
        lambda leaf: taint_declassify_p.bind(jnp.asarray(leaf),
                                             name=name), value)


def sink_value(value, name: str):
    """Bind the sink marker on every array leaf of `value`."""
    import jax
    import jax.numpy as jnp
    return jax.tree.map(
        lambda leaf: taint_sink_p.bind(jnp.asarray(leaf), name=name),
        value)


# ---------------------------------------------------------------------------
# the dataflow engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _Ctx:
    """Per-analysis context threaded through sub-jaxpr recursion."""
    target: str
    findings: List[Finding]
    emit: bool = True

    def quiet(self) -> "_Ctx":
        return dataclasses.replace(self, emit=False)


def _fmt(taint: frozenset) -> str:
    return "{" + ", ".join(sorted(taint)) + "}"


def _rel(path: str) -> str:
    try:
        rel = os.path.relpath(path)
    except ValueError:
        return path
    return path if rel.startswith("..") else rel


_ANALYSIS_FILES = (os.path.join("analysis", "taint.py"),
                   os.path.join("analysis", "privacy.py"))


def _eqn_loc(eqn) -> Tuple[str, int]:
    """Source location of an eqn, best-effort (file, line). Marker
    primitives bind inside this module's tree.map, so frames from the
    analysis layer itself are skipped — the finding points at the
    protocol code that reached the sink."""
    try:
        from jax._src import source_info_util
        fallback = None
        for frame in source_info_util.user_frames(eqn.source_info):
            loc = _rel(frame.file_name), int(frame.start_line)
            if fallback is None:
                fallback = loc
            if not frame.file_name.endswith(_ANALYSIS_FILES):
                return loc
        if fallback is not None:
            return fallback
    except Exception:
        pass
    return "<jaxpr>", 0


def _is_literal(atom) -> bool:
    from jax.extend.core import Literal
    return isinstance(atom, Literal)


def _union(taints: Sequence[frozenset]) -> frozenset:
    return frozenset().union(*taints) if taints else EMPTY


def _call_jaxpr(params: dict):
    """The single sub-jaxpr of a call-like eqn (pjit, closed_call,
    custom_jvp/vjp, remat), as a ClosedJaxpr, or None."""
    from jax.extend.core import ClosedJaxpr, Jaxpr
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        sub = params.get(key)
        if isinstance(sub, ClosedJaxpr):
            return sub
        if isinstance(sub, Jaxpr):
            return ClosedJaxpr(sub, ())
    return None


def _eval_jaxpr(jaxpr, in_taints: List[frozenset], ctx: _Ctx,
                const_taints=None) -> List[frozenset]:
    """Propagate taint through one (open) jaxpr; returns outvar taints."""
    env: Dict = {}
    consts = list(const_taints) if const_taints is not None \
        else [EMPTY] * len(jaxpr.constvars)
    for v, t in zip(jaxpr.constvars, consts):
        env[v] = t
    for v, t in zip(jaxpr.invars, in_taints):
        env[v] = t

    def read(atom) -> frozenset:
        return EMPTY if _is_literal(atom) else env.get(atom, EMPTY)

    for eqn in jaxpr.eqns:
        outs = _eval_eqn(eqn, [read(a) for a in eqn.invars], ctx)
        for v, t in zip(eqn.outvars, outs):
            env[v] = t
    return [read(v) for v in jaxpr.outvars]


def _eval_eqn(eqn, ins: List[frozenset], ctx: _Ctx) -> List[frozenset]:
    name = eqn.primitive.name
    union = _union(ins)

    if name == "taint_declassify":
        # a registered declassifier's output: taint cleared by decree,
        # with the justification recorded in privacy.DECLASSIFIERS
        return [EMPTY for _ in eqn.outvars]

    if name == "taint_sink":
        snk = eqn.params.get("name", "?")
        if ins and ins[0] and ctx.emit:
            path, line = _eqn_loc(eqn)
            ctx.findings.append(Finding(
                "taint-sink", path, line,
                f"{ctx.target}: sink {snk!r} receives a value tainted "
                f"by {_fmt(ins[0])} with no declassifier on the path"))
        return list(ins)

    if name in _CALLBACK_PRIMS:
        if union and ctx.emit:
            path, line = _eqn_loc(eqn)
            ctx.findings.append(Finding(
                "taint-callback", path, line,
                f"{ctx.target}: {name} operand tainted by "
                f"{_fmt(union)} crosses to the host undeclassified"))
        return [union for _ in eqn.outvars]

    if name == "scan":
        return _eval_scan(eqn, ins, ctx)
    if name == "while":
        return _eval_while(eqn, ins, ctx)
    if name == "cond":
        return _eval_cond(eqn, ins, ctx)
    if name == "pallas_call":
        # conservative: every output may depend on every input (the
        # kernel-contract layer checks launch structure, not dataflow)
        return [union for _ in eqn.outvars]

    sub = _call_jaxpr(eqn.params)
    if sub is not None and len(sub.jaxpr.invars) == len(ins):
        outs = _eval_jaxpr(sub.jaxpr, ins, ctx)
        if len(outs) == len(eqn.outvars):
            return outs
    # structural default: union of inputs flows to every output
    return [union for _ in eqn.outvars]


_FIXPOINT_CAP = 32  # |labels| passes suffice; cap is a safety net


def _eval_scan(eqn, ins, ctx) -> List[frozenset]:
    p = eqn.params
    body = p["jaxpr"].jaxpr
    nc, nk = p["num_consts"], p["num_carry"]
    consts, carry, xs = list(ins[:nc]), list(ins[nc:nc + nk]), \
        list(ins[nc + nk:])
    quiet = ctx.quiet()
    for _ in range(_FIXPOINT_CAP):
        outs = _eval_jaxpr(body, consts + carry + xs, quiet)
        new_carry = [c | o for c, o in zip(carry, outs[:nk])]
        if new_carry == carry:
            break
        carry = new_carry
    outs = _eval_jaxpr(body, consts + carry + xs, ctx)
    return [c | o for c, o in zip(carry, outs[:nk])] + outs[nk:]


def _eval_while(eqn, ins, ctx) -> List[frozenset]:
    p = eqn.params
    cond, body = p["cond_jaxpr"].jaxpr, p["body_jaxpr"].jaxpr
    cn, bn = p["cond_nconsts"], p["body_nconsts"]
    cconsts = list(ins[:cn])
    bconsts = list(ins[cn:cn + bn])
    carry = list(ins[cn + bn:])
    quiet = ctx.quiet()
    for _ in range(_FIXPOINT_CAP):
        outs = _eval_jaxpr(body, bconsts + carry, quiet)
        new_carry = [c | o for c, o in zip(carry, outs)]
        if new_carry == carry:
            break
        carry = new_carry
    outs = _eval_jaxpr(body, bconsts + carry, ctx)
    carry = [c | o for c, o in zip(carry, outs)]
    # implicit flow: the loop's exit condition gates every output
    pred = _union(_eval_jaxpr(cond, cconsts + carry, ctx))
    return [c | pred for c in carry]


def _eval_cond(eqn, ins, ctx) -> List[frozenset]:
    branches = eqn.params["branches"]
    pred, ops = ins[0], ins[1:]
    per_branch = []
    for br in branches:
        if len(br.jaxpr.invars) == len(ops):
            per_branch.append(_eval_jaxpr(br.jaxpr, list(ops), ctx))
        else:  # arity surprise: fall back to full union
            per_branch.append([_union(ops)] * len(eqn.outvars))
    n_out = len(eqn.outvars)
    # branch union + predicate taint (implicit flow through selection)
    return [_union([b[i] for b in per_branch if i < len(b)]) | pred
            for i in range(n_out)]


# ---------------------------------------------------------------------------
# analysis targets
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TaintTarget:
    """One jaxpr to verify. `build()` -> (fn, args, labels): `fn` is
    traced as jax.make_jaxpr(fn)(*args); `labels` mirrors the pytree
    structure of `args` with a source-label string per leaf ("" =
    untainted) — build label trees with jax.tree.map over the args so
    the flattenings line up."""
    name: str
    build: Callable


# name -> target; populated by fixture modules at import time
TARGETS: Dict[str, TaintTarget] = {}


def taint_target(*, name: str, build: Callable) -> TaintTarget:
    """Register an analysis target (the fixture-module hook, mirroring
    `registry.kernel_contract`)."""
    t = TaintTarget(name=name, build=build)
    TARGETS[name] = t
    return t


class capture_targets:
    """Context manager: record targets registered while active (used to
    check fixture modules in isolation from head_targets)."""

    def __enter__(self) -> List[TaintTarget]:
        self._before = set(TARGETS)
        self._new: List[TaintTarget] = []
        return self._new

    def __exit__(self, *exc):
        for k in set(TARGETS) - self._before:
            self._new.append(TARGETS.pop(k))
        return False


def check_target(target: TaintTarget) -> List[Finding]:
    """Trace one target under the marker scope and run the engine."""
    import jax

    findings: List[Finding] = []
    try:
        fn, args, labels = target.build()
        with privacy.tracing():
            closed = jax.make_jaxpr(fn)(*args)
        label_leaves = jax.tree_util.tree_leaves(labels)
        in_taints = [frozenset([lab]) if lab else EMPTY
                     for lab in label_leaves]
        if len(in_taints) != len(closed.jaxpr.invars):
            return [Finding(
                "taint-trace-error", "<taint>", 0,
                f"{target.name}: {len(in_taints)} source labels for "
                f"{len(closed.jaxpr.invars)} jaxpr invars — the label "
                f"tree must mirror the args tree")]
        ctx = _Ctx(target=target.name, findings=findings)
        _eval_jaxpr(closed.jaxpr, in_taints, ctx)
    except Exception as e:  # noqa: BLE001 — any trace failure is a finding
        return [Finding(
            "taint-trace-error", "<taint>", 0,
            f"{target.name}: {type(e).__name__}: {e}")]
    return findings


def check_targets(targets=None) -> List[Finding]:
    targets = head_targets() if targets is None else targets
    out: List[Finding] = []
    for t in targets:
        out.extend(check_target(t))
    return out


# ---------------------------------------------------------------------------
# HEAD targets: the protocol surface, over a tiny oracle-backend fixture
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=1)
def _tiny():
    """4-client MLP federation on the oracle backends: the smallest
    shapes that exercise every protocol path (N=2 neighbors, 1 local
    step) while keeping each make_jaxpr trace sub-second."""
    import functools as ft

    import jax
    import jax.numpy as jnp

    from repro.configs.paper_models import ClientModelConfig, FedConfig
    from repro.core import protocol
    from repro.models import apply_client_model, init_client_model
    from repro.optim import adam

    m, d, classes, n_loc, n_ref = 4, 8, 3, 8, 4
    mcfg = ClientModelConfig("taint-mlp", "mlp", (d,), classes,
                             hidden=(8,))
    fed = FedConfig(num_clients=m, num_neighbors=2, top_k=1,
                    local_steps=1, local_batch=4, lsh_bits=32, lr=1e-2,
                    selection_backend="oracle",
                    exchange_backend="oracle")
    apply_fn = ft.partial(apply_client_model, mcfg)

    def init_fn(k):
        return init_client_model(mcfg, k)

    opt = adam(fed.lr)
    state = protocol.init_state(apply_fn, init_fn, opt, fed,
                                jax.random.PRNGKey(0))
    data = {
        "x_train": jnp.zeros((m, n_loc, d), jnp.float32),
        "y_train": jnp.zeros((m, n_loc), jnp.int32),
        "x_ref": jnp.zeros((m, n_ref, d), jnp.float32),
        "y_ref": jnp.zeros((m, n_ref), jnp.int32),
        "x_test": jnp.zeros((m, n_loc, d), jnp.float32),
        "y_test": jnp.zeros((m, n_loc), jnp.int32),
    }
    return {"fed": fed, "apply_fn": apply_fn, "init_fn": init_fn,
            "opt": opt, "state": state, "data": data, "m": m, "d": d}


def _fed_labels(state):
    """FedState label tree: params/opt_state private, published fields
    (codes, rankings, commitments — last round's declassified
    announcements) and rng/round untainted."""
    import jax
    lab = jax.tree.map(lambda _: "", state)
    return lab._replace(
        params=jax.tree.map(lambda _: SRC_PARAMS, state.params),
        opt_state=jax.tree.map(lambda _: SRC_OPT, state.opt_state))


def _data_labels(data):
    import jax
    return jax.tree.map(lambda _: SRC_DATA, data)


def _head_target_builders():
    """name -> build() pairs for every protocol surface the verifier
    proves clean (one entry per (fn, args, labels) trace)."""
    t = _tiny()
    fed, apply_fn, opt = t["fed"], t["apply_fn"], t["opt"]
    state, data = t["state"], t["data"]

    from repro.core import adversary, baselines, protocol
    from repro.core.rounds import make_segment_fn
    from repro.service import driver as svc_driver
    from repro.service import serving
    from repro.service.membership import ServiceConfig, init_service_state

    sd = (state, data)
    sd_labels = (_fed_labels(state), _data_labels(data))

    def _phase_select():
        return (lambda st: protocol.select_phase(st, fed),
                (state,), (_fed_labels(state),))

    def _phase_exchange():
        def fn(st, d):
            sel = protocol.select_phase(st, fed)
            return protocol.exchange_phase(apply_fn, fed, st.params, d,
                                           sel)
        return fn, sd, sd_labels

    def _phase_update():
        def fn(st, d):
            import jax
            sel = protocol.select_phase(st, fed)
            exch = protocol.exchange_phase(apply_fn, fed, st.params, d,
                                           sel)
            return protocol.update_phase(apply_fn, opt, fed, st.params,
                                         st.opt_state, d, exch,
                                         jax.random.PRNGKey(1))
        return fn, sd, sd_labels

    def _phase_announce():
        def fn(st, d):
            sel = protocol.select_phase(st, fed)
            exch = protocol.exchange_phase(apply_fn, fed, st.params, d,
                                           sel)
            return protocol.announce_phase(fed, st.params, sel, exch,
                                           st.round)
        return fn, sd, sd_labels

    wpfed = protocol.wpfed_program(apply_fn, opt, fed)

    def _wpfed_global():
        return wpfed.global_round, sd, sd_labels

    def _wpfed_gossip():
        def fn(st, d):
            sel = protocol.select_phase(st, fed)
            return wpfed.gossip_round(st, d, sel)
        return fn, sd, sd_labels

    def _wpfed_segment_tap():
        seg = make_segment_fn(wpfed, 3, metrics_tap=lambda s: None)
        return seg, sd, sd_labels

    def _instrumented_global():
        tm = adversary.resolve_threat(
            "lsh_cheat", num_clients=t["m"], attacker_frac=0.25,
            init_fn=t["init_fn"], start_round=0, target_id=0)
        inst = adversary.instrument_program(wpfed, tm)
        seg = make_segment_fn(inst, 2, metrics_tap=lambda s: None)
        return seg, sd, sd_labels

    def _baseline(name):
        def build():
            import jax.numpy as jnp
            kwargs = {}
            if name == "fedmd":
                kwargs["shared_ref_x"] = jnp.zeros(
                    data["x_ref"].shape[1:], data["x_ref"].dtype)
            prog = baselines.BASELINE_PROGRAMS[name](apply_fn, opt, fed,
                                                     **kwargs)
            return prog.global_round, sd, sd_labels
        return build

    svc = ServiceConfig(reselect_every=2)
    svc_prog = svc_driver.service_program(apply_fn, opt, fed, svc)
    svc_state = init_service_state(state, svc)
    ssd = (svc_state, data)
    ssd_labels = (svc_state._replace(
        fed=_fed_labels(state),
        active="", code_age="", gossip_count="", period_start=""),
        _data_labels(data))

    def _service_global():
        return svc_prog.global_round, ssd, ssd_labels

    def _service_segment_tap():
        seg = make_segment_fn(svc_prog, 2, metrics_tap=lambda s: None)
        return seg, ssd, ssd_labels

    def _service_degraded():
        # a DEGRADED round (DESIGN.md §15): stragglers masked inactive
        # mid-service and a stale re-joiner with nonzero code_age —
        # the disclosure boundary must hold on the faulted path too
        # (the -inf masking / staleness discount are extra dataflow
        # through the Eq. 8 scores into the ledger-publish sink)
        import jax.numpy as jnp
        from repro.service.membership import mask_stragglers
        degraded = mask_stragglers(
            svc_state._replace(
                code_age=jnp.arange(t["m"], dtype=jnp.int32)),
            jnp.arange(t["m"]) == 1)
        return svc_prog.global_round, (degraded, data), ssd_labels

    def _serving_forward():
        import jax
        import jax.numpy as jnp
        ids = jnp.zeros((2,), jnp.int32)
        x = jnp.zeros((2, t["d"]), jnp.float32)
        return (functools.partial(serving._forward_fn, apply_fn),
                (state.params, ids, x),
                (jax.tree.map(lambda _: SRC_PARAMS, state.params),
                 "", ""))

    return [
        ("phase-select", _phase_select),
        ("phase-exchange", _phase_exchange),
        ("phase-update", _phase_update),
        ("phase-announce", _phase_announce),
        ("wpfed-global-round", _wpfed_global),
        ("wpfed-gossip-round", _wpfed_gossip),
        ("wpfed-segment-tapped", _wpfed_segment_tap),
        ("wpfed-instrumented-segment", _instrumented_global),
        ("baseline-silo", _baseline("silo")),
        ("baseline-fedmd", _baseline("fedmd")),
        ("baseline-proxyfl", _baseline("proxyfl")),
        ("baseline-kdpdfl", _baseline("kdpdfl")),
        ("service-global-round", _service_global),
        ("service-segment-tapped", _service_segment_tap),
        ("service-degraded-round", _service_degraded),
        ("serving-forward", _serving_forward),
    ]


def head_targets() -> List[TaintTarget]:
    return [TaintTarget(name=name, build=build)
            for name, build in _head_target_builders()]
