"""Declassifier + sink registries for the privacy-taint verifier
(DESIGN.md §14).

The paper's trust-free claim is a *dataflow* property: the only values
that ever leave a client are LSH codes (Eq. 5-6), rank reveals and
scores (Eq. 7), commitments (Eq. 9-10), and logits on the exchanged
reference set — never raw parameters, optimizer state, or private
batches. `repro.analysis.taint` proves that property over the actual
jaxprs; this module is the annotation surface the protocol code uses
to declare it:

  * `@declassifier(...)` marks a function whose OUTPUT is deemed
    releasable, with the paper equation it implements and a recorded
    justification. At runtime the wrapper is a passthrough (zero graph
    overhead); while the analyzer traces (`tracing()` active) it binds
    a `taint_declassify` marker primitive on each output leaf, which
    the propagation engine clears.
  * `sink(name, value)` marks a disclosure point — a value that is
    about to cross the trust boundary (announcement fields the host
    ledger publishes, metric taps, serving responses). Passthrough at
    runtime; under `tracing()` it binds a `taint_sink` marker, and the
    engine reports a `taint-sink` finding whenever a tainted value
    reaches one.

The registries mirror `registry.kernel_contract`: populated at import
time of the protocol modules, inspected by the checker, restorable in
isolation for fixtures (`capture_declassifiers`). Like `registry`,
this module is import-light on purpose (stdlib only at module level):
`core.chain` / `core.lsh` / `core.rounds` import it at import time, so
it must not pull in jax or any `repro` sibling. The marker primitives
themselves live in `repro.analysis.taint` and are imported lazily,
only while the analyzer is tracing.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Callable, Dict, List

# sink name -> what crosses the trust boundary there (the static table
# `sink()` validates against; DESIGN.md §14 documents each row)
SINKS: Dict[str, str] = {
    "chain-announcement": "Announcement fields (codes, rankings, "
                          "commitments) consumed by Blockchain."
                          "publish_round and the §3.6 reveals",
    "ledger-publish": "the merged per-period state fields the service "
                      "publisher reads onto the host ledger and the "
                      "checkpointed chain JSON",
    "metrics-tap": "per-round scalar metrics streamed to the host "
                   "through the ordered io_callback tap",
    "serving-response": "logits returned to a client by the "
                        "PersonalizedServer forward",
}

# declassifier name -> entry; populated at protocol-module import time
DECLASSIFIERS: Dict[str, "DeclassifierEntry"] = {}

# analyzer-tracing flag: list-wrapped so `tracing()` mutates in place
_ACTIVE = [False]


@dataclasses.dataclass(frozen=True)
class DeclassifierEntry:
    name: str
    module: str
    qualname: str
    paper_eq: str        # the equation/section whose disclosure this is
    justification: str   # why releasing this value is trust-free


def declassifier(*, name: str, paper_eq: str, justification: str):
    """Register `fn` as a declassifier; its output is releasable.

    The wrapper returns `fn`'s output unchanged at runtime. While the
    taint analyzer traces, every output leaf is tagged with the
    `taint_declassify` marker so the dataflow engine clears its taint
    (recording which declassifier cleared it)."""
    if not justification.strip():
        raise ValueError(f"declassifier({name!r}) needs a justification")

    def deco(fn: Callable) -> Callable:
        if name in DECLASSIFIERS and \
                DECLASSIFIERS[name].qualname != fn.__qualname__:
            raise ValueError(f"declassifier name {name!r} already "
                             f"registered by "
                             f"{DECLASSIFIERS[name].qualname}")
        DECLASSIFIERS[name] = DeclassifierEntry(
            name=name, module=fn.__module__, qualname=fn.__qualname__,
            paper_eq=paper_eq, justification=justification)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            out = fn(*args, **kwargs)
            if _ACTIVE[0]:
                from repro.analysis.taint import declassify_value
                return declassify_value(out, name)
            return out

        return wrapper

    return deco


def sink(name: str, value):
    """Mark `value` as reaching the disclosure sink `name`.

    Always validates the sink name against the static SINKS table (a
    typo'd sink would otherwise silently skip verification); binds the
    `taint_sink` marker only while the analyzer traces."""
    if name not in SINKS:
        raise ValueError(f"unknown sink: {name!r} "
                         f"(expected one of {tuple(sorted(SINKS))})")
    if _ACTIVE[0]:
        from repro.analysis.taint import sink_value
        return sink_value(value, name)
    return value


@contextlib.contextmanager
def tracing():
    """Analyzer-tracing scope: declassifier/sink markers bind inside.

    JAX caches traces by (function identity, avals) — invisible to the
    `_ACTIVE` flag — so a declassifier traced before the scope would
    keep serving its marker-FREE jaxpr inside it (and marker-laden
    jaxprs would leak out to runtime after). Both directions are fixed
    by dropping the caches at each outermost transition."""
    prev = _ACTIVE[0]
    _ACTIVE[0] = True
    try:
        if not prev:
            import jax
            jax.clear_caches()
        yield
    finally:
        _ACTIVE[0] = prev
        if not prev:
            import jax
            jax.clear_caches()


class capture_declassifiers:
    """Context manager: record declassifiers registered while active
    (fixture isolation, mirroring `registry.capture_registrations`)."""

    def __enter__(self) -> List[DeclassifierEntry]:
        self._before = set(DECLASSIFIERS)
        self._new: List[DeclassifierEntry] = []
        return self._new

    def __exit__(self, *exc):
        for k in set(DECLASSIFIERS) - self._before:
            self._new.append(DECLASSIFIERS.pop(k))
        return False
