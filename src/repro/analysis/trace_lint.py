"""Layer 2: AST trace-safety lint over `core/`, `kernels/`, `launch/`.

Flags the statically-detectable trace bugs this repo has actually hit
(DESIGN.md §12):

  * `traced-host-cast` — `int()` / `float()` / `.item()` / `np.*` on a
    value reachable from traced arguments inside a TRACED CONTEXT: a
    `jax.jit`-decorated function, a Pallas kernel body (first argument
    of a `pl.pallas_call`), or a function/lambda passed to `lax.scan`
    / `lax.cond` / `lax.while_loop` / `lax.fori_loop` / `lax.switch`.
    Keyword-only kernel-body params (bound via functools.partial) and
    `static_argnames` of jitted functions are static, not traced.
  * `host-if` — a Python `if` whose test references a traced value
    inside a traced context (PR 4's poison_step bug class: silently
    freezes the branch at trace time or crashes under scan).
  * `unseeded-key` — `jax.random.PRNGKey(<constant>)` (or
    `jax.random.key`) inside a traced context: the key is identical
    every round, so "random" behavior is round-independent (PR 1's
    dead-seed bug class).
  * `host-sync` — outside traced contexts, host extraction of values
    derived from function parameters: `.item()`, `np.*(derived)`, and
    `int()/float()` on non-trivial derived expressions (subscripts /
    calls — bare config-scalar names are not flagged, nor are
    `.shape`/`.ndim`/`.size`/`len()` accesses, which are host-static).
    Genuine host paths (telemetry, post-`block_until_ready` metric
    extraction, the host-side chain ledger) carry an explicit
    `# analysis: host-ok <why>` exemption on the finding line, the
    line above, or trailing the enclosing `def` line (function-wide).

The lint is intra-procedural by design: taint starts at the context's
traced params and propagates through assignments/loops syntactically.
Helpers called WITH traced values are not followed — the registry's
kernel-contract layer covers kernels, and keeping the lint local keeps
its findings explainable (every finding names the tainted name chain's
function).
"""
from __future__ import annotations

import ast
import io
import os
import tokenize
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.report import Finding

HOST_OK_MARK = "analysis: host-ok"

# attributes whose access yields host-static metadata, not device data
_STATIC_ATTRS = {"shape", "ndim", "size", "dtype"}
_STATIC_CALLS = {"len", "range", "isinstance", "getattr", "type"}
_LAX_CONSUMERS = {"scan", "cond", "while_loop", "fori_loop", "switch",
                  "map", "associative_scan"}


def _dotted(node) -> Optional[str]:
    """`jax.lax.scan` -> "jax.lax.scan"; None for non-name chains."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _ModuleIndex:
    """Per-file context: defs by name, import aliases, comments."""

    def __init__(self, tree: ast.Module, src: str):
        self.defs: Dict[str, ast.FunctionDef] = {}
        self.np_aliases: Set[str] = set()
        self.exempt_lines: Set[int] = set()
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.defs[node.name] = node
            elif isinstance(node, ast.Import):
                for a in node.names:
                    if a.name in ("numpy", "numpy.typing"):
                        self.np_aliases.add(a.asname or "numpy")
            elif isinstance(node, ast.ImportFrom):
                if node.module == "numpy":
                    continue  # from numpy import X — rare, skip
        try:
            for tok in tokenize.generate_tokens(io.StringIO(src).readline):
                if tok.type == tokenize.COMMENT and \
                        HOST_OK_MARK in tok.string:
                    self.exempt_lines.add(tok.start[0])
        except tokenize.TokenError:
            pass


# ---------------------------------------------------------------------------
# traced-context discovery
# ---------------------------------------------------------------------------
def _jit_decorator_statics(dec) -> Optional[Tuple[bool, Set[str]]]:
    """(is_jit, static_argnames) if `dec` is a jit decorator."""
    d = _dotted(dec)
    if d in ("jax.jit", "jit"):
        return True, set()
    if isinstance(dec, ast.Call):
        f = _dotted(dec.func)
        statics: Set[str] = set()

        def collect(kwlist):
            for kw in kwlist:
                if kw.arg == "static_argnames":
                    v = kw.value
                    if isinstance(v, ast.Constant) and \
                            isinstance(v.value, str):
                        statics.add(v.value)
                    elif isinstance(v, (ast.Tuple, ast.List)):
                        for e in v.elts:
                            if isinstance(e, ast.Constant):
                                statics.add(str(e.value))

        if f in ("jax.jit", "jit"):
            collect(dec.keywords)
            return True, statics
        if f in ("functools.partial", "partial") and dec.args and \
                _dotted(dec.args[0]) in ("jax.jit", "jit"):
            collect(dec.keywords)
            return True, statics
    return None


def _first_arg_def_name(call: ast.Call) -> Optional[str]:
    """Kernel body name from `pallas_call(f, ...)` or
    `pallas_call(functools.partial(f, ...), ...)`."""
    if not call.args:
        return None
    a = call.args[0]
    if isinstance(a, ast.Name):
        return a.id
    if isinstance(a, ast.Call) and \
            _dotted(a.func) in ("functools.partial", "partial") and \
            a.args and isinstance(a.args[0], ast.Name):
        return a.args[0].id
    return None


def _find_traced_contexts(tree: ast.Module, idx: _ModuleIndex):
    """-> list of (node, kind, traced_params). node is FunctionDef or
    Lambda; kind in {"jit", "kernel", "lax"}."""
    contexts = []
    seen = set()

    def add(node, kind, traced):
        if node is not None and id(node) not in seen:
            seen.add(id(node))
            contexts.append((node, kind, traced))

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                jit = _jit_decorator_statics(dec)
                if jit is not None:
                    _is, statics = jit
                    params = _param_names(node.args)
                    add(node, "jit",
                        {p for p in params if p not in statics})
                    break
        elif isinstance(node, ast.Call):
            f = _dotted(node.func) or ""
            if f.endswith("pallas_call") or f == "pallas_call":
                name = _first_arg_def_name(node)
                body = idx.defs.get(name) if name else None
                if body is not None:
                    # positional refs are traced; kw-only params are
                    # functools.partial-bound statics
                    pos = [a.arg for a in body.args.posonlyargs
                           + body.args.args]
                    add(body, "kernel", set(pos))
            else:
                tail = f.rsplit(".", 1)[-1]
                base = f.rsplit(".", 1)[0] if "." in f else ""
                if tail in _LAX_CONSUMERS and (
                        base.endswith("lax") or base in ("jax", "")):
                    if base == "" and tail in ("map",):
                        continue  # bare map() is the builtin
                    for a in node.args:
                        if isinstance(a, ast.Name) and a.id in idx.defs:
                            body = idx.defs[a.id]
                            add(body, "lax",
                                set(_param_names(body.args)))
                        elif isinstance(a, ast.Lambda):
                            add(a, "lax", set(_param_names(a.args)))
    return contexts


def _param_names(args: ast.arguments) -> List[str]:
    names = [a.arg for a in args.posonlyargs + args.args + args.kwonlyargs]
    if args.vararg:
        names.append(args.vararg.arg)
    if args.kwarg:
        names.append(args.kwarg.arg)
    return names


# ---------------------------------------------------------------------------
# taint
# ---------------------------------------------------------------------------
def _refs_tainted(node, tainted: Set[str]) -> bool:
    """Does `node` reference a tainted name, ignoring host-static
    accessor subtrees (`x.shape`, `len(x)`, ...)?"""
    if isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS:
        return False
    if isinstance(node, ast.Call):
        f = _dotted(node.func)
        if f in _STATIC_CALLS:
            return False
    if isinstance(node, ast.Name):
        return node.id in tainted
    for child in ast.iter_child_nodes(node):
        if _refs_tainted(child, tainted):
            return True
    return False


def _target_names(t) -> List[str]:
    if isinstance(t, ast.Name):
        return [t.id]
    if isinstance(t, ast.Starred):
        return _target_names(t.value)
    if isinstance(t, (ast.Tuple, ast.List)):
        out = []
        for e in t.elts:
            out.extend(_target_names(e))
        return out
    if isinstance(t, (ast.Subscript, ast.Attribute)):
        return _target_names(t.value)
    return []


def _propagate_taint(fn_node, tainted: Set[str]) -> Set[str]:
    """Fixed-point syntactic taint through assignments/loops."""
    tainted = set(tainted)
    for _ in range(8):
        changed = False

        def mark(names):
            nonlocal changed
            for n in names:
                if n not in tainted:
                    tainted.add(n)
                    changed = True

        for node in ast.walk(fn_node):
            if isinstance(node, ast.Assign):
                if _refs_tainted(node.value, tainted):
                    for t in node.targets:
                        mark(_target_names(t))
            elif isinstance(node, ast.AugAssign):
                if _refs_tainted(node.value, tainted):
                    mark(_target_names(node.target))
            elif isinstance(node, ast.AnnAssign) and node.value:
                if _refs_tainted(node.value, tainted):
                    mark(_target_names(node.target))
            elif isinstance(node, ast.NamedExpr):
                if _refs_tainted(node.value, tainted):
                    mark(_target_names(node.target))
            elif isinstance(node, ast.For):
                if _refs_tainted(node.iter, tainted):
                    mark(_target_names(node.target))
            elif isinstance(node, ast.comprehension):
                if _refs_tainted(node.iter, tainted):
                    mark(_target_names(node.target))
            elif isinstance(node, ast.withitem) and node.optional_vars:
                if _refs_tainted(node.context_expr, tainted):
                    mark(_target_names(node.optional_vars))
        if not changed:
            break
    return tainted


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------
def _body_nodes(fn_node):
    if isinstance(fn_node, ast.Lambda):
        yield from ast.walk(fn_node.body)
        return
    for stmt in fn_node.body:
        yield from ast.walk(stmt)


def _is_np_call(node: ast.Call, np_aliases: Set[str]) -> bool:
    f = _dotted(node.func)
    return bool(f) and "." in f and f.split(".", 1)[0] in np_aliases


def _is_prng_const(node: ast.Call) -> bool:
    f = _dotted(node.func) or ""
    if not (f.endswith(".random.PRNGKey") or f.endswith(".random.key")
            or f == "PRNGKey"):
        return False
    return bool(node.args) and all(
        isinstance(a, ast.Constant) for a in node.args)


def _check_traced_context(fn_node, kind: str, traced: Set[str],
                          idx: _ModuleIndex, path: str) -> List[Finding]:
    out: List[Finding] = []
    tainted = _propagate_taint(fn_node, traced)
    ctx = getattr(fn_node, "name", "<lambda>")
    for node in _body_nodes(fn_node):
        if isinstance(node, ast.Call):
            f = _dotted(node.func)
            if f in ("int", "float") and any(
                    _refs_tainted(a, tainted) for a in node.args):
                out.append(Finding(
                    "traced-host-cast", path, node.lineno,
                    f"{f}() on a traced value inside {kind} context "
                    f"{ctx!r}"))
            elif isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "item" and not node.args and \
                    _refs_tainted(node.func.value, tainted):
                out.append(Finding(
                    "traced-host-cast", path, node.lineno,
                    f".item() on a traced value inside {kind} context "
                    f"{ctx!r}"))
            elif _is_np_call(node, idx.np_aliases) and any(
                    _refs_tainted(a, tainted) for a in node.args):
                out.append(Finding(
                    "traced-host-cast", path, node.lineno,
                    f"numpy call {_dotted(node.func)}() on a traced "
                    f"value inside {kind} context {ctx!r}"))
            elif _is_prng_const(node):
                out.append(Finding(
                    "unseeded-key", path, node.lineno,
                    f"constant PRNG key inside {kind} context {ctx!r} "
                    f"— the key never varies with the round"))
        elif isinstance(node, ast.If) and \
                _refs_tainted(node.test, tainted):
            out.append(Finding(
                "host-if", path, node.lineno,
                f"Python `if` on a traced value inside {kind} context "
                f"{ctx!r} (use lax.cond / jnp.where)"))
    return out


def _check_host_function(fn_node, idx: _ModuleIndex,
                         path: str) -> List[Finding]:
    out: List[Finding] = []
    tainted = _propagate_taint(
        fn_node, set(_param_names(fn_node.args)))
    ctx = fn_node.name
    for node in _body_nodes(fn_node):
        if not isinstance(node, ast.Call):
            continue
        f = _dotted(node.func)
        if f in ("int", "float"):
            for a in node.args[:1]:
                if not isinstance(a, (ast.Subscript, ast.Call,
                                      ast.Attribute)):
                    # bare names / arithmetic on them is config math;
                    # syncs look like extractions: x[i], d.get(k), x.v
                    continue
                if _refs_tainted(a, tainted):
                    out.append(Finding(
                        "host-sync", path, node.lineno,
                        f"{f}() forces a device sync on a derived "
                        f"value in {ctx!r}"))
        elif isinstance(node.func, ast.Attribute) and \
                node.func.attr == "item" and not node.args and \
                _refs_tainted(node.func.value, tainted):
            out.append(Finding(
                "host-sync", path, node.lineno,
                f".item() forces a device sync in {ctx!r}"))
        elif _is_np_call(node, idx.np_aliases) and any(
                _refs_tainted(a, tainted) for a in node.args):
            out.append(Finding(
                "host-sync", path, node.lineno,
                f"{f}() pulls a derived value to host in {ctx!r}"))
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
def lint_source(src: str, path: str) -> List[Finding]:
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Finding("host-sync", path, e.lineno or 1,
                        f"syntax error: {e.msg}")]
    idx = _ModuleIndex(tree, src)
    contexts = _find_traced_contexts(tree, idx)
    traced_ids = {id(n) for n, _, _ in contexts}

    findings: List[Finding] = []
    fn_spans: List[Tuple[int, int]] = []
    for node, kind, traced in contexts:
        findings.extend(_check_traced_context(node, kind, traced,
                                              idx, path))

    def inside_traced(node) -> bool:
        return id(node) in traced_ids

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and not inside_traced(node):
            # nested defs inside traced contexts are covered above;
            # nested host helpers get their own pass (params tainted)
            findings.extend(_check_host_function(node, idx, path))
            fn_spans.append((node.lineno,
                             getattr(node, "end_lineno", node.lineno)))

    # host-ok exemptions: marker on the line, the line above, or the
    # def line of the enclosing function
    def_lines = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.lineno in idx.exempt_lines:
            def_lines.add((node.lineno,
                           getattr(node, "end_lineno", node.lineno)))

    def exempt(f: Finding) -> bool:
        if f.line in idx.exempt_lines or (f.line - 1) in idx.exempt_lines:
            return True
        return any(lo <= f.line <= hi for lo, hi in def_lines)

    # de-dup (a call can match in both a traced context and its
    # enclosing host pass walk) and drop exempted findings
    uniq = {}
    for f in findings:
        if not exempt(f):
            uniq.setdefault((f.rule, f.path, f.line, f.message), f)
    return sorted(uniq.values(), key=lambda f: (f.path, f.line, f.rule))


def lint_file(path: str) -> List[Finding]:
    with open(path, "r", encoding="utf-8") as fh:
        return lint_source(fh.read(), path)


def _walk_py(paths):
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = [d for d in dirs if d != "__pycache__"]
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)
        elif p.endswith(".py"):
            yield p


def lint_paths(paths) -> List[Finding]:
    out: List[Finding] = []
    for path in _walk_py(paths):
        out.extend(lint_file(path))
    return out


def collect_host_ok(paths) -> List[Tuple[str, int, str]]:
    """The `# analysis: host-ok` exemption INVENTORY over `paths`:
    [(path, line, justification-comment)], sorted. The CLI publishes it
    in the JSON report and `analysis/exemptions.py` pins the count, so
    a new host escape is a deliberate, reviewed change rather than a
    silent comment (ISSUE 9 satellite)."""
    out: List[Tuple[str, int, str]] = []
    for path in _walk_py(paths):
        try:
            with open(path, "r", encoding="utf-8") as fh:
                src = fh.read()
        except OSError:
            continue
        try:
            for tok in tokenize.generate_tokens(
                    io.StringIO(src).readline):
                if tok.type == tokenize.COMMENT and \
                        HOST_OK_MARK in tok.string:
                    out.append((path, tok.start[0],
                                tok.string.lstrip("# ").strip()))
        except tokenize.TokenError:
            continue
    return sorted(out)
