"""Pinned `# analysis: host-ok` exemption inventory (DESIGN.md §14).

The trace-safety lint lets a genuine host path escape with an
`# analysis: host-ok <why>` comment. That is the right local mechanism
— but silently accumulating exemptions would erode the gate one
innocent-looking comment at a time. So the COUNT is pinned here: the
CLI's default run collects the full inventory
(`trace_lint.collect_host_ok` over the default lint dirs), publishes
every site in the JSON report (`host_ok.sites`), and emits a
`host-ok-drift` warning-severity finding when the count moves — strict
mode (the CI gate) fails on it, a plain run only reports it.

Adding or removing a host-ok comment is therefore a two-line change by
design: the comment itself (with its justification) AND this pin. The
diff makes the new host escape visible to review instead of burying it
in a comment.
"""
from __future__ import annotations

# number of `# analysis: host-ok` comments under the default lint dirs
# (src/repro/{core,kernels,launch,service,train,checkpoint}); PR 10
# added 11: the fault layer (core/faults.py — deterministic verdicts,
# counters, CLI spec parsing), the bulletin-board transport
# (service/transport.py — the device->host announcement boundary), and
# the crash-safe resume path (driver min_round pull, chain.head_round,
# store.steps filename parsing)
EXPECTED_HOST_OK = 39
