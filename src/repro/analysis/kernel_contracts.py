"""Layer 1: abstract interpretation of Pallas launch contracts.

For every registered kernel (see `registry.kernel_contract`) and every
representative shape point, this module captures the actual
`pl.pallas_call` parameters (grid, BlockSpecs, out_shape, scratch) and
verifies the contracts a machine can check (DESIGN.md §12):

  (a) output-tile coverage — enumerating the grid and evaluating each
      output BlockSpec's index map (a plain Python function of the
      grid indices) must tile every output array with no gaps, no
      out-of-bounds blocks, and no two grid points writing the same
      block except along axes the entry DECLARES as revisit
      (accumulation) axes. Input revisits ("the lsh seed") are always
      legal and never checked.
  (b) block/arity consistency — BlockSpec rank and divisibility
      against the actual operands, out_specs against out_shape, and
      the kernel body's positional signature against
      n_inputs + n_outputs + n_scratch.
  (c) estimator truthfulness — the VMEM bytes implied by the captured
      block shapes (blocks + the entry's declared intermediate model)
      must match the estimator registered in
      `core.backends.VMEM_ESTIMATORS` within the declared slack, so
      `resolve_tiling("auto")` can never silently drift from the
      kernels it budgets for (§10's drift bug class).

Also checked per entry: the declared oracle twin exists in
`kernels/ref.py`, the declared estimator is registered, and the number
of captured sites matches the declaration.
"""
from __future__ import annotations

import ast
import functools
import inspect
import itertools
import math
import os
from typing import Dict, List, Optional

from repro.analysis.registry import (REGISTRY, CapturedSite, KernelEntry,
                                     capture_sites, unjitted)
from repro.analysis.report import Finding

# kernel modules whose import populates REGISTRY
KERNEL_MODULES = (
    "repro.kernels.lsh_projection",
    "repro.kernels.hamming",
    "repro.kernels.selection",
    "repro.kernels.exchange",
    "repro.kernels.flash_attention",
)


def head_entries() -> List[KernelEntry]:
    import importlib
    for mod in KERNEL_MODULES:
        importlib.import_module(mod)
    return [REGISTRY[k] for k in sorted(REGISTRY)]


def _entry_loc(entry: KernelEntry):
    fn = unjitted(entry.fn)
    code = getattr(fn, "__code__", None)
    if code is None:
        return entry.module, 1
    return code.co_filename, code.co_firstlineno


def _itemsize(dtype) -> int:
    import numpy as np
    return np.dtype(dtype).itemsize


def _block_bytes(block_shape, dtype) -> int:
    return math.prod(int(b) for b in block_shape) * _itemsize(dtype)


def _scratch_bytes(s) -> int:
    shape = getattr(s, "shape", None)
    dtype = getattr(s, "dtype", None)
    if shape is None or dtype is None:
        return 0
    return _block_bytes(shape, dtype)


def _kernel_positional_arity(kernel_fn) -> Optional[int]:
    """Positional parameter count of the (possibly functools.partial-
    bound) kernel body — partial-bound keywords are keyword-only in
    the underlying def, so counting positional kinds is exact."""
    fn = kernel_fn
    while isinstance(fn, functools.partial):
        fn = fn.func
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return None
    return sum(1 for p in sig.parameters.values()
               if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD))


def _check_site_blocks(entry: KernelEntry, site: CapturedSite,
                       path: str, line: int) -> List[Finding]:
    """(b) block/arity consistency for one captured launch."""
    out: List[Finding] = []

    def bad(msg):
        out.append(Finding("block-mismatch", path, line,
                           f"{entry.name}: {msg}"))

    arity = _kernel_positional_arity(site.kernel_fn)
    expected = (len(site.in_specs) + len(site.out_specs)
                + len(site.scratch_shapes))
    if arity is not None and arity != expected:
        bad(f"kernel body takes {arity} positional refs but the launch "
            f"binds {len(site.in_specs)} inputs + {len(site.out_specs)} "
            f"outputs + {len(site.scratch_shapes)} scratch = {expected}")

    if len(site.in_specs) != len(site.operands):
        bad(f"{len(site.in_specs)} in_specs for "
            f"{len(site.operands)} operands")
    for k, (spec, op) in enumerate(zip(site.in_specs, site.operands)):
        bs = tuple(spec.block_shape)
        if len(bs) != len(op.shape):
            bad(f"in_specs[{k}] block rank {len(bs)} != operand rank "
                f"{len(op.shape)} (block {bs}, operand {op.shape})")
            continue
        for d, (b, s) in enumerate(zip(bs, op.shape)):
            if b is None:
                continue
            if b > s or s % b != 0:
                bad(f"in_specs[{k}] block {bs} does not evenly tile "
                    f"operand {tuple(op.shape)} (dim {d})")
                break

    if len(site.out_specs) != len(site.out_shapes):
        bad(f"{len(site.out_specs)} out_specs for "
            f"{len(site.out_shapes)} out_shapes")
    for k, (spec, os) in enumerate(zip(site.out_specs, site.out_shapes)):
        bs = tuple(spec.block_shape)
        if len(bs) != len(os.shape):
            bad(f"out_specs[{k}] block rank {len(bs)} != out_shape rank "
                f"{len(os.shape)} (block {bs}, out {tuple(os.shape)})")
            continue
        for d, (b, s) in enumerate(zip(bs, os.shape)):
            if b is None:
                continue
            if b > s or s % b != 0:
                bad(f"out_specs[{k}] block {bs} does not evenly tile "
                    f"out_shape {tuple(os.shape)} (dim {d})")
                break
    return out


def _check_site_coverage(entry: KernelEntry, site: CapturedSite,
                         revisit_axes, path: str, line: int
                         ) -> List[Finding]:
    """(a) output-tile coverage / race / bounds for one launch."""
    out: List[Finding] = []
    grid = site.grid
    if not grid:
        return out
    grid_points = list(itertools.product(*[range(g) for g in grid]))
    for k, (spec, os) in enumerate(zip(site.out_specs, site.out_shapes)):
        bs = tuple(spec.block_shape)
        if len(bs) != len(os.shape) or any(b is None for b in bs):
            continue  # already reported by the block check
        nblocks = tuple(-(-s // b) for s, b in zip(os.shape, bs))
        seen = {}
        oob = False
        for pt in grid_points:
            bi = spec.index_map(*pt)
            bi = tuple(int(x) for x in (
                bi if isinstance(bi, (tuple, list)) else (bi,)))
            if len(bi) != len(nblocks) or any(
                    i < 0 or i >= n for i, n in zip(bi, nblocks)):
                if not oob:
                    out.append(Finding(
                        "tile-oob", path, line,
                        f"{entry.name}: out_specs[{k}] maps grid point "
                        f"{pt} to block {bi}, outside the "
                        f"{nblocks}-block output"))
                    oob = True
                continue
            reduced = tuple(0 if a in revisit_axes else pt[a]
                            for a in range(len(grid)))
            seen.setdefault(bi, set()).add(reduced)
        if oob:
            continue
        missing = [b for b in itertools.product(*[range(n) for n in nblocks])
                   if b not in seen]
        if missing:
            out.append(Finding(
                "tile-gap", path, line,
                f"{entry.name}: out_specs[{k}] never writes "
                f"{len(missing)}/{math.prod(nblocks)} output blocks "
                f"(first missing: {missing[0]}, grid {grid})"))
        raced = [b for b, pts in seen.items() if len(pts) > 1]
        if raced:
            out.append(Finding(
                "tile-race", path, line,
                f"{entry.name}: out_specs[{k}] block {raced[0]} is "
                f"written by {len(seen[raced[0]])} grid points outside "
                f"the declared revisit axes {tuple(revisit_axes)} "
                f"(grid {grid})"))
    return out


def _implied_vmem_bytes(entry: KernelEntry, site: CapturedSite,
                        point: dict) -> int:
    """Per-program VMEM implied by the captured launch: input blocks +
    output blocks + scratch + the entry's declared intermediate model
    (unpack expansions, weight tiles) computed from the same captured
    block shapes."""
    total = 0
    for spec, op in zip(site.in_specs, site.operands):
        total += _block_bytes(
            [b for b in spec.block_shape if b is not None], op.dtype)
    for spec, os in zip(site.out_specs, site.out_shapes):
        total += _block_bytes(
            [b for b in spec.block_shape if b is not None], os.dtype)
    for s in site.scratch_shapes:
        total += _scratch_bytes(s)
    if entry.vmem_extra is not None:
        total += int(entry.vmem_extra(site, point))
    return total


def _resolve_estimator(entry: KernelEntry):
    """Estimator declared as a name in core.backends.VMEM_ESTIMATORS
    (the introspection hook) or directly as a callable (fixtures)."""
    if entry.estimator is None:
        return None, None
    if callable(entry.estimator):
        return entry.estimator, None
    from repro.core import backends
    est = backends.VMEM_ESTIMATORS.get(entry.estimator)
    if est is None:
        return None, Finding(
            "estimator-missing", *_entry_loc(entry),
            f"{entry.name}: estimator {entry.estimator!r} is not "
            f"registered in core.backends.VMEM_ESTIMATORS")
    return est, None


def check_entry(entry: KernelEntry) -> List[Finding]:
    """All contract checks for one registry entry at all its points."""
    path, line = _entry_loc(entry)
    out: List[Finding] = []

    if entry.oracle is not None:
        from repro.kernels import ref
        if not hasattr(ref, entry.oracle):
            out.append(Finding(
                "oracle-missing", path, line,
                f"{entry.name}: oracle {entry.oracle!r} not found in "
                f"kernels/ref.py"))

    estimator, est_finding = _resolve_estimator(entry)
    if est_finding is not None:
        out.append(est_finding)

    for point in entry.points:
        sites = capture_sites(entry, point)
        if len(sites) != entry.sites:
            out.append(Finding(
                "site-count", path, line,
                f"{entry.name}: {len(sites)} pallas_call site(s) "
                f"captured at {point}, registry declares {entry.sites}"))
            continue
        implied = 0
        for si, site in enumerate(sites):
            out.extend(_check_site_blocks(entry, site, path, line))
            out.extend(_check_site_coverage(
                entry, site, entry.out_revisit[si], path, line))
            implied = max(implied,
                          _implied_vmem_bytes(entry, site, point))
        if estimator is not None and entry.estimator_kwargs is not None:
            est = int(estimator(**entry.estimator_kwargs(point)))
            if abs(est - implied) > entry.slack * max(est, implied, 1):
                out.append(Finding(
                    "estimator-drift", path, line,
                    f"{entry.name}: estimator says {est} bytes at "
                    f"{point} but the captured BlockSpecs imply "
                    f"{implied} bytes (slack {entry.slack:.0%})"))
    return out


def check_entries(entries=None) -> List[Finding]:
    entries = head_entries() if entries is None else entries
    out: List[Finding] = []
    for entry in entries:
        out.extend(check_entry(entry))
    return out


# ---------------------------------------------------------------------------
# registry completeness: NO pallas_call site anywhere in src/repro may
# dodge contract registration (not just the hardcoded kernel-file list)
# ---------------------------------------------------------------------------
def pallas_call_lines(path: str) -> List[int]:
    """Line numbers of `pallas_call(...)` CALL expressions in `path`.

    AST Call nodes only — assignments (`real = pl.pallas_call`, the
    registry's capture monkey-patch), attribute mentions, and docstring
    text do not count, which is what makes the walk safe to run over
    every module instead of a curated list."""
    try:
        with open(path, "r", encoding="utf-8") as fh:
            tree = ast.parse(fh.read(), filename=path)
    except (OSError, SyntaxError):
        return []
    lines = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            f = _dotted_name(node.func)
            if f == "pallas_call" or (f or "").endswith(".pallas_call"):
                lines.append(node.lineno)
    return sorted(lines)


def _dotted_name(node) -> Optional[str]:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _declared_sites_by_file(entries) -> Dict[str, int]:
    declared: Dict[str, int] = {}
    for e in entries:
        path = os.path.realpath(_entry_loc(e)[0])
        declared[path] = declared.get(path, 0) + e.sites
    return declared


def completeness_file_findings(path: str, entries) -> List[Finding]:
    """Compare one file's textual pallas_call sites against the
    contracts registered for functions defined in it (path mode /
    fixture driver)."""
    lines = pallas_call_lines(path)
    declared = _declared_sites_by_file(entries).get(
        os.path.realpath(path), 0)
    if len(lines) == declared:
        return []
    return [Finding(
        "unregistered-kernel", path, lines[0] if lines else 1,
        f"{len(lines)} pallas_call site(s) at lines {lines} but the "
        f"registered kernel contracts declare {declared} — every "
        f"launch needs a kernel_contract entry")]


def completeness_findings(entries=None,
                          src_root: Optional[str] = None) -> List[Finding]:
    """Walk ALL of src/repro (not just KERNEL_MODULES) and require the
    per-file pallas_call site counts to match the registered contract
    declarations exactly — a kernel added outside kernels/ cannot dodge
    registration (ISSUE 9 satellite; one seeded fixture pins it)."""
    entries = head_entries() if entries is None else entries
    if src_root is None:
        # .../src/repro, from .../src/repro/analysis/kernel_contracts.py
        src_root = os.path.dirname(os.path.dirname(
            os.path.abspath(__file__)))
    declared = _declared_sites_by_file(entries)
    out: List[Finding] = []
    for root, dirs, files in os.walk(src_root):
        dirs[:] = [d for d in dirs if d != "__pycache__"]
        for f in sorted(files):
            if not f.endswith(".py"):
                continue
            path = os.path.join(root, f)
            lines = pallas_call_lines(path)
            want = declared.get(os.path.realpath(path), 0)
            if len(lines) != want:
                out.append(Finding(
                    "unregistered-kernel", path,
                    lines[0] if lines else 1,
                    f"{len(lines)} pallas_call site(s) at lines "
                    f"{lines} but the registered kernel contracts "
                    f"declare {want} for this module"))
    return out
