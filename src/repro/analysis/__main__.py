"""CLI: `python -m repro.analysis [--strict] [--json PATH] [paths...]`.

With no paths: verify every registered kernel contract (importing the
kernel modules populates the registry) and lint `src/repro/{core,
kernels,launch}`. With paths: lint those files/directories instead,
and additionally contract-check any `kernel_contract(` registrations
the given .py files make at import time (this is how the seeded-bad
fixtures under tests/analysis_fixtures/ are driven, in isolation from
the HEAD registry).

Exit status: 0 when clean; 1 when any error-severity finding exists
(`--strict` promotes everything, warnings included). `--json PATH`
additionally writes the diffable rule->count->location payload
(benchmarks/ANALYSIS_report.json in CI).
"""
from __future__ import annotations

import argparse
import importlib.util
import os
import sys
from typing import List, Optional

from repro.analysis.registry import capture_registrations
from repro.analysis.report import Finding, render_json, render_text

DEFAULT_LINT_DIRS = ("core", "kernels", "launch", "service")


def _default_lint_paths() -> List[str]:
    # .../src/repro, from .../src/repro/analysis/__main__.py
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    return [os.path.join(root, d) for d in DEFAULT_LINT_DIRS]


def _has_registrations(path: str) -> bool:
    try:
        with open(path, "r", encoding="utf-8") as fh:
            return "kernel_contract(" in fh.read()
    except OSError:
        return False


def _check_module_file(path: str) -> List[Finding]:
    """Import one .py file in isolation and contract-check whatever it
    registers (fixture driver)."""
    from repro.analysis.kernel_contracts import check_entries
    name = "_analysis_target_" + \
        os.path.splitext(os.path.basename(path))[0]
    with capture_registrations() as entries:
        spec = importlib.util.spec_from_file_location(name, path)
        mod = importlib.util.module_from_spec(spec)
        try:
            spec.loader.exec_module(mod)
        except Exception as e:  # a fixture that cannot import is a finding
            return [Finding("block-mismatch", path, 1,
                            f"import failed: {e}")]
    return check_entries(entries)


def run(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="kernel-contract checker + trace-safety lint")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to analyze (default: the HEAD "
                         "kernel registry + src/repro/{core,kernels,"
                         "launch})")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero on ANY finding (CI gate)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the JSON report to PATH")
    args = ap.parse_args(argv)

    from repro.analysis.trace_lint import lint_paths

    findings: List[Finding] = []
    checked: List[str] = []
    if args.paths:
        lint_targets = list(args.paths)
        for p in args.paths:
            files = []
            if os.path.isdir(p):
                for root, dirs, names in os.walk(p):
                    dirs[:] = [d for d in dirs if d != "__pycache__"]
                    files += [os.path.join(root, f)
                              for f in sorted(names)
                              if f.endswith(".py")]
            elif p.endswith(".py"):
                files.append(p)
            for f in files:
                if _has_registrations(f):
                    checked.append(f)
                    findings.extend(_check_module_file(f))
    else:
        from repro.analysis.kernel_contracts import (check_entries,
                                                     head_entries)
        entries = head_entries()
        checked = [e.name for e in entries]
        findings.extend(check_entries(entries))
        lint_targets = _default_lint_paths()

    findings.extend(lint_paths(lint_targets))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))

    print(render_text(findings))
    if args.json:
        payload = render_json(findings, strict=args.strict,
                              checked_entries=checked,
                              linted_paths=[os.path.relpath(p)
                                            for p in lint_targets])
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w", encoding="utf-8") as fh:
            fh.write(payload + "\n")
        print(f"report written to {args.json}")

    if args.strict:
        return 1 if findings else 0
    return 1 if any(f.severity == "error" for f in findings) else 0


if __name__ == "__main__":
    sys.exit(run())
