"""CLI: `python -m repro.analysis [--strict] [--json PATH] [paths...]`.

With no paths, the full HEAD gate runs (DESIGN.md §12/§14):

  1. kernel contracts — verify every registered entry (importing the
     kernel modules populates the registry) AND the completeness walk:
     every `pallas_call` site anywhere under src/repro must be covered
     by a contract declaration (`unregistered-kernel` otherwise);
  2. trace-safety lint over src/repro/{core,kernels,launch,service,
     train,checkpoint}, plus the `# analysis: host-ok` exemption
     inventory — the count is pinned in `analysis/exemptions.py` and
     drift is a warning-severity finding (fails --strict only);
  3. privacy-taint verification — `analysis.taint.head_targets()`
     traces every protocol phase, round program, tapped segment, the
     service driver, and the serving forward, and proves no private
     source reaches a disclosure sink undeclassified.

With paths: lint those files/directories instead, and drive fixture
modules in isolation — any `kernel_contract(` registrations are
contract-checked, any `taint_target(` registrations are taint-checked,
and per-file pallas_call completeness is enforced (this is how the
seeded-bad fixtures under tests/analysis_fixtures/ run without
touching the HEAD registries).

Exit status: 0 when clean; 1 when any error-severity finding exists
(`--strict` promotes everything, warnings included). `--json PATH`
additionally writes the schema-versioned, deterministic payload
(benchmarks/ANALYSIS_report.json in CI) including the analysis
wall-time.
"""
from __future__ import annotations

import argparse
import importlib.util
import os
import sys
import time
from typing import List, Optional

from repro.analysis.registry import capture_registrations
from repro.analysis.report import Finding, render_json, render_text

DEFAULT_LINT_DIRS = ("core", "kernels", "launch", "service", "train",
                     "checkpoint")


def _default_lint_paths() -> List[str]:
    # .../src/repro, from .../src/repro/analysis/__main__.py
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    return [os.path.join(root, d) for d in DEFAULT_LINT_DIRS]


def _registration_kinds(path: str) -> tuple:
    """(has kernel_contract, has taint_target) textual pre-check, so
    only fixture files that actually register anything get imported."""
    try:
        with open(path, "r", encoding="utf-8") as fh:
            src = fh.read()
    except OSError:
        return False, False
    return "kernel_contract(" in src, "taint_target(" in src


def _check_fixture_file(path: str) -> List[Finding]:
    """Import one .py file in isolation and check whatever it registers
    (fixture driver): kernel contracts, taint targets, and per-file
    pallas_call completeness."""
    from repro.analysis.kernel_contracts import (check_entries,
                                                 completeness_file_findings)
    from repro.analysis.privacy import capture_declassifiers
    from repro.analysis.taint import capture_targets, check_targets
    name = "_analysis_target_" + \
        os.path.splitext(os.path.basename(path))[0]
    with capture_registrations() as entries, \
            capture_targets() as targets, capture_declassifiers():
        spec = importlib.util.spec_from_file_location(name, path)
        mod = importlib.util.module_from_spec(spec)
        try:
            spec.loader.exec_module(mod)
        except Exception as e:  # a fixture that cannot import is a finding
            return [Finding("block-mismatch", path, 1,
                            f"import failed: {e}")]
    findings = check_entries(entries)
    findings += completeness_file_findings(path, entries)
    findings += check_targets(targets)
    return findings


def run(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="kernel-contract checker + trace-safety lint + "
                    "privacy-taint verifier")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to analyze (default: the full "
                         "HEAD gate — kernel registry, lint dirs, "
                         "taint targets)")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero on ANY finding, warnings "
                         "included (CI gate)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the JSON report to PATH")
    args = ap.parse_args(argv)

    from repro.analysis.trace_lint import collect_host_ok, lint_paths

    t0 = time.monotonic()
    findings: List[Finding] = []
    checked: List[str] = []
    taint_names: List[str] = []
    host_ok = None
    if args.paths:
        lint_targets = list(args.paths)
        for p in args.paths:
            files = []
            if os.path.isdir(p):
                for root, dirs, names in os.walk(p):
                    dirs[:] = [d for d in dirs if d != "__pycache__"]
                    files += [os.path.join(root, f)
                              for f in sorted(names)
                              if f.endswith(".py")]
            elif p.endswith(".py"):
                files.append(p)
            for f in files:
                has_kc, has_tt = _registration_kinds(f)
                if has_kc or has_tt:
                    checked.append(f)
                    findings.extend(_check_fixture_file(f))
                else:
                    # registration-free file: pallas_call sites here
                    # are unregistered by definition
                    from repro.analysis.kernel_contracts import \
                        completeness_file_findings
                    findings.extend(completeness_file_findings(f, ()))
    else:
        from repro.analysis.kernel_contracts import (check_entries,
                                                     completeness_findings,
                                                     head_entries)
        from repro.analysis.taint import check_targets, head_targets
        entries = head_entries()
        checked = [e.name for e in entries]
        findings.extend(check_entries(entries))
        findings.extend(completeness_findings(entries))
        targets = head_targets()
        taint_names = [t.name for t in targets]
        findings.extend(check_targets(targets))
        lint_targets = _default_lint_paths()

    findings.extend(lint_paths(lint_targets))

    if not args.paths:
        # exemption inventory (default gate only: fixture path runs
        # must not trip the HEAD pin)
        from repro.analysis.exemptions import EXPECTED_HOST_OK
        host_ok = [(os.path.relpath(p), ln, why)
                   for p, ln, why in collect_host_ok(lint_targets)]
        if len(host_ok) != EXPECTED_HOST_OK:
            findings.append(Finding(
                "host-ok-drift", "src/repro/analysis/exemptions.py", 1,
                f"{len(host_ok)} `# analysis: host-ok` exemptions under "
                f"the default lint dirs, pin says {EXPECTED_HOST_OK} — "
                f"update the pin alongside the new/removed exemption",
                severity="warning"))

    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.message))
    wall = time.monotonic() - t0

    print(render_text(findings))
    if args.json:
        payload = render_json(findings, strict=args.strict,
                              checked_entries=checked,
                              linted_paths=[os.path.relpath(p)
                                            for p in lint_targets],
                              taint_targets=taint_names,
                              host_ok=host_ok,
                              wall_time_s=wall)
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w", encoding="utf-8") as fh:
            fh.write(payload + "\n")
        print(f"report written to {args.json}")

    if args.strict:
        return 1 if findings else 0
    return 1 if any(f.severity == "error" for f in findings) else 0


if __name__ == "__main__":
    sys.exit(run())
