"""Sharding rules: PartitionSpec trees for params, optimizer states,
batches, and decode caches, and helpers to bind them to a mesh.

Conventions (DESIGN.md §6):
  - batch / client axes shard over ("pod","data") when present, ("data",)
    on a single pod;
  - tensor parallelism shards heads / ffn / experts over "model";
  - scanned layer stacks have an unsharded leading (reps,) axis.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import rglru, xlstm
from repro.models.transformer import param_specs


def batch_axes(mesh: Mesh):
    """Mesh axes the global batch is sharded over."""
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


def batch_spec(mesh: Mesh, *trailing) -> P:
    return P(batch_axes(mesh), *trailing)


def named(mesh: Mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(cfg: ModelConfig):
    """AdamW state: step replicated; m/v mirror the param specs."""
    ps = param_specs(cfg)
    return {"step": P(), "m": ps, "v": ps}


def train_batch_specs(cfg: ModelConfig, mesh: Mesh):
    b = batch_axes(mesh)
    specs = {"tokens": P(b, None), "labels": P(b, None)}
    if cfg.is_encdec:
        specs["audio"] = P(b, None, None)
    if cfg.vision_tokens:
        specs["vision"] = P(b, None, None)
    return specs


# ---------------------------------------------------------------------------
# decode-cache specs (mirrors transformer.init_cache structure)
# ---------------------------------------------------------------------------
def _add_layer_dim(tree):
    return jax.tree.map(lambda s: P(None, *s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _block_cache_specs(cfg: ModelConfig, t: str, b, *, decoder: bool):
    c = {}
    if t in "AL" and not (cfg.is_encdec and not decoder):
        c["kv"] = {"k": P(b, None, "model", None),
                   "v": P(b, None, "model", None)}
    elif t == "X":
        c["kv"] = {"k": P(b, None, "model", None),
                   "v": P(b, None, "model", None)}
    elif t == "R":
        c["state"] = rglru.rglru_state_specs(cfg, b)
    elif t == "S":
        c["state"] = xlstm.slstm_state_specs(cfg, b)
    elif t == "M":
        c["state"] = xlstm.mlstm_state_specs(cfg, b)
    if decoder and cfg.is_encdec:
        c["cross"] = {"k": P(b, None, "model", None),
                      "v": P(b, None, "model", None)}
    return c


def cache_specs(cfg: ModelConfig, mesh: Mesh):
    b = batch_axes(mesh)
    pattern = cfg.block_pattern
    reps, tail = cfg.pattern_reps, cfg.pattern_tail
    decoder = cfg.is_encdec
    out = {}
    if reps > 0:
        out["layers"] = tuple(
            _add_layer_dim(_block_cache_specs(cfg, t, b, decoder=decoder))
            for t in pattern)
    out["tail"] = tuple(
        _block_cache_specs(cfg, pattern[i], b, decoder=decoder)
        for i in range(tail))
    return out
