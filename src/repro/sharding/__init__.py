from repro.sharding.rules import (  # noqa: F401
    batch_axes,
    batch_spec,
    cache_specs,
    named,
    opt_state_specs,
    train_batch_specs,
)
