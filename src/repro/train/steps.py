"""Train / prefill / serve step builders for the transformer zoo.

These are the functions the launcher lowers onto the production mesh
(launch/dryrun.py) and executes at reduced scale in tests/examples.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import (decode_step, forward, init_cache,
                                      init_params, prefill)
from repro.optim.optimizers import Optimizer, apply_updates, clip_by_global_norm

MOE_AUX_WEIGHT = 0.01


def lm_loss(cfg: ModelConfig, params, batch, *, remat: str = "block",
            window_override: int = 0, unroll: bool = False,
            scan_unroll: int = 1):
    extra = {k: batch[k] for k in ("audio", "vision") if k in batch}
    logits, aux = forward(cfg, params, batch["tokens"], extra or None,
                          remat=remat, window_override=window_override,
                          unroll=unroll, scan_unroll=scan_unroll)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None],
                               axis=-1)[..., 0]
    ce = jnp.mean(nll)
    return ce + MOE_AUX_WEIGHT * aux / max(cfg.num_layers, 1), (ce, aux)


def make_train_step(cfg: ModelConfig, optimizer: Optimizer, *,
                    remat: str = "block", grad_clip: float = 1.0,
                    unroll: bool = False, scan_unroll: int = 1,
                    grad_accum: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    grad_accum > 1 scans over microbatches accumulating f32 grads before
    one optimizer update (§Perf iteration 7: peak activation memory
    scales with the microbatch, letting shapes that exceed HBM fit).
    """

    def grad_fn(params, mb):
        return jax.value_and_grad(
            lambda p: lm_loss(cfg, p, mb, remat=remat, unroll=unroll,
                              scan_unroll=scan_unroll),
            has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if grad_accum > 1:
            micro = jax.tree.map(
                lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum,
                                    *x.shape[1:]), batch)

            def acc_step(carry, mb):
                g_acc, l_acc, c_acc, a_acc = carry
                (loss, (ce, aux)), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / grad_accum,
                    g_acc, g)
                return (g_acc, l_acc + loss / grad_accum,
                        c_acc + ce / grad_accum,
                        a_acc + aux / grad_accum), None

            zeros = jax.tree.map(
                lambda q: jnp.zeros(q.shape, jnp.float32), params)
            (grads, loss, ce, aux), _ = jax.lax.scan(
                acc_step, (zeros, jnp.float32(0), jnp.float32(0),
                           jnp.float32(0)), micro)
        else:
            (loss, (ce, aux)), grads = grad_fn(params, batch)
        if grad_clip:
            grads, gnorm = clip_by_global_norm(grads, grad_clip)
        else:
            gnorm = jnp.zeros((), jnp.float32)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"loss": loss, "ce": ce, "moe_aux": aux,
                   "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, *, window_override: int = 0,
                      cache_len: int = 0, unroll: bool = False,
                      scan_unroll: int = 1):
    """(params, batch) -> (last logits (B,V), cache).

    `cache_len` sizes the returned KV cache beyond the prompt (0 =
    prompt length only) — a server that decodes `max_new` tokens after
    the prompt passes prompt_len + max_new here and reuses the ONE
    compiled prefill for cache building (launch/serve.py)."""

    def prefill_step(params, batch):
        extra = {k: batch[k] for k in ("audio", "vision") if k in batch}
        return prefill(cfg, params, batch["tokens"], extra or None,
                       window_override=window_override,
                       cache_len=cache_len, unroll=unroll,
                       scan_unroll=scan_unroll)

    return prefill_step


def make_serve_step(cfg: ModelConfig, *, window_override: int = 0,
                    temperature: float = 0.0, unroll: bool = False,
                    scan_unroll: int = 1):
    """One decode step: (params, cache, token (B,), pos) ->
    (next_token (B,), logits (B,V), new_cache)."""

    def serve_step(params, cache, token, pos):
        logits, cache = decode_step(cfg, params, cache, token, pos,
                                    window_override=window_override,
                                    unroll=unroll, scan_unroll=scan_unroll)
        if temperature > 0:
            key = jax.random.fold_in(jax.random.PRNGKey(0), pos)
            nxt = jax.random.categorical(key, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32), logits, cache

    return serve_step


def init_train_state(cfg: ModelConfig, optimizer: Optimizer, key,
                     dtype=jnp.float32):
    params = init_params(cfg, key, dtype)
    opt_state = optimizer.init(params)
    return params, opt_state


def make_decode_cache(cfg: ModelConfig, params, batch: int, cache_len: int,
                      dtype=jnp.float32, extra=None, *,
                      window_override: int = 0):
    return init_cache(cfg, params, batch, cache_len, dtype, extra,
                      window_override=window_override)
