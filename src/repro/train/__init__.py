from repro.train.steps import (  # noqa: F401
    init_train_state,
    lm_loss,
    make_decode_cache,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
