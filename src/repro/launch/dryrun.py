"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production mesh, print memory/cost analyses, and emit the roofline
terms (EXPERIMENTS.md §Dry-run / §Roofline read from this output).

Counting modes (XLA's cost analysis tallies a while-loop body ONCE, so
scanned-layer models under-report per-layer work):

  scan2  (default) compile twice — lax.scan(unroll=1) and (unroll=2).
         The count delta isolates one layer-body exactly, so
         total = base + reps * body is reconstructed from compiled
         artifacts at ~1/10th the compile cost of full unrolling.
         memory_analysis comes from the unroll=1 executable (the form
         real training runs).
  unroll python-loop over layers (exact counts, expensive compiles —
         used for the three §Perf hillclimb pairs).
  scan   single lax.scan compile (fast smoke; counts under-report).

Known caveat: inner *time* loops (xlstm's sLSTM step scan and mLSTM
chunk scan) are still counted once in all modes; xlstm-350m compute
terms are lower bounds (documented in EXPERIMENTS.md).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch minitron-4b \
        --shape train_4k [--multi-pod] [--mode scan2] [--json out.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
# The first two lines must run before ANY other import (jax locks the
# device count at first init):
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import functools
import json
import math
import re
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config, list_archs, supports_shape
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import init_cache, init_params, param_specs
from repro.optim import adamw
from repro.sharding import (batch_axes, cache_specs, named, opt_state_specs,
                            train_batch_specs)
from repro.train import (make_prefill_step, make_serve_step, make_train_step)

# ---------------------------------------------------------------------------
# hardware constants (TPU v5e, per task spec)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

DTYPE = jnp.bfloat16

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[^\s(]+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|f8e\w+|s64|s32|s16|s8|u64|u32|u16|u8|pred)"
    r"\[([\d,]*)\]")
_DT_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
             "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
             "pred": 1}


def collective_stats(hlo_text: str) -> Dict[str, Any]:
    """Per-device collective bytes by op kind, parsed from post-SPMD HLO."""
    per_kind: Dict[str, float] = {}
    count = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(m.group(1)):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DT_BYTES.get(dt.split("e")[0] if dt.startswith("f8")
                                        else dt, 2)
        per_kind[kind] = per_kind.get(kind, 0) + nbytes
        count += 1
    return {"bytes_by_kind": per_kind,
            "total_bytes": sum(per_kind.values()),
            "num_collectives": count}


def _sanitize(spec_tree, shape_tree, mesh):
    """Drop sharding on dims not divisible by the mesh axis size (e.g.
    whisper's vocab 51865 on a 16-way model axis, or batch=1 for
    long_500k on the 16-way data axis) — replicate those dims instead."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(spec, sds):
        if not isinstance(spec, P):
            return spec
        parts = list(spec) + [None] * (len(sds.shape) - len(spec))
        out = []
        for dim, ax in zip(sds.shape, parts):
            if ax is None:
                out.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            div = math.prod(sizes[a] for a in axes)
            out.append(ax if dim % div == 0 else None)
        return P(*out)

    return jax.tree.map(fix, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _shard(mesh, spec_tree, sds_tree):
    return named(mesh, _sanitize(spec_tree, sds_tree, mesh))


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs — no allocation ever happens)
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                dtype=DTYPE) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for the step lowered at this shape (stubs included)."""
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.mode == "decode":
        specs = {"tokens": sds((b,), jnp.int32)}
    else:
        specs = {"tokens": sds((b, s), jnp.int32),
                 "labels": sds((b, s), jnp.int32)}
    if cfg.is_encdec:
        specs["audio"] = sds((b, cfg.encoder_seq_len, cfg.d_model), dtype)
    if cfg.vision_tokens:
        specs["vision"] = sds((b, cfg.vision_tokens,
                               cfg.vision_dim or cfg.d_model), dtype)
    return specs


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill) / 2*N*B (decode),
    N = active params (MoE: routed only)."""
    n = cfg.active_param_count()
    if shape.mode == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.mode == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # decode: one token / seq


def _counts(compiled) -> Dict[str, Any]:  # analysis: host-ok
    # compiler cost stats, not device values — nothing to sync
    cost = compiled.cost_analysis() or {}
    coll = collective_stats(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll}


def _memory_stats(compiled) -> Dict[str, Any]:
    try:
        mem = compiled.memory_analysis()
        return {
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        }
    except Exception as e:                                 # pragma: no cover
        return {"error": str(e)}


# ---------------------------------------------------------------------------
# lower + compile one (arch, shape, mesh)
# ---------------------------------------------------------------------------
def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               remat: str = "block", mode: str = "scan2",
               moe_impl: str = "sharded",
               verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = supports_shape(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()

    # §Perf iterations 2-4 (EXPERIMENTS.md): MoE dispatch distribution.
    #   moe_impl="jit"      textbook global dispatch (baseline)
    #   moe_impl="sharded"  shard_map local-dispatch + psum (default; the
    #                       jit-level variants replicate expert compute or
    #                       all-reduce the dispatch buffer — both measured
    #                       catastrophic at kimi/grok scale)
    from repro.models import moe as moe_mod
    moe_mod.set_dispatch_spec(None)
    moe_mod.set_sharded_impl(None)
    if cfg.is_moe and moe_impl == "sharded":
        moe_mod.set_sharded_impl(mesh, batch_axes=batch_axes(mesh))

    params_sds = jax.eval_shape(
        functools.partial(init_params, cfg, dtype=DTYPE),
        jax.random.PRNGKey(0))
    p_shard = _shard(mesh, param_specs(cfg), params_sds)
    batch_sds = input_specs(cfg, shape)
    window_override = (cfg.serve_window
                       if (shape.name == "long_500k"
                           and cfg.family == "dense") else 0)

    def build_lowered(unroll: bool, scan_unroll: int):
        if shape.mode == "train":
            opt = adamw(1e-4, weight_decay=0.1)
            opt_sds = jax.eval_shape(opt.init, params_sds)
            o_shard = _shard(mesh, opt_state_specs(cfg), opt_sds)
            b_shard = _shard(mesh, {k: v for k, v in
                                    train_batch_specs(cfg, mesh).items()
                                    if k in batch_sds}, batch_sds)
            step = make_train_step(cfg, opt, remat=remat, unroll=unroll,
                                   scan_unroll=scan_unroll)
            m_shard = jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                   {"loss": 0, "ce": 0, "moe_aux": 0,
                                    "grad_norm": 0})
            fn = jax.jit(step,
                         in_shardings=(p_shard, o_shard, b_shard),
                         out_shardings=(p_shard, o_shard, m_shard))
            return fn.lower(params_sds, opt_sds, batch_sds)
        if shape.mode == "prefill":
            b_shard = _shard(mesh, {k: v for k, v in
                                    train_batch_specs(cfg, mesh).items()
                                    if k in batch_sds}, batch_sds)
            step = make_prefill_step(cfg, unroll=unroll,
                                     scan_unroll=scan_unroll)
            out_sds = jax.eval_shape(step, params_sds, batch_sds)
            logits_shard = _shard(mesh, P(batch_axes(mesh), "model"),
                                  out_sds[0])
            c_shard = _shard(mesh, cache_specs(cfg, mesh), out_sds[1])
            fn = jax.jit(step, in_shardings=(p_shard, b_shard),
                         out_shardings=(logits_shard, c_shard))
            return fn.lower(params_sds, batch_sds)
        # decode
        b = shape.global_batch
        extra_sds = {k: v for k, v in batch_sds.items() if k != "tokens"}
        cache_len = min(shape.seq_len, window_override) \
            if window_override else shape.seq_len
        cache_sds = jax.eval_shape(
            functools.partial(init_cache, cfg, batch=b, cache_len=cache_len,
                              dtype=DTYPE, window_override=window_override),
            params_sds, extra=extra_sds or None)
        c_shard = _shard(mesh, cache_specs(cfg, mesh), cache_sds)
        step = make_serve_step(cfg, window_override=window_override,
                               unroll=unroll, scan_unroll=scan_unroll)
        tok_sds = batch_sds["tokens"]
        pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
        out_sds = jax.eval_shape(step, params_sds, cache_sds, tok_sds,
                                 pos_sds)
        tok_shard = _shard(mesh, P(batch_axes(mesh)), tok_sds)
        logits_shard = _shard(mesh, P(batch_axes(mesh), "model"), out_sds[1])
        pos_shard = NamedSharding(mesh, P())
        fn = jax.jit(step,
                     in_shardings=(p_shard, c_shard, tok_shard, pos_shard),
                     out_shardings=(tok_shard, logits_shard, c_shard))
        return fn.lower(params_sds, cache_sds, tok_sds, pos_sds)

    reps = cfg.pattern_reps
    with mesh:
        if mode == "unroll":
            lowered = build_lowered(True, 1)
            compiled = lowered.compile()
            c1 = _counts(compiled)
            flops, nbytes, coll = c1["flops"], c1["bytes"], c1["coll"]
            mem_stats = _memory_stats(compiled)
            compiles = 1
        elif mode == "scan":
            lowered = build_lowered(False, 1)
            compiled = lowered.compile()
            c1 = _counts(compiled)
            flops, nbytes, coll = c1["flops"], c1["bytes"], c1["coll"]
            mem_stats = _memory_stats(compiled)
            compiles = 1
        else:  # scan2: reconstruct total = base + reps*body from u1/u2
            lowered = build_lowered(False, 1)
            compiled = lowered.compile()
            c1 = _counts(compiled)
            mem_stats = _memory_stats(compiled)
            compiles = 1
            if reps > 1:
                lowered2 = build_lowered(False, 2)
                compiled2 = lowered2.compile()
                c2 = _counts(compiled2)
                compiles = 2

                def corr(a, b):
                    return a + max(reps - 1, 0) * max(b - a, 0.0)

                flops = corr(c1["flops"], c2["flops"])
                nbytes = corr(c1["bytes"], c2["bytes"])
                kinds = set(c1["coll"]["bytes_by_kind"]) \
                    | set(c2["coll"]["bytes_by_kind"])
                by_kind = {k: corr(c1["coll"]["bytes_by_kind"].get(k, 0),
                                   c2["coll"]["bytes_by_kind"].get(k, 0))
                           for k in kinds}
                coll = {"bytes_by_kind": by_kind,
                        "total_bytes": sum(by_kind.values()),
                        "num_collectives":
                            c1["coll"]["num_collectives"]}
            else:
                flops, nbytes, coll = c1["flops"], c1["bytes"], c1["coll"]
    t_total = time.time() - t0

    # --- roofline terms (per §Roofline; post-SPMD HLO counts are
    # per-device, i.e. already divided by `chips`) ---
    t_compute = flops / PEAK_FLOPS
    t_memory = nbytes / HBM_BW
    t_coll = coll["total_bytes"] / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    useful = mf / (flops * chips) if flops else 0.0

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "chips": chips, "remat": remat, "mode": mode,
        "moe_impl": moe_impl if cfg.is_moe else None,
        "window_override": window_override,
        "wall_s": round(t_total, 1), "compiles": compiles,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": nbytes,
        "collectives": coll,
        "memory": mem_stats,
        "roofline": {**{k: round(v, 6) for k, v in terms.items()},
                     "dominant": dominant,
                     "model_flops": f"{mf:.3e}",
                     "useful_flop_frac": round(useful, 4)},
    }
    if verbose:
        print(json.dumps(result, indent=1, default=str), flush=True)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="block", choices=["none", "block"])
    ap.add_argument("--mode", default="scan2",
                    choices=["scan2", "scan", "unroll"])
    ap.add_argument("--moe-impl", default="sharded",
                    choices=["sharded", "jit"])
    ap.add_argument("--json", default=None, help="write results to file")
    args = ap.parse_args(argv)

    combos = []
    if args.all:
        for a in list_archs():
            for s in SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    results = []
    for a, s in combos:
        print(f"=== dryrun {a} x {s} "
              f"({'multi-pod 2x16x16' if args.multi_pod else '16x16'}) ===",
              flush=True)
        try:
            results.append(dryrun_one(a, s, multi_pod=args.multi_pod,
                                      remat=args.remat, mode=args.mode,
                                      moe_impl=args.moe_impl))
        except Exception as e:
            results.append({"arch": a, "shape": s, "error": repr(e)})
            print(f"FAILED: {e!r}", file=sys.stderr, flush=True)
        if args.json:
            with open(args.json, "w") as f:
                json.dump(results, f, indent=1, default=str)
    n_err = sum("error" in r for r in results)
    print(f"\n{len(results)} combos: {n_err} errors, "
          f"{sum('skipped' in r for r in results)} skipped")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
