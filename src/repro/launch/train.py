"""Training driver for the transformer zoo.

Runs REDUCED configs end-to-end on CPU (examples, smoke); FULL configs
are exercised via launch/dryrun.py. Supports checkpoint/restore and the
synthetic token pipeline.

    PYTHONPATH=src python -m repro.launch.train --arch phi3-medium-14b \
        --reduced --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.configs import get_config
from repro.data import TokenStream
from repro.optim import adamw, linear_warmup_cosine
from repro.train import init_train_state, make_train_step


def train(arch: str, *, steps: int = 50, batch: int = 8, seq: int = 128,
          lr: float = 3e-4, reduced: bool = True, ckpt_dir: str = "",
          ckpt_every: int = 0, seed: int = 0, log_every: int = 10,
          remat: str = "none"):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    stream = TokenStream(cfg, batch, seq, seed=seed)
    opt = adamw(linear_warmup_cosine(lr, max(steps // 10, 1), steps),
                weight_decay=0.1)
    params, opt_state = init_train_state(cfg, opt, jax.random.PRNGKey(seed))

    start = 0
    if ckpt_dir and (last := ckpt.latest_step(ckpt_dir)) is not None:
        params, opt_state = ckpt.restore(ckpt_dir, last,
                                         (params, opt_state))
        start = last
        print(f"restored step {last} from {ckpt_dir}")

    step_fn = jax.jit(make_train_step(cfg, opt, remat=remat))
    history = []
    t0 = time.time()
    for step in range(start, steps):
        batch_np = stream.next_batch()
        batch_j = {k: jnp.asarray(v) for k, v in batch_np.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch_j)
        if step % log_every == 0 or step == steps - 1:
            # analysis: host-ok — metric sync gated behind log_every
            loss = float(metrics["loss"])
            gnorm = float(metrics["grad_norm"])  # analysis: host-ok
            history.append({"step": step, "loss": loss,
                            "grad_norm": gnorm})
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"gnorm {gnorm:7.3f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
        if ckpt_dir and ckpt_every and (step + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, step + 1, (params, opt_state))
    if ckpt_dir:
        ckpt.save(ckpt_dir, steps, (params, opt_state))
    return params, history


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full", action="store_true",
                    help="use the full (not reduced) config — CPU-hostile")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--remat", default="none", choices=["none", "block"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    _, history = train(args.arch, steps=args.steps, batch=args.batch,
                       seq=args.seq, lr=args.lr, reduced=not args.full,
                       ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                       seed=args.seed, remat=args.remat)
    print(json.dumps(history[-3:], indent=1))


if __name__ == "__main__":
    main()
