"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — smoke tests must keep seeing a
single CPU device; only launch/dryrun.py forces 512 host devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: v5e-256 as (data=16, model=16).
    Multi-pod: 2 pods x 256 chips as (pod=2, data=16, model=16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU tests (same axis names)."""
    return jax.make_mesh((1, 1), ("data", "model"))
