"""Serving driver: batched prefill + greedy decode on a KV cache, and
the federated mode — batched inference from the per-client PERSONALIZED
models of a live (or checkpointed) federation via
`repro.service.PersonalizedServer` (DESIGN.md §13).

    PYTHONPATH=src python -m repro.launch.serve --arch phi3-medium-14b \
        --reduced --batch 4 --prompt-len 32 --max-new 16
    PYTHONPATH=src python -m repro.launch.serve --federated \
        --ckpt-dir /tmp/svc --requests 64
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import modality_stub
from repro.models import init_params
from repro.train import make_prefill_step, make_serve_step


def serve(arch: str, *, batch: int = 4, prompt_len: int = 32,
          max_new: int = 16, reduced: bool = True, seed: int = 0,
          window_override: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    rs = np.random.RandomState(seed)  # analysis: host-ok (host prompt rng)
    prompts = {"tokens": jnp.asarray(
        rs.randint(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)}
    prompts.update({k: jnp.asarray(v) for k, v in
                    modality_stub(cfg, batch, rs).items()})

    # ONE prefill, sized for prompt + generation up front (cache_len)
    prefill_step = jax.jit(make_prefill_step(
        cfg, window_override=window_override,
        cache_len=prompt_len + max_new))
    serve_step = jax.jit(make_serve_step(
        cfg, window_override=window_override))

    t0 = time.time()
    logits, cache = prefill_step(params, prompts)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0

    out = [tok]
    t0 = time.time()
    for i in range(max_new - 1):
        tok, logits, cache = serve_step(params, cache, tok,
                                        jnp.int32(prompt_len + i))
        out.append(tok)
    gen = jnp.stack(out, axis=1)
    t_decode = time.time() - t0
    # analysis: host-ok — the generated tokens ARE the result
    return {"generated": np.asarray(gen),
            "prefill_s": t_prefill,
            "decode_tok_per_s": batch * (max_new - 1) / max(t_decode, 1e-9)}


def serve_personalized(dataset="mnist", *, ckpt_dir=None, requests=64,
                       seed=0, reselect_every=4, num_clients=0,
                       log=print):
    """Serve batched inference from the federation's per-client
    personalized models. With `ckpt_dir`, the models are the live
    service's latest checkpoint (the kill/resume snapshot doubles as
    the serving snapshot); without, a fresh (untrained) federation —
    useful for smoke/bench runs. Requests draw test examples for
    random ACTIVE clients and batch across them through ONE vmapped
    forward per bucket (repro.service.serving). Returns the server's
    throughput summary plus served-prediction accuracy."""
    from repro.configs.paper_models import FedConfig, PAPER_FED_OPTIMA
    from repro.core import init_state
    from repro.data import DATASETS
    from repro.launch.fed import MODEL_FOR
    from repro.models import apply_client_model, init_client_model
    from repro.optim import adam
    from repro.service import (PersonalizedServer, ServiceConfig,
                               checkpoint_num_clients,
                               init_service_state, resume_service)
    ds_fn = DATASETS[dataset]
    if ckpt_dir and num_clients == 0:
        # size the template from the snapshot, not the dataset default:
        # the checkpointed service fixed M when it started
        num_clients = checkpoint_num_clients(ckpt_dir)
    ds = ds_fn(seed=seed) if num_clients == 0 else \
        ds_fn(num_clients=num_clients, seed=seed)
    n_opt, alpha, gamma = PAPER_FED_OPTIMA[dataset]
    fed = FedConfig(num_clients=ds.num_clients, num_neighbors=n_opt,
                    alpha=alpha, gamma=gamma)
    mcfg = MODEL_FOR[dataset]()
    apply_fn = functools.partial(apply_client_model, mcfg)
    template = init_service_state(
        init_state(apply_fn, lambda k: init_client_model(mcfg, k),
                   adam(fed.lr), fed, jax.random.PRNGKey(seed)),
        ServiceConfig(reselect_every=reselect_every))
    if ckpt_dir:
        state, _chain, _next = resume_service(ckpt_dir, template)
    else:
        state = template
    server = PersonalizedServer(apply_fn, state.fed.params)
    data = {k: jnp.asarray(v) for k, v in ds.stacked().items()}
    rs = np.random.RandomState(seed)  # analysis: host-ok (request sampling)
    active_ids = np.flatnonzero(np.asarray(state.active))
    want = []
    for _ in range(requests):
        # analysis: host-ok — request construction at the serving edge
        cid = int(active_ids[rs.randint(len(active_ids))])
        t = rs.randint(data["x_test"].shape[1])
        server.submit(cid, data["x_test"][cid, t])
        # analysis: host-ok — ground-truth label for the accuracy check
        want.append(int(data["y_test"][cid, t]))
    # analysis: host-ok — flushed responses are host arrays already
    preds = [int(np.argmax(lg)) for lg in server.flush()]
    # analysis: host-ok — summary over host-side predictions
    acc = float(np.mean(np.asarray(preds) == np.asarray(want)))
    res = {**server.throughput(), "served_acc": acc,
           "num_models": int(active_ids.size)}
    log(f"served {requests} requests from {active_ids.size} "
        f"personalized models: {res['requests_per_s']:.0f} req/s, "
        f"p50 {res['p50_latency_s'] * 1e3:.1f} ms, acc {acc:.3f}")
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="",
                    help="transformer zoo arch (decode mode); omit "
                         "with --federated")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--window", type=int, default=0)
    ap.add_argument("--federated", action="store_true",
                    help="serve per-client personalized models from a "
                         "federation checkpoint (repro.service)")
    ap.add_argument("--dataset", default="mnist",
                    choices=["mnist", "aecg", "seeg"])
    ap.add_argument("--ckpt-dir", default="",
                    help="[federated] service checkpoint directory "
                         "(omit for a fresh federation)")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.federated:
        serve_personalized(args.dataset, ckpt_dir=args.ckpt_dir or None,
                           requests=args.requests, seed=args.seed)
        return
    if not args.arch:
        ap.error("--arch is required unless --federated")
    res = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                max_new=args.max_new, reduced=not args.full,
                window_override=args.window)
    print(f"prefill {res['prefill_s']:.2f}s, "
          f"decode {res['decode_tok_per_s']:.1f} tok/s")
    print("sample:", res["generated"][0][:16])


if __name__ == "__main__":
    main()
