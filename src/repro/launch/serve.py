"""Serving driver: batched prefill + greedy decode on a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch phi3-medium-14b \
        --reduced --batch 4 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import modality_stub
from repro.models import init_params
from repro.train import make_prefill_step, make_serve_step


def serve(arch: str, *, batch: int = 4, prompt_len: int = 32,
          max_new: int = 16, reduced: bool = True, seed: int = 0,
          window_override: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    rs = np.random.RandomState(seed)  # analysis: host-ok (host prompt rng)
    prompts = {"tokens": jnp.asarray(
        rs.randint(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)}
    prompts.update({k: jnp.asarray(v) for k, v in
                    modality_stub(cfg, batch, rs).items()})

    prefill_step = jax.jit(make_prefill_step(
        cfg, window_override=window_override))
    serve_step = jax.jit(make_serve_step(
        cfg, window_override=window_override))

    t0 = time.time()
    # size the cache for prompt + generation
    extra = {k: v for k, v in prompts.items() if k != "tokens"}
    from repro.models.transformer import prefill as _prefill
    logits, cache = jax.jit(
        lambda p, t, e: _prefill(cfg, p, t, e or None,
                                 cache_len=prompt_len + max_new,
                                 window_override=window_override)
    )(params, prompts["tokens"], extra)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0

    out = [tok]
    t0 = time.time()
    for i in range(max_new - 1):
        tok, logits, cache = serve_step(params, cache, tok,
                                        jnp.int32(prompt_len + i))
        out.append(tok)
    gen = jnp.stack(out, axis=1)
    t_decode = time.time() - t0
    # analysis: host-ok — the generated tokens ARE the result
    return {"generated": np.asarray(gen),
            "prefill_s": t_prefill,
            "decode_tok_per_s": batch * (max_new - 1) / max(t_decode, 1e-9)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--window", type=int, default=0)
    args = ap.parse_args(argv)
    res = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                max_new=args.max_new, reduced=not args.full,
                window_override=args.window)
    print(f"prefill {res['prefill_s']:.2f}s, "
          f"decode {res['decode_tok_per_s']:.1f} tok/s")
    print("sample:", res["generated"][0][:16])


if __name__ == "__main__":
    main()
