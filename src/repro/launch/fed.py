"""Federated launcher: run the WPFed protocol at laptop scale (paper
reproduction) or lower a round program onto the production mesh with
the client axis sharded over "data" (TPU scale-out — beyond-paper).

Rounds run through the round-program engine (`core.rounds.run_rounds`,
DESIGN.md §8): `--schedule sync` is the paper's per-round protocol,
`--schedule gossip --reselect-every G` runs the global LSH
re-selection every G rounds with cheap gossip epochs in between, and
the host `Blockchain` ledger records one block per reselection.

    PYTHONPATH=src python -m repro.launch.fed --dataset mnist --rounds 10
    PYTHONPATH=src python -m repro.launch.fed --schedule gossip \
        --reselect-every 4 --rounds 12
    PYTHONPATH=src python -m repro.launch.fed --dryrun   # 256-client mesh
"""
from __future__ import annotations

import argparse
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import (FedConfig, PAPER_FED_OPTIMA,
                                        aecg_tcn, mnist_cnn,
                                        recommended_dedupe, seeg_tcn)
from repro.core import (evaluate, init_state, instrument_program,
                        make_segment_fn, resolve_schedule, resolve_threat,
                        run_rounds, wpfed_program)
from repro.core.adversary import THREATS
from repro.core.chain import Blockchain, lsh_code_hex, sha256_commit
from repro.data import DATASETS
from repro.models import apply_client_model, init_client_model
from repro.optim import adam
from repro.service import (ServiceConfig, init_service_state, parse_events,
                           parse_fault_spec, resume_service, run_service)

MODEL_FOR = {"mnist": mnist_cnn, "aecg": aecg_tcn, "seeg": seeg_tcn}


def chain_publisher(chain: Blockchain, num_clients: int):
    """`on_reselect` callback: publish a reselection's announcements
    a_i = {lsh_i, C_i} plus the revealed rankings to the host ledger
    (WPFed §2.2 — codes/rankings/commitments are frozen across the
    period's gossip epochs, so one block per reselection is the
    complete record)."""

    def publish(round_idx: int, state) -> None:  # analysis: host-ok
        # intentional device->host pull, once per reselection period:
        # the ledger records announcements, not device arrays (§8)
        codes = np.asarray(state.codes)
        rankings = np.asarray(state.rankings)
        ann = {i: {"lsh": lsh_code_hex(codes[i]),
                   "commit": sha256_commit(rankings[i])}
               for i in range(num_clients)}
        reveals = {i: [int(x) for x in rankings[i]]
                   for i in range(num_clients)}
        chain.publish_round(round_idx + 1, ann, reveals=reveals)

    return publish


def run_federation(dataset: str = "mnist", rounds: int = 10,
                   num_clients: int = 0, seed: int = 0, fed: FedConfig = None,
                   backend: str = "auto", ref_mode: str = "personal",
                   tiling: str = "auto", schedule: str = "sync",
                   reselect_every: int = 0, attack: str = "none",
                   attack_frac: float = 0.5, attack_start: int = -1,
                   ann_prefix_bits: int = -1, ann_probes: int = -1,
                   log=print):
    """`backend` drives BOTH kernel-backed subsystems (selection and
    exchange — one flag, resolved by repro.core.backends.resolve;
    "ann" applies to selection only and leaves exchange on "auto" —
    DESIGN.md §11), and
    `tiling` both VMEM regimes (resolve_tiling, DESIGN.md §10).
    An explicit `fed` config wins outright: backend/ref_mode/tiling
    apply only to the default-constructed config (asserted, not
    silently dropped). ref_mode="public" also enables the Eq. 7
    duplicate-evidence dedupe (every selector sees the same l_ij for a
    neighbor there — DESIGN.md §7). `schedule`/`reselect_every` resolve
    via core.rounds.resolve_schedule; `attack` resolves via
    core.adversary.resolve_threat and instruments the program in-graph
    (DESIGN.md §9) — evaluation then reports the honest cohort.
    `attack_start=-1` keeps the threat's registry defaults (e.g. the
    §4.8 poison warm-up). Publishes every reselection to a host
    `Blockchain` and verifies the chain before returning
    (state, history).
    """
    if fed is not None and (backend != "auto" or ref_mode != "personal"
                            or tiling != "auto" or ann_prefix_bits >= 0
                            or ann_probes >= 0):
        raise ValueError("pass backend/ref_mode/tiling/ann knobs inside "
                         "the explicit FedConfig, not alongside it")
    sched = resolve_schedule(schedule, reselect_every)
    ds_fn = DATASETS[dataset]
    ds = ds_fn(seed=seed) if num_clients == 0 else \
        ds_fn(num_clients=num_clients, seed=seed)
    n_opt, alpha, gamma = PAPER_FED_OPTIMA[dataset]
    defaults = FedConfig()
    fed = fed or FedConfig(num_clients=ds.num_clients, num_neighbors=n_opt,
                           alpha=alpha, gamma=gamma, rounds=rounds,
                           selection_backend=backend,
                           exchange_backend="auto" if backend == "ann"
                           else backend, ref_mode=ref_mode,
                           selection_tiling=tiling, exchange_tiling=tiling,
                           dedupe_rankings=recommended_dedupe(ref_mode),
                           ann_prefix_bits=ann_prefix_bits
                           if ann_prefix_bits >= 0
                           else defaults.ann_prefix_bits,
                           ann_probes=ann_probes if ann_probes >= 0
                           else defaults.ann_probes)
    mcfg = MODEL_FOR[dataset]()
    apply_fn = functools.partial(apply_client_model, mcfg)
    init_fn = lambda k: init_client_model(mcfg, k)
    opt = adam(fed.lr)
    data = {k: jnp.asarray(v) for k, v in ds.stacked().items()}
    state = init_state(apply_fn, init_fn, opt, fed, jax.random.PRNGKey(seed))
    program = wpfed_program(apply_fn, opt, fed)
    honest_mask = None
    if attack != "none":
        tm = resolve_threat(
            attack, num_clients=fed.num_clients, attacker_frac=attack_frac,
            init_fn=init_fn, key=jax.random.PRNGKey(seed + 31),
            start_round=None if attack_start < 0 else attack_start)
        program = instrument_program(program, tm)
        honest_mask = (~tm.attacker_mask).astype(jnp.float32)
    chain = Blockchain()
    state, history = run_rounds(
        program, state, data, rounds=rounds, schedule=sched,
        eval_fn=lambda st, d: {"acc": evaluate(
            apply_fn, st, d, honest_mask=honest_mask)["mean_acc"]},
        on_reselect=chain_publisher(chain, fed.num_clients), log=log)
    assert chain.verify_chain(), "host ledger integrity violated"
    return state, history


def run_service_federation(dataset: str = "mnist", periods: int = 3,
                           reselect_every: int = 4, num_clients: int = 0,
                           seed: int = 0, churn: str = "",
                           gossip_counts: str = "",
                           staleness_lambda: float = 0.5,
                           checkpoint_every: int = 1, keep_last_k: int = 3,
                           ckpt_dir: str = None, resume: bool = False,
                           faults: str = "", log=print):
    """The continuous-service scenario (DESIGN.md §13): the same
    construction as `run_federation`, driven by `repro.service` instead
    of run_rounds — unbounded reselection periods, churn events between
    them (`churn` = "period:kind:client,..."), per-client gossip
    budgets (`gossip_counts` = comma list of G_i), durable checkpoints
    under `ckpt_dir`, `--resume` picking up a killed service from
    its latest readable snapshot (bit-exact, verified against the
    recovered ledger), and `faults` (a `core.faults.parse_fault_spec`
    string, e.g. "seed=7,drop=0.1,straggle=0.2") running the whole
    service under deterministic fault injection (DESIGN.md §15).
    Evaluation reports the ACTIVE cohort — departed clients' frozen
    models don't dilute the service metric. Returns
    (state, chain, history)."""
    ds_fn = DATASETS[dataset]
    ds = ds_fn(seed=seed) if num_clients == 0 else \
        ds_fn(num_clients=num_clients, seed=seed)
    n_opt, alpha, gamma = PAPER_FED_OPTIMA[dataset]
    fed = FedConfig(num_clients=ds.num_clients, num_neighbors=n_opt,
                    alpha=alpha, gamma=gamma,
                    rounds=periods * reselect_every)
    svc = ServiceConfig(reselect_every=reselect_every,
                        staleness_lambda=staleness_lambda,
                        checkpoint_every=checkpoint_every,
                        keep_last_k=keep_last_k)
    mcfg = MODEL_FOR[dataset]()
    apply_fn = functools.partial(apply_client_model, mcfg)
    init_fn = lambda k: init_client_model(mcfg, k)
    opt = adam(fed.lr)
    data = {k: jnp.asarray(v) for k, v in ds.stacked().items()}
    counts = None
    if gossip_counts:
        counts = [int(c) for c in gossip_counts.split(",")]
    template = init_service_state(
        init_state(apply_fn, init_fn, opt, fed, jax.random.PRNGKey(seed)),
        svc, gossip_counts=counts)
    if resume:
        if not ckpt_dir:
            raise ValueError("--resume needs --ckpt-dir")
        state, chain, start_period = resume_service(ckpt_dir, template)
    else:
        state, chain, start_period = template, Blockchain(), 0
    events = parse_events(churn) if churn else []
    plan = parse_fault_spec(faults) if faults else None
    state, chain, history = run_service(
        apply_fn, opt, fed, svc, state, data, periods=periods,
        events=events, chain=chain, ckpt_dir=ckpt_dir,
        start_period=start_period, faults=plan,
        eval_fn=lambda st, d: {"acc": evaluate(
            apply_fn, st.fed, d,
            honest_mask=st.active.astype(jnp.float32))["mean_acc"]},
        log=log)
    assert chain.verify_chain(), "host ledger integrity violated"
    return state, chain, history


def dryrun_fed_round(num_clients: int = 256, arch: str = "phi3-medium-14b",
                     backend: str = "kernel", ref_mode: str = "personal",
                     tiling: str = "auto", reselect_every: int = 1,
                     attack: str = "none", attack_frac: float = 0.5,
                     attack_start: int = -1):
    """Beyond-paper: lower one WPFed reselection period with
    REDUCED-transformer clients sharded over the production mesh's data
    axis — proves the protocol itself scales out (the paper simulated
    <=40 clients on GPU). Defaults to the kernel backends so the
    lowering exercises the batched LSH + fused selection + fused
    exchange kernels under sharding; ref_mode="public" lowers the
    M-forward shared-reference exchange instead of the M*N personal
    one (DESIGN.md §7). `tiling="tiled"` forces the VMEM-tiled
    streaming kernels (column-tiled selection + R/C-tiled exchange,
    DESIGN.md §10) so their lowering composes with sharding — at the
    dryrun's own lsh_bits=128 / C=1024 shapes "auto" still resolves
    to one-shot (the budget only forces tiled past M ~ 10^4 at
    256-bit codes, or vocab-scale C), which is exactly why the tiled
    path needs the explicit flag here. `reselect_every=G` lowers the
    full segment —
    one global round plus G-1 gossip epochs under lax.scan
    (DESIGN.md §8). `attack` instruments the program with an in-graph
    ThreatModel before lowering (DESIGN.md §9) — e.g. a 256-client
    poisoned segment, with the lax.cond-gated re-init of the attacker
    cohort compiled into the sharded round.

    Must be called in a fresh process with XLA_FLAGS set (see dryrun.py).
    """
    from repro.configs import get_config
    from repro.launch.mesh import make_production_mesh
    from repro.models.transformer import forward, init_params
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = get_config(arch).reduced()
    fed = FedConfig(num_clients=num_clients, num_neighbors=8, top_k=4,
                    local_steps=1, lsh_bits=128, ref_batch=8,
                    selection_backend=backend,
                    exchange_backend="kernel" if backend == "ann"
                    else backend,
                    ref_mode=ref_mode, selection_tiling=tiling,
                    exchange_tiling=tiling,
                    dedupe_rankings=recommended_dedupe(ref_mode))
    mesh = make_production_mesh()

    def apply_fn(params, tokens):
        logits, _ = forward(cfg, params, tokens)
        return logits[:, -1, :]                     # classify-next-token

    init_fn = functools.partial(init_params, cfg, dtype=jnp.bfloat16)
    opt = adam(fed.lr)
    program = wpfed_program(apply_fn, opt, fed)
    if attack != "none":
        # the lowering traces BOTH lax.cond branches, so any
        # attack_start exercises the full attacked graph
        program = instrument_program(program, resolve_threat(
            attack, num_clients=num_clients, attacker_frac=attack_frac,
            init_fn=init_fn, key=jax.random.PRNGKey(1),
            start_round=None if attack_start < 0 else attack_start))
    segment_fn = make_segment_fn(program, reselect_every)

    m, r, s = num_clients, 8, 32
    sds = jax.ShapeDtypeStruct
    key_sds = jax.random.PRNGKey(0)
    state_sds = jax.eval_shape(
        functools.partial(init_state, apply_fn, init_fn, opt, fed), key_sds)
    data_sds = {
        "x_train": sds((m, 64, s), jnp.int32),
        "y_train": sds((m, 64), jnp.int32),
        "x_ref": sds((m, r, s), jnp.int32),
        "y_ref": sds((m, r), jnp.int32),
    }

    def spec_like(sd):
        return NamedSharding(mesh, P("data", *([None] * (len(sd.shape) - 1))))

    state_shard = jax.tree.map(spec_like, state_sds)
    # scalars (rng, round) replicated
    state_shard = state_shard._replace(
        rng=NamedSharding(mesh, P()), round=NamedSharding(mesh, P()),
        commitments=NamedSharding(mesh, P("data")))
    data_shard = jax.tree.map(spec_like, data_sds)
    with mesh:
        lowered = jax.jit(segment_fn,
                          in_shardings=(state_shard, data_shard),
                          out_shardings=None).lower(state_sds, data_sds)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):                  # older jax returns [dict]
        cost = cost[0] if cost else {}
    print(json.dumps({
        "fed_round_clients": m,
        "client_arch": cfg.name,
        "ref_mode": ref_mode,
        "tiling": tiling,
        "reselect_every": reselect_every,
        "attack": attack,
        "mesh": "16x16",
        # analysis: host-ok — AOT cost_analysis dict, no device value
        "flops_per_device": float(cost.get("flops", 0)),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "ok": True}, indent=1))
    return compiled


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="mnist",
                    choices=["mnist", "aecg", "seeg"])
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dryrun", action="store_true",
                    help="lower a 256-client WPFed segment on the 16x16 mesh")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "kernel", "oracle", "ann"],
                    help="kernel-backed subsystem backend — drives both "
                         "selection AND exchange (DESIGN.md §4, §7); "
                         "'ann' switches SELECTION to the sub-quadratic "
                         "LSH-bucket candidate index (DESIGN.md §11) and "
                         "leaves exchange on auto")
    ap.add_argument("--ann-prefix-bits", type=int, default=-1,
                    help="ANN bucket prefix length (-1 = FedConfig "
                         "default; 0 = one-bucket exact fallback)")
    ap.add_argument("--ann-probes", type=int, default=-1,
                    help="ANN multi-probe bit flips — the recall knob "
                         "(-1 = FedConfig default)")
    ap.add_argument("--ref-mode", default="personal",
                    choices=["personal", "public"],
                    help="personal: each client's own reference set "
                         "(M*N forwards); public: one shared reference "
                         "set, exchange is a gather (DESIGN.md §7)")
    ap.add_argument("--tiling", default="auto",
                    choices=["auto", "oneshot", "tiled"],
                    help="kernel VMEM regime — drives both selection "
                         "AND exchange (DESIGN.md §10): oneshot holds "
                         "the full working set per program, tiled "
                         "streams VMEM-bounded tiles, auto picks from "
                         "the explicit VMEM estimate")
    ap.add_argument("--schedule", default="sync",
                    choices=["sync", "gossip"],
                    help="sync: re-select every round (the paper); "
                         "gossip: global re-selection every "
                         "--reselect-every rounds, cheap gossip epochs "
                         "in between (DESIGN.md §8)")
    ap.add_argument("--reselect-every", type=int, default=0,
                    help="gossip period G (0 = schedule default)")
    ap.add_argument("--attack", default="none",
                    choices=("none",) + THREATS,
                    help="in-graph threat model instrumenting the run "
                         "(core.adversary.resolve_threat, DESIGN.md §9)")
    ap.add_argument("--attack-frac", type=float, default=0.5,
                    help="fraction of clients that are attackers "
                         "(the tail of the client axis)")
    ap.add_argument("--attack-start", type=int, default=-1,
                    help="first attacked round (-1 = the threat's "
                         "registry default, e.g. poison's §4.8 warm-up)")
    ap.add_argument("--service", action="store_true",
                    help="run the continuous federation service "
                         "(repro.service, DESIGN.md §13) instead of a "
                         "fixed-round experiment")
    ap.add_argument("--periods", type=int, default=3,
                    help="[service] reselection periods to run")
    ap.add_argument("--churn", default="",
                    help="[service] churn events as "
                         "'period:kind:client,...' e.g. "
                         "'1:leave:4,2:join:5'")
    ap.add_argument("--gossip-counts", default="",
                    help="[service] per-client gossip budgets G_i as a "
                         "comma list (default: full period for all)")
    ap.add_argument("--staleness-lambda", type=float, default=0.5,
                    help="[service] Eq. 8 staleness discount "
                         "exp(-lambda * code_age)")
    ap.add_argument("--ckpt-dir", default="",
                    help="[service] checkpoint directory (durable "
                         "state + chain.json)")
    ap.add_argument("--keep-last-k", type=int, default=3,
                    help="[service] checkpoint retention")
    ap.add_argument("--resume", action="store_true",
                    help="[service] resume from the latest checkpoint "
                         "in --ckpt-dir")
    ap.add_argument("--faults", default="",
                    help="[service] deterministic fault-injection spec "
                         "'seed=7,drop=0.1,delay=0.1,corrupt=0.1,"
                         "straggle=0.2,publish_fail=0.3,crash=2,fork=1' "
                         "(core.faults.parse_fault_spec, DESIGN.md §15)")
    args = ap.parse_args(argv)
    if args.service:
        _, _, history = run_service_federation(
            args.dataset, periods=args.periods,
            reselect_every=args.reselect_every or 4,
            num_clients=args.clients, seed=args.seed, churn=args.churn,
            gossip_counts=args.gossip_counts,
            staleness_lambda=args.staleness_lambda,
            keep_last_k=args.keep_last_k,
            ckpt_dir=args.ckpt_dir or None, resume=args.resume,
            faults=args.faults)
        print(json.dumps(history[-3:], indent=1))
        return
    if args.dryrun:
        import os
        assert "xla_force_host_platform_device_count" in \
            os.environ.get("XLA_FLAGS", ""), \
            "run with XLA_FLAGS=--xla_force_host_platform_device_count=512"
        sched = resolve_schedule(args.schedule, args.reselect_every)
        dryrun_fed_round(num_clients=args.clients or 256,
                         backend="kernel" if args.backend == "auto"
                         else args.backend,  # "ann" lowers the ann path
                         ref_mode=args.ref_mode, tiling=args.tiling,
                         reselect_every=sched.reselect_every,
                         attack=args.attack, attack_frac=args.attack_frac,
                         attack_start=args.attack_start)
        return
    _, history = run_federation(args.dataset, args.rounds,
                                num_clients=args.clients, seed=args.seed,
                                backend=args.backend,
                                ref_mode=args.ref_mode,
                                tiling=args.tiling,
                                schedule=args.schedule,
                                reselect_every=args.reselect_every,
                                attack=args.attack,
                                attack_frac=args.attack_frac,
                                attack_start=args.attack_start,
                                ann_prefix_bits=args.ann_prefix_bits,
                                ann_probes=args.ann_probes)
    print(json.dumps(history[-3:], indent=1))


if __name__ == "__main__":
    main()
