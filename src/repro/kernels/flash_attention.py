"""Pallas TPU kernel: flash-attention forward (online softmax).

The §Perf analysis (EXPERIMENTS.md iteration 6) showed dense train_4k is
memory-bound on the f32 S^2 score chain; the JAX-level chunked attention
fixes the accounting, but the TPU-native answer is this kernel: scores
and probabilities never leave VMEM — HBM traffic reduces to Q/K/V/O.

Layout: q (N, Sq, dh), k/v (N, Sk, dh) with N = batch*heads (the ops.py
wrapper maps GQA onto this). Grid (N, Sq/BQ, Sk/BK), KV innermost so
each program accumulates into the same (BQ, dh) VMEM scratch with the
standard online-softmax correction; the last KV step writes the
normalized output block.

VMEM per program ~= (BQ + 2*BK) * dh * 4 + BQ * BK * 4 + BQ * dh * 4
bytes; defaults (BQ=BK=256, dh<=256) < 2 MB. MXU dims (BQ, dh, BK) are
128-multiples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis.registry import kernel_contract

BQ = 256
BK = 256
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                      # (BQ, dh)
    k = k_ref[0].astype(jnp.float32)                      # (BK, dh)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    if causal:
        qi = pl.program_id(1)
        qpos = qi * q_ref.shape[1] + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 0)
        kpos = ki * k_ref.shape[1] + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)

    m_prev = m_ref[:, 0]
    l_prev = l_ref[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jnp.dot(p, v, preferred_element_type=jnp.float32))
    m_ref[...] = m_new[:, None]
    l_ref[...] = l_new[:, None]

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def _flash_point_args(pt):
    n, sq, sk, dh = pt["n"], pt["sq"], pt["sk"], pt["dh"]
    q = jax.ShapeDtypeStruct((n, sq, dh), jnp.float32)
    kv = jax.ShapeDtypeStruct((n, sk, dh), jnp.float32)
    return (q, kv, kv), dict(causal=True)


@kernel_contract(
    name="flash_attention", sites=1, oracle="flash_attention_ref",
    estimator=None, exactness="tolerance",
    out_revisit=(2,),           # KV axis accumulates into scratch
    points=({"n": 2, "sq": 512, "sk": 512, "dh": 128},
            {"n": 1, "sq": 1024, "sk": 512, "dh": 64}),
    make_args=_flash_point_args)
@functools.partial(jax.jit,
                   static_argnames=("causal", "scale", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, scale: float = 0.0,
                    interpret: bool = True):
    """q: (N, Sq, dh), k/v: (N, Sk, dh) -> (N, Sq, dh)."""
    n, sq, dh = q.shape
    sk = k.shape[1]
    bq, bk = min(BQ, sq), min(BK, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, sk)
    nq, nk = sq // bq, sk // bk
    scale = scale or dh ** -0.5
    import jax.experimental.pallas.tpu as pltpu
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal, nk=nk),
        grid=(n, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
