"""Jitted public wrappers around the Pallas kernels.

These handle padding/packing and backend selection (interpret=True on
CPU, compiled on TPU) and expose pytree-level convenience APIs used by
repro.core.lsh. The pure-jnp semantics live in ref.py; tests assert the
kernel and oracle agree bit-exactly across shape/dtype sweeps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.hamming import BM, BN, hamming_all_pairs
from repro.kernels.lsh_projection import (BLOCK_M, CHUNK,
                                          lsh_project_sums,
                                          lsh_project_sums_batched)


def _interpret() -> bool:
    from repro.core.backends import interpret  # see resolve_backend
    return interpret()


def resolve_backend(backend: str) -> str:
    """Delegates to the single validated resolver in
    repro.core.backends (function-level import: repro.core's package
    __init__ pulls in the whole protocol, which imports this module)."""
    from repro.core.backends import resolve
    return resolve(backend)


def flatten_params(params) -> jnp.ndarray:
    """Pytree -> single f32 vector, padded to a CHUNK multiple."""
    leaves = [jnp.ravel(x).astype(jnp.float32)
              for x in jax.tree.leaves(params)]
    flat = jnp.concatenate(leaves) if leaves else jnp.zeros((0,), jnp.float32)
    pad = (-flat.shape[0]) % CHUNK
    return jnp.pad(flat, (0, pad))


def pack_bits(sums) -> jnp.ndarray:
    """Sign bits of projection sums -> packed uint32 words (little-endian
    within each word). sums: (..., bits) with bits % 32 == 0."""
    bits = (sums > 0).astype(jnp.uint32)
    *lead, b = bits.shape
    words = bits.reshape(*lead, b // 32, 32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return jnp.sum(words * weights, axis=-1, dtype=jnp.uint32)


def unpack_bits(codes, bits: int) -> jnp.ndarray:
    words = codes[..., :, None]
    shifts = jnp.arange(32, dtype=jnp.uint32)
    out = ((words >> shifts) & jnp.uint32(1)).astype(jnp.uint32)
    return out.reshape(*codes.shape[:-1], codes.shape[-1] * 32)[..., :bits]


def flatten_params_batched(stacked_params) -> jnp.ndarray:
    """Stacked (M, ...) pytree -> (M, P) f32 matrix, P padded to a CHUNK
    multiple. Row i equals flatten_params of client i's subtree (same
    leaf order, same ravel)."""
    leaves = [x.reshape(x.shape[0], -1).astype(jnp.float32)
              for x in jax.tree.leaves(stacked_params)]
    flat = jnp.concatenate(leaves, axis=1)
    pad = (-flat.shape[1]) % CHUNK
    return jnp.pad(flat, ((0, 0), (0, pad)))


def batched_lsh_codes(flat2d, seed, *, bits: int = 256,
                      use_kernel: bool = True):
    """WPFed Eq. (5) over the stacked client axis: (M, P) f32 (P a CHUNK
    multiple) -> (M, W) packed uint32 codes. Kernel path pads M to the
    BLOCK_M row grid; padded rows are discarded."""
    m = flat2d.shape[0]
    if use_kernel:
        pm = (-m) % BLOCK_M
        x = jnp.pad(flat2d, ((0, pm), (0, 0)))
        sums = lsh_project_sums_batched(x, seed, bits=bits,
                                        interpret=_interpret())[:m]
    else:
        sums = ref.lsh_project_sums_batched_ref(flat2d, seed, bits=bits)
    return pack_bits(sums)


def lsh_code(params, seed, *, bits: int = 256, use_kernel: bool = True):
    """WPFed Eq. (5): packed uint32 LSH code of a parameter pytree."""
    flat = flatten_params(params)
    if use_kernel:
        sums = lsh_project_sums(flat, seed, bits=bits, interpret=_interpret())
    else:
        sums = ref.lsh_project_sums_ref(flat, seed, bits=bits)
    return pack_bits(sums)


def hamming_matrix(codes, *, use_kernel: bool = True):
    """WPFed Eq. (6) for all pairs: codes (M, W) uint32 -> (M, M) int32.

    Pads M to the kernel tile grid and the word axis to the 128-lane
    width; padding words are zero so they contribute 0 to distances.
    """
    m, w = codes.shape
    if not use_kernel:
        return ref.hamming_all_pairs_ref(codes, codes)
    pm = (-m) % max(BM, BN)
    pw = (-w) % 128
    padded = jnp.pad(codes, ((0, pm), (0, pw)))
    d = hamming_all_pairs(padded, padded, interpret=_interpret())
    return d[:m, :m]


def gqa_flash_attention(q, k, v, *, causal: bool = True,
                        use_kernel: bool = True):
    """GQA wrapper: q (B, Sq, H, dh), k/v (B, Sk, KV, dh) -> (B, Sq, H, dh).
    Expands KV heads to H (gather view) and maps onto the (N, S, dh)
    kernel layout."""
    b, sq, h, dh = q.shape
    kv = k.shape[2]
    g = h // kv
    qk = jnp.moveaxis(q, 2, 1).reshape(b * h, sq, dh)
    kx = jnp.repeat(jnp.moveaxis(k, 2, 1), g, axis=1).reshape(b * h, -1, dh)
    vx = jnp.repeat(jnp.moveaxis(v, 2, 1), g, axis=1).reshape(b * h, -1, dh)
    if use_kernel:
        o = flash_attention(qk, kx, vx, causal=causal,
                            interpret=_interpret())
    else:
        o = ref.flash_attention_ref(qk, kx, vx, causal=causal)
    return jnp.moveaxis(o.reshape(b, h, sq, dh), 1, 2)
