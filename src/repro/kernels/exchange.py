"""Pallas TPU kernel: fused all-in-one exchange (WPFed Eq. 3 + §3.5 +
the distillation-target mean in a single pass).

The unfused round ran three separate log-softmax passes over the same
(M, N, R, C) neighbor-logit tensor — one inside `distill.cross_entropy`
(Eq. 3), one inside `verify.kl_divergence` (§3.5), and then re-read the
tensor a third time for `distill.aggregate_neighbor_outputs`. This
kernel computes ONE shared neighbor log-softmax per client block and
derives all three results from it while the (N, R, C) tile sits in
VMEM (DESIGN.md §7):

  * Eq. 3 CE losses l_ij via take_along_axis on the reference labels
    (a one-hot compare+sum lowers more naturally on TPU but XLA's
    fusion rewrites it away from the gathered value in the last ulp —
    see the in-kernel comment; revisit if Mosaic rejects the gather on
    compiled TPU);
  * §3.5 output-KL divergences against the client's own reference
    outputs, plus the upper-half keep filter. The rank is computed in
    counting form — rank(n) = #{m : kl_m < kl_n} + #{m < n : kl_m ==
    kl_n} — which equals the stable-argsort rank the unfused
    `verify.lsh_verification_mask` derives from a double argsort
    (jnp.argsort is stable; ties break ascending-index), at O(N^2)
    compares instead of an in-kernel sort Mosaic would struggle with;
  * the masked distillation-target mean over the neighbors that passed
    (zeros fallback when none do — `has_target` is derived from the
    returned mask by the wrapper, it is a free reduction).

Bit-exactness (tests/test_exchange_pipeline.py): every derived value
consumes the same floats in the same reduction order as the jnp oracle
twin (`ref.all_in_one_exchange_ref`), so kernel and oracle agree
bit-exactly in interpret mode; the oracle in turn is bit-identical to
the unfused cross_entropy -> lsh_verification_mask ->
aggregate_neighbor_outputs composition the round used to run.

VMEM per program ~= BM_EXC * (N + 1) * R * C * 4 bytes for the logit
tiles (at BM=4, N=16, R=64, C=1024 that is ~17 MB) — `fused_exchange`
therefore caps near C ~ 10^3; vocab-scale reference sets need
`fused_exchange_streamed` (DESIGN.md §10): a (client-block, R-tile,
C-tile) grid that streams (BM, N, BR, BC) blocks with a
flash-attention-style online max / log-sum-exp for the shared neighbor
log-softmax (see kernels/flash_attention.py). CE reduces to
lse_nb - x_nb[y] (the label logit is gathered as C tiles stream by),
the §3.5 output-KL to B/A - lse_own + lse_nb where A/B are online
exp-weighted sums, and the per-row means accumulate across R tiles.
Exactness contract (DESIGN.md §10): the online reductions REORDER the
softmax sums, so the streamed path is NOT bit-exact against the
one-shot oracle — l_ij and target are tolerance-bounded (last-ulp
scale) against both `ref.all_in_one_exchange_ref` and the streaming
jnp twin `ref.streamed_exchange_ref` (same tile walk; XLA's
fusion-dependent FMA/reassociation rewrites keep even kernel-vs-twin
agreement at the ulp level rather than bitwise), while the §3.5 valid
mask only flips on exact kl ties and is pinned EQUAL in tests. The
one-shot kernel/oracle pair remains the bit-exact default; backend
resolution (`core.backends.resolve_tiling`) only picks the streamed
path when the one-shot working set exceeds the VMEM budget. The
distillation-target mean is a second, stateless pass
(`_target_kernel`) over the same tiles once the §3.5 mask is known;
its per-element N-contraction is unchanged by R/C tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis.registry import kernel_contract

BM_EXC = 4          # client block per program
BR_EXC = 8          # reference-row tile of the streamed kernel
BC_EXC = 512        # class-column tile of the streamed kernel


def _upper_half_mask(kl_mean, sel_int):
    """§3.5 upper-half keep filter in counting-rank form, shared by the
    one-shot and streamed kernels: rank(n) = #{m : kl_m < kl_n} +
    #{m < n : kl_m == kl_n} (the stable-argsort rank)."""
    bm, n = kl_mean.shape
    selm = sel_int != 0
    kls = jnp.where(selm, kl_mean, jnp.inf)
    n_valid = jnp.sum(sel_int, axis=-1, keepdims=True)
    keep = (n_valid + 1) // 2
    lt = kls[:, :, None] < kls[:, None, :]
    eq = kls[:, :, None] == kls[:, None, :]
    a_idx = jax.lax.broadcasted_iota(jnp.int32, (bm, n, n), 1)
    b_idx = jax.lax.broadcasted_iota(jnp.int32, (bm, n, n), 2)
    rank_of = jnp.sum((lt | (eq & (a_idx < b_idx))).astype(jnp.int32),
                      axis=1)                         # stable-sort rank
    return (rank_of < keep) & selm


def _exchange_kernel(own_ref, nb_ref, y_ref, sel_ref,
                     l_ref, valid_ref, target_ref, *,
                     lsh_verification: bool):
    nb = nb_ref[...].astype(jnp.float32)              # (BM, N, R, C)
    bm, n, r, c = nb.shape
    logp_nb = jax.nn.log_softmax(nb, axis=-1)         # ONE shared pass
    selm = sel_ref[...] != 0                          # (BM, N)

    # Eq. 3: CE of each neighbor's logits on the reference labels.
    # take_along_axis, NOT a one-hot sum: XLA's fusion rewrites
    # sum(where(onehot, logp, 0)) into a form that differs from the
    # gathered value in the last ulp, which would break kernel/oracle
    # bit-exactness (verified empirically; the two are identical
    # un-jitted).
    nll = -jnp.take_along_axis(logp_nb, y_ref[...][:, None, :, None],
                               axis=-1)[..., 0]
    l_ref[...] = jnp.mean(nll, axis=-1)               # (BM, N)

    # §3.5: output-KL upper-half filter over the selected slots
    if lsh_verification:
        logp_own = jax.nn.log_softmax(
            own_ref[...].astype(jnp.float32), axis=-1)  # (BM, R, C)
        kl = jnp.sum(jnp.exp(logp_own)[:, None]
                     * (logp_own[:, None] - logp_nb), axis=-1)
        valid = _upper_half_mask(jnp.mean(kl, axis=-1), sel_ref[...])
    else:
        valid = selm
    valid_ref[...] = valid.astype(jnp.int32)

    # masked distillation-target mean (zeros fallback when none pass)
    w = valid.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(w, axis=-1), 1.0)
    target_ref[...] = (jnp.einsum("bn,bnrc->brc", w, nb)
                       / denom[:, None, None])


# --- repro.analysis contract helpers (DESIGN.md §12) -----------------------
def _exchange_point_args(pt):
    """Abstract (ShapeDtypeStruct) args for an {m, n, r, c} point."""
    m, n, r, c = pt["m"], pt["n"], pt["r"], pt["c"]
    args = (jax.ShapeDtypeStruct((m, r, c), jnp.float32),
            jax.ShapeDtypeStruct((m, n, r, c), jnp.float32),
            jax.ShapeDtypeStruct((m, r), jnp.int32),
            jax.ShapeDtypeStruct((m, n), jnp.bool_))
    return args, dict(lsh_verification=True)


@kernel_contract(
    name="exchange_oneshot", sites=1, oracle="all_in_one_exchange_ref",
    estimator="exchange_vmem_bytes", exactness="bit_exact",
    out_revisit=(),
    points=({"m": 8, "n": 8, "r": 32, "c": 512},
            {"m": 8, "n": 16, "r": 64, "c": 1024},
            {"m": 4, "n": 4, "r": 16, "c": 256}),
    make_args=_exchange_point_args,
    estimator_kwargs=lambda pt: {"n": pt["n"], "r": pt["r"],
                                 "c": pt["c"]},
    slack=0.05)
@functools.partial(jax.jit, static_argnames=("lsh_verification",
                                             "interpret"))
def fused_exchange(own_logits, neighbor_logits, y_ref, sel_mask, *,
                   lsh_verification: bool = True, interpret: bool = True):
    """Fused Eq. 3 + §3.5 + target mean. own_logits: (M, R, C);
    neighbor_logits: (M, N, R, C); y_ref: (M, R) int; sel_mask: (M, N)
    bool -> (l_ij (M, N) f32, valid (M, N) bool, target_ref (M, R, C)
    f32, has_target (M,) bool). Pads M to the client-block grid; padded
    rows carry an all-False selection mask and are discarded."""
    m, n, r, c = neighbor_logits.shape
    pm = (-m) % BM_EXC
    own_p = jnp.pad(own_logits.astype(jnp.float32),
                    ((0, pm), (0, 0), (0, 0)))
    nb_p = jnp.pad(neighbor_logits.astype(jnp.float32),
                   ((0, pm), (0, 0), (0, 0), (0, 0)))
    y_p = jnp.pad(y_ref.astype(jnp.int32), ((0, pm), (0, 0)))
    sel_p = jnp.pad(sel_mask.astype(jnp.int32), ((0, pm), (0, 0)))
    mp = m + pm
    l_ij, valid, target = pl.pallas_call(
        functools.partial(_exchange_kernel,
                          lsh_verification=lsh_verification),
        grid=(mp // BM_EXC,),
        in_specs=[
            pl.BlockSpec((BM_EXC, r, c), lambda i: (i, 0, 0)),
            pl.BlockSpec((BM_EXC, n, r, c), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((BM_EXC, r), lambda i: (i, 0)),
            pl.BlockSpec((BM_EXC, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BM_EXC, n), lambda i: (i, 0)),
            pl.BlockSpec((BM_EXC, n), lambda i: (i, 0)),
            pl.BlockSpec((BM_EXC, r, c), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, n), jnp.float32),
            jax.ShapeDtypeStruct((mp, n), jnp.int32),
            jax.ShapeDtypeStruct((mp, r, c), jnp.float32),
        ],
        interpret=interpret,
    )(own_p, nb_p, y_p, sel_p)
    valid = valid[:m].astype(bool)
    return l_ij[:m], valid, target[:m], jnp.any(valid, axis=-1)


# ---------------------------------------------------------------------------
# streamed (R/C-tiled) variant — vocab-scale reference sets
# ---------------------------------------------------------------------------
def _streamed_stats_kernel(own_ref, nb_ref, y_ref, sel_ref,
                           l_ref, valid_ref,
                           l_acc, kl_acc, m_nb, a_nb, g_nb, b_x,
                           m_own, a_own, *, lsh_verification: bool,
                           r_real: int, c_real: int, br: int, bc: int,
                           nr: int, nc: int):
    ri = pl.program_id(1)
    ci = pl.program_id(2)

    @pl.when((ri == 0) & (ci == 0))
    def _init_round():
        l_acc[...] = jnp.zeros_like(l_acc)
        kl_acc[...] = jnp.zeros_like(kl_acc)

    @pl.when(ci == 0)
    def _init_tile():
        m_nb[...] = jnp.full_like(m_nb, -jnp.inf)
        a_nb[...] = jnp.zeros_like(a_nb)
        g_nb[...] = jnp.zeros_like(g_nb)
        b_x[...] = jnp.zeros_like(b_x)
        m_own[...] = jnp.full_like(m_own, -jnp.inf)
        a_own[...] = jnp.zeros_like(a_own)

    xo = own_ref[...].astype(jnp.float32)             # (BM, BR, BC)
    xn = nb_ref[...].astype(jnp.float32)              # (BM, N, BR, BC)
    col = ci * bc + jax.lax.broadcasted_iota(jnp.int32, (bc,), 0)
    cvalid = col < c_real                             # (BC,)
    xo_m = jnp.where(cvalid, xo, -jnp.inf)
    xn_m = jnp.where(cvalid, xn, -jnp.inf)

    # online max / sum-exp (flash-attention correction; every C tile
    # contains at least one real column, so the new max is finite and
    # the correction factors never see inf - inf)
    mo_new = jnp.maximum(m_own[...], jnp.max(xo_m, axis=-1))
    co = jnp.exp(m_own[...] - mo_new)
    po = jnp.exp(xo_m - mo_new[..., None])            # (BM, BR, BC)
    a_own[...] = a_own[...] * co + jnp.sum(po, axis=-1)
    mn_new = jnp.maximum(m_nb[...], jnp.max(xn_m, axis=-1))
    cn = jnp.exp(m_nb[...] - mn_new)
    a_nb[...] = (a_nb[...] * cn
                 + jnp.sum(jnp.exp(xn_m - mn_new[..., None]), axis=-1))
    # cross term of the §3.5 KL: sum_c exp(x_own - m) * (x_own - x_nb)
    b_x[...] = (b_x[...] * co[:, None]
                + jnp.sum(po[:, None] * (xo[:, None] - xn), axis=-1))
    # Eq. 3 label-logit gather: the C tile holding y contributes x[y]
    # exactly once (raw logits, exact zeros elsewhere)
    match = col[None, None, :] == y_ref[...][:, :, None]  # (BM, BR, BC)
    g_nb[...] = g_nb[...] + jnp.sum(
        jnp.where(match[:, None], xn, 0.0), axis=-1)
    m_own[...] = mo_new
    m_nb[...] = mn_new

    @pl.when(ci == nc - 1)
    def _fold_tile():
        lse_nb = m_nb[...] + jnp.log(a_nb[...])       # (BM, N, BR)
        lse_own = m_own[...] + jnp.log(a_own[...])    # (BM, BR)
        rvalid = (ri * br
                  + jax.lax.broadcasted_iota(jnp.int32, (br,), 0)) < r_real
        nll = lse_nb - g_nb[...]
        l_acc[...] = l_acc[...] + jnp.sum(
            jnp.where(rvalid, nll, 0.0), axis=-1)
        kl_r = (b_x[...] / a_own[...][:, None]
                - lse_own[:, None] + lse_nb)
        kl_acc[...] = kl_acc[...] + jnp.sum(
            jnp.where(rvalid, kl_r, 0.0), axis=-1)

    @pl.when((ri == nr - 1) & (ci == nc - 1))
    def _finalize():
        l_ref[...] = l_acc[...] / float(r_real)
        if lsh_verification:
            valid = _upper_half_mask(kl_acc[...] / float(r_real),
                                     sel_ref[...])
        else:
            valid = sel_ref[...] != 0
        valid_ref[...] = valid.astype(jnp.int32)


def _target_kernel(nb_ref, w_ref, t_ref):
    """Masked distillation-target mean over one (BM, N, BR, BC) tile.
    Stateless: the N-contraction is per output element, so R/C tiling
    does not change its value."""
    w = w_ref[...].astype(jnp.float32)                # (BM, N)
    denom = jnp.maximum(jnp.sum(w, axis=-1), 1.0)
    t_ref[...] = (jnp.einsum("bn,bnrc->brc", w,
                             nb_ref[...].astype(jnp.float32))
                  / denom[:, None, None])


def streamed_tiles(r: int, c: int, block_r: int, block_c: int):
    """Clamp the (BR, BC) tile to the (8, 128)-padded problem so small
    shapes run as a single tile; returns (br, pr, bc, pc)."""
    br = min(block_r, r + (-r) % 8)
    bc = min(block_c, c + (-c) % 128)
    return br, (-r) % br, bc, (-c) % bc


@kernel_contract(
    name="exchange_streamed", sites=2, oracle="streamed_exchange_ref",
    estimator="exchange_tiled_vmem_bytes", exactness="tolerance",
    # stats site: outputs land once at (i, 0) while the (ri, ci) tile
    # axes accumulate into scratch; target site writes (i, ri, ci)
    # exactly once.
    out_revisit=((1, 2), ()),
    points=({"m": 8, "n": 8, "r": 32, "c": 2048},
            {"m": 4, "n": 16, "r": 64, "c": 1024},
            {"m": 4, "n": 8, "r": 16, "c": 4096}),
    make_args=_exchange_point_args,
    estimator_kwargs=lambda pt: {"n": pt["n"]},
    slack=0.05)
@functools.partial(jax.jit, static_argnames=(
    "lsh_verification", "interpret", "block_m", "block_r", "block_c"))
def fused_exchange_streamed(own_logits, neighbor_logits, y_ref, sel_mask,
                            *, lsh_verification: bool = True,
                            interpret: bool = True, block_m: int = BM_EXC,
                            block_r: int = BR_EXC, block_c: int = BC_EXC):
    """Streamed Eq. 3 + §3.5 + target mean (DESIGN.md §10): same
    contract as `fused_exchange`, but VMEM per program is
    O(BM * N * BR * BC) — R and C are bounded by HBM, not VMEM.
    Tolerance-bounded against the one-shot pair and the streaming twin
    `ref.streamed_exchange_ref` (the online softmax reorders the
    reductions; the §3.5 mask flips only on exact kl ties — see the
    module docstring for the full §10 contract)."""
    m, n, r, c = neighbor_logits.shape
    import jax.experimental.pallas.tpu as pltpu
    bm = min(block_m, m + (-m) % BM_EXC)
    pm = (-m) % bm
    br, pr, bc, pc = streamed_tiles(r, c, block_r, block_c)
    own_p = jnp.pad(own_logits.astype(jnp.float32),
                    ((0, pm), (0, pr), (0, pc)))
    nb_p = jnp.pad(neighbor_logits.astype(jnp.float32),
                   ((0, pm), (0, 0), (0, pr), (0, pc)))
    y_p = jnp.pad(y_ref.astype(jnp.int32), ((0, pm), (0, pr)))
    sel_p = jnp.pad(sel_mask.astype(jnp.int32), ((0, pm), (0, 0)))
    mp, nr, nc = m + pm, (r + pr) // br, (c + pc) // bc
    l_ij, valid = pl.pallas_call(
        functools.partial(_streamed_stats_kernel,
                          lsh_verification=lsh_verification,
                          r_real=r, c_real=c, br=br, bc=bc, nr=nr, nc=nc),
        grid=(mp // bm, nr, nc),                      # C innermost
        in_specs=[
            pl.BlockSpec((bm, br, bc), lambda i, ri, ci: (i, ri, ci)),
            pl.BlockSpec((bm, n, br, bc),
                         lambda i, ri, ci: (i, 0, ri, ci)),
            pl.BlockSpec((bm, br), lambda i, ri, ci: (i, ri)),
            pl.BlockSpec((bm, n), lambda i, ri, ci: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, n), lambda i, ri, ci: (i, 0)),
            pl.BlockSpec((bm, n), lambda i, ri, ci: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, n), jnp.float32),
            jax.ShapeDtypeStruct((mp, n), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, n), jnp.float32),         # l_acc
            pltpu.VMEM((bm, n), jnp.float32),         # kl_acc
            pltpu.VMEM((bm, n, br), jnp.float32),     # running max (nb)
            pltpu.VMEM((bm, n, br), jnp.float32),     # running sum-exp (nb)
            pltpu.VMEM((bm, n, br), jnp.float32),     # label-logit gather
            pltpu.VMEM((bm, n, br), jnp.float32),     # KL cross term
            pltpu.VMEM((bm, br), jnp.float32),        # running max (own)
            pltpu.VMEM((bm, br), jnp.float32),        # running sum-exp (own)
        ],
        interpret=interpret,
    )(own_p, nb_p, y_p, sel_p)
    target = pl.pallas_call(
        _target_kernel,
        grid=(mp // bm, nr, nc),
        in_specs=[
            pl.BlockSpec((bm, n, br, bc),
                         lambda i, ri, ci: (i, 0, ri, ci)),
            pl.BlockSpec((bm, n), lambda i, ri, ci: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, br, bc), lambda i, ri, ci: (i, ri, ci)),
        out_shape=jax.ShapeDtypeStruct((mp, r + pr, c + pc), jnp.float32),
        interpret=interpret,
    )(nb_p, valid)
    valid_b = valid[:m].astype(bool)
    return (l_ij[:m], valid_b, target[:m, :r, :c],
            jnp.any(valid_b, axis=-1))
