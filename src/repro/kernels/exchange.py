"""Pallas TPU kernel: fused all-in-one exchange (WPFed Eq. 3 + §3.5 +
the distillation-target mean in a single pass).

The unfused round ran three separate log-softmax passes over the same
(M, N, R, C) neighbor-logit tensor — one inside `distill.cross_entropy`
(Eq. 3), one inside `verify.kl_divergence` (§3.5), and then re-read the
tensor a third time for `distill.aggregate_neighbor_outputs`. This
kernel computes ONE shared neighbor log-softmax per client block and
derives all three results from it while the (N, R, C) tile sits in
VMEM (DESIGN.md §7):

  * Eq. 3 CE losses l_ij via take_along_axis on the reference labels
    (a one-hot compare+sum lowers more naturally on TPU but XLA's
    fusion rewrites it away from the gathered value in the last ulp —
    see the in-kernel comment; revisit if Mosaic rejects the gather on
    compiled TPU);
  * §3.5 output-KL divergences against the client's own reference
    outputs, plus the upper-half keep filter. The rank is computed in
    counting form — rank(n) = #{m : kl_m < kl_n} + #{m < n : kl_m ==
    kl_n} — which equals the stable-argsort rank the unfused
    `verify.lsh_verification_mask` derives from a double argsort
    (jnp.argsort is stable; ties break ascending-index), at O(N^2)
    compares instead of an in-kernel sort Mosaic would struggle with;
  * the masked distillation-target mean over the neighbors that passed
    (zeros fallback when none do — `has_target` is derived from the
    returned mask by the wrapper, it is a free reduction).

Bit-exactness (tests/test_exchange_pipeline.py): every derived value
consumes the same floats in the same reduction order as the jnp oracle
twin (`ref.all_in_one_exchange_ref`), so kernel and oracle agree
bit-exactly in interpret mode; the oracle in turn is bit-identical to
the unfused cross_entropy -> lsh_verification_mask ->
aggregate_neighbor_outputs composition the round used to run.

VMEM per program ~= BM_EXC * (N + 1) * R * C * 4 bytes for the logit
tiles (at BM=4, N=16, R=64, C=1024 that is ~17 MB — reduce BM_EXC or
tile R before running vocab-scale reference sets compiled).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM_EXC = 4          # client block per program


def _exchange_kernel(own_ref, nb_ref, y_ref, sel_ref,
                     l_ref, valid_ref, target_ref, *,
                     lsh_verification: bool):
    nb = nb_ref[...].astype(jnp.float32)              # (BM, N, R, C)
    bm, n, r, c = nb.shape
    logp_nb = jax.nn.log_softmax(nb, axis=-1)         # ONE shared pass
    selm = sel_ref[...] != 0                          # (BM, N)

    # Eq. 3: CE of each neighbor's logits on the reference labels.
    # take_along_axis, NOT a one-hot sum: XLA's fusion rewrites
    # sum(where(onehot, logp, 0)) into a form that differs from the
    # gathered value in the last ulp, which would break kernel/oracle
    # bit-exactness (verified empirically; the two are identical
    # un-jitted).
    nll = -jnp.take_along_axis(logp_nb, y_ref[...][:, None, :, None],
                               axis=-1)[..., 0]
    l_ref[...] = jnp.mean(nll, axis=-1)               # (BM, N)

    # §3.5: output-KL upper-half filter over the selected slots
    if lsh_verification:
        logp_own = jax.nn.log_softmax(
            own_ref[...].astype(jnp.float32), axis=-1)  # (BM, R, C)
        kl = jnp.sum(jnp.exp(logp_own)[:, None]
                     * (logp_own[:, None] - logp_nb), axis=-1)
        kls = jnp.where(selm, jnp.mean(kl, axis=-1), jnp.inf)
        n_valid = jnp.sum(sel_ref[...], axis=-1, keepdims=True)
        keep = (n_valid + 1) // 2
        lt = kls[:, :, None] < kls[:, None, :]
        eq = kls[:, :, None] == kls[:, None, :]
        a_idx = jax.lax.broadcasted_iota(jnp.int32, (bm, n, n), 1)
        b_idx = jax.lax.broadcasted_iota(jnp.int32, (bm, n, n), 2)
        rank_of = jnp.sum((lt | (eq & (a_idx < b_idx))).astype(jnp.int32),
                          axis=1)                     # stable-sort rank
        valid = (rank_of < keep) & selm
    else:
        valid = selm
    valid_ref[...] = valid.astype(jnp.int32)

    # masked distillation-target mean (zeros fallback when none pass)
    w = valid.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(w, axis=-1), 1.0)
    target_ref[...] = (jnp.einsum("bn,bnrc->brc", w, nb)
                       / denom[:, None, None])


@functools.partial(jax.jit, static_argnames=("lsh_verification",
                                             "interpret"))
def fused_exchange(own_logits, neighbor_logits, y_ref, sel_mask, *,
                   lsh_verification: bool = True, interpret: bool = True):
    """Fused Eq. 3 + §3.5 + target mean. own_logits: (M, R, C);
    neighbor_logits: (M, N, R, C); y_ref: (M, R) int; sel_mask: (M, N)
    bool -> (l_ij (M, N) f32, valid (M, N) bool, target_ref (M, R, C)
    f32, has_target (M,) bool). Pads M to the client-block grid; padded
    rows carry an all-False selection mask and are discarded."""
    m, n, r, c = neighbor_logits.shape
    pm = (-m) % BM_EXC
    own_p = jnp.pad(own_logits.astype(jnp.float32),
                    ((0, pm), (0, 0), (0, 0)))
    nb_p = jnp.pad(neighbor_logits.astype(jnp.float32),
                   ((0, pm), (0, 0), (0, 0), (0, 0)))
    y_p = jnp.pad(y_ref.astype(jnp.int32), ((0, pm), (0, 0)))
    sel_p = jnp.pad(sel_mask.astype(jnp.int32), ((0, pm), (0, 0)))
    mp = m + pm
    l_ij, valid, target = pl.pallas_call(
        functools.partial(_exchange_kernel,
                          lsh_verification=lsh_verification),
        grid=(mp // BM_EXC,),
        in_specs=[
            pl.BlockSpec((BM_EXC, r, c), lambda i: (i, 0, 0)),
            pl.BlockSpec((BM_EXC, n, r, c), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((BM_EXC, r), lambda i: (i, 0)),
            pl.BlockSpec((BM_EXC, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BM_EXC, n), lambda i: (i, 0)),
            pl.BlockSpec((BM_EXC, n), lambda i: (i, 0)),
            pl.BlockSpec((BM_EXC, r, c), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, n), jnp.float32),
            jax.ShapeDtypeStruct((mp, n), jnp.int32),
            jax.ShapeDtypeStruct((mp, r, c), jnp.float32),
        ],
        interpret=interpret,
    )(own_p, nb_p, y_p, sel_p)
    valid = valid[:m].astype(bool)
    return l_ij[:m], valid, target[:m], jnp.any(valid, axis=-1)
