"""Pallas TPU kernel: sign-random-projection LSH over a parameter vector.

WPFed Eq. (5): lsh_i = LSH(theta_i, b). At LLM scale the parameter vector
has up to 10^12 entries, so the P x b Gaussian projection matrix of the
textbook construction can never be materialized. We instead use a
*Rademacher* (+-1) projection whose entries are generated on the fly
inside the kernel from a counter-based integer hash of (param_index,
bit_index, seed) — an equally valid angular-distance LSH (sign random
projection only needs a symmetric sub-Gaussian row distribution), with
zero memory traffic for the projection matrix. This is the TPU-native
adaptation recorded in DESIGN.md §3.

Grid (single client): one program per parameter chunk; each program
materializes a (CHUNK, BITS) +-1 block in VREGs via iota hashing,
computes the (1, CHUNK) x (CHUNK, BITS) partial product on the MXU, and
accumulates into the (1, BITS) output block (revisited across the whole
grid).

Batched variant (DESIGN.md §4): the federation hot path hashes ALL M
clients per round, so `lsh_project_sums_batched` runs a 2D grid over
(client-block, chunk) directly on the stacked (M, P) parameter matrix.
Each program computes a (BLOCK_M, CHUNK) x (CHUNK, BITS) partial
product — the Rademacher block is generated ONCE per chunk step and
shared by all BLOCK_M clients in the block, amortizing the hash
arithmetic M-fold versus vmapping the single-client kernel (which has
no batching rule anyway). Chunk is the innermost grid axis so the
(BLOCK_M, BITS) output block accumulates across chunk steps in the
same chunk order as the single-client kernel; within-chunk matmul
reduction order may differ by shape, so projection *sums* agree to f32
tolerance while the packed sign-bit *codes* are bit-exact (tested).

VMEM budget per program ~= CHUNK*4 (x block) + CHUNK*BITS*4 (R block)
+ BITS*4 bytes; defaults (2048, 256) ~= 2.1 MB. The batched kernel
multiplies the x and out terms by BLOCK_M (default 8): ~2.2 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis.registry import kernel_contract

CHUNK = 2048
BLOCK_M = 8        # client rows per batched program (f32 sublane width)
_K1 = 2654435761   # Knuth multiplicative hash (plain ints: pallas kernels
_K2 = 40503        # may not close over externally-created jax arrays)
_K3 = 2246822519


def rademacher_block(i0, chunk, bits, seed):
    """Deterministic +-1 block R[i0:i0+chunk, :bits] (f32).

    Shared by kernel and oracle (ref.py imports it) — the hash is pure
    uint32 arithmetic so it lowers identically on TPU and in interpret
    mode on CPU.
    """
    i = (jnp.uint32(i0) + jax.lax.broadcasted_iota(jnp.uint32, (chunk, bits), 0))
    j = jax.lax.broadcasted_iota(jnp.uint32, (chunk, bits), 1)
    h = i * jnp.uint32(_K1) ^ (j * jnp.uint32(_K2)
                               + jnp.uint32(seed) * jnp.uint32(_K3))
    h = h ^ (h >> jnp.uint32(15))
    h = h * jnp.uint32(_K3)
    h = h ^ (h >> jnp.uint32(13))
    bit = (h >> jnp.uint32(9)) & jnp.uint32(1)
    return 1.0 - 2.0 * bit.astype(jnp.float32)


def _lsh_kernel(seed_ref, x_ref, out_ref, *, bits: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)                    # (1, CHUNK)
    r = rademacher_block(step * CHUNK, CHUNK, bits, seed_ref[0])
    out_ref[...] += jnp.dot(x, r, preferred_element_type=jnp.float32)


@kernel_contract(
    name="lsh_single", sites=1, oracle="lsh_project_sums_ref",
    estimator=None, exactness="tolerance",
    out_revisit=(0,),           # the (1, bits) block accumulates chunks
    points=({"p": 4096, "bits": 256}, {"p": 8192, "bits": 256}),
    make_args=lambda pt: (
        (jax.ShapeDtypeStruct((pt["p"],), jnp.float32),),
        dict(seed=7, bits=pt["bits"])))
@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def lsh_project_sums(x, seed, *, bits: int = 256, interpret: bool = True):
    """x: (P,) f32 (P padded to CHUNK by the caller) -> (bits,) f32 sums."""
    assert x.ndim == 1 and x.shape[0] % CHUNK == 0, x.shape
    n_chunks = x.shape[0] // CHUNK
    x2 = x.reshape(n_chunks, CHUNK)
    seed_arr = jnp.asarray([seed], jnp.uint32)
    out = pl.pallas_call(
        functools.partial(_lsh_kernel, bits=bits),
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),            # seed (revisited)
            pl.BlockSpec((1, CHUNK), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bits), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, bits), jnp.float32),
        interpret=interpret,
    )(seed_arr, x2)
    return out[0]


def _lsh_batched_kernel(seed_ref, x_ref, out_ref, *, bits: int):
    chunk_step = pl.program_id(1)

    @pl.when(chunk_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)                    # (BLOCK_M, CHUNK)
    r = rademacher_block(chunk_step * CHUNK, CHUNK, bits, seed_ref[0])
    out_ref[...] += jnp.dot(x, r, preferred_element_type=jnp.float32)


@kernel_contract(
    name="lsh_batched", sites=1, oracle="lsh_project_sums_batched_ref",
    estimator=None, exactness="tolerance",
    out_revisit=(1,),           # chunk axis accumulates into (BM, bits)
    points=({"m": 16, "p": 4096, "bits": 256},
            {"m": 8, "p": 8192, "bits": 256}),
    make_args=lambda pt: (
        (jax.ShapeDtypeStruct((pt["m"], pt["p"]), jnp.float32),),
        dict(seed=7, bits=pt["bits"])))
@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def lsh_project_sums_batched(x, seed, *, bits: int = 256,
                             interpret: bool = True):
    """Batched Eq. (5) over the stacked client axis.

    x: (M, P) f32 with M % BLOCK_M == 0 and P % CHUNK == 0 (caller pads;
    see ops.batched_lsh_codes) -> (M, bits) f32 projection sums.

    Grid is (M // BLOCK_M, P // CHUNK) with chunk innermost, so each
    (BLOCK_M, bits) output block is revisited across its row of chunk
    programs and accumulates in the same chunk order as the
    single-client kernel.
    """
    assert x.ndim == 2 and x.shape[0] % BLOCK_M == 0 \
        and x.shape[1] % CHUNK == 0, x.shape
    m, p = x.shape
    seed_arr = jnp.asarray(jnp.reshape(seed, (1,)), jnp.uint32)
    return pl.pallas_call(
        functools.partial(_lsh_batched_kernel, bits=bits),
        grid=(m // BLOCK_M, p // CHUNK),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),         # seed (revisited)
            pl.BlockSpec((BLOCK_M, CHUNK), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((BLOCK_M, bits), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, bits), jnp.float32),
        interpret=interpret,
    )(seed_arr, x)
