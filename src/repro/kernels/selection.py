"""Pallas TPU kernel: fused peer selection (WPFed Eq. 6-8 in one pass).

The unfused round does hamming_matrix -> normalized_distance ->
selection_weights -> top_k, materializing three (M, M) arrays in HBM
plus a (M, M, W) XOR-broadcast intermediate. This kernel fuses the whole
chain: each program owns a (BM, M) row block of the weight matrix and
produces the per-row top-N ids/weights directly — nothing (M, M)-shaped
ever leaves VMEM (DESIGN.md §4).

Distance trick: instead of XOR + SWAR popcount (pure VPU integer work),
codes are unpacked to +-1 floats and the Gram matrix goes through the
MXU: dot(u_i, u_j) = agreements - disagreements = bits_tot - 2 * d_ij,
so d_ij = (bits_tot - dot) / 2. Every intermediate is an integer with
|value| <= bits_tot << 2^24, exact in f32 regardless of reduction
order — the kernel is therefore bit-exact against the jnp oracle
(ref.fused_select_ref, which computes the same integers via popcount +
an exp lookup table, the CPU-fast form) AND against the unfused
popcount composition. Caveat: the distances are exact everywhere, but
exp is not — in interpret mode kernel and oracle share XLA's exp
(bit-exact, tested); on compiled TPU, Mosaic's exp lowering could
differ from XLA's in the last ulp, which would flip selection order
only for weights within 1 ulp of each other. If TPU hardware ever
shows such divergence, pass the oracle's (bits+1)-entry LUT into the
kernel and gather instead of calling exp (DESIGN.md §4).

Weighting (Eq. 8): w_ij = s_j * exp(-gamma * d_ij / bits), with the
Table-3 ablation switches compiled in (use_lsh / use_rank static flags;
the both-off random ablation needs an rng and stays outside the kernel —
see core.neighbor.select_partners). Self-weights and padded columns are
masked to -inf before selection.

Top-N: N iterations of (max, argmax, knock out) over the row block.
argmax takes the first maximum, which reproduces jax.lax.top_k's
tie-breaking (ascending index among equal values), so selected ids
match the unfused path exactly as long as N <= M-1 (always true: the
protocol clamps N to M-1, and every non-self weight is finite).

The packed word axis is NOT padded: the arrays the kernel computes on
are the unpacked (rows, W*32) bit matrices, whose last dim is already
a lane multiple for any bits in {128, 256, 512, ...}. VMEM per program
~= (BM + M) * bits * 4 (unpacked codes) + BM * M * 4 (weights); at
BM=8, M=4096, bits=256 that is ~4.3 MB. Scaling past M ~ 10^4 needs a
column-tiled two-pass top-N (DESIGN.md §4, future).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM_SEL = 8          # row block (f32 sublane width)


def unpack_pm1(words):
    """(R, W) packed uint32 -> (R, W*32) f32 in {-1, +1} (bit=1 -> +1).
    Pure shifts + masks; lowers identically on TPU and in interpret
    mode."""
    r, w = words.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (r, w, 32), 2)
    bits01 = ((words[:, :, None] >> shifts) & jnp.uint32(1))
    return (2.0 * bits01.astype(jnp.float32) - 1.0).reshape(r, w * 32)


def _select_kernel(a_ref, b_ref, s_ref, ids_ref, w_ref, *, bits: int,
                   gamma: float, nsel: int, m_real: int,
                   use_lsh: bool, use_rank: bool):
    row0 = pl.program_id(0) * BM_SEL
    ua = unpack_pm1(a_ref[...])                       # (BM, bits_tot)
    ub = unpack_pm1(b_ref[...])                       # (Mp, bits_tot)
    bits_tot = ua.shape[1]
    gram = jnp.dot(ua, ub.T, preferred_element_type=jnp.float32)
    d = (float(bits_tot) - gram) * 0.5                # exact integer f32

    mp = d.shape[1]
    if use_rank:
        w = jnp.broadcast_to(s_ref[...], (BM_SEL, mp))
    else:
        w = jnp.ones((BM_SEL, mp), jnp.float32)
    if use_lsh:
        w = w * jnp.exp(-gamma * (d / float(bits)))

    col = jax.lax.broadcasted_iota(jnp.int32, (BM_SEL, mp), 1)
    row = row0 + jax.lax.broadcasted_iota(jnp.int32, (BM_SEL, mp), 0)
    w = jnp.where((col == row) | (col >= m_real), -jnp.inf, w)

    ids, vals = [], []
    for _ in range(nsel):                             # static unroll
        vals.append(jnp.max(w, axis=1))
        idx = jnp.argmax(w, axis=1)
        ids.append(idx)
        w = jnp.where(col == idx[:, None], -jnp.inf, w)
    ids_ref[...] = jnp.stack(ids, axis=1).astype(jnp.int32)
    w_ref[...] = jnp.stack(vals, axis=1)


@functools.partial(jax.jit, static_argnames=(
    "bits", "gamma", "num_neighbors", "use_lsh", "use_rank", "interpret"))
def fused_select(codes, scores, *, bits: int, gamma: float,
                 num_neighbors: int, use_lsh: bool = True,
                 use_rank: bool = True, interpret: bool = True):
    """Fused Eq. 6-8 + top-N. codes: (M, W) uint32, scores: (M,) f32
    -> (ids (M, N) int32, top_w (M, N) f32). Pads M to the row-block
    grid; padded rows are discarded and padded columns never win
    (masked to -inf in-kernel)."""
    m, w = codes.shape
    nsel = min(num_neighbors, m - 1)
    if nsel <= 0:                       # degenerate M <= 1 federation
        return (jnp.zeros((m, 0), jnp.int32), jnp.zeros((m, 0), jnp.float32))
    pm = (-m) % BM_SEL
    padded = jnp.pad(codes, ((0, pm), (0, 0)))
    scores_p = jnp.pad(scores.astype(jnp.float32), (0, pm))[None, :]
    mp = m + pm
    ids, top_w = pl.pallas_call(
        functools.partial(_select_kernel, bits=bits, gamma=gamma,
                          nsel=nsel, m_real=m, use_lsh=use_lsh,
                          use_rank=use_rank),
        grid=(mp // BM_SEL,),
        in_specs=[
            pl.BlockSpec((BM_SEL, w), lambda i: (i, 0)),
            pl.BlockSpec((mp, w), lambda i: (0, 0)),        # revisited
            pl.BlockSpec((1, mp), lambda i: (0, 0)),        # revisited
        ],
        out_specs=[
            pl.BlockSpec((BM_SEL, nsel), lambda i: (i, 0)),
            pl.BlockSpec((BM_SEL, nsel), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, nsel), jnp.int32),
            jax.ShapeDtypeStruct((mp, nsel), jnp.float32),
        ],
        interpret=interpret,
    )(padded, padded, scores_p)
    return ids[:m], top_w[:m]
