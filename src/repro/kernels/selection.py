"""Pallas TPU kernel: fused peer selection (WPFed Eq. 6-8 in one pass).

The unfused round does hamming_matrix -> normalized_distance ->
selection_weights -> top_k, materializing three (M, M) arrays in HBM
plus a (M, M, W) XOR-broadcast intermediate. This kernel fuses the whole
chain: each program owns a (BM, M) row block of the weight matrix and
produces the per-row top-N ids/weights directly — nothing (M, M)-shaped
ever leaves VMEM (DESIGN.md §4).

Distance trick: instead of XOR + SWAR popcount (pure VPU integer work),
codes are unpacked to +-1 floats and the Gram matrix goes through the
MXU: dot(u_i, u_j) = agreements - disagreements = bits_tot - 2 * d_ij,
so d_ij = (bits_tot - dot) / 2. Every intermediate is an integer with
|value| <= bits_tot << 2^24, exact in f32 regardless of reduction
order — the kernel is therefore bit-exact against the jnp oracle
(ref.fused_select_ref, which computes the same integers via popcount +
an exp lookup table, the CPU-fast form) AND against the unfused
popcount composition. Caveat: the distances are exact everywhere, but
exp is not — in interpret mode kernel and oracle share XLA's exp
(bit-exact, tested); on compiled TPU, Mosaic's exp lowering could
differ from XLA's in the last ulp, which would flip selection order
only for weights within 1 ulp of each other. If TPU hardware ever
shows such divergence, pass the oracle's (bits+1)-entry LUT into the
kernel and gather instead of calling exp (DESIGN.md §4).

Weighting (Eq. 8): w_ij = s_j * exp(-gamma * d_ij / bits), with the
Table-3 ablation switches compiled in (use_lsh / use_rank static flags;
the both-off random ablation needs an rng and stays outside the kernel —
see core.neighbor.select_partners). Self-weights and padded columns are
masked to -inf before selection.

Top-N: N iterations of (max, argmax, knock out) over the row block.
argmax takes the first maximum, which reproduces jax.lax.top_k's
tie-breaking (ascending index among equal values), so selected ids
match the unfused path exactly as long as N <= M-1 (always true: the
protocol clamps N to M-1, and every non-self weight is finite).

The packed word axis is NOT padded: the arrays the kernel computes on
are the unpacked (rows, W*32) bit matrices, whose last dim is already
a lane multiple for any bits in {128, 256, 512, ...}. VMEM per program
~= (BM + M) * bits * 4 (unpacked codes) + BM * M * 4 (weights); at
BM=8, M=4096, bits=256 that is ~4.3 MB — `fused_select` therefore caps
at M ~ 10^4 clients.

`fused_select_tiled` removes that ceiling (DESIGN.md §10): a second
grid axis streams (BM, BK) *column tiles* of the same ±1 Gram matrix
while a VMEM scratch carries a per-row running top-N. Pass 1 is the
streamed merge-by-knockout: each tile's weights are concatenated with
the running (vals, ids) candidates and N knockout iterations keep the
best N. Because earlier tiles hold strictly smaller global column
indices, putting the running candidates FIRST in the concatenation
preserves `lax.top_k`'s first-max (ascending-index) tie-breaking
exactly; weights are the same exact-integer distances fed to the same
elementwise exp, so ids AND weights are bit-exact against
`ref.fused_select_ref` and the one-shot kernel at every M. Pass 2
(the Eq. 8 weighting itself) is unchanged — it is computed per tile
from the exact distances. VMEM per program ~= (BM + BK) * bits * 4 +
BM * BK * 4, independent of M.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis.registry import kernel_contract

BM_SEL = 8          # row block (f32 sublane width)
BM_SEL_TILED = 128  # row block of the column-tiled kernel
BK_SEL = 512        # column tile of the column-tiled kernel
BM_ANN = 8          # row block of the ANN candidate kernel
BK_ANN = 256        # candidate tile of the ANN kernel (VMEM ~2 MB)


def unpack_pm1(words):
    """(R, W) packed uint32 -> (R, W*32) f32 in {-1, +1} (bit=1 -> +1).
    Pure shifts + masks; lowers identically on TPU and in interpret
    mode."""
    r, w = words.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (r, w, 32), 2)
    bits01 = ((words[:, :, None] >> shifts) & jnp.uint32(1))
    return (2.0 * bits01.astype(jnp.float32) - 1.0).reshape(r, w * 32)


def _eq8_weights(d, s, row_ids, col_ids, *, bits: int, gamma: float,
                 m_real: int, use_lsh: bool, use_rank: bool):
    """Eq. 8 weighting + self/padding mask on a tile of exact integer
    distances. Shared VERBATIM by the dense kernels (via
    `_gram_weights`) and the ANN candidate kernel, so weights are
    bit-identical wherever the same (d, s) pair appears. `col_ids >=
    m_real` also masks the ANN path's sentinel candidate ids."""
    shape = d.shape
    if use_rank:
        w = jnp.broadcast_to(s, shape)
    else:
        w = jnp.ones(shape, jnp.float32)
    if use_lsh:
        w = w * jnp.exp(-gamma * (d / float(bits)))
    return jnp.where((col_ids == row_ids) | (col_ids >= m_real),
                     -jnp.inf, w)


def _gram_weights(a_words, b_words, s_row, row0, col0, *, bits: int,
                  gamma: float, m_real: int, use_lsh: bool, use_rank: bool):
    """Shared Eq. 6-8 tile: unpack -> ±1 Gram distances -> weights ->
    self/padding mask. Identical ops in the one-shot and tiled kernels,
    so the weights are bit-identical between them."""
    ua = unpack_pm1(a_words)                          # (BM, bits_tot)
    ub = unpack_pm1(b_words)                          # (BK, bits_tot)
    bits_tot = ua.shape[1]
    gram = jnp.dot(ua, ub.T, preferred_element_type=jnp.float32)
    d = (float(bits_tot) - gram) * 0.5                # exact integer f32

    bm, bk = d.shape
    col = col0 + jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 1)
    row = row0 + jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 0)
    w = _eq8_weights(d, s_row, row, col, bits=bits, gamma=gamma,
                     m_real=m_real, use_lsh=use_lsh, use_rank=use_rank)
    return w, col


def _knockout_topn(cand_v, cand_i, nsel: int):
    """N iterations of (max, first-argmax, knock out) over the
    candidate axis — reproduces lax.top_k's ascending-index
    tie-breaking as long as cand_i is ascending within equal values."""
    pos = jax.lax.broadcasted_iota(jnp.int32, cand_v.shape, 1)
    ids, vals = [], []
    for _ in range(nsel):                             # static unroll
        vals.append(jnp.max(cand_v, axis=1))
        p = jnp.argmax(cand_v, axis=1)
        ids.append(jnp.take_along_axis(cand_i, p[:, None], axis=1)[:, 0])
        cand_v = jnp.where(pos == p[:, None], -jnp.inf, cand_v)
    return jnp.stack(vals, axis=1), jnp.stack(ids, axis=1).astype(jnp.int32)


def _select_kernel(a_ref, b_ref, s_ref, ids_ref, w_ref, *, bits: int,
                   gamma: float, nsel: int, m_real: int,
                   use_lsh: bool, use_rank: bool):
    row0 = pl.program_id(0) * BM_SEL
    w, col = _gram_weights(a_ref[...], b_ref[...], s_ref[...], row0, 0,
                           bits=bits, gamma=gamma, m_real=m_real,
                           use_lsh=use_lsh, use_rank=use_rank)
    vals, ids = _knockout_topn(w, col, nsel)
    ids_ref[...] = ids
    w_ref[...] = vals


# --- repro.analysis contract helpers (DESIGN.md §12) -----------------------
def _select_point_args(pt):
    """Abstract (ShapeDtypeStruct) args for a {m, bits} shape point."""
    w = pt["bits"] // 32
    args = (jax.ShapeDtypeStruct((pt["m"], w), jnp.uint32),
            jax.ShapeDtypeStruct((pt["m"],), jnp.float32))
    return args, dict(bits=pt["bits"], gamma=1.0, num_neighbors=16)


def _select_vmem_extra(site, pt):
    """Kernel-internal intermediates beyond the blocks, from the
    CAPTURED shapes: unpacked ±1 row/column codes + the (BM, M)
    weight tile (see the VMEM paragraph in the module docstring)."""
    bm, w = site.in_specs[0].block_shape
    mp = site.in_specs[1].block_shape[0]
    bits_tot = w * 32
    return (bm + mp) * bits_tot * 4 + bm * mp * 4


@kernel_contract(
    name="selection_oneshot", sites=1, oracle="fused_select_ref",
    estimator="selection_vmem_bytes", exactness="bit_exact",
    out_revisit=(),
    points=({"m": 256, "bits": 256}, {"m": 1024, "bits": 256},
            {"m": 768, "bits": 512}),
    make_args=_select_point_args,
    estimator_kwargs=lambda pt: {"m": pt["m"], "bits_tot": pt["bits"]},
    vmem_extra=_select_vmem_extra, slack=0.08)
@functools.partial(jax.jit, static_argnames=(
    "bits", "gamma", "num_neighbors", "use_lsh", "use_rank", "interpret"))
def fused_select(codes, scores, *, bits: int, gamma: float,
                 num_neighbors: int, use_lsh: bool = True,
                 use_rank: bool = True, interpret: bool = True):
    """Fused Eq. 6-8 + top-N. codes: (M, W) uint32, scores: (M,) f32
    -> (ids (M, N) int32, top_w (M, N) f32). Pads M to the row-block
    grid; padded rows are discarded and padded columns never win
    (masked to -inf in-kernel)."""
    m, w = codes.shape
    nsel = min(num_neighbors, m - 1)
    if nsel <= 0:                       # degenerate M <= 1 federation
        return (jnp.zeros((m, 0), jnp.int32), jnp.zeros((m, 0), jnp.float32))
    pm = (-m) % BM_SEL
    padded = jnp.pad(codes, ((0, pm), (0, 0)))
    scores_p = jnp.pad(scores.astype(jnp.float32), (0, pm))[None, :]
    mp = m + pm
    ids, top_w = pl.pallas_call(
        functools.partial(_select_kernel, bits=bits, gamma=gamma,
                          nsel=nsel, m_real=m, use_lsh=use_lsh,
                          use_rank=use_rank),
        grid=(mp // BM_SEL,),
        in_specs=[
            pl.BlockSpec((BM_SEL, w), lambda i: (i, 0)),
            pl.BlockSpec((mp, w), lambda i: (0, 0)),        # revisited
            pl.BlockSpec((1, mp), lambda i: (0, 0)),        # revisited
        ],
        out_specs=[
            pl.BlockSpec((BM_SEL, nsel), lambda i: (i, 0)),
            pl.BlockSpec((BM_SEL, nsel), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, nsel), jnp.int32),
            jax.ShapeDtypeStruct((mp, nsel), jnp.float32),
        ],
        interpret=interpret,
    )(padded, padded, scores_p)
    return ids[:m], top_w[:m]


def _select_tiled_kernel(a_ref, b_ref, s_ref, ids_ref, w_ref,
                         vals_scr, ids_scr, *, bits: int, gamma: float,
                         nsel: int, m_real: int, use_lsh: bool,
                         use_rank: bool, bm: int, bk: int, nj: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_scr[...] = jnp.full_like(vals_scr, -jnp.inf)
        ids_scr[...] = jnp.zeros_like(ids_scr)

    row0 = pl.program_id(0) * bm
    w, col = _gram_weights(a_ref[...], b_ref[...], s_ref[...],
                           row0, j * bk, bits=bits, gamma=gamma,
                           m_real=m_real, use_lsh=use_lsh,
                           use_rank=use_rank)
    # Merge-by-knockout: running candidates FIRST — they come from
    # earlier column tiles, so their global ids are strictly smaller
    # and first-max argmax keeps lax.top_k's ascending-index
    # tie-breaking across tile boundaries.
    cand_v = jnp.concatenate([vals_scr[...], w], axis=1)
    cand_i = jnp.concatenate([ids_scr[...], col], axis=1)
    vals, ids = _knockout_topn(cand_v, cand_i, nsel)
    vals_scr[...] = vals
    ids_scr[...] = ids

    @pl.when(j == nj - 1)
    def _finalize():
        ids_ref[...] = ids_scr[...]
        w_ref[...] = vals_scr[...]


def _select_tiled_vmem_extra(site, pt):
    """Unpacked ±1 row/column-tile codes + the (BM, BK) weight tile,
    from the captured block shapes — O(tile), independent of M."""
    bm, w = site.in_specs[0].block_shape
    bk = site.in_specs[1].block_shape[0]
    bits_tot = w * 32
    return (bm + bk) * bits_tot * 4 + bm * bk * 4


@kernel_contract(
    name="selection_tiled", sites=1, oracle="fused_select_ref",
    estimator="selection_tiled_vmem_bytes", exactness="bit_exact",
    out_revisit=(1,),           # column-tile axis j accumulates top-N
    points=({"m": 1024, "bits": 256}, {"m": 2048, "bits": 256},
            {"m": 4096, "bits": 512}),
    make_args=_select_point_args,
    estimator_kwargs=lambda pt: {"bits_tot": pt["bits"]},
    vmem_extra=_select_tiled_vmem_extra, slack=0.10)
@functools.partial(jax.jit, static_argnames=(
    "bits", "gamma", "num_neighbors", "use_lsh", "use_rank", "interpret",
    "block_m", "block_k"))
def fused_select_tiled(codes, scores, *, bits: int, gamma: float,
                       num_neighbors: int, use_lsh: bool = True,
                       use_rank: bool = True, interpret: bool = True,
                       block_m: int = BM_SEL_TILED, block_k: int = BK_SEL):
    """Column-tiled two-pass fused selection (DESIGN.md §10): same
    contract as `fused_select` — (ids (M, N) int32, top_w (M, N) f32),
    bit-exact against it and `ref.fused_select_ref` — but VMEM per
    program is O(block_m * block_k) instead of O(block_m * M), so M is
    bounded by HBM, not VMEM. Rows pad to the `block_m` grid, columns
    to the `block_k` stream; padded columns are masked to -inf
    in-kernel and never win."""
    m, w = codes.shape
    nsel = min(num_neighbors, m - 1)
    if nsel <= 0:                       # degenerate M <= 1 federation
        return (jnp.zeros((m, 0), jnp.int32), jnp.zeros((m, 0), jnp.float32))
    import jax.experimental.pallas.tpu as pltpu
    bm = min(block_m, m + (-m) % BM_SEL)          # small-M: one row block
    pm = (-m) % bm
    rows = jnp.pad(codes, ((0, pm), (0, 0)))
    bk = min(block_k, m + (-m) % 128)             # small-M: one column tile
    pk = (-m) % bk
    cols = jnp.pad(codes, ((0, pk), (0, 0)))
    scores_p = jnp.pad(scores.astype(jnp.float32), (0, pk))[None, :]
    mr, mc = m + pm, m + pk
    nj = mc // bk
    ids, top_w = pl.pallas_call(
        functools.partial(_select_tiled_kernel, bits=bits, gamma=gamma,
                          nsel=nsel, m_real=m, use_lsh=use_lsh,
                          use_rank=use_rank, bm=bm, bk=bk, nj=nj),
        grid=(mr // bm, nj),                      # column tiles innermost
        in_specs=[
            pl.BlockSpec((bm, w), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, w), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bk), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, nsel), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, nsel), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mr, nsel), jnp.int32),
            jax.ShapeDtypeStruct((mr, nsel), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, nsel), jnp.float32),
            pltpu.VMEM((bm, nsel), jnp.int32),
        ],
        interpret=interpret,
    )(rows, cols, scores_p)
    return ids[:m], top_w[:m]


def _select_ann_kernel(a_ref, c_ref, ci_ref, cs_ref, ids_ref, w_ref,
                       vals_scr, ids_scr, *, bits: int, gamma: float,
                       nsel: int, m_real: int, use_lsh: bool,
                       use_rank: bool, bm: int, bk: int, nj: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_scr[...] = jnp.full_like(vals_scr, -jnp.inf)
        ids_scr[...] = jnp.zeros_like(ids_scr)

    row0 = pl.program_id(0) * bm
    ua = unpack_pm1(a_ref[...])                       # (BM, bits_tot)
    cw = c_ref[...]                                   # (BM, BK, W)
    w_words = cw.shape[-1]
    uc = unpack_pm1(cw.reshape(bm * bk, w_words)).reshape(bm, bk, -1)
    bits_tot = ua.shape[1]
    # per-row batched Gram: each row block has its OWN candidate codes,
    # so the contraction batches over the row axis instead of sharing
    # one ±1 matrix. Distances stay exact integers in f32 (§4).
    gram = jax.lax.dot_general(
        ua, uc, (((1,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)           # (BM, BK)
    d = (float(bits_tot) - gram) * 0.5
    row = row0 + jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 0)
    col = ci_ref[...]                                 # gathered global ids
    w = _eq8_weights(d, cs_ref[...], row, col, bits=bits, gamma=gamma,
                     m_real=m_real, use_lsh=use_lsh, use_rank=use_rank)
    # §10 knockout merge, running candidates FIRST: earlier candidate
    # tiles hold earlier candidate positions, so first-max argmax
    # reproduces lax.top_k's tie-breaking over the full candidate axis
    # (and, in the one-bucket fallback where candidates are ascending
    # client ids, over the full client axis — the bit-exact case).
    cand_v = jnp.concatenate([vals_scr[...], w], axis=1)
    cand_i = jnp.concatenate([ids_scr[...], col], axis=1)
    vals, ids = _knockout_topn(cand_v, cand_i, nsel)
    vals_scr[...] = vals
    ids_scr[...] = ids

    @pl.when(j == nj - 1)
    def _finalize():
        ids_ref[...] = ids_scr[...]
        w_ref[...] = vals_scr[...]


def _select_ann_point_args(pt):
    w = pt["bits"] // 32
    args = (jax.ShapeDtypeStruct((pt["m"], w), jnp.uint32),
            jax.ShapeDtypeStruct((pt["m"],), jnp.float32),
            jax.ShapeDtypeStruct((pt["m"], pt["k"]), jnp.int32))
    return args, dict(bits=pt["bits"], gamma=1.0, num_neighbors=16)


def _select_ann_vmem_extra(site, pt):
    """Unpacked ±1 row codes + per-row unpacked candidate codes + the
    (BM, BK) weight tile, from the captured block shapes."""
    bm, w = site.in_specs[0].block_shape
    bk = site.in_specs[1].block_shape[1]
    bits_tot = w * 32
    return (bm + bm * bk) * bits_tot * 4 + bm * bk * 4


@kernel_contract(
    name="selection_ann", sites=1, oracle="ann_select_ref",
    estimator="ann_vmem_bytes", exactness="bit_exact",
    out_revisit=(1,),           # candidate-tile axis j accumulates top-N
    points=({"m": 512, "k": 256, "bits": 256},
            {"m": 1024, "k": 512, "bits": 256},
            {"m": 512, "k": 256, "bits": 512}),
    make_args=_select_ann_point_args,
    estimator_kwargs=lambda pt: {"bits_tot": pt["bits"]},
    vmem_extra=_select_ann_vmem_extra, slack=0.08)
@functools.partial(jax.jit, static_argnames=(
    "bits", "gamma", "num_neighbors", "use_lsh", "use_rank", "interpret",
    "block_m", "block_k"))
def fused_select_ann(codes, scores, cand_ids, *, bits: int, gamma: float,
                     num_neighbors: int, use_lsh: bool = True,
                     use_rank: bool = True, interpret: bool = True,
                     block_m: int = BM_ANN, block_k: int = BK_ANN):
    """ANN candidate selection (DESIGN.md §11): exact Eq. 6-8 weights
    computed ONLY on `cand_ids` (the (M, K) per-client candidate sets
    from core.ann — bucket tiles + score teaser, sentinel id M in
    invalid slots), streamed in (block_m, block_k) tiles with the §10
    running top-N knockout merge. O(M*K*bits) FLOPs instead of
    O(M^2*bits); VMEM per program is O(tile).

    Bit-exact against `ref.ann_select_ref` on the same candidate sets
    (same exact integer distances, same exp inputs, same tie-breaking
    by candidate position), and — because the one-bucket fallback
    makes the candidate set every client in ascending id order —
    bit-exact against `fused_select` / `fused_select_ref` when
    `core.ann` is run with prefix_bits=0 (pinned in tests).

    Returns (ids (M, N) int32, top_w (M, N) f32); slots with no finite
    candidate get id 0 and weight -inf (callers mask on isfinite, as
    with the exact path's degenerate shapes).
    """
    m, w = codes.shape
    k = cand_ids.shape[1]
    nsel = min(num_neighbors, m - 1)
    if nsel <= 0:                       # degenerate M <= 1 federation
        return (jnp.zeros((m, 0), jnp.int32), jnp.zeros((m, 0), jnp.float32))
    import jax.experimental.pallas.tpu as pltpu
    bm = block_m
    pm = (-m) % bm
    bk = min(block_k, k + (-k) % 128)             # small-K: one tile
    pk = (-k) % bk
    # gather candidate codes/scores OUTSIDE the kernel (XLA gather);
    # the sentinel id M hits the appended zero row / zero score and is
    # masked to -inf in-kernel via col >= m_real, like padded columns.
    cand_p = jnp.pad(cand_ids.astype(jnp.int32), ((0, pm), (0, pk)),
                     constant_values=m)
    codes_pad = jnp.concatenate(
        [codes, jnp.zeros((1, w), codes.dtype)], axis=0)
    scores_pad = jnp.concatenate(
        [scores.astype(jnp.float32), jnp.zeros((1,), jnp.float32)])
    cand_codes = codes_pad[cand_p]                # (MR, KP, W)
    cand_scores = scores_pad[cand_p]              # (MR, KP)
    rows = jnp.pad(codes, ((0, pm), (0, 0)))
    mr, kp = m + pm, k + pk
    nj = kp // bk
    ids, top_w = pl.pallas_call(
        functools.partial(_select_ann_kernel, bits=bits, gamma=gamma,
                          nsel=nsel, m_real=m, use_lsh=use_lsh,
                          use_rank=use_rank, bm=bm, bk=bk, nj=nj),
        grid=(mr // bm, nj),                      # candidate tiles innermost
        in_specs=[
            pl.BlockSpec((bm, w), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, bk, w), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, nsel), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, nsel), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mr, nsel), jnp.int32),
            jax.ShapeDtypeStruct((mr, nsel), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, nsel), jnp.float32),
            pltpu.VMEM((bm, nsel), jnp.int32),
        ],
        interpret=interpret,
    )(rows, cand_codes, cand_p, cand_scores)
    ids, top_w = ids[:m], top_w[:m]
    # no-finite-candidate slots: pin the id to 0 (matches the twin's
    # clamp) so downstream gathers stay in range; sel_mask is False.
    return jnp.where(jnp.isfinite(top_w), ids, 0), top_w
