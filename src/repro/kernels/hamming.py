"""Pallas TPU kernel: all-pairs Hamming distance over packed LSH codes.

WPFed Eq. (6): d_ij = HammingDist(lsh_i, lsh_j). Codes are bit-packed
into uint32 words (W words = bits/32, zero-padded to the 128-lane TPU
register width by ops.py). Each grid program computes one (BM, BN) output
tile: XOR-broadcast (BM, 1, W) ^ (1, BN, W), SWAR popcount, reduce over
the word axis. Pure VPU integer work — no MXU.

VMEM per program ~= (BM + BN) * W * 4 + BM * BN * W * 4 bytes;
defaults (32, 128, W=128) ~= 2.2 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis.registry import kernel_contract

BM = 32
BN = 128


def popcount_u32(v):
    """SWAR popcount for uint32 arrays (shared with ref.py)."""
    v = v - ((v >> jnp.uint32(1)) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> jnp.uint32(2))
                                        & jnp.uint32(0x33333333))
    v = (v + (v >> jnp.uint32(4))) & jnp.uint32(0x0F0F0F0F)
    return ((v * jnp.uint32(0x01010101)) >> jnp.uint32(24)).astype(jnp.int32)


def _hamming_kernel(a_ref, b_ref, out_ref):
    a = a_ref[...]                                        # (BM, W) uint32
    b = b_ref[...]                                        # (BN, W) uint32
    x = a[:, None, :] ^ b[None, :, :]                     # (BM, BN, W)
    out_ref[...] = jnp.sum(popcount_u32(x), axis=-1)


@kernel_contract(
    name="hamming", sites=1, oracle="hamming_all_pairs_ref",
    estimator=None, exactness="bit_exact",
    out_revisit=(),             # each (BM, BN) tile is written once
    points=({"m": 64, "n": 256, "w": 8}, {"m": 32, "n": 128, "w": 8},
            {"m": 96, "n": 384, "w": 16}),
    make_args=lambda pt: (
        (jax.ShapeDtypeStruct((pt["m"], pt["w"]), jnp.uint32),
         jax.ShapeDtypeStruct((pt["n"], pt["w"]), jnp.uint32)), {}))
@functools.partial(jax.jit, static_argnames=("interpret",))
def hamming_all_pairs(codes_a, codes_b, *, interpret: bool = True):
    """codes: (M, W) x (N, W) uint32 (M % BM == 0, N % BN == 0, caller
    pads) -> (M, N) int32 distances."""
    m, w = codes_a.shape
    n = codes_b.shape[0]
    assert m % BM == 0 and n % BN == 0, (m, n)
    return pl.pallas_call(
        _hamming_kernel,
        grid=(m // BM, n // BN),
        in_specs=[
            pl.BlockSpec((BM, w), lambda i, j: (i, 0)),
            pl.BlockSpec((BN, w), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(codes_a, codes_b)
