"""Pure-jnp oracles for the Pallas kernels (bit-exact references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.lsh_projection import CHUNK, rademacher_block
from repro.kernels.hamming import popcount_u32


def lsh_project_sums_ref(x, seed, *, bits: int = 256):
    """Oracle for lsh_projection: same on-the-fly Rademacher matrix,
    single dense matmul. x: (P,) with P % CHUNK == 0."""
    p = x.shape[0]
    r = rademacher_block(0, p, bits, seed)
    return jnp.dot(x.astype(jnp.float32), r)


def lsh_project_sums_batched_ref(x2d, seed, *, bits: int = 256):
    """Per-client oracle for the batched LSH kernel: vmap of the single
    full-width matmul. x2d: (M, P) with P % CHUNK == 0 -> (M, bits).

    Sums may differ from the chunk-accumulating kernel in the last f32
    ulps (different reduction order); the packed sign-bit codes are
    bit-exact (asserted in tests)."""
    return jax.vmap(
        lambda v: lsh_project_sums_ref(v, seed, bits=bits))(x2d)


def fused_select_ref(codes, scores, *, bits: int, gamma: float,
                     num_neighbors: int, use_lsh: bool = True,
                     use_rank: bool = True):
    """Oracle for the fused selection kernel: XOR+popcount distances
    (CPU-fast; the kernel's +-1 Gram matmul produces the same exact
    integers on the MXU), Eq. 8 weighting through a discrete-domain
    exp LUT, self-mask, lax.top_k.

    The LUT trick (DESIGN.md §4): d only takes integer values in
    [0, W*32], so exp(-gamma * d / bits) is a gather into a
    (W*32 + 1)-entry table whose entries are jnp.exp evaluated on
    exactly the inputs the direct formula would see — bit-identical
    weights at M^2 loads instead of M^2 transcendentals.

    codes: (M, W) uint32, scores: (M,) f32 ->
    (ids (M, N) int32, top_w (M, N) f32).
    """
    m = codes.shape[0]
    nsel = min(num_neighbors, m - 1)
    d = hamming_all_pairs_ref(codes, codes)            # exact int32
    if use_rank:
        w = jnp.broadcast_to(scores.astype(jnp.float32)[None, :], (m, m))
    else:
        w = jnp.ones((m, m), jnp.float32)
    if use_lsh:
        dmax = codes.shape[1] * 32
        table = jnp.exp(-gamma * (
            jnp.arange(dmax + 1, dtype=jnp.float32) / float(bits)))
        w = w * table[d]
    w = jnp.where(jnp.eye(m, dtype=bool), -jnp.inf, w)
    top_w, top_i = jax.lax.top_k(w, nsel)
    return top_i.astype(jnp.int32), top_w


def hamming_all_pairs_ref(codes_a, codes_b):
    """Oracle for hamming: broadcast XOR + SWAR popcount."""
    x = codes_a[:, None, :] ^ codes_b[None, :, :]
    return jnp.sum(popcount_u32(x), axis=-1)


def flash_attention_ref(q, k, v, *, causal: bool = True, scale: float = 0.0):
    """Oracle for flash_attention: naive softmax attention.
    q: (N, Sq, dh), k/v: (N, Sk, dh)."""
    import jax
    dh = q.shape[-1]
    scale = scale or dh ** -0.5
    s = jnp.einsum("nqd,nkd->nqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        sq, sk = s.shape[-2], s.shape[-1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("nqk,nkd->nqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
