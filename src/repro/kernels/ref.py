"""Pure-jnp oracles for the Pallas kernels (bit-exact references)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.lsh_projection import CHUNK, rademacher_block
from repro.kernels.hamming import popcount_u32


def lsh_project_sums_ref(x, seed, *, bits: int = 256):
    """Oracle for lsh_projection: same on-the-fly Rademacher matrix,
    single dense matmul. x: (P,) with P % CHUNK == 0."""
    p = x.shape[0]
    r = rademacher_block(0, p, bits, seed)
    return jnp.dot(x.astype(jnp.float32), r)


def hamming_all_pairs_ref(codes_a, codes_b):
    """Oracle for hamming: broadcast XOR + SWAR popcount."""
    x = codes_a[:, None, :] ^ codes_b[None, :, :]
    return jnp.sum(popcount_u32(x), axis=-1)


def flash_attention_ref(q, k, v, *, causal: bool = True, scale: float = 0.0):
    """Oracle for flash_attention: naive softmax attention.
    q: (N, Sq, dh), k/v: (N, Sk, dh)."""
    import jax
    dh = q.shape[-1]
    scale = scale or dh ** -0.5
    s = jnp.einsum("nqd,nkd->nqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        sq, sk = s.shape[-2], s.shape[-1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("nqk,nkd->nqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
