"""Pure-jnp oracles for the Pallas kernels (bit-exact references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.lsh_projection import CHUNK, rademacher_block
from repro.kernels.hamming import popcount_u32


def lsh_project_sums_ref(x, seed, *, bits: int = 256):
    """Oracle for lsh_projection: same on-the-fly Rademacher matrix,
    single dense matmul. x: (P,) with P % CHUNK == 0."""
    p = x.shape[0]
    r = rademacher_block(0, p, bits, seed)
    return jnp.dot(x.astype(jnp.float32), r)


def lsh_project_sums_batched_ref(x2d, seed, *, bits: int = 256):
    """Per-client oracle for the batched LSH kernel: vmap of the single
    full-width matmul. x2d: (M, P) with P % CHUNK == 0 -> (M, bits).

    Sums may differ from the chunk-accumulating kernel in the last f32
    ulps (different reduction order); the packed sign-bit codes are
    bit-exact (asserted in tests)."""
    return jax.vmap(
        lambda v: lsh_project_sums_ref(v, seed, bits=bits))(x2d)


def fused_select_ref(codes, scores, *, bits: int, gamma: float,
                     num_neighbors: int, use_lsh: bool = True,
                     use_rank: bool = True):
    """Oracle for the fused selection kernel: XOR+popcount distances
    (CPU-fast; the kernel's +-1 Gram matmul produces the same exact
    integers on the MXU), Eq. 8 weighting through a discrete-domain
    exp LUT, self-mask, lax.top_k.

    The LUT trick (DESIGN.md §4): d only takes integer values in
    [0, W*32], so exp(-gamma * d / bits) is a gather into a
    (W*32 + 1)-entry table whose entries are jnp.exp evaluated on
    exactly the inputs the direct formula would see — bit-identical
    weights at M^2 loads instead of M^2 transcendentals.

    codes: (M, W) uint32, scores: (M,) f32 ->
    (ids (M, N) int32, top_w (M, N) f32).
    """
    m = codes.shape[0]
    nsel = min(num_neighbors, m - 1)
    d = hamming_all_pairs_ref(codes, codes)            # exact int32
    if use_rank:
        w = jnp.broadcast_to(scores.astype(jnp.float32)[None, :], (m, m))
    else:
        w = jnp.ones((m, m), jnp.float32)
    if use_lsh:
        dmax = codes.shape[1] * 32
        table = jnp.exp(-gamma * (
            jnp.arange(dmax + 1, dtype=jnp.float32) / float(bits)))
        w = w * table[d]
    w = jnp.where(jnp.eye(m, dtype=bool), -jnp.inf, w)
    top_w, top_i = jax.lax.top_k(w, nsel)
    return top_i.astype(jnp.int32), top_w


def ann_select_ref(codes, scores, cand_ids, *, bits: int, gamma: float,
                   num_neighbors: int, use_lsh: bool = True,
                   use_rank: bool = True):
    """Twin of `kernels.selection.fused_select_ann` (DESIGN.md §11):
    exact XOR+popcount distances and Eq. 8 LUT weights computed only
    on the (M, K) candidate sets from `core.ann` (sentinel id M in
    invalid slots), then one lax.top_k over the candidate axis.

    Bit-exact against the kernel: distances are the same exact
    integers, the LUT entries are jnp.exp on the same inputs the
    kernel's elementwise exp sees (the `fused_select_ref` argument),
    and top_k's first-max tie-breaking by candidate position matches
    the kernel's running-candidates-first knockout merge. Slots with
    no finite candidate get id 0 / weight -inf, same as the kernel's
    clamp. This is also the CPU-fast ANN path `core.neighbor`
    dispatches to off-TPU.
    """
    m = codes.shape[0]
    nsel = min(num_neighbors, m - 1)
    if nsel <= 0:
        return (jnp.zeros((m, 0), jnp.int32), jnp.zeros((m, 0), jnp.float32))
    cand = cand_ids.astype(jnp.int32)
    codes_pad = jnp.concatenate(
        [codes, jnp.zeros((1, codes.shape[1]), codes.dtype)], axis=0)
    cand_codes = codes_pad[cand]                       # (M, K, W)
    d = jnp.sum(popcount_u32(codes[:, None, :] ^ cand_codes), axis=-1)
    if use_rank:
        scores_pad = jnp.concatenate(
            [scores.astype(jnp.float32), jnp.zeros((1,), jnp.float32)])
        w = scores_pad[cand]
    else:
        w = jnp.ones(cand.shape, jnp.float32)
    if use_lsh:
        dmax = codes.shape[1] * 32
        table = jnp.exp(-gamma * (
            jnp.arange(dmax + 1, dtype=jnp.float32) / float(bits)))
        w = w * table[d]
    row = jnp.arange(m, dtype=jnp.int32)[:, None]
    w = jnp.where((cand == row) | (cand >= m), -jnp.inf, w)
    top_w, pos = jax.lax.top_k(w, nsel)
    ids = jnp.take_along_axis(cand, pos, axis=1)
    return (jnp.where(jnp.isfinite(top_w), ids, 0).astype(jnp.int32),
            top_w)


def all_in_one_exchange_ref(own_logits, neighbor_logits, y_ref, sel_mask,
                            *, lsh_verification: bool = True):
    """Oracle for the fused exchange kernel (WPFed Eq. 3 + §3.5 + the
    distillation-target mean in one shared log-softmax pass).

    own_logits: (M, R, C) f32 — each client's outputs on its reference
    set; neighbor_logits: (M, N, R, C) f32 — selected neighbors' outputs
    on the same set; y_ref: (M, R) int32 labels; sel_mask: (M, N) bool.

    Returns (l_ij (M, N) f32, valid (M, N) bool, target_ref (M, R, C)
    f32, has_target (M,) bool). Semantics are bit-identical to the
    unfused composition the round used to run (`distill.cross_entropy`
    -> `verify.lsh_verification_mask` -> `distill
    .aggregate_neighbor_outputs`): the neighbor log-softmax that the CE
    and KL terms both consume is a deterministic elementwise-row op, so
    computing it once is exact, and the §3.5 rank is the stable-argsort
    rank in counting form (ties break ascending-index, matching
    jnp.argsort). Tested in tests/test_exchange_pipeline.py.
    """
    own = own_logits.astype(jnp.float32)
    nb = neighbor_logits.astype(jnp.float32)
    logp_nb = jax.nn.log_softmax(nb, axis=-1)           # ONE shared pass
    # Eq. 3: per-neighbor CE on the reference labels
    nll = -jnp.take_along_axis(
        logp_nb, y_ref[:, None, :, None].astype(jnp.int32), axis=-1)[..., 0]
    l_ij = jnp.mean(nll, axis=-1)                       # (M, N)
    # §3.5: output-KL similarity, upper-half filter over selected slots
    if lsh_verification:
        logp_own = jax.nn.log_softmax(own, axis=-1)     # (M, R, C)
        kl = jnp.sum(jnp.exp(logp_own)[:, None]
                     * (logp_own[:, None] - logp_nb), axis=-1)
        kls = jnp.where(sel_mask, jnp.mean(kl, axis=-1), jnp.inf)
        n_valid = jnp.sum(sel_mask.astype(jnp.int32), axis=-1, keepdims=True)
        keep = (n_valid + 1) // 2
        lt = kls[:, :, None] < kls[:, None, :]          # rank candidates n
        eq = kls[:, :, None] == kls[:, None, :]
        n_idx = jnp.arange(kls.shape[1])
        first = n_idx[:, None] < n_idx[None, :]         # m before n
        rank_of = jnp.sum(lt | (eq & first), axis=1)    # stable-sort rank
        valid = (rank_of < keep) & sel_mask
    else:
        valid = sel_mask
    # masked distillation-target mean (zeros fallback when none pass)
    w = valid.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(w, axis=-1), 1.0)
    target = jnp.einsum("mn,mnrc->mrc", w, nb) / denom[:, None, None]
    has_target = jnp.sum(w, axis=-1) > 0
    return l_ij, valid, target, has_target


def streamed_exchange_ref(own_logits, neighbor_logits, y_ref, sel_mask,
                          *, lsh_verification: bool = True,
                          block_r: int = 8, block_c: int = 512):
    """Streaming twin of `kernels.exchange.fused_exchange_streamed`
    (DESIGN.md §10): walks the SAME (R-tile, C-tile) grid with the same
    online max / log-sum-exp updates in the same order — the semantic
    reference for the streaming algorithm, and the CPU path for
    vocab-scale shapes the one-shot oracle cannot hold. Agreement with
    the kernel AND with `all_in_one_exchange_ref` is tolerance-bounded,
    not bitwise: the online softmax reorders the C reduction, the R
    means accumulate per tile, and XLA's fusion-dependent
    FMA/reassociation rewrites move the running accumulators by last
    ulps between compilation contexts. The §3.5 mask only flips on
    exact kl ties and is pinned equal in tests
    (tests/test_tiled_kernels.py)."""
    from repro.kernels.exchange import streamed_tiles

    m, n, r, c = neighbor_logits.shape
    br, pr, bc, pc = streamed_tiles(r, c, block_r, block_c)
    own_p = jnp.pad(own_logits.astype(jnp.float32), ((0, 0), (0, pr),
                                                     (0, pc)))
    nb_p = jnp.pad(neighbor_logits.astype(jnp.float32),
                   ((0, 0), (0, 0), (0, pr), (0, pc)))
    y_p = jnp.pad(y_ref.astype(jnp.int32), ((0, 0), (0, pr)))
    nr, nc = (r + pr) // br, (c + pc) // bc

    l_acc = jnp.zeros((m, n), jnp.float32)
    kl_acc = jnp.zeros((m, n), jnp.float32)
    for ri in range(nr):
        m_nb = jnp.full((m, n, br), -jnp.inf)
        a_nb = jnp.zeros((m, n, br))
        g_nb = jnp.zeros((m, n, br))
        b_x = jnp.zeros((m, n, br))
        m_own = jnp.full((m, br), -jnp.inf)
        a_own = jnp.zeros((m, br))
        y_t = y_p[:, ri * br:(ri + 1) * br]
        for ci in range(nc):
            xo = own_p[:, ri * br:(ri + 1) * br, ci * bc:(ci + 1) * bc]
            xn = nb_p[:, :, ri * br:(ri + 1) * br, ci * bc:(ci + 1) * bc]
            col = ci * bc + jnp.arange(bc, dtype=jnp.int32)
            cvalid = col < c
            xo_m = jnp.where(cvalid, xo, -jnp.inf)
            xn_m = jnp.where(cvalid, xn, -jnp.inf)
            mo_new = jnp.maximum(m_own, jnp.max(xo_m, axis=-1))
            co = jnp.exp(m_own - mo_new)
            po = jnp.exp(xo_m - mo_new[..., None])
            a_own = a_own * co + jnp.sum(po, axis=-1)
            mn_new = jnp.maximum(m_nb, jnp.max(xn_m, axis=-1))
            cn = jnp.exp(m_nb - mn_new)
            a_nb = (a_nb * cn
                    + jnp.sum(jnp.exp(xn_m - mn_new[..., None]), axis=-1))
            b_x = (b_x * co[:, None]
                   + jnp.sum(po[:, None] * (xo[:, None] - xn), axis=-1))
            match = col[None, None, :] == y_t[:, :, None]
            g_nb = g_nb + jnp.sum(jnp.where(match[:, None], xn, 0.0),
                                  axis=-1)
            m_own, m_nb = mo_new, mn_new
        lse_nb = m_nb + jnp.log(a_nb)
        lse_own = m_own + jnp.log(a_own)
        rvalid = (ri * br + jnp.arange(br, dtype=jnp.int32)) < r
        nll = lse_nb - g_nb
        l_acc = l_acc + jnp.sum(jnp.where(rvalid, nll, 0.0), axis=-1)
        kl_r = b_x / a_own[:, None] - lse_own[:, None] + lse_nb
        kl_acc = kl_acc + jnp.sum(jnp.where(rvalid, kl_r, 0.0), axis=-1)

    l_ij = l_acc / float(r)
    sel_int = sel_mask.astype(jnp.int32)
    if lsh_verification:
        from repro.kernels.exchange import _upper_half_mask
        valid = _upper_half_mask(kl_acc / float(r), sel_int)
    else:
        valid = sel_mask.astype(bool)
    w = valid.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(w, axis=-1), 1.0)
    target = (jnp.einsum("mn,mnrc->mrc", w, nb_p)
              / denom[:, None, None])[:, :r, :c]
    has_target = jnp.sum(w, axis=-1) > 0
    return l_ij, valid, target, has_target


def hamming_all_pairs_ref(codes_a, codes_b):
    """Oracle for hamming: broadcast XOR + SWAR popcount."""
    x = codes_a[:, None, :] ^ codes_b[None, :, :]
    return jnp.sum(popcount_u32(x), axis=-1)


def flash_attention_ref(q, k, v, *, causal: bool = True, scale: float = 0.0):
    """Oracle for flash_attention: naive softmax attention.
    q: (N, Sq, dh), k/v: (N, Sk, dh)."""
    import jax
    dh = q.shape[-1]
    scale = scale or dh ** -0.5
    s = jnp.einsum("nqd,nkd->nqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        sq, sk = s.shape[-2], s.shape[-1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("nqk,nkd->nqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
