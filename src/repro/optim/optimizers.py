"""Optimizers (no optax in this environment — built from scratch).

API mirrors the familiar gradient-transformation style:

    opt = adamw(3e-4, weight_decay=0.1)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

Learning rates may be floats or schedules (callables step -> lr); states
carry the step counter.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Any]   # (grads, state, params)


def _lr_at(lr: Schedule, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p + u.astype(p.dtype)) if u is not None else p,
        params, updates)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def sgd(lr: Schedule, momentum: float = 0.0) -> Optimizer:
    def init(params):
        mu = (jax.tree.map(jnp.zeros_like, params) if momentum else None)
        return {"step": jnp.zeros((), jnp.int32), "mu": mu}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g,
                              state["mu"], grads)
            upd = jax.tree.map(lambda m: -lr_t * m, mu)
            return upd, {"step": step, "mu": mu}
        upd = jax.tree.map(lambda g: -lr_t * g, grads)
        return upd, {"step": step, "mu": None}

    return Optimizer(init, update)


def adam(lr: Schedule, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8) -> Optimizer:
    return adamw(lr, b1=b1, b2=b2, eps=eps, weight_decay=0.0)


def adamw(lr: Schedule, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    """AdamW with decoupled weight decay; moments in f32 regardless of
    param dtype (mixed-precision safe)."""
    def init(params):
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(f32, params),
                "v": jax.tree.map(f32, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1)
                         * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m_, v_, p):
            u = -(lr_t * (m_ / c1) / (jnp.sqrt(v_ / c2) + eps))
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)
