"""Learning-rate schedules (callables: step -> lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_decay(lr: float, decay_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.minimum(step.astype(jnp.float32), decay_steps) / decay_steps
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)
    return fn


def linear_warmup_cosine(lr: float, warmup_steps: int, decay_steps: int,
                         final_frac: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = lr * s / max(warmup_steps, 1)
        t = jnp.clip((s - warmup_steps) / max(decay_steps - warmup_steps, 1),
                     0.0, 1.0)
        cos = lr * (final_frac + (1 - final_frac)
                    * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(s < warmup_steps, warm, cos)
    return fn
