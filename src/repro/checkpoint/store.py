"""Pytree checkpointing to .npz (orbax-free, offline-friendly).

Layout: <dir>/step_<N>.npz with flattened key paths; tree structure is
reconstructed from the key paths on restore (dicts / tuples / lists).
"""
from __future__ import annotations

import os
import re
from typing import Any, List, Optional

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_seg(p) for p in path)
        arr = np.asarray(leaf)  # analysis: host-ok — checkpointing IS the device->host pull
        if arr.dtype.name == "bfloat16":     # npz can't serialize ml_dtypes
            arr = arr.astype(np.float32)     # (restore casts back per `like`)
        out[key] = arr
    return out


def _seg(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return f"d:{p.key}"
    if isinstance(p, jax.tree_util.SequenceKey):
        return f"s:{p.idx}"
    if isinstance(p, jax.tree_util.GetAttrKey):
        return f"a:{p.name}"
    return str(p)


def save(ckpt_dir: str, step: int, tree: Any, *,
         keep_last_k: Optional[int] = None) -> str:
    """Atomic snapshot; with `keep_last_k`, prune older step_*.npz AFTER
    the new file is durably in place (a continuously-running service
    would otherwise accumulate one snapshot per period forever). The
    newest k survive by step number; pruning never touches other files
    (e.g. the service's chain.json lives in the same directory)."""
    if keep_last_k is not None and keep_last_k < 1:
        raise ValueError(f"keep_last_k must be >= 1, got {keep_last_k}")
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    tmp = path + ".tmp.npz"          # .npz suffix so np.savez doesn't append
    np.savez(tmp, **_flatten(tree))  # analysis: host-ok — durable snapshot write
    os.replace(tmp, path)
    if keep_last_k is not None:
        steps = sorted(  # analysis: host-ok — int() parses filenames, not device values
            int(m.group(1)) for f in os.listdir(ckpt_dir)
            if (m := re.match(r"step_(\d+)\.npz$", f)))
        for old in steps[:-keep_last_k]:
            os.remove(os.path.join(ckpt_dir, f"step_{old:08d}.npz"))
    return path


def steps(ckpt_dir: str) -> List[int]:
    """All retained snapshot steps, ascending. The crash-safe resume
    path walks this list backwards: a truncated/corrupt newest file
    falls back to the previous retained snapshot."""
    if not os.path.isdir(ckpt_dir):
        return []
    # analysis: host-ok — int() parses snapshot filenames, not device values
    return sorted(int(m.group(1)) for f in os.listdir(ckpt_dir)
                  if (m := re.match(r"step_(\d+)\.npz$", f)))


def latest_step(ckpt_dir: str) -> Optional[int]:
    found = steps(ckpt_dir)
    return found[-1] if found else None


def restore(ckpt_dir: str, step: int, like: Any) -> Any:
    """Restore into the structure of ``like`` (a template pytree)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    with np.load(path) as data:  # analysis: host-ok — snapshot file read
        flat = {k: data[k] for k in data.files}
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_keys, leaf in paths:
        key = _SEP.join(_seg(p) for p in path_keys)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
