"""Shared layer primitives: norms, activations, MLPs, embeddings, RoPE."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def init_norm(cfg: ModelConfig, dtype):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), dtype),
                "bias": jnp.zeros((cfg.d_model,), dtype)}
    return {"scale": jnp.ones((cfg.d_model,), dtype)}


def norm_specs(cfg: ModelConfig):
    if cfg.norm == "layernorm":
        return {"scale": P(None), "bias": P(None)}
    return {"scale": P(None)}


def apply_norm(cfg: ModelConfig, p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# activations / MLP
# ---------------------------------------------------------------------------
def _act(name: str, x):
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu2":
        r = jax.nn.relu(x)
        return r * r
    if name == "silu":
        return jax.nn.silu(x)
    raise ValueError(name)


GATED = {"swiglu": "silu", "geglu": "gelu"}


def init_mlp(cfg: ModelConfig, key, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = split_keys(key, 3)
    p = {}
    if cfg.activation in GATED:
        p["wg"] = dense_init(ks[0], (d, f), dtype)
    p["wi"] = dense_init(ks[1], (d, f), dtype)
    p["wo"] = dense_init(ks[2], (f, d), dtype)
    if cfg.mlp_bias:
        p["bi"] = jnp.zeros((f,), dtype)
        p["bo"] = jnp.zeros((d,), dtype)
    return p


def mlp_specs(cfg: ModelConfig):
    p = {}
    if cfg.activation in GATED:
        p["wg"] = P(None, "model")
    p["wi"] = P(None, "model")
    p["wo"] = P("model", None)
    if cfg.mlp_bias:
        p["bi"] = P("model")
        p["bo"] = P(None)
    return p


def apply_mlp(cfg: ModelConfig, p, x):
    h = jnp.einsum("...d,df->...f", x, p["wi"])
    if cfg.mlp_bias:
        h = h + p["bi"]
    if cfg.activation in GATED:
        g = _act(GATED[cfg.activation], jnp.einsum("...d,df->...f", x, p["wg"]))
        h = g * h
    else:
        h = _act(cfg.activation, h)
    out = jnp.einsum("...f,fd->...d", h, p["wo"])
    if cfg.mlp_bias:
        out = out + p["bo"]
    return out


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------
def init_embed(cfg: ModelConfig, key, dtype):
    ks = split_keys(key, 3)
    p = {"tok": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype, scale=1.0)}
    if cfg.learned_pos_embed:
        p["pos"] = dense_init(ks[1], (cfg.learned_pos_embed, cfg.d_model), dtype,
                              scale=0.02)
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[2], (cfg.d_model, cfg.vocab_size), dtype)
    return p


def embed_specs(cfg: ModelConfig):
    p = {"tok": P("model", None)}
    if cfg.learned_pos_embed:
        p["pos"] = P(None, None)
    if not cfg.tie_embeddings:
        p["lm_head"] = P(None, "model")
    return p


def embed_tokens(cfg: ModelConfig, p, tokens):
    return p["tok"][tokens]


def lm_logits(cfg: ModelConfig, p, x):
    w = p["tok"].T if cfg.tie_embeddings else p["lm_head"]
    return jnp.einsum("...d,dv->...v", x, w).astype(jnp.float32)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                        # (dh/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos = jnp.cos(ang)[..., :, None, :]                  # (..., S, 1, dh/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
