"""Small per-client models for the faithful WPFed reproduction.

The paper uses MobileNetV2 (MNIST) and a Temporal Convolutional Network
(A-ECG / S-EEG). At 28x28 / 60-dim scale we implement a depthwise-
separable CNN (the MobileNetV2 building block) and a dilated causal TCN
with residual blocks — both pure JAX, CPU-friendly, and cheap enough to
train tens of client replicas inside `vmap`.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.paper_models import ClientModelConfig
from repro.models.layers import dense_init, split_keys


# ---------------------------------------------------------------------------
# depthwise-separable CNN (MobileNetV2-style at MNIST scale)
# ---------------------------------------------------------------------------
def _init_cnn(cfg: ClientModelConfig, key, dtype):
    # NOTE: MobileNetV2's depthwise-separable stage is replaced by a
    # regular conv: vmapped grouped-conv *gradients* are ~30x slower in
    # XLA CPU (measured), and at 28x28x1 scale the separable
    # factorization saves nothing. Recorded in DESIGN.md §2.
    h0, h1 = cfg.hidden
    kk = cfg.kernel_size
    cin = cfg.input_shape[-1]
    ks = split_keys(key, 6)
    flat = (cfg.input_shape[0] // 4) * (cfg.input_shape[1] // 4) * h1
    return {
        "conv1": dense_init(ks[0], (kk, kk, cin, h0), dtype, scale=0.1),
        "b1": jnp.zeros((h0,), dtype),
        "conv2": dense_init(ks[1], (kk, kk, h0, h1), dtype, scale=0.1),
        "b2": jnp.zeros((h1,), dtype),
        "fc1": dense_init(ks[3], (flat, 128), dtype),
        "bf1": jnp.zeros((128,), dtype),
        "fc2": dense_init(ks[4], (128, cfg.num_classes), dtype),
        "bf2": jnp.zeros((cfg.num_classes,), dtype),
    }


def _apply_cnn(cfg: ClientModelConfig, p, x):
    """x: (B, H, W, C) -> logits (B, num_classes)."""
    y = jax.lax.conv_general_dilated(
        x, p["conv1"], (2, 2), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b1"]
    y = jax.nn.relu(y)
    y = jax.lax.conv_general_dilated(
        y, p["conv2"], (2, 2), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b2"]
    y = jax.nn.relu(y)
    y = y.reshape(y.shape[0], -1)
    y = jax.nn.relu(y @ p["fc1"] + p["bf1"])
    return y @ p["fc2"] + p["bf2"]


# ---------------------------------------------------------------------------
# dilated causal TCN
# ---------------------------------------------------------------------------
def _init_tcn(cfg: ClientModelConfig, key, dtype):
    cin = cfg.input_shape[-1]
    kk = cfg.kernel_size
    p = {"blocks": []}
    ks = split_keys(key, len(cfg.hidden) + 2)
    ch_in = cin
    for i, ch in enumerate(cfg.hidden):
        bk = split_keys(ks[i], 3)
        p["blocks"].append({
            "conv": dense_init(bk[0], (kk, ch_in, ch), dtype, scale=0.1),
            "b": jnp.zeros((ch,), dtype),
            "res": dense_init(bk[1], (ch_in, ch), dtype)
            if ch_in != ch else None,
        })
        ch_in = ch
    p["fc"] = dense_init(ks[-2], (ch_in, cfg.num_classes), dtype)
    p["bf"] = jnp.zeros((cfg.num_classes,), dtype)
    return p


def _apply_tcn(cfg: ClientModelConfig, p, x):
    """x: (B, T, C) -> logits (B, num_classes)."""
    kk = cfg.kernel_size
    y = x
    for i, blk in enumerate(p["blocks"]):
        dil = 2 ** i
        pad = (kk - 1) * dil
        yp = jnp.pad(y, ((0, 0), (pad, 0), (0, 0)))
        conv = jax.lax.conv_general_dilated(
            yp, blk["conv"], (1,), "VALID", rhs_dilation=(dil,),
            dimension_numbers=("NTC", "TIO", "NTC")) + blk["b"]
        res = y @ blk["res"] if blk["res"] is not None else y
        y = jax.nn.relu(conv) + res
    y = jnp.mean(y, axis=1)                                # global avg pool
    return y @ p["fc"] + p["bf"]


# ---------------------------------------------------------------------------
# MLP (used in fast unit tests)
# ---------------------------------------------------------------------------
def _init_mlp(cfg: ClientModelConfig, key, dtype):
    # static config product stays in Python: routing it through jnp
    # makes init_fn un-jittable (init now also runs inside compiled
    # attack transforms — core.adversary)
    dims = (math.prod(cfg.input_shape), *cfg.hidden, cfg.num_classes)
    ks = split_keys(key, len(dims))
    return {"w": [dense_init(ks[i], (dims[i], dims[i + 1]), dtype)
                  for i in range(len(dims) - 1)],
            "b": [jnp.zeros((dims[i + 1],), dtype)
                  for i in range(len(dims) - 1)]}


def _apply_mlp(cfg: ClientModelConfig, p, x):
    y = x.reshape(x.shape[0], -1)
    n = len(p["w"])
    for i in range(n):
        y = y @ p["w"][i] + p["b"][i]
        if i < n - 1:
            y = jax.nn.relu(y)
    return y


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------
def init_client_model(cfg: ClientModelConfig, key, dtype=jnp.float32):
    return {"cnn": _init_cnn, "tcn": _init_tcn, "mlp": _init_mlp}[cfg.kind](
        cfg, key, dtype)


def apply_client_model(cfg: ClientModelConfig, params, x):
    return {"cnn": _apply_cnn, "tcn": _apply_tcn, "mlp": _apply_mlp}[cfg.kind](
        cfg, params, x)
