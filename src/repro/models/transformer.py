"""Model assembly: init / forward / prefill / decode for every family.

Depth is organized as ``reps`` repetitions of ``cfg.block_pattern`` scanned
with ``jax.lax.scan`` (stacked params, one compiled super-block — keeps
HLO size flat in depth, as production frameworks do), plus an unrolled
``tail`` for depths not divisible by the pattern length.

Families:
  dense / moe        "A" blocks (+ MoE FFN)
  hybrid             ("R","R","L") RecurrentGemma pattern
  ssm                ("S","M") xLSTM pattern
  vlm                ("A"x4,"X") with a vision-patch projector (stub tower)
  audio              encoder (bidir "A") + decoder ("A"+cross) — conv
                     frontend stubbed: encoder input is frame embeddings
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import rglru, xlstm
from repro.models.layers import (apply_mlp, apply_norm, dense_init,
                                 embed_specs, embed_tokens, init_embed,
                                 init_mlp, init_norm, lm_logits, mlp_specs,
                                 norm_specs, split_keys)
from repro.models.moe import init_moe, moe_forward, moe_specs

Params = Dict[str, Any]


# ===========================================================================
# init
# ===========================================================================
def _block_has_mlp(cfg: ModelConfig, t: str) -> bool:
    return cfg.d_ff > 0


def init_block(cfg: ModelConfig, key, t: str, dtype, *, decoder: bool = False):
    ks = split_keys(key, 4)
    p: Params = {"ln": init_norm(cfg, dtype)}
    if t in "ALX":
        p["attn"] = attn.init_attn(cfg, ks[0], dtype)
    elif t == "R":
        p["rec"] = rglru.init_rglru(cfg, ks[0], dtype)
    elif t == "S":
        p["rec"] = xlstm.init_slstm(cfg, ks[0], dtype)
    elif t == "M":
        p["rec"] = xlstm.init_mlstm(cfg, ks[0], dtype)
    if decoder and cfg.is_encdec:
        p["ln_x"] = init_norm(cfg, dtype)
        p["xattn"] = attn.init_attn(cfg, ks[2], dtype)
    if _block_has_mlp(cfg, t):
        p["ln2"] = init_norm(cfg, dtype)
        p["mlp"] = (init_moe(cfg, ks[1], dtype) if cfg.is_moe
                    else init_mlp(cfg, ks[1], dtype))
    return p


def block_specs(cfg: ModelConfig, t: str, *, decoder: bool = False):
    p: Params = {"ln": norm_specs(cfg)}
    if t in "ALX":
        p["attn"] = attn.attn_specs(cfg)
    elif t == "R":
        p["rec"] = rglru.rglru_specs(cfg)
    elif t == "S":
        p["rec"] = xlstm.slstm_specs(cfg)
    elif t == "M":
        p["rec"] = xlstm.mlstm_specs(cfg)
    if decoder and cfg.is_encdec:
        p["ln_x"] = norm_specs(cfg)
        p["xattn"] = attn.attn_specs(cfg)
    if _block_has_mlp(cfg, t):
        p["ln2"] = norm_specs(cfg)
        p["mlp"] = moe_specs(cfg) if cfg.is_moe else mlp_specs(cfg)
    return p


def _stack_init(cfg, key, reps, pattern, dtype, decoder=False):
    """Stacked per-pattern-position params: tuple over pattern positions,
    each a pytree with leading (reps,) axis."""
    out = []
    for pi, t in enumerate(pattern):
        keys = jnp.stack(split_keys(jax.random.fold_in(key, pi), reps))
        out.append(jax.vmap(
            lambda k, t=t: init_block(cfg, k, t, dtype, decoder=decoder)
        )(keys))
    return tuple(out)


def _add_layer_dim(spec_tree):
    return jax.tree.map(lambda s: P(None, *s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    ks = split_keys(key, 6)
    params: Params = {"embed": init_embed(cfg, ks[0], dtype)}
    pattern = cfg.block_pattern
    reps, tail = cfg.pattern_reps, cfg.pattern_tail
    decoder = cfg.is_encdec
    if reps > 0:
        params["layers"] = _stack_init(cfg, ks[1], reps, pattern, dtype,
                                       decoder=decoder)
    params["tail"] = tuple(
        init_block(cfg, jax.random.fold_in(ks[2], i), pattern[i], dtype,
                   decoder=decoder)
        for i in range(tail))
    params["final_norm"] = init_norm(cfg, dtype)
    if cfg.is_encdec:
        enc_reps = cfg.encoder_layers
        params["encoder"] = {
            "pos": dense_init(ks[3], (cfg.encoder_seq_len, cfg.d_model),
                              dtype, scale=0.02),
            "layers": _stack_init(cfg, ks[4], enc_reps, ("A",), dtype),
            "final_norm": init_norm(cfg, dtype),
        }
    if cfg.vision_tokens:
        params["vision_proj"] = dense_init(
            ks[5], (cfg.vision_dim or cfg.d_model, cfg.d_model), dtype)
    return params


def param_specs(cfg: ModelConfig) -> Params:
    pattern = cfg.block_pattern
    reps, tail = cfg.pattern_reps, cfg.pattern_tail
    decoder = cfg.is_encdec
    specs: Params = {"embed": embed_specs(cfg)}
    if reps > 0:
        specs["layers"] = tuple(
            _add_layer_dim(block_specs(cfg, t, decoder=decoder))
            for t in pattern)
    specs["tail"] = tuple(block_specs(cfg, pattern[i], decoder=decoder)
                          for i in range(tail))
    specs["final_norm"] = norm_specs(cfg)
    if cfg.is_encdec:
        specs["encoder"] = {
            "pos": P(None, None),
            "layers": tuple([_add_layer_dim(block_specs(cfg, "A"))]),
            "final_norm": norm_specs(cfg),
        }
    if cfg.vision_tokens:
        specs["vision_proj"] = P(None, "model")
    return specs


# ===========================================================================
# full-sequence forward (train / prefill)
# ===========================================================================
def _apply_block(cfg: ModelConfig, t: str, p, x, *, positions, context,
                 window_override: int = 0, collect_kv: bool = False):
    """Returns (x, aux_loss, kv_or_state) — kv/state only if collect_kv."""
    h = apply_norm(cfg, p["ln"], x)
    kv_state = None
    if t in "AL":
        mode = "causal" if (t == "A" and not window_override) else "window"
        if cfg.is_encdec and t == "A" and context is None:
            mode = "bidir"                                 # encoder block
        win = window_override or cfg.window
        out, kv = attn.attn_forward(cfg, p["attn"], h, positions=positions,
                                    mode=mode, window=win)
        kv_state = kv
    elif t == "X":
        out, _ = attn.attn_forward(cfg, p["attn"], h, positions=positions,
                                   mode="cross", context=context)
    elif t == "R":
        out, kv_state = rglru.rglru_forward(cfg, p["rec"], h)
    elif t == "S":
        out, kv_state = xlstm.slstm_forward(cfg, p["rec"], h)
    elif t == "M":
        out, kv_state = xlstm.mlstm_forward(cfg, p["rec"], h)
    x = x + out
    if "xattn" in p and context is not None:               # enc-dec decoder
        hx = apply_norm(cfg, p["ln_x"], x)
        out, _ = attn.attn_forward(cfg, p["xattn"], hx, positions=positions,
                                   mode="cross", context=context)
        x = x + out
    aux = jnp.zeros((), jnp.float32)
    if "mlp" in p:
        h2 = apply_norm(cfg, p["ln2"], x)
        if cfg.is_moe:
            out, moe_aux = moe_forward(cfg, p["mlp"], h2)
            aux = moe_aux["load_balance"]
        else:
            out = apply_mlp(cfg, p["mlp"], h2)
        x = x + out
    if collect_kv:
        return x, aux, kv_state
    return x, aux, None


def _run_stack(cfg: ModelConfig, params, x, *, positions, context,
               pattern, window_override=0, remat: str = "none",
               unroll: bool = False, scan_unroll: int = 1):
    """Scan over reps (or an unrolled python loop when ``unroll`` — used
    by the dry-run so cost_analysis counts every layer, since XLA's cost
    model tallies while-loop bodies only once), then the tail.
    Returns (x, aux_sum)."""
    def rep_body(xc, layer_slices):
        aux_t = jnp.zeros((), jnp.float32)
        for pi, t in enumerate(pattern):
            xc, aux, _ = _apply_block(cfg, t, layer_slices[pi], xc,
                                      positions=positions, context=context,
                                      window_override=window_override)
            aux_t += aux
        return xc, aux_t

    if remat == "block":
        rep_body = jax.checkpoint(rep_body)

    aux_total = jnp.zeros((), jnp.float32)
    if "layers" in params:
        if unroll:
            reps = jax.tree.leaves(params["layers"])[0].shape[0]
            for r in range(reps):
                sl = jax.tree.map(lambda a: a[r], params["layers"])
                x, aux = rep_body(x, sl)
                aux_total += aux
        else:
            x, auxs = jax.lax.scan(rep_body, x, params["layers"],
                                   unroll=scan_unroll)
            aux_total += jnp.sum(auxs)
    for i, bp in enumerate(params.get("tail", ())):
        x, aux, _ = _apply_block(cfg, pattern[i], bp, x, positions=positions,
                                 context=context,
                                 window_override=window_override)
        aux_total += aux
    return x, aux_total


def encode_audio(cfg: ModelConfig, params, frames, *, unroll: bool = False,
                 scan_unroll: int = 1):
    """Stubbed-frontend encoder: frames (B, enc_seq, D) -> (B, enc_seq, D)."""
    enc = params["encoder"]
    x = frames + enc["pos"][None, :frames.shape[1], :]
    pos = jnp.broadcast_to(jnp.arange(frames.shape[1]), frames.shape[:2])
    x, _ = _run_stack(cfg, {"layers": enc["layers"], "tail": ()}, x,
                      positions=pos, context=None, pattern=("A",),
                      unroll=unroll, scan_unroll=scan_unroll)
    return apply_norm(cfg, enc["final_norm"], x)


def _context_from_extra(cfg: ModelConfig, params, extra, *,
                        unroll: bool = False, scan_unroll: int = 1):
    if cfg.is_encdec:
        return encode_audio(cfg, params, extra["audio"], unroll=unroll,
                            scan_unroll=scan_unroll)
    if cfg.vision_tokens:
        return jnp.einsum("btv,vd->btd", extra["vision"],
                          params["vision_proj"])
    return None


def forward(cfg: ModelConfig, params: Params, tokens, extra=None, *,
            window_override: int = 0, remat: str = "none",
            unroll: bool = False, scan_unroll: int = 1):
    """tokens: (B, S) int32 -> (logits (B,S,V) f32, aux_loss scalar)."""
    b, s = tokens.shape
    x = embed_tokens(cfg, params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    if cfg.learned_pos_embed:
        idx = jnp.minimum(jnp.arange(s), cfg.learned_pos_embed - 1)
        x = x + params["embed"]["pos"][idx][None]
    context = _context_from_extra(cfg, params, extra, unroll=unroll,
                                  scan_unroll=scan_unroll)
    x, aux = _run_stack(cfg, params, x, positions=positions, context=context,
                        pattern=cfg.block_pattern,
                        window_override=window_override, remat=remat,
                        unroll=unroll, scan_unroll=scan_unroll)
    x = apply_norm(cfg, params["final_norm"], x)
    return lm_logits(cfg, params["embed"], x), aux


# ===========================================================================
# decode: cache init + single-token step
# ===========================================================================
def _block_cache_init(cfg, t, p, batch, cache_len, dtype, context, *,
                      window_override=0):
    c: Params = {}
    if t in "AL" and not (cfg.is_encdec and context is None):
        win = window_override or cfg.window
        size = min(win, cache_len) if (t == "L" or window_override) \
            else cache_len
        c["kv"] = attn.init_attn_cache(cfg, batch, size, dtype)
    elif t == "X":
        c["kv"] = attn.cross_kv(cfg, p["attn"], context)
    elif t == "R":
        c["state"] = rglru.init_rglru_state(cfg, batch, dtype)
    elif t == "S":
        c["state"] = xlstm.init_slstm_state(cfg, batch)
    elif t == "M":
        c["state"] = xlstm.init_mlstm_state(cfg, batch)
    if "xattn" in p and context is not None:
        c["cross"] = attn.cross_kv(cfg, p["xattn"], context)
    return c


def init_cache(cfg: ModelConfig, params: Params, batch: int, cache_len: int,
               dtype=jnp.float32, extra=None, *, window_override: int = 0):
    """Build an empty decode cache (cross-attention K/V precomputed)."""
    context = _context_from_extra(cfg, params, extra)
    pattern = cfg.block_pattern
    cache: Params = {}
    if "layers" in params:
        cache["layers"] = tuple(
            jax.vmap(lambda bp, t=t: _block_cache_init(
                cfg, t, bp, batch, cache_len, dtype, context,
                window_override=window_override))(params["layers"][pi])
            for pi, t in enumerate(pattern))
    cache["tail"] = tuple(
        _block_cache_init(cfg, pattern[i], bp, batch, cache_len, dtype,
                          context, window_override=window_override)
        for i, bp in enumerate(params.get("tail", ())))
    return cache


def _block_decode(cfg, t, p, x, c, pos, *, window_override=0):
    h = apply_norm(cfg, p["ln"], x)
    new_c = dict(c)
    if t in "AL":
        if t == "A" and not window_override:
            mode, win = "causal", 0
        else:
            mode, win = "window", (window_override or cfg.window)
        out, kv = attn.attn_decode(cfg, p["attn"], h, c["kv"], pos,
                                   mode=mode, window=win)
        new_c["kv"] = kv
    elif t == "X":
        out, _ = attn.attn_decode(cfg, p["attn"], h, c["kv"], pos,
                                  mode="cross")
    elif t == "R":
        out, st = rglru.rglru_decode(cfg, p["rec"], h, c["state"])
        new_c["state"] = st
    elif t == "S":
        out, st = xlstm.slstm_decode(cfg, p["rec"], h, c["state"])
        new_c["state"] = st
    elif t == "M":
        out, st = xlstm.mlstm_decode(cfg, p["rec"], h, c["state"])
        new_c["state"] = st
    x = x + out
    if "cross" in c:
        hx = apply_norm(cfg, p["ln_x"], x)
        out, _ = attn.attn_decode(cfg, p["xattn"], hx, c["cross"], pos,
                                  mode="cross")
        x = x + out
    if "mlp" in p:
        h2 = apply_norm(cfg, p["ln2"], x)
        if cfg.is_moe:
            out, _ = moe_forward(cfg, p["mlp"], h2)
        else:
            out = apply_mlp(cfg, p["mlp"], h2)
        x = x + out
    return x, new_c


def decode_step(cfg: ModelConfig, params: Params, cache: Params, token,
                pos, *, window_override: int = 0, unroll: bool = False,
                scan_unroll: int = 1):
    """token: (B,) int32, pos: scalar int32 -> (logits (B,V), new_cache)."""
    x = embed_tokens(cfg, params["embed"], token[:, None])
    if cfg.learned_pos_embed:
        idx = jnp.minimum(pos, cfg.learned_pos_embed - 1)
        x = x + params["embed"]["pos"][idx][None, None]
    pattern = cfg.block_pattern
    new_cache: Params = {}

    if "layers" in params:
        def rep_body(xc, slices):
            new_slices = []
            for pi, t in enumerate(pattern):
                xc, nc = _block_decode(cfg, t, slices[0][pi], xc,
                                       slices[1][pi], pos,
                                       window_override=window_override)
                new_slices.append(nc)
            return xc, tuple(new_slices)

        if unroll:
            reps = jax.tree.leaves(params["layers"])[0].shape[0]
            ys = []
            for r in range(reps):
                sl = jax.tree.map(lambda a: a[r],
                                  (params["layers"], cache["layers"]))
                x, nc = rep_body(x, sl)
                ys.append(nc)
            new_cache["layers"] = jax.tree.map(
                lambda *zs: jnp.stack(zs), *ys)
        else:
            x, new_layer_cache = jax.lax.scan(
                rep_body, x, (params["layers"], cache["layers"]),
                unroll=scan_unroll)
            new_cache["layers"] = new_layer_cache
    new_tail = []
    for i, bp in enumerate(params.get("tail", ())):
        x, nc = _block_decode(cfg, pattern[i], bp, x, cache["tail"][i], pos,
                              window_override=window_override)
        new_tail.append(nc)
    new_cache["tail"] = tuple(new_tail)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_logits(cfg, params["embed"], x)[:, 0]
    return logits, new_cache


# ===========================================================================
# prefill: full forward that also returns a usable decode cache
# ===========================================================================
def prefill(cfg: ModelConfig, params: Params, tokens, extra=None, *,
            window_override: int = 0, cache_len: int = 0,
            unroll: bool = False, scan_unroll: int = 1):
    """Returns (last-position logits (B,V), cache positioned at pos=S).

    ``cache_len`` (default: S) sizes the full-attention KV caches so the
    subsequent decode steps have room: pass S + max_new_tokens.
    """
    b, s = tokens.shape
    x = embed_tokens(cfg, params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    if cfg.learned_pos_embed:
        idx = jnp.minimum(jnp.arange(s), cfg.learned_pos_embed - 1)
        x = x + params["embed"]["pos"][idx][None]
    context = _context_from_extra(cfg, params, extra, unroll=unroll,
                                  scan_unroll=scan_unroll)
    pattern = cfg.block_pattern
    full_len = max(cache_len, s)

    def pad_full(k):
        """Grow a (B,S,KV,dh) tensor to (B,full_len,KV,dh) with zeros."""
        if full_len == s:
            return k
        return jnp.pad(k, ((0, 0), (0, full_len - s), (0, 0), (0, 0)))

    def ring_pack(k, win):
        """Pack the last `win` positions into ring layout (slot = p % win)."""
        if s < win:                       # identity slots + zero tail
            return jnp.pad(k, ((0, 0), (0, win - s), (0, 0), (0, 0)))
        i = jnp.arange(win)
        slot_pos = (s - 1) - jnp.mod((s - 1) - i, win)
        return jnp.take(k, slot_pos, axis=1)

    def block_with_cache(t, p, xc):
        xc, aux, kv_state = _apply_block(
            cfg, t, p, xc, positions=positions, context=context,
            window_override=window_override, collect_kv=True)
        c: Params = {}
        if t in "AL" and kv_state is not None:
            k, v = kv_state
            win = window_override or cfg.window
            if t == "L" or window_override:
                c["kv"] = {"k": ring_pack(k, win), "v": ring_pack(v, win)}
            else:
                c["kv"] = {"k": pad_full(k), "v": pad_full(v)}
        elif t == "X":
            c["kv"] = attn.cross_kv(cfg, p["attn"], context)
        elif t in "RSM":
            c["state"] = kv_state
        if "xattn" in p and context is not None:
            c["cross"] = attn.cross_kv(cfg, p["xattn"], context)
        return xc, aux, c

    cache: Params = {}
    aux_total = jnp.zeros((), jnp.float32)
    if "layers" in params:
        def rep_body(xc, layer_slices):
            caches, aux_t = [], jnp.zeros((), jnp.float32)
            for pi, t in enumerate(pattern):
                xc, aux, c = block_with_cache(t, layer_slices[pi], xc)
                caches.append(c)
                aux_t += aux
            return xc, (tuple(caches), aux_t)

        if unroll:
            reps = jax.tree.leaves(params["layers"])[0].shape[0]
            ys = []
            for r in range(reps):
                sl = jax.tree.map(lambda a: a[r], params["layers"])
                x, (cs, aux) = rep_body(x, sl)
                ys.append(cs)
                aux_total += aux
            cache["layers"] = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
        else:
            x, (layer_caches, auxs) = jax.lax.scan(rep_body, x,
                                                   params["layers"],
                                                   unroll=scan_unroll)
            cache["layers"] = layer_caches
            aux_total += jnp.sum(auxs)
    tail_caches = []
    for i, bp in enumerate(params.get("tail", ())):
        x, aux, c = block_with_cache(pattern[i], bp, x)
        tail_caches.append(c)
        aux_total += aux
    cache["tail"] = tuple(tail_caches)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_logits(cfg, params["embed"], x[:, -1:, :])[:, 0]
    return logits, cache
