"""xLSTM blocks (arXiv:2405.04517): sLSTM (scalar memory, nonlinear
state-mixing recurrence) and mLSTM (matrix memory, attention-like
parallel training form).

Training:
  - sLSTM: stabilized exponential gating, sequential ``lax.scan`` over
    time (the recurrence is nonlinear -> no associative form exists).
  - mLSTM: stabilized quadratic parallel form (decay matrix D from
    cumulative log forget gates), O(S^2) like attention; decode is the
    O(1) recurrent update on the (C, n, m) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, split_keys

NEG_INF = -1e30


# ===========================================================================
# sLSTM
# ===========================================================================
def init_slstm(cfg: ModelConfig, key, dtype):
    d, h = cfg.d_model, cfg.num_heads
    dh = d // h
    ks = split_keys(key, 3)
    return {
        "w": dense_init(ks[0], (4, d, d), dtype),          # i,f,z,o input
        "r": dense_init(ks[1], (4, h, dh, dh), dtype),     # block-diag recur
        "b": jnp.zeros((4, d), dtype),
        "w_out": dense_init(ks[2], (d, d), dtype),
    }


def slstm_specs(cfg: ModelConfig):
    # tiny model (<=350M): replicated (data-parallel only); see DESIGN.md
    return {"w": P(None, None, None), "r": P(None, None, None, None),
            "b": P(None, None), "w_out": P(None, None)}


def _slstm_step(cfg, p, state, wx_t):
    """state: (h, c, n, m) each (B, D) f32; wx_t: (4, B, D) precomputed Wx."""
    h_prev, c_prev, n_prev, m_prev = state
    hh = h_prev.reshape(h_prev.shape[0], cfg.num_heads, -1)
    rec = jnp.einsum("bhe,ghef->gbhf", hh, p["r"].astype(jnp.float32))
    rec = rec.reshape(4, h_prev.shape[0], -1)
    pre = wx_t + rec + p["b"].astype(jnp.float32)[:, None, :]
    i_t, f_t, z_t, o_t = pre[0], pre[1], pre[2], pre[3]
    m_t = jnp.maximum(f_t + m_prev, i_t)
    i_g = jnp.exp(i_t - m_t)
    f_g = jnp.exp(f_t + m_prev - m_t)
    c_t = f_g * c_prev + i_g * jnp.tanh(z_t)
    n_t = f_g * n_prev + i_g
    h_t = jax.nn.sigmoid(o_t) * c_t / jnp.maximum(n_t, 1e-6)
    return (h_t, c_t, n_t, m_t)


def slstm_forward(cfg: ModelConfig, p, x, state=None):
    """x: (B,S,D) -> (out, final_state)."""
    b, s, d = x.shape
    if state is None:
        state = init_slstm_state(cfg, b)
    wx = jnp.einsum("bsd,gde->gbse", x.astype(jnp.float32),
                    p["w"].astype(jnp.float32))            # (4,B,S,D)

    def step(carry, wx_t):
        new = _slstm_step(cfg, p, carry, wx_t)
        return new, new[0]

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(wx, 2, 0))
    hs = jnp.moveaxis(hs, 0, 1).astype(x.dtype)            # (B,S,D)
    out = jnp.einsum("bsd,de->bse", hs, p["w_out"])
    return out, state


def init_slstm_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return (z, z, z, z - 30.0)                             # m init low


def slstm_state_specs(cfg: ModelConfig, batch_axes):
    s = P(batch_axes, None)
    return (s, s, s, s)


def slstm_decode(cfg: ModelConfig, p, x, state):
    """x: (B,1,D)."""
    wx = jnp.einsum("bd,gde->gbe", x[:, 0].astype(jnp.float32),
                    p["w"].astype(jnp.float32))
    state = _slstm_step(cfg, p, state, wx)
    out = jnp.einsum("bd,de->be", state[0].astype(x.dtype), p["w_out"])
    return out[:, None, :], state


# ===========================================================================
# mLSTM
# ===========================================================================
def init_mlstm(cfg: ModelConfig, key, dtype):
    d = cfg.d_model
    di = 2 * d                                             # inner width
    ks = split_keys(key, 7)
    return {
        "w_up": dense_init(ks[0], (d, di), dtype),
        "w_z": dense_init(ks[1], (d, di), dtype),          # gate branch
        "w_q": dense_init(ks[2], (di, di), dtype),
        "w_k": dense_init(ks[3], (di, di), dtype),
        "w_v": dense_init(ks[4], (di, di), dtype),
        "w_if": dense_init(ks[5], (di, 2 * cfg.num_heads), dtype, scale=0.01),
        "b_if": jnp.zeros((2 * cfg.num_heads,), jnp.float32),
        "w_down": dense_init(ks[6], (di, d), dtype),
    }


def mlstm_specs(cfg: ModelConfig):
    return {"w_up": P(None, None), "w_z": P(None, None), "w_q": P(None, None),
            "w_k": P(None, None), "w_v": P(None, None),
            "w_if": P(None, None), "b_if": P(None),
            "w_down": P(None, None)}


def _mlstm_qkv_gates(cfg, p, x):
    u = jnp.einsum("bsd,de->bse", x, p["w_up"])
    b, s, di = u.shape
    h = cfg.num_heads
    dh = di // h

    def heads(w):
        return jnp.einsum("bse,ef->bsf", u, w).reshape(b, s, h, dh)

    q, k, v = heads(p["w_q"]), heads(p["w_k"]), heads(p["w_v"])
    gates = jnp.einsum("bse,eg->bsg", u.astype(jnp.float32),
                       p["w_if"].astype(jnp.float32)) + p["b_if"]
    log_i = gates[..., :h]                                 # (B,S,H)
    log_f = jax.nn.log_sigmoid(gates[..., h:])             # (B,S,H)
    z = jax.nn.silu(jnp.einsum("bsd,de->bse", x, p["w_z"]))
    return u, q, k, v, log_i, log_f, z, dh


MLSTM_CHUNK = 256


def mlstm_forward(cfg: ModelConfig, p, x, state=None):
    """Chunkwise-parallel stabilized form: intra-chunk quadratic +
    inter-chunk recurrent (C, n, m) state — peak memory O(B*L^2*H) per
    chunk of length L instead of O(B*S^2*H). x: (B,S,D)."""
    u, q, k, v, log_i, log_f, z, dh = _mlstm_qkv_gates(cfg, p, x)
    b, s, h, _ = q.shape
    if state is None:
        state = init_mlstm_state(cfg, b)
    L = MLSTM_CHUNK if s % MLSTM_CHUNK == 0 else s         # fallback: 1 chunk
    nc = s // L
    scale = dh ** -0.5

    def to_chunks(a):
        return jnp.moveaxis(a.reshape(b, nc, L, *a.shape[2:]), 1, 0)

    qc, kc, vc = (to_chunks(a.astype(jnp.float32)) for a in (q, k, v))
    lic, lfc = to_chunks(log_i), to_chunks(log_f)          # (nc,B,L,H)

    causal = jnp.tril(jnp.ones((L, L), bool))

    def chunk_step(carry, inp):
        C_p, n_p, m_p = carry                              # prev state
        q_b, k_b, v_b, li, lf = inp
        fcs = jnp.cumsum(lf, axis=1)                       # (B,L,H) inclusive
        ftot = fcs[:, -1]                                  # (B,H)
        # intra-chunk decay  D[t,τ] = fcs[t] - fcs[τ] + li[τ]
        dmat = fcs[:, :, None, :] - fcs[:, None, :, :] + li[:, None, :, :]
        dmat = jnp.where(causal[None, :, :, None], dmat, NEG_INF)
        # prior-state log scale at position t:  b_t = fcs[t] + m_prev
        b_t = fcs + m_p[:, None, :]                        # (B,L,H)
        m_t = jnp.maximum(jnp.max(dmat, axis=2), b_t)      # (B,L,H)
        dexp = jnp.exp(dmat - m_t[:, :, None, :])
        inter_w = jnp.exp(b_t - m_t)                       # (B,L,H)

        scores = jnp.einsum("bthd,bshd->btsh", q_b, k_b) * scale
        num_intra = jnp.einsum("btsh,btsh,bshe->bthe", scores, dexp, v_b)
        num_inter = inter_w[..., None] * jnp.einsum(
            "bhde,bthd->bthe", C_p, q_b) * scale
        den_intra = jnp.einsum("btsh,btsh->bth", scores, dexp)
        den_inter = inter_w * jnp.einsum("bhd,bthd->bth", n_p, q_b) * scale
        den = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m_t))
        h_out = (num_intra + num_inter) / den[..., None]   # (B,L,H,dh)

        # state update to end of chunk
        w_tau = ftot[:, None, :] - fcs + li                # (B,L,H)
        m_new = jnp.maximum(m_p + ftot, jnp.max(w_tau, axis=1))
        wexp = jnp.exp(w_tau - m_new[:, None, :])
        decay = jnp.exp(m_p + ftot - m_new)                # (B,H)
        C_new = decay[..., None, None] * C_p + jnp.einsum(
            "bsh,bshd,bshe->bhde", wexp, k_b, v_b)
        n_new = decay[..., None] * n_p + jnp.einsum(
            "bsh,bshd->bhd", wexp, k_b)
        return (C_new, n_new, m_new), h_out

    carry0 = (state["C"], state["n"], state["m"])
    (C_f, n_f, m_f), hs = jax.lax.scan(chunk_step, carry0,
                                       (qc, kc, vc, lic, lfc))
    out_h = jnp.moveaxis(hs, 0, 1).reshape(b, s, -1)       # (B,S,2D)
    out_h = out_h.astype(x.dtype) * z
    out = jnp.einsum("bse,ed->bsd", out_h, p["w_down"])
    return out, {"C": C_f, "n": n_f, "m": m_f}


def init_mlstm_state(cfg: ModelConfig, batch: int):
    h = cfg.num_heads
    dh = 2 * cfg.d_model // h
    return {"C": jnp.zeros((batch, h, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, h, dh), jnp.float32),
            "m": jnp.full((batch, h), -30.0, jnp.float32)}


def mlstm_state_specs(cfg: ModelConfig, batch_axes):
    return {"C": P(batch_axes, None, None, None),
            "n": P(batch_axes, None, None),
            "m": P(batch_axes, None)}


def mlstm_decode(cfg: ModelConfig, p, x, state):
    """O(1) recurrent update. x: (B,1,D)."""
    u, q, k, v, log_i, log_f, z, dh = _mlstm_qkv_gates(cfg, p, x)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                    # (B,H,dh)
    log_i, log_f = log_i[:, 0], log_f[:, 0]                # (B,H)
    m_t = jnp.maximum(log_f + state["m"], log_i)
    f_g = jnp.exp(log_f + state["m"] - m_t)[..., None]
    i_g = jnp.exp(log_i - m_t)[..., None]
    kf, vf, qf = (a.astype(jnp.float32) for a in (k, v, q))
    C = f_g[..., None] * state["C"] + i_g[..., None] * kf[..., :, None] \
        * vf[..., None, :]
    n = f_g * state["n"] + i_g * kf
    num = jnp.einsum("bhde,bhd->bhe", C, qf) * (dh ** -0.5)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qf)) * (dh ** -0.5),
                      jnp.exp(-m_t))
    out_h = (num / den[..., None]).reshape(x.shape[0], -1)
    out_h = out_h.astype(x.dtype) * z[:, 0]
    out = jnp.einsum("be,ed->bd", out_h, p["w_down"])
    return out[:, None, :], {"C": C, "n": n, "m": m_t}
