"""RG-LRU recurrent block (RecurrentGemma, arXiv:2402.19427).

Block: x -> [linear_x -> causal depthwise conv1d -> RG-LRU] * gelu(linear_gate)
         -> linear_out

RG-LRU recurrence (real-gated linear recurrent unit):
    r_t = sigmoid(u_t W_ra + b_ra)            # recurrence gate
    i_t = sigmoid(u_t W_rx + b_rx)            # input gate
    log a_t = -c * softplus(Lambda) * r_t     # c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Training uses ``jax.lax.associative_scan`` over time (log-depth on TPU);
decode is the one-step recurrence with (h, conv window) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, split_keys

_C = 8.0


def init_rglru(cfg: ModelConfig, key, dtype):
    d = cfg.d_model
    w = cfg.lru_width or d
    cw = cfg.conv1d_width
    ks = split_keys(key, 7)
    return {
        "w_in": dense_init(ks[0], (d, w), dtype),
        "w_gate": dense_init(ks[1], (d, w), dtype),
        "conv_w": dense_init(ks[2], (cw, w), dtype, scale=cw ** -0.5),
        "conv_b": jnp.zeros((w,), dtype),
        "w_ra": dense_init(ks[3], (w, w), dtype),
        "b_ra": jnp.zeros((w,), dtype),
        "w_rx": dense_init(ks[4], (w, w), dtype),
        "b_rx": jnp.zeros((w,), dtype),
        "lam": (jax.random.uniform(ks[5], (w,), jnp.float32, 0.9, 0.999)
                ).astype(jnp.float32),   # a ≈ sigmoid-free direct param
        "w_out": dense_init(ks[6], (w, d), dtype),
    }


def rglru_specs(cfg: ModelConfig):
    return {
        "w_in": P(None, "model"),
        "w_gate": P(None, "model"),
        "conv_w": P(None, "model"),
        "conv_b": P("model"),
        "w_ra": P(None, "model"),
        "b_ra": P("model"),
        "w_rx": P(None, "model"),
        "b_rx": P("model"),
        "lam": P("model"),
        "w_out": P("model", None),
    }


def _conv1d_causal(u, w, b):
    """Depthwise causal conv. u: (B,S,W), w: (cw,W)."""
    cw = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (cw - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for j in range(cw):                                   # tiny unrolled loop
        out = out + pad[:, j:j + u.shape[1], :] * w[cw - 1 - j]
    return out + b


def _gates(p, u):
    r = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", u, p["w_ra"]) + p["b_ra"])
    i = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", u, p["w_rx"]) + p["b_rx"])
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) \
        * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) \
        * (i.astype(jnp.float32) * u.astype(jnp.float32))
    return a, gated_in


def rglru_forward(cfg: ModelConfig, p, x):
    """Training / prefill path. x: (B,S,D) -> (out (B,S,D), state)."""
    u = jnp.einsum("bsd,dw->bsw", x, p["w_in"])
    u = _conv1d_causal(u, p["conv_w"], p["conv_b"])
    a, gin = _gates(p, u)                                 # (B,S,W) f32

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_cum, h = jax.lax.associative_scan(combine, (a, gin), axis=1)
    h = h.astype(x.dtype)

    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"]))
    out = jnp.einsum("bsw,wd->bsd", h * gate, p["w_out"])
    cw = cfg.conv1d_width
    raw = jnp.einsum("bsd,dw->bsw", x, p["w_in"])
    state = {"h": h[:, -1].astype(jnp.float32),
             "conv": raw[:, -(cw - 1):, :] if cw > 1 else
             jnp.zeros((x.shape[0], 0, raw.shape[-1]), raw.dtype)}
    return out, state


def init_rglru_state(cfg: ModelConfig, batch: int, dtype):
    w = cfg.lru_width or cfg.d_model
    cw = cfg.conv1d_width
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cw - 1, w), dtype)}


def rglru_state_specs(cfg: ModelConfig, batch_axes):
    return {"h": P(batch_axes, "model"), "conv": P(batch_axes, None, "model")}


def rglru_decode(cfg: ModelConfig, p, x, state):
    """One-step decode. x: (B,1,D). state: {"h": (B,W), "conv": (B,cw-1,W)}."""
    raw = jnp.einsum("bsd,dw->bsw", x, p["w_in"])         # (B,1,W)
    hist = jnp.concatenate([state["conv"].astype(raw.dtype), raw], axis=1)
    cw = cfg.conv1d_width
    # training conv gives u_{t-k} weight w[k]; hist is oldest->newest so
    # the kernel must be reversed here to match.
    u = jnp.einsum("btw,tw->bw", hist, p["conv_w"][::-1]) + p["conv_b"]
    a, gin = _gates(p, u)                                 # (B,W)
    h = a * state["h"] + gin
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"]))[:, 0]
    out = jnp.einsum("bw,wd->bd", h.astype(x.dtype) * gate, p["w_out"])
    new_state = {"h": h, "conv": hist[:, 1:, :]}
    return out[:, None, :], new_state
