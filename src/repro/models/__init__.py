from repro.models.transformer import (  # noqa: F401
    decode_step,
    forward,
    init_cache,
    init_params,
    param_specs,
    prefill,
)
from repro.models.client import (  # noqa: F401
    apply_client_model,
    init_client_model,
)
