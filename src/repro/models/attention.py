"""GQA attention: global-causal / sliding-window / bidirectional / cross,
with full-sequence (train, prefill) and single-token (decode) paths.

KV caches are functional pytrees. Sliding-window decode uses a ring
buffer of size ``window``: slot ``p % window`` holds position ``p``; keys
are stored RoPE'd at their true position so relative attention is exact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init, split_keys

NEG_INF = -1e30


def init_attn(cfg: ModelConfig, key, dtype):
    d, dh = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * dh), dtype),
        "wk": dense_init(ks[1], (d, kv * dh), dtype),
        "wv": dense_init(ks[2], (d, kv * dh), dtype),
        "wo": dense_init(ks[3], (h * dh, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), dtype)
        p["bk"] = jnp.zeros((kv * dh,), dtype)
        p["bv"] = jnp.zeros((kv * dh,), dtype)
    return p


def attn_specs(cfg: ModelConfig):
    p = {
        "wq": P(None, "model"),
        "wk": P(None, "model"),
        "wv": P(None, "model"),
        "wo": P("model", None),
    }
    if cfg.qkv_bias:
        p["bq"] = P("model")
        p["bk"] = P("model")
        p["bv"] = P("model")
    return p


def _project_q(cfg, p, x):
    h, dh = cfg.num_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    return q.reshape(*x.shape[:2], h, dh)


def _project_kv(cfg, p, x):
    kv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    k = jnp.einsum("bsd,de->bse", x, p["wk"])
    v = jnp.einsum("bsd,de->bse", x, p["wv"])
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    return (k.reshape(*x.shape[:2], kv, dh),
            v.reshape(*x.shape[:2], kv, dh))


def _gqa_scores(cfg, q, k):
    """q: (B,Sq,H,dh)  k: (B,Sk,KV,dh) -> scores (B,KV,G,Sq,Sk) in f32."""
    h, kv = cfg.num_heads, cfg.num_kv_heads
    g = h // kv
    q = q.reshape(q.shape[0], q.shape[1], kv, g, q.shape[-1])
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q.astype(jnp.float32),
                        k.astype(jnp.float32))
    return scores * (cfg.resolved_head_dim ** -0.5)


def _gqa_out(cfg, p, probs, v, out_shape):
    # (§Perf iteration 5 tried casting probs to bf16 here — REFUTED: the
    # cast materializes an extra S^2 pass and XLA had already fused the
    # f32 read into the matmul. Kept in f32.)
    ctx = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    ctx = ctx.reshape(*out_shape[:2], cfg.num_heads * cfg.resolved_head_dim)
    return jnp.einsum("bse,ed->bsd", ctx.astype(v.dtype), p["wo"])


# Global attention implementation policy — a §Perf lever.
#   "naive":   full (Sq, Sk) score tensor (fine for short sequences)
#   "chunked": flash-style online-softmax over KV chunks (memory O(chunk^2))
#   "auto":    chunked when Sq*Sk exceeds the threshold below.
# §Perf iteration 6: threshold lowered from 4096^2 to 2048^2 — at
# train_4k the materialized f32 probs made backward peak memory 147 GB
# per device (9x over HBM); chunked attention brings the peak under HBM.
_ATTN_IMPL = "auto"
_CHUNK_Q = 1024
_CHUNK_K = 1024
_AUTO_THRESHOLD = 2048 * 2048


def set_attn_impl(impl: str):
    global _ATTN_IMPL
    assert impl in ("auto", "naive", "chunked")
    _ATTN_IMPL = impl


def get_attn_impl() -> str:
    return _ATTN_IMPL


def _naive_attn(cfg, p, q, k, v, mode, window, out_shape):
    scores = _gqa_scores(cfg, q, k)                       # (B,KV,G,Sq,Sk)
    sq, sk = scores.shape[-2], scores.shape[-1]
    if mode in ("causal", "window"):
        i = jnp.arange(sq)[:, None]
        j = jnp.arange(sk)[None, :]
        mask = i >= j
        if mode == "window":
            mask &= (i - j) < window
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(cfg, p, probs, v, out_shape)


def _chunked_attn(cfg, p, q, k, v, mode, window, out_shape):
    """Flash-style attention: scan over KV chunks with an online softmax.

    Peak live memory is O(B * KV * G * CHUNK_Q * CHUNK_K) instead of
    O(B * KV * G * Sq * Sk).
    """
    h, kv_heads = cfg.num_heads, cfg.num_kv_heads
    g = h // kv_heads
    dh = cfg.resolved_head_dim
    b, sq = q.shape[0], q.shape[1]
    sk = k.shape[1]
    cq = min(_CHUNK_Q, sq)
    ck = min(_CHUNK_K, sk)
    if sq % cq or sk % ck:
        return _naive_attn(cfg, p, q, k, v, mode, window, out_shape)
    nq, nk = sq // cq, sk // ck
    scale = dh ** -0.5

    qc = q.reshape(b, nq, cq, kv_heads, g, dh).astype(jnp.float32)
    kc = jnp.moveaxis(k.reshape(b, nk, ck, kv_heads, dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nk, ck, kv_heads, dh), 1, 0)

    def q_block(qi, q_blk):
        # online softmax over key chunks
        acc0 = jnp.zeros((b, kv_heads, g, cq, dh), jnp.float32)
        l0 = jnp.zeros((b, kv_heads, g, cq), jnp.float32)
        m0 = jnp.full((b, kv_heads, g, cq), NEG_INF, jnp.float32)

        def kv_block(carry, inp):
            acc, l, m = carry
            ki, k_blk, v_blk = inp
            s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk,
                           k_blk.astype(jnp.float32)) * scale
            if mode in ("causal", "window"):
                qpos = qi * cq + jnp.arange(cq)[:, None]
                kpos = ki * ck + jnp.arange(ck)[None, :]
                msk = qpos >= kpos
                if mode == "window":
                    msk &= (qpos - kpos) < window
                s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            pexp = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(pexp, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", pexp, v_blk.astype(jnp.float32))
            return (acc_new, l_new, m_new), None

        (acc, l, _), _ = jax.lax.scan(
            kv_block, (acc0, l0, m0), (jnp.arange(nk), kc, vc))
        return acc / jnp.maximum(l, 1e-30)[..., None]     # (b,kv,g,cq,dh)

    out = jax.lax.map(lambda i: q_block(i, qc[:, i]), jnp.arange(nq))
    out = jnp.moveaxis(out, 0, 3)                         # (b,kv,g,nq,cq,dh)
    ctx = out.reshape(b, kv_heads, g, sq, dh)
    ctx = jnp.moveaxis(ctx.reshape(b, kv_heads * g, sq, dh), 1, 2)
    ctx = ctx.reshape(b, sq, h * dh).astype(v.dtype)
    return jnp.einsum("bse,ed->bsd", ctx, p["wo"])


def attn_forward(cfg: ModelConfig, p, x, *, positions, mode: str,
                 context=None, window: int = 0):
    """Full-sequence attention.

    mode: "causal" | "window" | "bidir" | "cross".
    context: (B, Tc, D) for cross-attention.
    Returns (out, (k, v)) so prefill can build the cache.
    """
    q = _project_q(cfg, p, x)
    src = context if mode == "cross" else x
    k, v = _project_kv(cfg, p, src)
    if cfg.rope and mode != "cross":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope and mode == "cross":
        q = apply_rope(q, positions, cfg.rope_theta)

    sq, sk = q.shape[1], k.shape[1]
    use_chunked = (_ATTN_IMPL == "chunked"
                   or (_ATTN_IMPL == "auto" and sq * sk > _AUTO_THRESHOLD))
    if use_chunked:
        out = _chunked_attn(cfg, p, q, k, v, mode, window, x.shape)
    else:
        out = _naive_attn(cfg, p, q, k, v, mode, window, x.shape)
    return out, (k, v)


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------
def init_attn_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    kv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    return {"k": jnp.zeros((batch, cache_len, kv, dh), dtype),
            "v": jnp.zeros((batch, cache_len, kv, dh), dtype)}


def attn_cache_specs(cfg: ModelConfig, batch_axes):
    s = P(batch_axes, None, "model", None)
    return {"k": s, "v": s}


def _ring_slot_positions(pos, cache_len):
    """Position stored at each ring slot after writing token ``pos``.

    slot i holds p = pos - ((pos - i) mod W); p < 0 means empty.
    """
    i = jnp.arange(cache_len)
    return pos - jnp.mod(pos - i, cache_len)


def attn_decode(cfg: ModelConfig, p, x, cache, pos, *, mode: str,
                window: int = 0):
    """One-token decode. x: (B, 1, D). pos: scalar int32 (current index).

    mode "causal": cache slot i holds position i (cache_len >= pos+1).
    mode "window": ring buffer, slot = pos % window.
    mode "cross": cache holds precomputed context k/v; no write.
    Returns (out, new_cache).
    """
    b = x.shape[0]
    q = _project_q(cfg, p, x)
    if cfg.rope:
        q = apply_rope(q, jnp.full((b, 1), pos, jnp.int32), cfg.rope_theta)

    if mode == "cross":
        k, v = cache["k"], cache["v"]
        scores = _gqa_scores(cfg, q, k)
        probs = jax.nn.softmax(scores, axis=-1)
        return _gqa_out(cfg, p, probs, v, x.shape), cache

    k_new, v_new = _project_kv(cfg, p, x)                 # (B,1,KV,dh)
    if cfg.rope:
        k_new = apply_rope(k_new, jnp.full((b, 1), pos, jnp.int32),
                           cfg.rope_theta)
    cache_len = cache["k"].shape[1]
    slot = jnp.mod(pos, cache_len) if mode == "window" else pos
    k = jax.lax.dynamic_update_slice(
        cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(
        cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))

    scores = _gqa_scores(cfg, q, k)                       # (B,KV,G,1,Sc)
    if mode == "window":
        slot_pos = _ring_slot_positions(pos, cache_len)
        valid = slot_pos >= 0
    else:
        valid = jnp.arange(cache_len) <= pos
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(cfg, p, probs, v, x.shape)
    return out, {"k": k, "v": v}


def cross_kv(cfg: ModelConfig, p, context):
    """Precompute cross-attention k/v from a context once per request."""
    k, v = _project_kv(cfg, p, context)
    return {"k": k, "v": v}
