"""Mixture-of-Experts FFN with top-k routing and capacity-bounded
scatter/gather dispatch (no (T, E, C) one-hot tensors — memory-light and
all-to-all-friendly under expert sharding).

Dispatch algorithm (per call, T = flattened tokens):
  1. router logits (T, E) -> softmax -> top-k expert ids + weights.
  2. position-in-expert via SORT over the (T*k,) expert assignments
     (O(Tk log Tk)); the textbook (T*k, E) one-hot cumsum is O(Tk*E)
     compute AND lowers to a size-Tk reduce-window in XLA — measured
     481x the useful MoE FLOPs at kimi-k2 scale (EXPERIMENTS.md §Perf
     iteration 1).
  3. tokens scattered into an (E*C, D) buffer (capacity C drops overflow),
     expert FFNs run batched over E, outputs gathered back and combined
     with router weights.

Sharding: expert-major params (E, D, F). For E >= 16 the expert axis is
sharded on the mesh "model" axis (expert parallelism; XLA inserts the
all-to-all-equivalent collectives at the scatter/gather); for small E the
FFN width is sharded instead (tensor parallelism inside each expert).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import GATED, _act, dense_init, split_keys

EXPERT_SHARD_MIN = 16

# Dispatch distribution knobs, set by the launcher (tests/CPU leave the
# defaults). Two measured pathologies motivate them (EXPERIMENTS.md
# §Perf iterations 2-3):
#   * without a buffer constraint the SPMD partitioner shards the
#     dispatch buffer on E only, so every data-axis device REPLICATES
#     the expert matmuls (16x redundant compute at kimi-k2 scale);
#   * with a single global dispatch, tokens scatter across data shards
#     and XLA all-gathers the whole (T*k, D) update tensor (~120 GB/dev
#     at kimi train_4k). Grouped dispatch (_NUM_GROUPS = data shards)
#     keeps the scatter group-local; the only cross-device traffic left
#     is the genuine expert-parallel exchange over the model axis.
_DISPATCH_SPEC = None      # PartitionSpec for the (G, E, C, D) buffer
_NUM_GROUPS = 1


def set_dispatch_spec(spec, num_groups: int = 1):
    global _DISPATCH_SPEC, _NUM_GROUPS
    _DISPATCH_SPEC = spec
    _NUM_GROUPS = max(int(num_groups), 1)


def default_dispatch_spec(cfg: ModelConfig, batch_axes):
    e_axis = "model" if cfg.num_experts >= EXPERT_SHARD_MIN else None
    return P(batch_axes, e_axis, None, None)


def _constrain(x):
    if _DISPATCH_SPEC is None:
        return x
    spec = _DISPATCH_SPEC
    if x.shape[0] == 1:               # grouping fell back to G=1
        spec = P(None, *list(spec)[1:])
    return jax.lax.with_sharding_constraint(x, spec)


def init_moe(cfg: ModelConfig, key, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = split_keys(key, 4)
    p = {"router": dense_init(ks[0], (d, e), dtype, scale=d ** -0.5)}
    if cfg.activation in GATED:
        p["wg"] = dense_init(ks[1], (e, d, f), dtype)
    p["wi"] = dense_init(ks[2], (e, d, f), dtype)
    p["wo"] = dense_init(ks[3], (e, f, d), dtype)
    return p


def moe_specs(cfg: ModelConfig):
    if cfg.num_experts >= EXPERT_SHARD_MIN:
        up, down = P("model", None, None), P("model", None, None)
    else:
        up, down = P(None, None, "model"), P(None, "model", None)
    p = {"router": P(None, None), "wi": up, "wo": down}
    if cfg.activation in GATED:
        p["wg"] = up
    return p


def _position_in_expert(flat_e):
    """Rank of each slot within its expert group, via stable sort.

    sort by expert id -> group positions are index minus group start
    (cummax of group-start indices) -> undo the permutation.
    """
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)              # (N,)
    sorted_e = jnp.take(flat_e, order)
    idx = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool),
                                sorted_e[1:] != sorted_e[:-1]])
    group_start = jax.lax.cummax(jnp.where(is_start, idx, 0))
    pos_sorted = idx - group_start
    inv = jnp.argsort(order, stable=True)                 # undo permutation
    return jnp.take(pos_sorted, inv)


def _capacity(cfg: ModelConfig, num_tokens: int) -> int:
    c = int(num_tokens * cfg.experts_per_token * cfg.moe_capacity_factor
            / cfg.num_experts)
    return max(c, cfg.experts_per_token)


def _dispatch_ffn(cfg: ModelConfig, p, xt, e_ids, cap):
    """Capacity-bounded dispatch + expert FFN + combine for ONE group.

    xt: (T, D) tokens; e_ids context: experts are p["wi"].shape[0] (may
    be a LOCAL shard under shard_map). Returns (T, D) combined output
    and keep mask. Tokens routed to experts outside [0, E_here) are
    masked out (shard_map path: other ranks own them)."""
    t, d = xt.shape
    e_here = p["wi"].shape[0]
    k = e_ids.shape[-1]
    flat_e = e_ids.reshape(-1)
    here = (flat_e >= 0) & (flat_e < e_here)
    flat_pos = _position_in_expert(jnp.where(here, flat_e, e_here))
    keep = here & (flat_pos < cap)
    dest = jnp.where(keep, flat_e * cap + flat_pos, e_here * cap)

    src = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((e_here * cap + 1, d), xt.dtype).at[dest].add(
        xt[src] * keep[:, None].astype(xt.dtype))
    buf = buf[:-1].reshape(e_here, cap, d)

    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    if cfg.activation in GATED:
        gate = _act(GATED[cfg.activation],
                    jnp.einsum("ecd,edf->ecf", buf, p["wg"]))
        h = gate * h
    else:
        h = _act(cfg.activation, h)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"]).reshape(e_here * cap, d)
    out_buf = jnp.concatenate(
        [out_buf, jnp.zeros((1, d), out_buf.dtype)])
    return out_buf, dest, keep, src


def apply_moe(cfg: ModelConfig, p, x):
    """x: (B, S, D) -> (B, S, D), plus aux dict (load-balance stats).

    Dispatch runs in ``G = _NUM_GROUPS`` independent groups (the
    launcher sets G to the data-shard count so each group's
    scatter/gather stays device-local; G=1 reproduces the global
    textbook dispatch — capacity is per-group either way).
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.experts_per_token
    g = _NUM_GROUPS if t % _NUM_GROUPS == 0 else 1
    tg = t // g
    xt = x.reshape(g, tg, d)

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                  # (G,Tg,k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    cap = _capacity(cfg, tg)
    flat_e = topi.reshape(g, tg * k)                      # (G,Tg*k)
    flat_pos = jax.vmap(_position_in_expert)(flat_e)
    keep = flat_pos < cap
    dest = flat_e * cap + flat_pos
    dest = jnp.where(keep, dest, e * cap)                 # overflow slot

    src = jnp.repeat(jnp.arange(tg), k)                   # token idx per slot
    gi = jnp.arange(g)[:, None]
    buf = jnp.zeros((g, e * cap + 1, d), x.dtype).at[gi, dest].add(
        xt[:, src] * keep[..., None].astype(x.dtype))
    buf = _constrain(buf[:, :-1].reshape(g, e, cap, d))

    h = jnp.einsum("gecd,edf->gecf", buf, p["wi"])
    if cfg.activation in GATED:
        gate = _act(GATED[cfg.activation],
                    jnp.einsum("gecd,edf->gecf", buf, p["wg"]))
        h = gate * h
    else:
        h = _act(cfg.activation, h)
    out_buf = _constrain(jnp.einsum("gecf,efd->gecd", h, p["wo"]))
    out_buf = out_buf.reshape(g, e * cap, d)
    out_buf = jnp.concatenate(
        [out_buf, jnp.zeros((g, 1, d), out_buf.dtype)], axis=1)

    gathered = out_buf[gi, dest] * (
        topw.reshape(g, -1, 1).astype(out_buf.dtype)
        * keep[..., None].astype(out_buf.dtype))
    out = jnp.zeros((g, tg, d), out_buf.dtype).at[gi, src].add(gathered)

    # load-balance aux loss terms (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))                     # router prob mass
    ce = jnp.mean(jax.nn.one_hot(topi[..., 0], e, dtype=jnp.float32),
                  axis=(0, 1))
    aux = {"load_balance": e * jnp.sum(me * ce),
           "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32))}
    return out.reshape(b, s, d), aux


# ===========================================================================
# shard_map implementation (§Perf iteration 4 — beyond-paper)
# ===========================================================================
# XLA's SPMD partitioner cannot prove locality of the data-dependent
# dispatch scatter, so at jit level it either replicates expert compute
# (no constraint), or all-reduces the full dispatch buffer (constrained;
# measured 5342 s collective at kimi train_4k). shard_map makes the
# schedule explicit: tokens are replicated within a model-axis row; each
# model rank dispatches ONLY to the experts it owns (E-sharded, E >= 16)
# or runs every expert's FFN shard (F-sharded, E < 16); a single psum
# over "model" combines outputs — identical collective shape to a
# tensor-parallel MLP all-reduce.
_SHARDED = None


def set_sharded_impl(mesh=None, *, batch_axes=("data",)):
    """Enable (mesh given) or disable (None) the shard_map MoE path."""
    global _SHARDED
    _SHARDED = None if mesh is None else {"mesh": mesh,
                                          "batch_axes": tuple(batch_axes)}


def moe_forward(cfg: ModelConfig, p, x):
    """Entry point used by the transformer blocks."""
    if _SHARDED is not None:
        return apply_moe_sharded(cfg, p, x)
    return apply_moe(cfg, p, x)


def apply_moe_sharded(cfg: ModelConfig, p, x):
    mesh = _SHARDED["mesh"]
    baxes = _SHARDED["batch_axes"]
    e, k = cfg.num_experts, cfg.experts_per_token
    e_sharded = e >= EXPERT_SHARD_MIN
    n_model = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]

    up = P("model", None, None) if e_sharded else P(None, None, "model")
    down = P("model", None, None) if e_sharded else P(None, "model", None)
    wspec = {"router": P(None, None), "wi": up, "wo": down}
    if cfg.activation in GATED:
        wspec["wg"] = up
    xspec = P(baxes, None, None)
    all_axes = tuple(a for a in mesh.axis_names)

    def body(p_l, x_l):
        b, s, d = x_l.shape
        t = b * s
        xt = x_l.reshape(t, d)
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                            p_l["router"].astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        topw, topi = jax.lax.top_k(probs, k)              # (T,k) global ids
        topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

        e_here = p_l["wi"].shape[0]                       # local expert count
        if e_sharded:
            r = jax.lax.axis_index("model")
            local_ids = topi - r * e_here                 # out-of-range ->
        else:                                             # masked in dispatch
            local_ids = topi
        cap = _capacity(cfg, t)
        out_buf, dest, keep, src = _dispatch_ffn(cfg, p_l, xt,
                                                 local_ids, cap)
        gathered = out_buf[dest] * (
            topw.reshape(-1, 1).astype(out_buf.dtype)
            * keep[:, None].astype(out_buf.dtype))
        out = jnp.zeros((t, d), out_buf.dtype).at[src].add(gathered)
        out = jax.lax.psum(out, "model")                  # the ONE collective

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(topi[:, 0], e, dtype=jnp.float32),
                      axis=0)
        lb = e * jnp.sum(me * ce)
        kept = jnp.sum(keep.astype(jnp.float32))
        slots = jnp.float32(t * k) / (n_model if e_sharded else 1)
        aux = {"load_balance": jax.lax.pmean(lb, all_axes),
               "dropped_frac": 1.0 - jax.lax.pmean(kept, all_axes)
               / slots}
        return out.reshape(b, s, d).astype(x_l.dtype), aux

    from repro.compat import shard_map
    return shard_map(
        body, mesh=mesh, in_specs=(wspec, xspec),
        out_specs=(xspec, {"load_balance": P(), "dropped_frac": P()}),
        check_vma=False)(p, x)
