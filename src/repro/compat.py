"""jax version compatibility shims (no repro imports — safe to use
from any module without creating cycles)."""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """jax >= 0.5 exposes `jax.shard_map(..., check_vma=)`; older
    releases only `jax.experimental.shard_map.shard_map(...,
    check_rep=)` (same meaning, old name). All repo code routes through
    this wrapper so both spellings work."""
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
