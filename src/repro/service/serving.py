"""Personalized serving front (DESIGN.md §13).

WPFed's output is not ONE model — it is M personalized models, stacked
on the padded client axis of the live federation state. Serving them
individually (one forward per request against one client's params)
wastes the stacked layout; `PersonalizedServer` instead batches
requests ACROSS clients: gather the requested rows of the stacked
params, one vmapped forward over the whole batch. Requests for
different clients ride the same XLA program.

Static shapes meet variable load the same way churn meets the client
axis — padding. Batches pad up to a small ladder of bucket sizes, so
the server compiles once per bucket (not once per load level) and a
lone request does not retrace.

The server reads params by reference and `update_params` swaps them
between periods — the service driver serves period t's models while
period t+1 trains.
"""
from __future__ import annotations

import functools
import time
from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.privacy import declassifier, sink

DEFAULT_BUCKETS = (1, 4, 16, 64, 256)


@declassifier(
    name="served-logits", paper_eq="§2.1 (personalized model outputs)",
    justification="output of client i's OWN personalized model on the "
                  "requester's input — serving a client its own "
                  "predictions is the product of the federation, not a "
                  "cross-client disclosure")
def served_logits(logits):
    return logits


def _forward_fn(apply_fn: Callable, ps, ids, x):
    """The server's one XLA program: gather the requested client rows,
    then a single-example forward per request (vmapped) — cross-client
    batching in one call. Module-level (not a closure) so the taint
    verifier can trace exactly the jaxpr that serves
    (`analysis.taint.head_targets`, target "serving-forward")."""
    out = jax.vmap(
        lambda row, xi: apply_fn(row, xi[None])[0]
    )(jax.tree.map(lambda p: p[ids], ps), x)
    return sink("serving-response", served_logits(out))


class PersonalizedServer:
    """Batched inference over the federation's per-client models.

    apply_fn(params_i, x) -> logits — ONE client's forward over a batch
    of examples (the same contract as `core.protocol`). `params` is the
    stacked (M, ...) pytree from FedState.
    """

    def __init__(self, apply_fn: Callable, params: Any, *,
                 batch_buckets: Sequence[int] = DEFAULT_BUCKETS):
        if not batch_buckets or any(b < 1 for b in batch_buckets):
            raise ValueError(
                f"batch_buckets must be positive, got {batch_buckets!r}")
        self._buckets = tuple(sorted(set(int(b) for b in batch_buckets)))
        self._params = params
        self._num_clients = jax.tree.leaves(params)[0].shape[0]
        # one program, compiled once per bucket size (see _forward_fn)
        self._forward = jax.jit(functools.partial(_forward_fn, apply_fn))
        self._queue: List[Tuple[int, jnp.ndarray]] = []
        self.stats: Dict[str, Any] = {
            "requests": 0, "batches": 0, "padded_slots": 0,
            "total_s": 0.0, "latency_s": []}

    # -- request path ------------------------------------------------------
    def submit(self, client_id: int, x) -> int:
        """Enqueue one request (a single example for `client_id`'s
        personalized model). Returns its position in the next flush."""
        if not 0 <= client_id < self._num_clients:
            raise ValueError(
                f"client_id {client_id} outside the client axis "
                f"[0, {self._num_clients})")
        self._queue.append((int(client_id), jnp.asarray(x)))
        return len(self._queue) - 1

    def _bucket(self, n: int) -> int:
        for b in self._buckets:
            if n <= b:
                return b
        return self._buckets[-1]

    def flush(self) -> List[np.ndarray]:  # analysis: host-ok (serving edge)
        """Serve every queued request; returns one logits array per
        request, in submit order. Oversized queues drain in
        largest-bucket chunks."""
        out: List[np.ndarray] = []
        while self._queue:
            chunk = self._queue[:self._buckets[-1]]
            del self._queue[:len(chunk)]
            out.extend(self._serve_chunk(chunk))
        return out

    def _serve_chunk(self, chunk):  # analysis: host-ok (request marshalling)
        n = len(chunk)
        b = self._bucket(n)
        ids = np.zeros((b,), np.int32)
        ids[:n] = [c for c, _ in chunk]
        x = jnp.stack([xi for _, xi in chunk])
        if b > n:  # pad to the bucket: same program for any load level
            x = jnp.concatenate(
                [x, jnp.zeros((b - n,) + x.shape[1:], x.dtype)])
        t0 = time.time()
        logits = self._forward(self._params, jnp.asarray(ids), x)
        logits = np.asarray(jax.block_until_ready(logits))
        dt = time.time() - t0
        self.stats["requests"] += n
        self.stats["batches"] += 1
        self.stats["padded_slots"] += b - n
        self.stats["total_s"] += dt
        self.stats["latency_s"].append(dt)
        return [logits[i] for i in range(n)]

    # -- federation integration -------------------------------------------
    def update_params(self, params: Any) -> None:
        """Hot-swap to a new period's personalized models. Shapes must
        match (the padded client axis is static — churn is masking)."""
        if jax.tree.leaves(params)[0].shape[0] != self._num_clients:
            raise ValueError("client axis changed; build a new server")
        self._params = params

    def throughput(self):  # analysis: host-ok (telemetry summarization)
        """Summary stats for BENCH_service.json."""
        lat = self.stats["latency_s"]
        total = max(self.stats["total_s"], 1e-9)
        return {
            "requests": float(self.stats["requests"]),
            "batches": float(self.stats["batches"]),
            "padded_slots": float(self.stats["padded_slots"]),
            "requests_per_s": self.stats["requests"] / total,
            "mean_batch_latency_s": float(np.mean(lat)) if lat else 0.0,
            "p50_latency_s": float(np.percentile(lat, 50)) if lat else 0.0,
            "p95_latency_s": float(np.percentile(lat, 95)) if lat else 0.0,
        }
