"""Continuous federation driver (DESIGN.md §13).

An io_callback / host-loop hybrid over the `core.rounds` engine:

  * INSIDE each reselection period everything is one compiled segment
    (`make_segment_fn` — global round + L-1 gossip epochs under
    lax.scan). Per-round scalar metrics can additionally stream to the
    host mid-segment through the engine's ordered-io_callback metrics
    tap, so a service operator sees rounds as they happen rather than
    once per period.
  * BETWEEN periods the host loop runs: churn events apply
    (membership.apply_events), the period's announcements publish to
    the host `Blockchain`, and the full ServiceState checkpoints
    through `checkpoint.store` (with retention) so a killed service
    resumes bit-exact (`resume_service`).

The service round program wraps the WPFed phases with the membership
masks:

  global round   §3.6 verification restricted to active reporters,
                 Eq. 8 scores discounted by exp(-lambda * code_age)
                 and forced to -inf for departed clients, updates and
                 announcements applied to active clients only
                 (inactive slots keep frozen codes/rankings/params and
                 age one period).
  gossip epoch   exchange + update against the cached SelectResult,
                 with the per-client heterogeneous gossip budget G_i:
                 client i trains only in the first G_i - 1 gossip
                 epochs of the period.

Unlike `run_rounds`, every period has the same (full) length — a
service has no final-rounds tail — so exactly ONE segment compiles per
run and the round axis is unbounded.
"""
from __future__ import annotations

import os
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.privacy import sink
from repro.checkpoint import store
from repro.configs.paper_models import FedConfig
from repro.core.chain import (Blockchain, load_chain, lsh_code_hex,
                              save_chain, sha256_commit)
from repro.core.protocol import (FedState, _round_metrics, announce_phase,
                                 exchange_phase, select_phase, update_phase)
from repro.core.rounds import RoundProgram, extract_history, make_segment_fn
from repro.service.membership import (ChurnEvent, ServiceConfig,
                                      ServiceState, apply_events,
                                      participation_mask,
                                      staleness_discount, validate_events)

CHAIN_FILE = "chain.json"


# ---------------------------------------------------------------------------
# the service round program
# ---------------------------------------------------------------------------
def _service_metrics(sel, exch, train_metrics, state: ServiceState,
                     participate) -> Dict[str, jnp.ndarray]:
    """The engine's per-round metrics plus the membership telemetry.
    Identical structure in the global round and every gossip epoch so
    a period stacks under lax.scan."""
    base = _round_metrics(sel, exch, train_metrics, state.fed.round)
    base["active_frac"] = jnp.mean(state.active.astype(jnp.float32))
    base["participation_frac"] = jnp.mean(
        participate.astype(jnp.float32))
    base["mean_code_age"] = jnp.mean(state.code_age.astype(jnp.float32))
    return base


def service_program(apply_fn: Callable, optimizer, fed: FedConfig,
                    svc: ServiceConfig) -> RoundProgram:
    """WPFed as a churn-tolerant service program over ServiceState.

    The decision here is churn-as-masking (DESIGN.md §13): departed
    clients still occupy their padded slot and their (frozen) params
    still evaluate inside exchanges that never read them — the price of
    one static shape per segment. What the masks guarantee:

      * a departed client's Eq. 8 weight is -inf, so it never enters
        any peer's top-N (and its stale rankings stop counting as
        Eq. 7 evidence);
      * a stale re-joiner is selectable, at a score discounted by
        exp(-staleness_lambda * code_age);
      * only participants' params / optimizer state advance;
      * only active clients announce — everyone else's codes,
        rankings, commitments and code_age carry over frozen.
    """
    if not fed.use_rank:
        raise ValueError(
            "the service requires use_rank=True: departed clients are "
            "excluded through the Eq. 8 score column (membership.py)")

    def global_round(state: ServiceState, data
                     ) -> Tuple[ServiceState, Any, Dict]:
        st = state.fed
        rng, rng_sel, rng_upd = jax.random.split(st.rng, 3)
        sel = select_phase(
            st, fed, rng=rng_sel, active=state.active,
            score_scale=staleness_discount(state.code_age,
                                           svc.staleness_lambda))
        exch = exchange_phase(apply_fn, fed, st.params, data, sel)
        params, opt_state, train_metrics = update_phase(
            apply_fn, optimizer, fed, st.params, st.opt_state, data,
            exch, rng_upd, participate=state.active)
        ann = announce_phase(fed, params, sel, exch, st.round)
        a = state.active
        # these merged fields are what service_publisher reads onto the
        # host ledger and what checkpoints as chain.json — the service's
        # disclosure point (repro.analysis.taint verifies it)
        codes, rankings, commitments = sink("ledger-publish", (
            jnp.where(a[:, None], ann.codes, st.codes),
            jnp.where(a[:, None], ann.rankings, st.rankings),
            jnp.where(a, ann.commitments, st.commitments)))
        new_fed = FedState(params, opt_state, codes, rankings,
                           commitments, rng, st.round + 1)
        metrics = _service_metrics(sel, exch, train_metrics, state, a)
        new_state = ServiceState(
            new_fed, a, jnp.where(a, 0, state.code_age + 1),
            state.gossip_count, jnp.asarray(st.round, jnp.int32))
        return new_state, sel, metrics

    def gossip_round(state: ServiceState, data, sel
                     ) -> Tuple[ServiceState, Any, Dict]:
        st = state.fed
        rng, rng_upd = jax.random.split(st.rng)
        # 0-based gossip epoch within the period (round already
        # advanced past the period's global round)
        epoch = st.round - state.period_start - 1
        part = participation_mask(state, epoch)
        exch = exchange_phase(apply_fn, fed, st.params, data, sel)
        params, opt_state, train_metrics = update_phase(
            apply_fn, optimizer, fed, st.params, st.opt_state, data,
            exch, rng_upd, participate=part)
        metrics = _service_metrics(sel, exch, train_metrics, state, part)
        new_state = state._replace(fed=st._replace(
            params=params, opt_state=opt_state, rng=rng,
            round=st.round + 1))
        return new_state, sel, metrics

    return RoundProgram("wpfed-service", global_round, gossip_round)


# ---------------------------------------------------------------------------
# ledger + durable state
# ---------------------------------------------------------------------------
def service_publisher(chain: Blockchain, num_clients: int) -> Callable:
    """Publish a period's announcements for ACTIVE clients only —
    departed clients announce nothing (their last block stands)."""

    def publish(round_idx: int, state: ServiceState):  # analysis: host-ok
        # intentional device->host pull, once per reselection period:
        # the ledger records announcements, not device arrays (§8)
        active = np.asarray(state.active)
        codes = np.asarray(state.fed.codes)
        rankings = np.asarray(state.fed.rankings)
        ann = {i: {"lsh": lsh_code_hex(codes[i]),
                   "commit": sha256_commit(rankings[i])}
               for i in range(num_clients) if active[i]}
        reveals = {i: [int(x) for x in rankings[i]]
                   for i in range(num_clients) if active[i]}
        chain.publish_round(round_idx, ann, reveals=reveals)

    return publish


def checkpoint_service(ckpt_dir: str, period: int, state: ServiceState,
                       chain: Blockchain, *, keep_last_k: int) -> str:
    """One durable snapshot: the full ServiceState pytree as
    step_<period>.npz (retained to the last k) plus the chain head as
    chain.json — everything `resume_service` needs."""
    path = store.save(ckpt_dir, period, state, keep_last_k=keep_last_k)
    save_chain(os.path.join(ckpt_dir, CHAIN_FILE), chain)
    return path


def checkpoint_num_clients(ckpt_dir: str) -> int:  # analysis: host-ok — reads snapshot file metadata, no device values
    """Client-axis size M of the latest snapshot, read from the stored
    active mask WITHOUT a template — lets a serving front rebuild a
    correctly-shaped template before calling resume_service."""
    period = store.latest_step(ckpt_dir)
    if period is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir!r}")
    with np.load(os.path.join(ckpt_dir,
                              f"step_{period:08d}.npz")) as z:
        return int(z["a:active"].shape[0])


def resume_service(ckpt_dir: str, like: ServiceState
                   ) -> Tuple[ServiceState, Blockchain, int]:
    """Restore (state, chain, next_period) from the latest checkpoint.

    `like` is a template ServiceState (same configs/shapes as the run
    being resumed — rebuild it with init_service_state). The restored
    chain must verify BEFORE the service continues: a resume from a
    tampered ledger is a trust violation, not a degraded start."""
    period = store.latest_step(ckpt_dir)
    if period is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir!r}")
    # restore() hands back numpy leaves; put them on device so the
    # resumed state drops into the compiled segment unchanged
    state = jax.tree.map(jnp.asarray, store.restore(ckpt_dir, period, like))
    chain = load_chain(os.path.join(ckpt_dir, CHAIN_FILE))
    if not chain.verify_chain():
        raise ValueError(
            f"restored ledger fails verify_chain ({ckpt_dir!r})")
    return state, chain, period + 1


# ---------------------------------------------------------------------------
# the continuous driver
# ---------------------------------------------------------------------------
def run_service(apply_fn: Callable, optimizer, fed: FedConfig,
                svc: ServiceConfig, state: ServiceState, data, *,
                periods: int, events: Sequence[ChurnEvent] = (),
                chain: Optional[Blockchain] = None,
                ckpt_dir: Optional[str] = None, start_period: int = 0,
                eval_fn: Optional[Callable] = None,
                metrics_tap: Optional[Callable] = None,
                log: Optional[Callable] = None
                ) -> Tuple[ServiceState, Blockchain, List[Dict]]:
    """Drive reselection periods `start_period .. periods-1`.

    Per period: apply churn events -> run ONE compiled segment of
    svc.reselect_every rounds -> publish active announcements to the
    ledger -> checkpoint (every svc.checkpoint_every periods, retaining
    svc.keep_last_k snapshots). `metrics_tap(scalars_dict)` streams
    per-round scalars from INSIDE the compiled segment (ordered
    io_callback); the returned history is extracted from the stacked
    period metrics after the host sync, exactly like run_rounds.

    Restart recipe: rebuild (fed, svc, state-template, data, events)
    from the same configuration, then
    `state, chain, p0 = resume_service(ckpt_dir, template)` and call
    run_service again with start_period=p0 — per-round metrics are
    identical to the uninterrupted run (regression-tested).
    """
    events = validate_events(events, fed.num_clients)
    chain = chain if chain is not None else Blockchain()
    publish = service_publisher(chain, fed.num_clients)
    program = service_program(apply_fn, optimizer, fed, svc)
    length = svc.reselect_every
    seg_fn = jax.jit(make_segment_fn(program, length, eval_fn=eval_fn,
                                     metrics_tap=metrics_tap))
    history: List[Dict] = []
    for period in range(start_period, periods):
        state = apply_events(state, events, period)
        t0 = time.time()
        state, metrics = seg_fn(state, data)
        jax.block_until_ready(metrics)
        dt = time.time() - t0
        r0 = period * length
        publish(r0, state)
        history.extend(extract_history(metrics, r0, length))
        if ckpt_dir is not None and \
                (period + 1 - start_period) % svc.checkpoint_every == 0:
            checkpoint_service(ckpt_dir, period, state, chain,
                               keep_last_k=svc.keep_last_k)
        if log is not None:
            last = history[-1]
            parts = [f"{k} {last[k]:.4f}" for k in ("acc", "mean_loss")
                     if k in last]
            log(f"period {period:3d} (rounds {r0}..{r0 + length - 1}) "
                + " ".join(parts)
                + f" active {last['active_frac']:.2f}"
                + f" ({dt:.1f}s)")
    return state, chain, history
