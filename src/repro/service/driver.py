"""Continuous federation driver (DESIGN.md §13).

An io_callback / host-loop hybrid over the `core.rounds` engine:

  * INSIDE each reselection period everything is one compiled segment
    (`make_segment_fn` — global round + L-1 gossip epochs under
    lax.scan). Per-round scalar metrics can additionally stream to the
    host mid-segment through the engine's ordered-io_callback metrics
    tap, so a service operator sees rounds as they happen rather than
    once per period.
  * BETWEEN periods the host loop runs: churn events apply
    (membership.apply_events), the period's announcements publish to
    the host `Blockchain`, and the full ServiceState checkpoints
    through `checkpoint.store` (with retention) so a killed service
    resumes bit-exact (`resume_service`).

The service round program wraps the WPFed phases with the membership
masks:

  global round   §3.6 verification restricted to active reporters,
                 Eq. 8 scores discounted by exp(-lambda * code_age)
                 and forced to -inf for departed clients, updates and
                 announcements applied to active clients only
                 (inactive slots keep frozen codes/rankings/params and
                 age one period).
  gossip epoch   exchange + update against the cached SelectResult,
                 with the per-client heterogeneous gossip budget G_i:
                 client i trains only in the first G_i - 1 gossip
                 epochs of the period.

Unlike `run_rounds`, every period has the same (full) length — a
service has no final-rounds tail — so exactly ONE segment compiles per
run and the round axis is unbounded.

Faults and degraded rounds (DESIGN.md §15): every ledger interaction
routes through `service.transport.BulletinTransport` — checksummed
announcements, bounded-retry publish/fetch, and (when a
`core.faults.FaultPlan` is supplied) deterministic fault injection.
Stragglers mask out of the segment through the SAME churn masking that
join/leave uses; failed deliveries revert to last-known-good codes
after the segment (`membership.merge_delivery`); per-period fault
counters stream through the existing io_callback metric channel and
land on the period's history entries.
"""
from __future__ import annotations

import os
import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.privacy import sink
from repro.checkpoint import store
from repro.configs.paper_models import FedConfig
from repro.core.chain import Blockchain, save_chain
from repro.core.faults import FaultPlan, fault_scalars
from repro.core.protocol import (FedState, _round_metrics, announce_phase,
                                 exchange_phase, select_phase, update_phase)
from repro.core.rounds import RoundProgram, extract_history, make_segment_fn
from repro.service.membership import (ChurnEvent, ServiceConfig,
                                      ServiceState, apply_events,
                                      mask_stragglers, merge_delivery,
                                      participation_mask,
                                      staleness_discount, validate_events)
from repro.service.transport import (CHAIN_FILE, BulletinTransport,
                                     recover_chain, rollback_view,
                                     write_fork_view)


class CrashInjected(RuntimeError):
    """A FaultPlan-scheduled crash-restart fired: the driver dies after
    the period's segment but BEFORE any durable effect (publish /
    checkpoint), exactly where a real process kill hurts most. The
    chaos soak catches this, resumes from the last checkpoint, and
    asserts bitwise equivalence with the uninterrupted run."""

    def __init__(self, period: int):
        super().__init__(
            f"fault-injected crash at period {period} (resume from the "
            f"last checkpoint to continue)")
        self.period = period


# ---------------------------------------------------------------------------
# the service round program
# ---------------------------------------------------------------------------
def _service_metrics(sel, exch, train_metrics, state: ServiceState,
                     participate) -> Dict[str, jnp.ndarray]:
    """The engine's per-round metrics plus the membership telemetry.
    Identical structure in the global round and every gossip epoch so
    a period stacks under lax.scan."""
    base = _round_metrics(sel, exch, train_metrics, state.fed.round)
    base["active_frac"] = jnp.mean(state.active.astype(jnp.float32))
    base["participation_frac"] = jnp.mean(
        participate.astype(jnp.float32))
    base["mean_code_age"] = jnp.mean(state.code_age.astype(jnp.float32))
    return base


def service_program(apply_fn: Callable, optimizer, fed: FedConfig,
                    svc: ServiceConfig) -> RoundProgram:
    """WPFed as a churn-tolerant service program over ServiceState.

    The decision here is churn-as-masking (DESIGN.md §13): departed
    clients still occupy their padded slot and their (frozen) params
    still evaluate inside exchanges that never read them — the price of
    one static shape per segment. What the masks guarantee:

      * a departed client's Eq. 8 weight is -inf, so it never enters
        any peer's top-N (and its stale rankings stop counting as
        Eq. 7 evidence);
      * a stale re-joiner is selectable, at a score discounted by
        exp(-staleness_lambda * code_age);
      * only participants' params / optimizer state advance;
      * only active clients announce — everyone else's codes,
        rankings, commitments and code_age carry over frozen.
    """
    if not fed.use_rank:
        raise ValueError(
            "the service requires use_rank=True: departed clients are "
            "excluded through the Eq. 8 score column (membership.py)")

    def global_round(state: ServiceState, data
                     ) -> Tuple[ServiceState, Any, Dict]:
        st = state.fed
        rng, rng_sel, rng_upd = jax.random.split(st.rng, 3)
        sel = select_phase(
            st, fed, rng=rng_sel, active=state.active,
            score_scale=staleness_discount(state.code_age,
                                           svc.staleness_lambda))
        exch = exchange_phase(apply_fn, fed, st.params, data, sel)
        params, opt_state, train_metrics = update_phase(
            apply_fn, optimizer, fed, st.params, st.opt_state, data,
            exch, rng_upd, participate=state.active)
        ann = announce_phase(fed, params, sel, exch, st.round)
        a = state.active
        # these merged fields are what transport.collect reads onto the
        # host ledger and what checkpoints as chain.json — the service's
        # disclosure point (repro.analysis.taint verifies it)
        codes, rankings, commitments = sink("ledger-publish", (
            jnp.where(a[:, None], ann.codes, st.codes),
            jnp.where(a[:, None], ann.rankings, st.rankings),
            jnp.where(a, ann.commitments, st.commitments)))
        new_fed = FedState(params, opt_state, codes, rankings,
                           commitments, rng, st.round + 1)
        metrics = _service_metrics(sel, exch, train_metrics, state, a)
        new_state = ServiceState(
            new_fed, a, jnp.where(a, 0, state.code_age + 1),
            state.gossip_count, jnp.asarray(st.round, jnp.int32))
        return new_state, sel, metrics

    def gossip_round(state: ServiceState, data, sel
                     ) -> Tuple[ServiceState, Any, Dict]:
        st = state.fed
        rng, rng_upd = jax.random.split(st.rng)
        # 0-based gossip epoch within the period (round already
        # advanced past the period's global round)
        epoch = st.round - state.period_start - 1
        part = participation_mask(state, epoch)
        exch = exchange_phase(apply_fn, fed, st.params, data, sel)
        params, opt_state, train_metrics = update_phase(
            apply_fn, optimizer, fed, st.params, st.opt_state, data,
            exch, rng_upd, participate=part)
        metrics = _service_metrics(sel, exch, train_metrics, state, part)
        new_state = state._replace(fed=st._replace(
            params=params, opt_state=opt_state, rng=rng,
            round=st.round + 1))
        return new_state, sel, metrics

    return RoundProgram("wpfed-service", global_round, gossip_round)


# ---------------------------------------------------------------------------
# durable state
# ---------------------------------------------------------------------------
def checkpoint_service(ckpt_dir: str, period: int, state: ServiceState,
                       chain: Blockchain, *, keep_last_k: int) -> str:
    """One durable snapshot: the full ServiceState pytree as
    step_<period>.npz (retained to the last k) plus the chain head as
    chain.json — everything `resume_service` needs."""
    path = store.save(ckpt_dir, period, state, keep_last_k=keep_last_k)
    save_chain(os.path.join(ckpt_dir, CHAIN_FILE), chain)
    return path


def checkpoint_num_clients(ckpt_dir: str) -> int:  # analysis: host-ok — reads snapshot file metadata, no device values
    """Client-axis size M of the latest snapshot, read from the stored
    active mask WITHOUT a template — lets a serving front rebuild a
    correctly-shaped template before calling resume_service."""
    period = store.latest_step(ckpt_dir)
    if period is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir!r}")
    with np.load(os.path.join(ckpt_dir,
                              f"step_{period:08d}.npz")) as z:
        return int(z["a:active"].shape[0])


def resume_service(ckpt_dir: str, like: ServiceState
                   ) -> Tuple[ServiceState, Blockchain, int]:
    """Restore (state, chain, next_period), crash-safely.

    `like` is a template ServiceState (same configs/shapes as the run
    being resumed — rebuild it with init_service_state).

    Degraded starts this survives: a truncated/corrupt newest snapshot
    falls back (with a warning) to the previous retained one; a
    tampered or missing chain.json falls back to any valid
    chain.fork*.json view, longest-valid-chain wins (transport.
    recover_chain). Trust violations it refuses: NO ledger view
    verifying at all (ValueError, as in PR 8), and a ledger that
    verifies but sits BEHIND the checkpoint's round counter
    (LedgerRollbackError — silent rollback is a fork symptom, not a
    degraded start)."""
    retained = store.steps(ckpt_dir)
    if not retained:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir!r}")
    state, period = None, -1
    for step in reversed(retained):
        try:
            # restore() hands back numpy leaves; put them on device so
            # the resumed state drops into the compiled segment
            # unchanged
            state = jax.tree.map(jnp.asarray,
                                 store.restore(ckpt_dir, step, like))
            period = step
            break
        except Exception as e:
            warnings.warn(
                f"checkpoint step_{step:08d}.npz unreadable ({e}); "
                f"falling back to the previous retained snapshot")
    if state is None:
        raise ValueError(
            f"every retained checkpoint under {ckpt_dir!r} failed to "
            f"load ({len(retained)} tried) — no snapshot to resume from")
    # the checkpoint's round counter: the chain must cover the period
    # that produced this snapshot, else it silently lost history
    min_round = int(state.period_start)  # analysis: host-ok — one scalar pull to cross-check ledger coverage at resume
    chain = recover_chain(ckpt_dir, min_round=min_round)
    return state, chain, period + 1


# ---------------------------------------------------------------------------
# the continuous driver
# ---------------------------------------------------------------------------
def run_service(apply_fn: Callable, optimizer, fed: FedConfig,
                svc: ServiceConfig, state: ServiceState, data, *,
                periods: int, events: Sequence[ChurnEvent] = (),
                chain: Optional[Blockchain] = None,
                ckpt_dir: Optional[str] = None, start_period: int = 0,
                eval_fn: Optional[Callable] = None,
                metrics_tap: Optional[Callable] = None,
                log: Optional[Callable] = None,
                faults: Optional[FaultPlan] = None,
                transport: Optional[BulletinTransport] = None
                ) -> Tuple[ServiceState, Blockchain, List[Dict]]:
    """Drive reselection periods `start_period .. periods-1`.

    Per period: apply churn events -> mask this period's stragglers
    (fault plans only) -> run ONE compiled segment of
    svc.reselect_every rounds -> reconcile announcement delivery and
    publish through the hardened transport (checksums, bounded retry,
    read-back fetch) -> checkpoint (every svc.checkpoint_every periods,
    retaining svc.keep_last_k snapshots). `metrics_tap(scalars_dict)`
    streams per-round scalars from INSIDE the compiled segment (ordered
    io_callback) — under a fault plan each round's dict additionally
    carries the period's fault counters (`core.faults.fault_scalars`).
    The returned history is extracted from the stacked period metrics
    after the host sync, exactly like run_rounds, with the fault
    counters attached to each period's last entry.

    `faults=FaultPlan(...)` turns on deterministic fault injection
    (shorthand for transport=BulletinTransport(chain, plan=faults));
    pass `transport=` directly to control retry policy or sleeping. A
    plan-scheduled crash period raises CrashInjected after the segment,
    before publish/checkpoint — except at `start_period` itself, so a
    resume that lands on the crash period replays it instead of dying
    in a loop.

    Restart recipe: rebuild (fed, svc, state-template, data, events)
    from the same configuration, then
    `state, chain, p0 = resume_service(ckpt_dir, template)` and call
    run_service again with start_period=p0 — per-round metrics are
    identical to the uninterrupted run (regression-tested, fault plans
    included).
    """
    events = validate_events(events, fed.num_clients)
    chain = chain if chain is not None else Blockchain()
    if transport is None:
        transport = BulletinTransport(chain, plan=faults)
    elif faults is not None and transport.plan is not faults:
        raise ValueError("pass either faults= or a transport= carrying "
                         "its own plan, not both")
    chain = transport.chain
    program = service_program(apply_fn, optimizer, fed, svc)
    length = svc.reselect_every

    # the fault-counter side channel into the compiled segment's metric
    # stream: the host cell is rewritten before each period's segment
    # runs, and the ordered io_callback tap reads it as rounds stream
    fault_cell: Dict[str, float] = {}
    tap = metrics_tap
    if metrics_tap is not None and transport.plan is not None:
        def tap(scalars):
            metrics_tap({**scalars, **fault_cell})
    seg_fn = jax.jit(make_segment_fn(program, length, eval_fn=eval_fn,
                                     metrics_tap=tap))
    history: List[Dict] = []
    for period in range(start_period, periods):
        state = apply_events(state, events, period)
        base_active = state.active
        pf = transport.period_faults(period, fed.num_clients)
        scalars = None
        if pf is not None:
            announcing = np.asarray(base_active, bool)  # analysis: host-ok — membership mask pull for host-side fault bookkeeping
            scalars = fault_scalars(pf, announcing)
            fault_cell.clear()
            fault_cell.update(scalars)
            stragglers = transport.straggler_mask(period, announcing)
            if stragglers.any():
                # degraded round: proceed on partial announcements by
                # the same masking churn uses (bit-identical to those
                # clients leaving for one period)
                state = mask_stragglers(state, stragglers)
            pre = (state.fed.codes, state.fed.rankings,
                   state.fed.commitments, state.code_age)
        seg_active = state.active
        t0 = time.time()
        state, metrics = seg_fn(state, data)
        jax.block_until_ready(metrics)
        dt = time.time() - t0
        if pf is not None and pf.crash and period != start_period:
            raise CrashInjected(period)
        r0 = period * length
        if pf is not None:
            state = state._replace(active=base_active)
            ann, reveals, failed, delayed = transport.collect(
                period, np.asarray(seg_active, bool), state)  # analysis: host-ok — announcement pull routes through the transport
            if failed.any() or delayed.any():
                state = merge_delivery(state, *pre, failed=failed,
                                       delayed=delayed)
        else:
            ann, reveals, _, _ = transport.collect(
                period, np.asarray(seg_active, bool), state)  # analysis: host-ok — announcement pull routes through the transport
        transport.publish(period, r0, ann, reveals)
        transport.fetch(period, r0)  # read-back verification
        entries = extract_history(metrics, r0, length)
        if scalars is not None:
            entries[-1].update(scalars)
        history.extend(entries)
        if ckpt_dir is not None and \
                (period + 1 - start_period) % svc.checkpoint_every == 0:
            checkpoint_service(ckpt_dir, period, state, chain,
                               keep_last_k=svc.keep_last_k)
            if transport.plan is not None and \
                    transport.plan.fork_at == period:
                # fault injection: a competing rolled-back ledger view
                # appears next to chain.json — resume must arbitrate
                write_fork_view(ckpt_dir, rollback_view(chain, 1))
        if log is not None:
            last = history[-1]
            parts = [f"{k} {last[k]:.4f}" for k in ("acc", "mean_loss")
                     if k in last]
            degraded = " DEGRADED" if scalars and \
                scalars.get("degraded_round") else ""
            log(f"period {period:3d} (rounds {r0}..{r0 + length - 1}) "
                + " ".join(parts)
                + f" active {last['active_frac']:.2f}"
                + f" ({dt:.1f}s){degraded}")
    return state, chain, history
