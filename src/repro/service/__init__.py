"""Continuous federation service (DESIGN.md §13).

The experiment runner (`core.rounds.run_rounds`) drives a FIXED cohort
for a FIXED number of rounds. This package turns the same round-program
engine into a *service*: an unbounded sequence of reselection periods
with client churn between periods, staleness-tolerant reselection,
durable checkpointed state (kill/resume bit-exact), and a serving front
that answers batched inference requests from the per-client
personalized models of the live federation.

  membership.py  padded-client-axis churn layer: ServiceState (active
                 mask, per-client code_age + gossip budget), join/leave
                 events, participation + degraded-round masks
  transport.py   hardened bulletin-board seam: checksummed
                 announcements, bounded-retry publish/fetch,
                 deterministic fault injection (core.faults.FaultPlan),
                 longest-valid-chain recovery
  driver.py      the continuous driver: compiled segments inside,
                 host sync + transport publish + checkpoint between
                 periods; resume_service restores bit-exact and
                 crash-safe
  serving.py     PersonalizedServer — batched inference across
                 per-client personalized models
"""
from repro.core.faults import (  # noqa: F401  (re-export: the fault
    FaultPlan,                   # plan rides the service API)
    FaultTrace,
    parse_fault_spec,
)
from repro.service.membership import (  # noqa: F401
    ChurnEvent,
    ServiceConfig,
    ServiceState,
    apply_events,
    init_service_state,
    join,
    leave,
    mask_stragglers,
    merge_delivery,
    parse_events,
    participation_mask,
    staleness_discount,
)
from repro.service.transport import (  # noqa: F401
    BulletinTransport,
    LedgerRollbackError,
    RetryPolicy,
    TransportError,
    recover_chain,
)
from repro.service.driver import (  # noqa: F401
    CrashInjected,
    checkpoint_num_clients,
    resume_service,
    run_service,
    service_program,
)
from repro.service.serving import PersonalizedServer  # noqa: F401
