"""Hardened bulletin-board transport (DESIGN.md §15).

PR 8's driver talked to the host `Blockchain` directly and assumed a
perfect link: every announcement arrives intact, every publish
succeeds, every resume finds one pristine ledger. `BulletinTransport`
is the seam where network reality enters — and where the protocol
survives it:

  * every announcement carries a checksum; a corrupted delivery is
    rejected board-side and the sender's last-known-good codes stand
    (the board never holds bytes that fail their own checksum);
  * publish/fetch run under bounded retry with exponential backoff and
    deterministic jitter (`RetryPolicy`) — exhaustion raises
    `TransportError` rather than silently losing a round;
  * duplicate deliveries dedupe idempotently (same bytes, same block);
  * resume recovers the longest VALID ledger view across `chain.json`
    and any `chain.fork*.json` competitors (`recover_chain`), refusing
    with `LedgerRollbackError` when even the best view is behind the
    checkpoint's round counter — the silent-rollback / fork symptom.

Fault *injection* (the `plan=FaultPlan(...)` argument) shares one
source of truth with the driver's degraded-round bookkeeping: both
read `core.faults.period_faults`, so the counters streamed through the
metric tap and the faults the transport actually applies can never
diverge. With `plan=None` the transport is the production fault-free
path — same checksums, same retry envelope, zero injected faults —
and `benchmarks/service_bench.py` pins its overhead against the bare
publisher.

Everything here is host-side by construction: the transport IS the
device->host disclosure boundary (the in-graph side of it is the
`sink("ledger-publish", ...)` merge in `service/driver.py`, verified
by `repro.analysis.taint`).
"""
from __future__ import annotations

import dataclasses
import glob
import hashlib
import os
import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.chain import (Block, Blockchain, load_chain, lsh_code_hex,
                              save_chain, sha256_commit)
from repro.core.faults import (FaultPlan, FaultTrace, PeriodFaults,
                               fault_u01, leading_failures, period_faults)

CHAIN_FILE = "chain.json"
FORK_PATTERN = "chain.fork*.json"


class TransportError(RuntimeError):
    """The bulletin-board link stayed down past the retry budget."""


class LedgerRollbackError(ValueError):
    """The best recoverable ledger view verifies but is BEHIND the
    checkpoint's round counter — a silent-rollback / fork symptom, not
    a degraded start. Resume refuses."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff and bounded jitter.

    Attempt k (0-based) that fails waits
    `min(base * 2^k, max) * (1 + jitter * (2u - 1))` where u is a
    deterministic [0,1) draw from the plan's "backoff" stream — so a
    replayed FaultPlan replays its exact retry timing too."""
    max_attempts: int = 5
    base_delay_s: float = 0.02
    max_delay_s: float = 1.0
    jitter: float = 0.25

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(
                f"max_attempts must be >= 1, got {self.max_attempts}")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError(f"jitter must be in [0, 1], got {self.jitter}")
        if self.base_delay_s < 0 or self.max_delay_s < self.base_delay_s:
            raise ValueError(
                f"need 0 <= base_delay_s <= max_delay_s, got "
                f"{self.base_delay_s}, {self.max_delay_s}")

    def delay_s(self, attempt: int, u01: float) -> float:
        d = min(self.base_delay_s * (2.0 ** attempt), self.max_delay_s)
        return d * (1.0 + self.jitter * (2.0 * u01 - 1.0))


def announcement_checksum(entry: Dict[str, str]) -> str:
    """End-to-end checksum over the announcement wire bytes (lsh hex +
    commitment hex). Travels WITH the entry; the board recomputes it on
    receipt and rejects a mismatch — corruption in transit can degrade
    a round but never poison the ledger."""
    h = hashlib.sha256()
    h.update(entry["lsh"].encode())
    h.update(b"|")
    h.update(entry["commit"].encode())
    return h.hexdigest()[:16]


def _corrupt_hex(hexstr: str, u01: float) -> str:  # analysis: host-ok — deterministic wire-byte corruption of host hex strings
    """Flip one nibble of a hex string at a u01-chosen position — the
    injected 'bytes damaged in transit' fault (checksum catches it)."""
    pos = min(int(u01 * len(hexstr)), len(hexstr) - 1)
    nibble = int(hexstr[pos], 16) ^ 0x1
    return hexstr[:pos] + format(nibble, "x") + hexstr[pos + 1:]


class BulletinTransport:
    """The client <-> bulletin-board link, with its failure modes.

    `plan=None` (production): faithful delivery under the same checksum
    + retry envelope. `plan=FaultPlan(...)`: deterministic fault
    injection on every operation, recorded into `self.trace`.
    `sleep` is injectable so unit tests retry without wall-clock cost.
    """

    def __init__(self, chain: Blockchain, *,
                 plan: Optional[FaultPlan] = None,
                 retry: Optional[RetryPolicy] = None,
                 sleep: Optional[Callable[[float], None]] = None):
        self.chain = chain
        self.plan = plan
        self.retry = retry if retry is not None else RetryPolicy()
        self.sleep = sleep if sleep is not None else time.sleep
        self.trace = FaultTrace()

    # -- fault verdicts ----------------------------------------------------
    def period_faults(self, period: int,
                      num_clients: int) -> Optional[PeriodFaults]:
        if self.plan is None:
            return None
        return period_faults(self.plan, period, num_clients,
                             self.retry.max_attempts)

    def straggler_mask(self, period: int, active) -> np.ndarray:  # analysis: host-ok — host-side deadline verdicts over the membership mask
        """(M,) bool — active clients that miss this period's deadline
        (recorded into the trace). The driver masks them inactive for
        the segment, which is EXACTLY the churn-leave path — the
        masking-equivalence invariant tests/test_faults.py pins."""
        active = np.asarray(active, bool)
        pf = self.period_faults(period, active.shape[0])
        if pf is None:
            return np.zeros(active.shape, bool)
        strag = pf.stragglers & active
        for i in np.nonzero(strag)[0]:
            self.trace.record(period, "straggle", int(i))
        return strag

    # -- the announcement path ---------------------------------------------
    def collect(self, period: int, announcing, state  # analysis: host-ok — the transport IS the device->host announcement pull (§13/§15)
                ) -> Tuple[Dict[int, Dict[str, str]], Dict[int, List[int]],
                           np.ndarray, np.ndarray]:
        """Pull the period's announcements off the device and deliver
        them across the (possibly faulty) link.

        Returns (announcements, reveals, failed, delayed):
          * `announcements[i]` = {"lsh", "commit", "sum"} for every
            client whose announcement actually LANDED intact;
          * `failed` (M,) bool — dropped in transit or rejected by the
            board's checksum: the board keeps the client's last block,
            so the driver must revert that client's in-graph
            codes/rankings/commitments to last-known-good and age them
            (`membership.merge_delivery`);
          * `delayed` (M,) bool — landed intact but past the selection
            deadline: fresh on the board, but next period's Eq. 8
            weight sees `code_age >= 1`.
        Duplicate deliveries are byte-identical and dedupe to one
        entry (counted in the trace, no state effect)."""
        announcing = np.asarray(announcing, bool)
        codes = np.asarray(state.fed.codes)
        rankings = np.asarray(state.fed.rankings)
        m = announcing.shape[0]
        pf = self.period_faults(period, m)
        failed = np.zeros(m, bool)
        delayed = np.zeros(m, bool)
        announcements: Dict[int, Dict[str, str]] = {}
        reveals: Dict[int, List[int]] = {}
        for i in range(m):
            if not announcing[i]:
                continue
            entry = {"lsh": lsh_code_hex(codes[i]),
                     "commit": sha256_commit(rankings[i])}
            entry["sum"] = announcement_checksum(entry)
            if pf is not None:
                if pf.drop[i]:
                    failed[i] = True
                    self.trace.record(period, "drop", i)
                    continue
                if pf.corrupt[i]:
                    wire = dict(entry)
                    wire["lsh"] = _corrupt_hex(
                        wire["lsh"], fault_u01(self.plan.seed, "corrupt",
                                               period, client=i, attempt=1))
                    if announcement_checksum(wire) != wire["sum"]:
                        # board-side rejection: the damaged bytes never
                        # enter the ledger
                        failed[i] = True
                        self.trace.record(period, "corrupt", i)
                        continue
                    entry = wire  # (unreachable for a 1-nibble flip)
                if pf.delay[i]:
                    delayed[i] = True
                    self.trace.record(period, "delay", i)
                if pf.duplicate[i]:
                    # the second, byte-identical copy dedupes to nothing
                    self.trace.record(period, "duplicate", i)
            announcements[i] = entry
            reveals[i] = [int(x) for x in rankings[i]]
        return announcements, reveals, failed, delayed

    def _with_retry(self, period: int, kind: str, stream: int,
                    fn: Callable[[], Any], what: str) -> Any:
        failures = 0
        if self.plan is not None:
            failures = leading_failures(self.plan, kind, period,
                                        self.retry.max_attempts)
        for attempt in range(self.retry.max_attempts):
            if attempt < failures:
                self.trace.record(period, kind)
                self.sleep(self.retry.delay_s(attempt, fault_u01(
                    self.plan.seed, "backoff", period, client=stream,
                    attempt=attempt)))
                continue
            return fn()
        raise TransportError(
            f"{what} failed after {self.retry.max_attempts} attempts "
            f"(period {period}) — bulletin board unreachable")

    def publish(self, period: int, round_idx: int,
                announcements: Dict[int, Dict[str, str]],
                reveals: Dict[int, List[int]]) -> Block:
        """Publish one period's block, idempotently (a replayed period
        after crash-restart finds its block already on chain and reuses
        it) and under bounded retry."""
        existing = self.chain.round_block(round_idx)
        if existing is not None:
            return existing
        return self._with_retry(
            period, "publish_fail", 0,
            lambda: self.chain.publish_round(round_idx, announcements,
                                             reveals=reveals),
            what=f"publish of round {round_idx}")

    def fetch(self, period: int, round_idx: int) -> Block:
        """Read-back verification: re-fetch the just-published block
        (under retry) so a publish that claimed success but didn't land
        is caught the same period, not at resume."""
        blk = self._with_retry(
            period, "fetch_fail", 1,
            lambda: self.chain.round_block(round_idx),
            what=f"fetch of round {round_idx}")
        if blk is None:
            raise TransportError(
                f"round {round_idx} missing from the ledger on "
                f"read-back (period {period})")
        return blk


# ---------------------------------------------------------------------------
# forked ledger views + longest-valid-chain recovery
# ---------------------------------------------------------------------------
def rollback_view(chain: Blockchain, drop_last: int = 1) -> Blockchain:
    """A VALID but shorter view of `chain` — what a rolled-back or
    lagging replica of the bulletin board would serve. verify_chain
    passes (nothing is tampered); only length distinguishes it."""
    if not 0 <= drop_last < len(chain.blocks):
        raise ValueError(
            f"drop_last must be in [0, {len(chain.blocks)}), "
            f"got {drop_last}")
    view = Blockchain.__new__(Blockchain)
    view.blocks = list(chain.blocks[:len(chain.blocks) - drop_last])
    return view


def divergent_view(chain: Blockchain, drop_last: int = 1) -> Blockchain:
    """A VALID same-length fork: the last `drop_last` blocks re-made
    with marked payloads and correctly re-chained hashes. Recovery must
    NOT prefer it over the canonical chain.json (ties go to
    chain.json)."""
    view = rollback_view(chain, drop_last)
    for b in chain.blocks[len(chain.blocks) - drop_last:]:
        payload = dict(b.payload)
        payload["fork"] = True
        blk = Block(b.index, view.blocks[-1].hash, payload,
                    timestamp=b.timestamp)
        blk.hash = blk.compute_hash()
        view.blocks.append(blk)
    return view


def write_fork_view(ckpt_dir: str, view: Blockchain, idx: int = 0) -> str:
    """Persist a competing ledger view next to chain.json (the file
    layout `recover_chain` arbitrates over)."""
    return save_chain(
        os.path.join(ckpt_dir, f"chain.fork{idx}.json"), view)


def recover_chain(ckpt_dir: str, *,
                  min_round: Optional[int] = None) -> Blockchain:
    """Longest-valid-chain recovery over every ledger view in
    `ckpt_dir` (chain.json plus chain.fork*.json).

    Unparseable or tampered views are skipped with a warning; among the
    views that pass `verify_chain`, the strictly longest wins and
    chain.json wins ties. No valid view at all -> ValueError (same
    refusal as PR 8's single-file verify_chain gate). A valid winner
    whose head round is behind `min_round` (the checkpoint's round
    counter) -> LedgerRollbackError: the ledger silently lost
    history, which resume must surface, not paper over."""
    candidates = [os.path.join(ckpt_dir, CHAIN_FILE)]
    candidates += sorted(glob.glob(os.path.join(ckpt_dir, FORK_PATTERN)))
    best: Optional[Blockchain] = None
    best_path = ""
    for path in candidates:
        if not os.path.exists(path):
            continue
        try:
            view = load_chain(path)
        except Exception as e:
            warnings.warn(f"ledger view {os.path.basename(path)} "
                          f"unreadable ({e}); skipping")
            continue
        if not view.verify_chain():
            warnings.warn(f"ledger view {os.path.basename(path)} fails "
                          f"verify_chain; skipping")
            continue
        if best is None or len(view.blocks) > len(best.blocks):
            best, best_path = view, path
    if best is None:
        raise ValueError(
            f"no ledger view under {ckpt_dir!r} passes verify_chain "
            f"(checked {[os.path.basename(c) for c in candidates]})")
    if min_round is not None and best.head_round() < min_round:
        raise LedgerRollbackError(
            f"recovered ledger ({os.path.basename(best_path)}) verifies "
            f"but its head round {best.head_round()} is behind the "
            f"checkpoint's round counter {min_round} — silent rollback "
            f"or fork. Refusing to resume: restore the full ledger, or "
            f"resume from an older checkpoint whose round counter the "
            f"ledger covers.")
    return best
