"""Membership / churn layer: ragged cohorts over a static padded
client axis (DESIGN.md §13).

XLA wants static shapes; open federations don't. The resolution is the
same one the N=M-1 clamp and the ragged-shape property tests already
anticipate: the client axis is padded to a fixed M and membership is a
mask. A departed client keeps its slot (params, codes, rankings stay
in the arrays) but

  * is excluded from every peer's Eq. 6-8 top-N (its Eq. 8 weight is
    forced to -inf through the score column — `neighbor.select_partners
    (active=...)`),
  * stops reporting rankings (reporter_mask &= active, §3.6),
  * stops training (update_phase `participate` mask freezes params and
    optimizer state), and
  * stops announcing (codes / rankings / commitments frozen; its
    `code_age` grows one per period).

A joining client simply flips its mask bit back on: it re-enters with
whatever codes it last announced (possibly several periods stale) and
`code_age > 0`, which the service's Eq. 8 weighting discounts by
`exp(-staleness_lambda * age)` until its next announcement refreshes
the code (age resets to 0). Churn is therefore *masking*, never a
reshape — every compiled segment keeps one shape, and join/leave are
pure host-side state edits between periods.

`gossip_count` is the per-client heterogeneous gossip budget G_i: in a
reselection period of length L, client i trains in the global round
plus the first G_i - 1 gossip epochs and then idles (params frozen,
still answering peers' exchanges — it is online, just lazier).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, NamedTuple, Optional, Sequence

import jax.numpy as jnp

from repro.core.protocol import FedState

EVENT_KINDS = ("join", "leave")


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Service-layer knobs, on top of FedConfig (which keeps owning the
    protocol hyperparameters)."""
    reselect_every: int = 4        # period length L (rounds per segment)
    staleness_lambda: float = 0.5  # Eq. 8 discount exp(-lambda * age)
    checkpoint_every: int = 1      # periods between durable checkpoints
    keep_last_k: int = 3           # checkpoint retention

    def __post_init__(self):
        if self.reselect_every < 1:
            raise ValueError(
                f"reselect_every must be >= 1, got {self.reselect_every}")
        if self.staleness_lambda < 0:
            raise ValueError(
                f"staleness_lambda must be >= 0, got "
                f"{self.staleness_lambda}")
        if self.checkpoint_every < 1:
            raise ValueError(
                f"checkpoint_every must be >= 1, got "
                f"{self.checkpoint_every}")
        if self.keep_last_k < 1:
            raise ValueError(
                f"keep_last_k must be >= 1, got {self.keep_last_k}")


class ServiceState(NamedTuple):
    """FedState plus the membership layer — one pytree, so the whole
    thing checkpoints through `checkpoint.store` and threads through
    compiled segments unchanged."""
    fed: FedState
    active: jnp.ndarray        # (M,) bool — current members
    code_age: jnp.ndarray      # (M,) int32 — periods since last announce
    gossip_count: jnp.ndarray  # (M,) int32 — per-client G_i in [1, L]
    period_start: jnp.ndarray  # () int32 — round of this period's global


class ChurnEvent(NamedTuple):
    """A membership change applied at the START of `period`."""
    period: int
    kind: str                  # "join" | "leave"
    client: int


def init_service_state(fed_state: FedState, svc: ServiceConfig, *,
                       active=None, gossip_counts=None) -> ServiceState:
    """Wrap a freshly-initialized FedState for the service driver.

    active: optional (M,) bool initial membership (default: everyone).
    gossip_counts: optional per-client G_i sequence; clamped to
    [1, reselect_every] (default: the full period for everyone)."""
    m = fed_state.codes.shape[0]
    if active is None:
        active = jnp.ones((m,), bool)
    else:
        active = jnp.asarray(active, bool)
        if active.shape != (m,):
            raise ValueError(f"active mask shape {active.shape} != ({m},)")
    if gossip_counts is None:
        counts = jnp.full((m,), svc.reselect_every, jnp.int32)
    else:
        counts = jnp.clip(jnp.asarray(gossip_counts, jnp.int32),
                          1, svc.reselect_every)
        if counts.shape != (m,):
            raise ValueError(
                f"gossip_counts shape {counts.shape} != ({m},)")
    return ServiceState(fed_state, active, jnp.zeros((m,), jnp.int32),
                        counts, jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# churn events
# ---------------------------------------------------------------------------
def join(state: ServiceState, client: int) -> ServiceState:
    """Flip a slot's membership on. Idempotent. The client re-enters
    with its last-announced (stale) codes and its accumulated
    code_age — selection discounts it until it re-announces."""
    return state._replace(active=state.active.at[client].set(True))


def leave(state: ServiceState, client: int) -> ServiceState:
    """Flip a slot's membership off. Idempotent. Params stay in the
    padded slot (the client may rejoin; its personalized model also
    remains servable)."""
    return state._replace(active=state.active.at[client].set(False))


def validate_events(events: Iterable[ChurnEvent],
                    num_clients: int) -> List[ChurnEvent]:
    out = []
    for ev in events:
        ev = ChurnEvent(*ev)
        if ev.kind not in EVENT_KINDS:
            raise ValueError(f"unknown churn event kind: {ev.kind!r} "
                             f"(expected one of {EVENT_KINDS})")
        if not 0 <= ev.client < num_clients:
            raise ValueError(
                f"churn event client {ev.client} outside the padded "
                f"client axis [0, {num_clients})")
        if ev.period < 0:
            raise ValueError(f"churn event period must be >= 0, got "
                             f"{ev.period}")
        out.append(ev)
    return out


def apply_events(state: ServiceState, events: Iterable[ChurnEvent],
                 period: int) -> ServiceState:
    """Apply every event scheduled for `period` (in list order — the
    deterministic replay order that kill/resume relies on)."""
    for ev in events:
        if ev.period != period:
            continue
        state = join(state, ev.client) if ev.kind == "join" \
            else leave(state, ev.client)
    return state


def parse_events(spec: str) -> List[ChurnEvent]:
    """Parse the CLI churn spec: "1:leave:4,2:join:5" ->
    [ChurnEvent(1, "leave", 4), ChurnEvent(2, "join", 5)]."""
    events = []
    for item in filter(None, (s.strip() for s in spec.split(","))):
        parts = item.split(":")
        if len(parts) != 3:
            raise ValueError(
                f"bad churn event {item!r} (want period:kind:client)")
        # analysis: host-ok — int() on CLI strings, not device values
        events.append(ChurnEvent(int(parts[0]), parts[1], int(parts[2])))
    return events


# ---------------------------------------------------------------------------
# masks consumed by the service round program
# ---------------------------------------------------------------------------
def staleness_discount(code_age, staleness_lambda: float):
    """Eq. 8 score multiplier exp(-lambda * age): a client whose
    published code is `age` periods old carries proportionally less
    selection weight (its code was projected with an old per-round
    seed, so its Hamming distances to fresh codes carry little
    similarity signal — the ranking score is the evidence that
    remains, and it decays)."""
    return jnp.exp(-staleness_lambda * code_age.astype(jnp.float32))


def participation_mask(state: ServiceState, epoch) -> jnp.ndarray:
    """(M,) bool — who trains in gossip epoch `epoch` (0-based within
    the period): active members whose gossip budget G_i covers the
    global round (1) plus `epoch + 1` gossip epochs."""
    return state.active & (epoch < state.gossip_count - 1)


# ---------------------------------------------------------------------------
# degraded-round masking (DESIGN.md §15)
# ---------------------------------------------------------------------------
def mask_stragglers(state: ServiceState, stragglers) -> ServiceState:
    """Treat this period's stragglers as churn-inactive for the
    duration of ONE segment: the round proceeds on partial
    announcements through exactly the same -inf-score / update-freeze
    / announce-freeze masking that join/leave already uses. This is
    the masking-equivalence invariant — a round with k stragglers is
    bit-identical to a round where those k clients left and rejoined
    (property-tested in tests/test_faults.py). The driver restores the
    real membership mask after the segment."""
    return state._replace(
        active=state.active & ~jnp.asarray(stragglers, bool))


def merge_delivery(state: ServiceState, pre_codes, pre_rankings,
                   pre_commitments, pre_age, *, failed,
                   delayed) -> ServiceState:
    """Reconcile the in-graph announcement merge with what the bulletin
    board ACTUALLY accepted (transport.collect verdicts).

    `failed` clients (dropped or checksum-rejected): the board kept
    their last block, so their device-side codes / rankings /
    commitments revert to the pre-segment snapshot and their code_age
    grows one period — indistinguishable from not announcing at all.
    `delayed` clients: the fresh announcement stands, but it landed
    past the selection deadline, so next period's Eq. 8 weight sees
    `code_age >= 1`. With all-False masks every jnp.where is a bitwise
    no-op, which is what keeps the fault-free path bit-identical to
    PR 8's driver."""
    failed = jnp.asarray(failed, bool)
    delayed = jnp.asarray(delayed, bool)
    fed = state.fed
    codes = jnp.where(failed[:, None], pre_codes, fed.codes)
    rankings = jnp.where(failed[:, None], pre_rankings, fed.rankings)
    commitments = jnp.where(failed, pre_commitments, fed.commitments)
    age = jnp.where(failed, pre_age + 1, state.code_age)
    age = jnp.where(delayed & ~failed, jnp.maximum(age, 1), age)
    return state._replace(
        fed=fed._replace(codes=codes, rankings=rankings,
                         commitments=commitments),
        code_age=age)
