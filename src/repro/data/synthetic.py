"""Synthetic LM token pipeline for the transformer zoo (training driver,
examples, and smoke tests). Deterministic, restartable, shardable.

The stream is a Zipf-distributed token source with short-range Markov
structure (so a model can actually reduce loss) plus the modality stubs
for audio/VLM archs (frame/patch embeddings per the task carve-out).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


class TokenStream:
    """Deterministic batched token stream. State = (seed, step)."""

    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int,
                 seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.step = 0
        v = cfg.vocab_size
        rs = np.random.RandomState(seed)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._zipf = (1.0 / ranks) / np.sum(1.0 / ranks)
        # sparse bigram preference: each token has a favorite successor
        self._succ = rs.randint(0, v, size=v)

    def _draw(self, rs, n):
        v = self.cfg.vocab_size
        base = rs.choice(v, size=n, p=self._zipf)
        out = np.empty(n, np.int64)
        out[0] = base[0]
        follow = rs.rand(n) < 0.35
        for i in range(1, n):
            out[i] = self._succ[out[i - 1]] if follow[i] else base[i]
        return out

    def next_batch(self) -> Dict[str, np.ndarray]:
        rs = np.random.RandomState((self.seed * 9176 + self.step) % 2**31)
        self.step += 1
        toks = self._draw(rs, self.batch * (self.seq_len + 1)).reshape(
            self.batch, self.seq_len + 1)
        batch = {"tokens": toks[:, :-1].astype(np.int32),
                 "labels": toks[:, 1:].astype(np.int32)}
        extra = modality_stub(self.cfg, self.batch, rs)
        batch.update(extra)
        return batch


def modality_stub(cfg: ModelConfig, batch: int,
                  rs: Optional[np.random.RandomState] = None):
    """Frame/patch embeddings for the stubbed audio/vision frontends."""
    rs = rs or np.random.RandomState(0)
    out: Dict[str, np.ndarray] = {}
    if cfg.is_encdec:
        out["audio"] = rs.randn(batch, cfg.encoder_seq_len,
                                cfg.d_model).astype(np.float32) * 0.1
    if cfg.vision_tokens:
        out["vision"] = rs.randn(batch, cfg.vision_tokens,
                                 cfg.vision_dim or cfg.d_model
                                 ).astype(np.float32) * 0.1
    return out
