from repro.data.federated import (  # noqa: F401
    DATASETS,
    ClientData,
    FederatedDataset,
    make_aecg_federated,
    make_mnist_federated,
    make_seeg_federated,
)
from repro.data.synthetic import TokenStream, modality_stub  # noqa: F401
