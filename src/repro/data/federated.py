"""Federated data pipeline with the paper's exact partition statistics
(WPFed §4.3), over synthetic stand-in datasets (the repro=2 data gate:
MNIST / PhysioNet A-ECG / Sleep-EEG are not available offline — see
DESIGN.md §2).

Synthetic generators produce class-conditional data with learnable
structure so collaborative effects are measurable:
  - "mnist":   28x28x1 images, 10 classes = blurred class-template +
               per-client style shift + noise.
  - "aecg":    60-dim RR-interval sequences, 2 classes (apnea events as
               oscillation bursts), per-patient baseline drift.
  - "seeg":    100-dim EEG windows, 3 sleep stages as band-limited
               oscillations with per-subject amplitude signatures.

Partitions:
  - mnist: 20 shards -> 2 per client x 10 clients, one digit class
           removed per shard (non-IID label skew).
  - aecg / seeg: one client per subject (35 / 40), sliding-window
           augmentation, per-subject distribution shift.
  - reference repository: mnist -> held-out test pool; aecg/seeg -> 20%
           of data pooled across subjects; each client samples a
           disjoint subset as its personal reference set.
  - local train/test split 7:3.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np


@dataclass
class ClientData:
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray
    x_ref: np.ndarray
    y_ref: np.ndarray


@dataclass
class FederatedDataset:
    name: str
    clients: list          # list[ClientData]
    num_classes: int
    input_shape: Tuple[int, ...]
    shared_ref_x: np.ndarray = None   # common public set (FedMD baseline)
    shared_ref_y: np.ndarray = None

    @property
    def num_clients(self) -> int:
        return len(self.clients)

    def stacked(self) -> Dict[str, np.ndarray]:
        """Stack per-client arrays (all clients have equal sizes) for
        vmap-based protocol simulation: dict of (M, n, ...) arrays."""
        f = lambda attr: np.stack([getattr(c, attr) for c in self.clients])
        return {k: f(k) for k in
                ("x_train", "y_train", "x_test", "y_test", "x_ref", "y_ref")}


# ---------------------------------------------------------------------------
# synthetic generators
# ---------------------------------------------------------------------------
def _mnist_like(rng, n, num_classes=10, side=28):
    """Class templates: smoothed random blobs; samples add noise+shift."""
    yy, xx = np.mgrid[0:side, 0:side] / side
    templates = []
    for c in range(num_classes):
        r = np.random.RandomState(1000 + c)
        t = np.zeros((side, side))
        for _ in range(6):
            cx, cy, s = r.rand(), r.rand(), 0.05 + 0.1 * r.rand()
            t += np.exp(-((xx - cx) ** 2 + (yy - cy) ** 2) / (2 * s ** 2))
        templates.append(t / t.max())
    templates = np.stack(templates)
    y = rng.randint(0, num_classes, n)
    x = templates[y] + 0.35 * rng.randn(n, side, side)
    return x[..., None].astype(np.float32), y.astype(np.int32)


def _timeseries_like(rng, n, length, num_classes, subject_sig=0.0):
    """Band-limited oscillations; class = dominant frequency band."""
    t = np.arange(length) / length
    y = rng.randint(0, num_classes, n)
    freqs = 3.0 + 4.0 * y[:, None]                       # class frequency
    phase = 2 * np.pi * rng.rand(n, 1)
    x = np.sin(2 * np.pi * freqs * t[None, :] + phase)
    x += 0.3 * np.sin(2 * np.pi * 1.5 * t[None, :])      # common rhythm
    x = (1.0 + subject_sig) * x + 0.4 * rng.randn(n, length)
    return x[..., None].astype(np.float32), y.astype(np.int32)


def _sliding_window(x, y, window_frac=0.8, n_windows=3, rng=None):
    """Paper §4.3: sliding-window augmentation for A-ECG / S-EEG."""
    length = x.shape[1]
    w = int(length * window_frac)
    outs_x, outs_y = [], []
    for s in np.linspace(0, length - w, n_windows).astype(int):
        seg = x[:, s:s + w]
        pad = np.zeros((x.shape[0], length - w, x.shape[2]), x.dtype)
        outs_x.append(np.concatenate([seg, pad], axis=1))
        outs_y.append(y)
    return np.concatenate(outs_x), np.concatenate(outs_y)


# ---------------------------------------------------------------------------
# partitioners
# ---------------------------------------------------------------------------
def _split_7_3(rng, x, y):
    idx = rng.permutation(len(x))
    cut = int(0.7 * len(x))
    tr, te = idx[:cut], idx[cut:]
    return x[tr], y[tr], x[te], y[te]


def make_mnist_federated(num_clients=10, per_client=400, ref_per_client=64,
                         seed=0, noise=0.55,
                         num_clusters=2) -> FederatedDataset:
    """10 clients x 2 shards; each shard has one digit class removed
    (paper §4.3 label skew), PLUS a personalization structure the paper's
    per-subject datasets have implicitly: clients belong to clusters with
    conflicting label semantics (cluster c relabels y -> (y + 5c) mod 10).
    Distilling from the wrong cluster is then actively harmful, so
    neighbor *selection* — the paper's contribution — carries signal.
    Reference labels follow each client's own mapping (the reference set
    is personal; only features are ever shared, §3.1)."""
    rng = np.random.RandomState(seed)
    pool_x, pool_y = _mnist_like(rng, num_clients * per_client * 3)
    pool_x += (noise - 0.35) * rng.randn(*pool_x.shape).astype(np.float32)
    ref_x, ref_y = _mnist_like(rng, 10_000)               # test set = repo
    shard_size = per_client
    clients = []
    ref_perm = rng.permutation(len(ref_x))

    def remap(y, cluster):
        return ((y + 5 * cluster) % 10).astype(np.int32)

    for i in range(num_clients):
        cluster = i % num_clusters
        # label skew: the client only ever SEES a subset of classes
        # (paper: one digit removed per shard; scarce-data regime makes
        # the skew stronger so neighbor knowledge is complementary)
        present = rng.choice(10, size=5, replace=False)
        xs, ys = [], []
        for shard in range(2):
            removed = int(rng.choice(present))            # per-shard removal
            keep_classes = np.setdiff1d(present, [removed])
            cand = np.where(np.isin(pool_y, keep_classes))[0]
            take = rng.choice(cand, shard_size // 2, replace=False)
            xs.append(pool_x[take])
            ys.append(remap(pool_y[take], cluster))
        x = np.concatenate(xs)
        y = np.concatenate(ys)
        xtr, ytr, xte, yte = _split_7_3(rng, x, y)
        rsl = ref_perm[i * ref_per_client:(i + 1) * ref_per_client]
        clients.append(ClientData(xtr, ytr, xte, yte, ref_x[rsl],
                                  remap(ref_y[rsl], cluster)))
    shared = ref_perm[num_clients * ref_per_client:
                      (num_clients + 1) * ref_per_client]
    return FederatedDataset("mnist", clients, 10, (28, 28, 1),
                            ref_x[shared], ref_y[shared])


def _make_subject_federated(name, num_clients, length, num_classes,
                            per_subject=120, ref_per_client=48, seed=0,
                            num_clusters=2):
    rng = np.random.RandomState(seed)
    subj_x, subj_y = [], []
    for s in range(num_clients):
        sig = 0.3 * rng.randn()                           # subject signature
        x, y = _timeseries_like(rng, per_subject, length, num_classes,
                                subject_sig=sig)
        x, y = _sliding_window(x, y, rng=rng)
        subj_x.append(x)
        subj_y.append(y)
    # 20% of each subject's data -> shared reference repository (labels
    # kept RAW; each client relabels its personal ref subset below)
    repo_x, repo_y, loc = [], [], []
    for x, y in zip(subj_x, subj_y):
        cut = int(0.2 * len(x))
        idx = rng.permutation(len(x))
        repo_x.append(x[idx[:cut]])
        repo_y.append(y[idx[:cut]])
        loc.append((x[idx[cut:]], y[idx[cut:]]))
    repo_x = np.concatenate(repo_x)
    repo_y = np.concatenate(repo_y)
    # keep per-client reference subsets disjoint even for small repos
    # (num_clients personal sets + 1 shared set must fit)
    ref_per_client = min(ref_per_client, len(repo_x) // (num_clients + 1))
    perm = rng.permutation(len(repo_x))
    clients = []
    for i, (x, y) in enumerate(loc):
        # cohort structure: clusters with cyclically-shifted label
        # semantics (see make_mnist_federated) — personalized selection
        # must find same-cohort subjects.
        shift = i % num_clusters
        y = ((y + shift) % num_classes).astype(np.int32)
        xtr, ytr, xte, yte = _split_7_3(rng, x, y)
        rsl = perm[i * ref_per_client:(i + 1) * ref_per_client]
        ref_y = ((repo_y[rsl] + shift) % num_classes).astype(np.int32)
        clients.append(ClientData(xtr, ytr, xte, yte, repo_x[rsl], ref_y))
    shared = perm[num_clients * ref_per_client:
                  (num_clients + 1) * ref_per_client]
    return FederatedDataset(name, clients, num_classes, (length, 1),
                            repo_x[shared], repo_y[shared])


def make_aecg_federated(num_clients=35, seed=0,
                        per_subject=120) -> FederatedDataset:
    return _make_subject_federated("aecg", num_clients, 60, 2, seed=seed,
                                   per_subject=per_subject)


def make_seeg_federated(num_clients=40, seed=0,
                        per_subject=120) -> FederatedDataset:
    return _make_subject_federated("seeg", num_clients, 100, 3, seed=seed,
                                   per_subject=per_subject)


DATASETS = {"mnist": make_mnist_federated,
            "aecg": make_aecg_federated,
            "seeg": make_seeg_federated}
