"""Grok-1 314B — 8-expert top-2 MoE. [hf:xai-org/grok-1]"""
from repro.configs.base import ModelConfig, register


@register("grok-1-314b")
def grok_1_314b() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b",
        family="moe",
        num_layers=64,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=32768,
        vocab_size=131072,
        num_experts=8,
        experts_per_token=2,
        activation="geglu",       # gated GeLU: matches the published 314B total
        norm="rmsnorm",
        rope=True,
        citation="hf:xai-org/grok-1",
    )
