"""Kimi K2 — trillion-parameter MoE (paper-table figures). [arXiv:2501.kimi2]"""
from repro.configs.base import ModelConfig, register


@register("kimi-k2-1t-a32b")
def kimi_k2_1t_a32b() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        num_layers=61,
        d_model=7168,
        num_heads=64,
        num_kv_heads=8,
        d_ff=2048,                 # per-expert FFN width
        vocab_size=163840,
        num_experts=384,
        experts_per_token=8,
        activation="swiglu",
        norm="rmsnorm",
        rope=True,
        citation="arXiv:2501.kimi2",
    )
