"""Configuration system for the repro framework.

Every architecture (the paper's own client models plus the ten assigned
public-literature architectures) is described by a frozen ``ModelConfig``.
Input shapes (train / prefill / decode / long-decode) are ``ShapeConfig``s.
A registry maps ``--arch <id>`` strings to configs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

# Block-type codes used in ``block_pattern`` (repeated cyclically over depth):
#   "A" global causal self-attention
#   "L" local (sliding-window) causal self-attention
#   "X" cross-attention (VLM image layers / enc-dec handled separately)
#   "R" RG-LRU recurrent block (RecurrentGemma)
#   "S" sLSTM block (xLSTM)
#   "M" mLSTM block (xLSTM)
VALID_BLOCKS = frozenset("ALXRSM")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    # --- MoE ---
    num_experts: int = 0             # 0 -> dense MLP
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # --- activations / norms / biases ---
    activation: str = "swiglu"       # swiglu | geglu | gelu | relu2
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    qkv_bias: bool = False
    mlp_bias: bool = False
    # --- positions ---
    rope: bool = True
    rope_theta: float = 10000.0
    learned_pos_embed: int = 0       # >0: learned positional table of this size
    # --- depth pattern (cycled; remainder layers form an unrolled tail) ---
    block_pattern: Tuple[str, ...] = ("A",)
    window: int = 0                  # sliding window for "L" blocks
    serve_window: int = 0            # >0: sliding-window serving variant exists
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq_len: int = 0         # e.g. 1500 mel frames
    # --- vlm ---
    vision_tokens: int = 0           # patch-embedding count from the stub tower
    vision_dim: int = 0              # raw patch-embedding dim (projector input)
    # --- recurrent dims ---
    lru_width: int = 0               # RG-LRU width (0 -> d_model)
    conv1d_width: int = 4
    # --- misc ---
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    citation: str = ""

    def __post_init__(self):
        assert self.family in ("dense", "moe", "ssm", "hybrid", "audio", "vlm")
        assert all(b in VALID_BLOCKS for b in self.block_pattern), self.block_pattern
        assert self.num_heads % max(self.num_kv_heads, 1) == 0

    # ---- derived quantities ----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def pattern_reps(self) -> int:
        return self.num_layers // len(self.block_pattern)

    @property
    def pattern_tail(self) -> int:
        return self.num_layers % len(self.block_pattern)

    def layer_type(self, i: int) -> str:
        return self.block_pattern[i % len(self.block_pattern)]

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, dh = self.d_model, self.resolved_head_dim
        h, kv = self.num_heads, self.num_kv_heads
        n = self.vocab_size * d                          # embedding
        if not self.tie_embeddings:
            n += d * self.vocab_size                     # lm head
        if self.learned_pos_embed:
            n += self.learned_pos_embed * d
        for i in range(self.num_layers):
            t = self.layer_type(i)
            n += d  # pre-norm scale
            if t in ("A", "L", "X"):
                n += d * h * dh + 2 * d * kv * dh + h * dh * d
                if self.qkv_bias:
                    n += (h + 2 * kv) * dh
            elif t == "R":
                w = self.lru_width or d
                n += d * w * 2 + self.conv1d_width * w + 3 * w + w * d
            elif t == "S":
                n += 4 * d * d + 4 * d * d // max(self.num_heads, 1) + 8 * d
            elif t == "M":
                n += 2 * d * 2 * d + (2 * d) * dh * 3 + 2 * d * 2 + 2 * d * d
            if t in ("A", "L", "X") or (t in "RSM" and self.d_ff > 0):
                f = self.d_ff
                if f > 0:
                    n += d  # post-norm
                    if self.is_moe:
                        gates = 2 if self.activation in ("swiglu", "geglu") else 1
                        n += d * self.num_experts  # router
                        n += self.num_experts * (gates * d * f + f * d)
                    else:
                        gates = 2 if self.activation in ("swiglu", "geglu") else 1
                        n += gates * d * f + f * d
        if self.is_encdec:
            # encoder self-attn + mlp, decoder gets an extra cross-attn per layer
            f = self.d_ff
            per_enc = 2 * d + d * h * dh + 2 * d * kv * dh + h * dh * d + 2 * d * f + f * d
            n += self.encoder_layers * per_enc
            n += self.num_layers * (d + d * h * dh + 2 * d * kv * dh + h * dh * d)
        if self.vision_tokens:
            n += (self.vision_dim or d) * d              # projector
        n += d                                           # final norm
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.is_moe:
            return self.param_count()
        gates = 2 if self.activation in ("swiglu", "geglu") else 1
        per_expert = gates * self.d_model * self.d_ff + self.d_ff * self.d_model
        inactive = (self.num_experts - self.experts_per_token) * per_expert
        return self.param_count() - self.num_layers * inactive

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2-ish layers, d_model<=512, <=4 experts.

        The block pattern is compressed to one occurrence of each distinct
        block type so every code path of the family is still exercised.
        """
        seen, pat = set(), []
        for b in self.block_pattern:
            if b not in seen:
                seen.add(b)
                pat.append(b)
        pat = tuple(pat[:2]) if len(pat) > 2 else tuple(pat)
        n_layers = max(2, len(pat))
        d = 256
        heads = 4
        kvh = max(1, heads * self.num_kv_heads // self.num_heads)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=n_layers,
            d_model=d,
            num_heads=heads,
            num_kv_heads=kvh,
            head_dim=64,
            d_ff=0 if self.d_ff == 0 else 512,
            vocab_size=1024,
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            block_pattern=pat,
            window=min(self.window, 64) if self.window else 0,
            serve_window=min(self.serve_window, 64) if self.serve_window else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq_len=16 if self.encoder_seq_len else 0,
            vision_tokens=8 if self.vision_tokens else 0,
            vision_dim=64 if self.vision_dim else 0,
            lru_width=256 if self.lru_width else 0,
            learned_pos_embed=128 if self.learned_pos_embed else 0,
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.mode == "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[arch_id] = fn
        return fn
    return deco


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _REGISTRY:
        # import side-effect registration
        from repro.configs import ALL_ARCHS  # noqa: F401
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]()


def list_archs():
    from repro.configs import ALL_ARCHS  # noqa: F401
    return sorted(_REGISTRY)


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) is exercised, and why not if skipped.

    long_500k needs sub-quadratic serving: native for SSM/hybrid archs,
    via the sliding-window variant for dense archs that define one, and
    skipped for full-attention MoE / enc-dec / VLM archs (see DESIGN.md).
    Encoder-decoder 'decode' uses the decoder with a fixed encoder context,
    which is supported; but 500k-token audio decode is out of scope.
    """
    if shape.name == "long_500k":
        if cfg.family in ("ssm", "hybrid"):
            return True, ""
        if cfg.family == "dense" and cfg.serve_window > 0:
            return True, "sliding-window serving variant"
        return False, (f"{cfg.name} is full-attention ({cfg.family}); no "
                       "sub-quadratic serving path — skipped per DESIGN.md")
    return True, ""
