"""Qwen1.5-32B — dense MHA with QKV bias. [hf:Qwen/Qwen1.5-0.5B]"""
from repro.configs.base import ModelConfig, register


@register("qwen1.5-32b")
def qwen1_5_32b() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b",
        family="dense",
        num_layers=64,
        d_model=5120,
        num_heads=40,
        num_kv_heads=40,           # full MHA
        d_ff=27392,
        vocab_size=152064,
        activation="swiglu",
        norm="rmsnorm",
        qkv_bias=True,
        rope=True,
        serve_window=4096,
        citation="hf:Qwen/Qwen1.5-0.5B",
    )
