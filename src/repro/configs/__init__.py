"""Config registry. Importing this package registers all architectures."""
from repro.configs.base import (  # noqa: F401
    ModelConfig,
    ShapeConfig,
    SHAPES,
    get_config,
    list_archs,
    register,
    supports_shape,
)

# side-effect registration of the assigned architectures
from repro.configs import (  # noqa: F401
    grok_1_314b,
    kimi_k2_1t_a32b,
    llama_3_2_vision_90b,
    minitron_4b,
    nemotron_4_340b,
    phi3_medium_14b,
    qwen1_5_32b,
    recurrentgemma_2b,
    whisper_small,
    xlstm_350m,
)
from repro.configs.paper_models import (  # noqa: F401
    ClientModelConfig,
    FedConfig,
    PAPER_FED_OPTIMA,
    aecg_tcn,
    mnist_cnn,
    seeg_tcn,
)

ALL_ARCHS = [
    "kimi-k2-1t-a32b",
    "whisper-small",
    "nemotron-4-340b",
    "llama-3.2-vision-90b",
    "qwen1.5-32b",
    "recurrentgemma-2b",
    "minitron-4b",
    "grok-1-314b",
    "xlstm-350m",
    "phi3-medium-14b",
]
