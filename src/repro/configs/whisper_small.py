"""Whisper-small — encoder-decoder audio backbone. [arXiv:2212.04356]

The mel-spectrogram + conv feature extractor frontend is a STUB per the
task carve-out: ``input_specs`` provides precomputed frame embeddings of
shape (batch, encoder_seq_len, d_model).
"""
from repro.configs.base import ModelConfig, register


@register("whisper-small")
def whisper_small() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        family="audio",
        num_layers=12,             # decoder layers
        encoder_layers=12,
        encoder_seq_len=1500,      # 30 s of audio at 50 Hz after conv frontend
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        d_ff=3072,
        vocab_size=51865,
        activation="gelu",
        norm="layernorm",
        rope=False,
        learned_pos_embed=1500,
        qkv_bias=True,
        mlp_bias=True,
        citation="arXiv:2212.04356",
    )
