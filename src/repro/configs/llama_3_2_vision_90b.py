"""Llama-3.2-Vision-90B — decoder with interleaved cross-attention image
layers. [hf:meta-llama/Llama-3.2-11B-Vision]

100 layers total = 80 self-attention + 20 cross-attention (every 5th layer
attends to vision-patch embeddings). The ViT/SigLIP vision tower +
projector frontend is a STUB: ``input_specs`` provides precomputed patch
embeddings (batch, vision_tokens, vision_dim); only the projector that
maps them into d_model is part of this model.
"""
from repro.configs.base import ModelConfig, register


@register("llama-3.2-vision-90b")
def llama_3_2_vision_90b() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        num_layers=100,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=28672,
        vocab_size=128256,
        activation="swiglu",
        norm="rmsnorm",
        rope=True,
        rope_theta=500000.0,
        block_pattern=("A", "A", "A", "A", "X"),
        vision_tokens=1601,        # 1 tile x (40x40 patches + cls)
        vision_dim=1280,
        citation="hf:meta-llama/Llama-3.2-11B-Vision",
    )
