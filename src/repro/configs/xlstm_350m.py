"""xLSTM-350M — alternating sLSTM + mLSTM blocks, no FFN (the blocks carry
their own up-projections). [arXiv:2405.04517]"""
from repro.configs.base import ModelConfig, register


@register("xlstm-350m")
def xlstm_350m() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m",
        family="ssm",
        num_layers=24,
        d_model=1024,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,                    # xLSTM blocks have internal projections
        vocab_size=50304,
        activation="gelu",
        norm="layernorm",
        rope=False,
        block_pattern=("S", "M"),
        citation="arXiv:2405.04517",
    )
