"""The paper's own client-model families (WPFed §4.3).

The paper uses MobileNetV2 on MNIST and a Temporal Convolutional Network
(TCN) on A-ECG / S-EEG. These are small per-client models trained on CPU
in the faithful reproduction; they live outside the transformer zoo and
are described by ``ClientModelConfig`` (consumed by repro.models.cnn /
repro.models.tcn).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class ClientModelConfig:
    name: str
    kind: str                      # "cnn" | "tcn" | "mlp"
    input_shape: Tuple[int, ...]   # per-example feature shape
    num_classes: int
    hidden: Tuple[int, ...] = (64, 64)
    kernel_size: int = 3
    citation: str = ""


def mnist_cnn() -> ClientModelConfig:
    """Depthwise-separable CNN in the MobileNetV2 spirit (inverted residual
    bottlenecks are reduced to two separable conv stages — appropriate at
    28x28x1 scale; the paper's full MobileNetV2 targets 224x224x3)."""
    return ClientModelConfig(
        name="mnist-cnn",
        kind="cnn",
        input_shape=(28, 28, 1),
        num_classes=10,
        hidden=(32, 64),
        kernel_size=3,
        citation="Sandler et al. 2018 (MobileNetV2), adapted",
    )


def aecg_tcn() -> ClientModelConfig:
    """TCN over 60-dim RR-interval vectors; binary apnea classification."""
    return ClientModelConfig(
        name="aecg-tcn",
        kind="tcn",
        input_shape=(60, 1),
        num_classes=2,
        hidden=(32, 32, 32),
        kernel_size=5,
        citation="Ismail et al. 2023 (TCN), Cai & Hu 2020 preprocessing",
    )


def seeg_tcn() -> ClientModelConfig:
    """TCN for 3-class sleep staging (awake / NREM / REM)."""
    return ClientModelConfig(
        name="seeg-tcn",
        kind="tcn",
        input_shape=(100, 1),
        num_classes=3,
        hidden=(32, 32, 32),
        kernel_size=5,
        citation="Rechtschaffen 1968 staging; Mourtazaev et al. 1995",
    )


@dataclass(frozen=True)
class FedConfig:
    """WPFed protocol hyperparameters (paper Table 1 optima)."""
    num_clients: int = 10
    num_neighbors: int = 12        # N
    alpha: float = 0.6             # local/collaborative trade-off
    gamma: float = 1.0             # LSH-similarity weighting
    top_k: int = 5                 # K in the ranking score (Eq. 7)
    lsh_bits: int = 256            # b
    rounds: int = 100
    local_steps: int = 5
    local_batch: int = 64
    lr: float = 1e-3
    ref_batch: int = 64            # reference-set size exchanged per round
    seed: int = 0
    # kernel-backed subsystem backends, one per subsystem, all resolved
    # by repro.core.backends.resolve: "kernel" runs the Pallas kernels
    # (interpret-mode off-TPU), "oracle" the bit-exact jnp twins,
    # "auto" kernel on TPU / oracle elsewhere. Selection additionally
    # accepts "ann" — the sub-quadratic LSH-bucket candidate index
    # (DESIGN.md §11); "auto" opts into it past the FLOP thresholds in
    # backends.resolve_selection.
    selection_backend: str = "auto"   # Eq. 5-8 selection (DESIGN.md §4, §11)
    exchange_backend: str = "auto"    # Eq. 3 + §3.5 exchange (DESIGN.md §7)
    # ANN selection knobs (DESIGN.md §11): clients sharing a seeded
    # `ann_prefix_bits`-bit code prefix bucket together
    # (2^prefix_bits buckets); each client additionally probes the
    # buckets reached by flipping up to `ann_probes` single prefix
    # bits — the standard multi-probe recall knob. prefix_bits=0
    # collapses to ONE bucket and is pinned bit-exact vs the exact
    # kernels. Effective values are clamped (core.ann) to the code
    # length and to MAX_PREFIX_BITS.
    ann_prefix_bits: int = 10
    ann_probes: int = 8
    # kernel tiling regime, resolved by repro.core.backends
    # .resolve_tiling (DESIGN.md §10): "oneshot" holds the full working
    # set in VMEM per program (bit-exact defaults), "tiled" streams
    # VMEM-bounded tiles (selection: column-tiled two-pass top-N,
    # bit-exact; exchange: R/C-tiled online softmax, tolerance-bounded),
    # "auto" picks from an explicit per-program VMEM estimate.
    selection_tiling: str = "auto"
    exchange_tiling: str = "auto"
    # reference-set regime (DESIGN.md §7): "personal" exchanges logits
    # on each client's own X_i^ref (M*N neighbor forwards via gathered
    # params — the paper's point-to-point protocol); "public" evaluates
    # ONE shared reference set (the abstract's public reference dataset)
    # so the exchange needs only M forwards and a logit gather.
    ref_mode: str = "personal"
    # Eq. 7 ranking-score dedupe (DESIGN.md §7 caveat): collapse
    # duplicate revealed ranking vectors to one vote before scoring.
    # Off by default (the paper's literal Eq. 7); the launchers set it
    # from `recommended_dedupe(ref_mode)` — on under "public", where
    # every selector sees the same l_ij for a neighbor and Eq. 7
    # otherwise aggregates duplicated evidence.
    dedupe_rankings: bool = False
    # verification toggles (ablations / attack studies)
    use_lsh: bool = True           # w/o LSH ablation
    use_rank: bool = True          # w/o Rank ablation
    lsh_verification: bool = True  # §3.5 output-KL lower-half filter
    rank_verification: bool = True # §3.6 commit-and-reveal


def recommended_dedupe(ref_mode: str) -> bool:
    """The Eq. 7 dedupe setting launchers apply per reference regime
    (DESIGN.md §10, one place): under "public" every selector sees the
    same l_ij for a neighbor, so duplicate revealed rankings carry no
    independent evidence and dedupe is on; "personal" keeps the
    paper's literal Eq. 7 (and the legacy bit-exactness pins)."""
    return ref_mode == "public"


PAPER_FED_OPTIMA = {
    # dataset -> (N, alpha, gamma)  — paper Table 1
    "mnist": (12, 0.6, 1.0),
    "aecg": (10, 0.6, 1.0),
    "seeg": (8, 0.6, 1.0),
}
