"""The paper's own client-model families (WPFed §4.3).

The paper uses MobileNetV2 on MNIST and a Temporal Convolutional Network
(TCN) on A-ECG / S-EEG. These are small per-client models trained on CPU
in the faithful reproduction; they live outside the transformer zoo and
are described by ``ClientModelConfig`` (consumed by repro.models.cnn /
repro.models.tcn).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class ClientModelConfig:
    name: str
    kind: str                      # "cnn" | "tcn" | "mlp"
    input_shape: Tuple[int, ...]   # per-example feature shape
    num_classes: int
    hidden: Tuple[int, ...] = (64, 64)
    kernel_size: int = 3
    citation: str = ""


def mnist_cnn() -> ClientModelConfig:
    """Depthwise-separable CNN in the MobileNetV2 spirit (inverted residual
    bottlenecks are reduced to two separable conv stages — appropriate at
    28x28x1 scale; the paper's full MobileNetV2 targets 224x224x3)."""
    return ClientModelConfig(
        name="mnist-cnn",
        kind="cnn",
        input_shape=(28, 28, 1),
        num_classes=10,
        hidden=(32, 64),
        kernel_size=3,
        citation="Sandler et al. 2018 (MobileNetV2), adapted",
    )


def aecg_tcn() -> ClientModelConfig:
    """TCN over 60-dim RR-interval vectors; binary apnea classification."""
    return ClientModelConfig(
        name="aecg-tcn",
        kind="tcn",
        input_shape=(60, 1),
        num_classes=2,
        hidden=(32, 32, 32),
        kernel_size=5,
        citation="Ismail et al. 2023 (TCN), Cai & Hu 2020 preprocessing",
    )


def seeg_tcn() -> ClientModelConfig:
    """TCN for 3-class sleep staging (awake / NREM / REM)."""
    return ClientModelConfig(
        name="seeg-tcn",
        kind="tcn",
        input_shape=(100, 1),
        num_classes=3,
        hidden=(32, 32, 32),
        kernel_size=5,
        citation="Rechtschaffen 1968 staging; Mourtazaev et al. 1995",
    )


@dataclass(frozen=True)
class FedConfig:
    """WPFed protocol hyperparameters (paper Table 1 optima)."""
    num_clients: int = 10
    num_neighbors: int = 12        # N
    alpha: float = 0.6             # local/collaborative trade-off
    gamma: float = 1.0             # LSH-similarity weighting
    top_k: int = 5                 # K in the ranking score (Eq. 7)
    lsh_bits: int = 256            # b
    rounds: int = 100
    local_steps: int = 5
    local_batch: int = 64
    lr: float = 1e-3
    ref_batch: int = 64            # reference-set size exchanged per round
    seed: int = 0
    # kernel-backed subsystem backends, one per subsystem, all resolved
    # by repro.core.backends.resolve: "kernel" runs the Pallas kernels
    # (interpret-mode off-TPU), "oracle" the bit-exact jnp twins,
    # "auto" kernel on TPU / oracle elsewhere.
    selection_backend: str = "auto"   # Eq. 5-8 selection (DESIGN.md §4)
    exchange_backend: str = "auto"    # Eq. 3 + §3.5 exchange (DESIGN.md §7)
    # reference-set regime (DESIGN.md §7): "personal" exchanges logits
    # on each client's own X_i^ref (M*N neighbor forwards via gathered
    # params — the paper's point-to-point protocol); "public" evaluates
    # ONE shared reference set (the abstract's public reference dataset)
    # so the exchange needs only M forwards and a logit gather.
    ref_mode: str = "personal"
    # verification toggles (ablations / attack studies)
    use_lsh: bool = True           # w/o LSH ablation
    use_rank: bool = True          # w/o Rank ablation
    lsh_verification: bool = True  # §3.5 output-KL lower-half filter
    rank_verification: bool = True # §3.6 commit-and-reveal


PAPER_FED_OPTIMA = {
    # dataset -> (N, alpha, gamma)  — paper Table 1
    "mnist": (12, 0.6, 1.0),
    "aecg": (10, 0.6, 1.0),
    "seeg": (8, 0.6, 1.0),
}
