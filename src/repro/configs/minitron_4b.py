"""Minitron-4B — pruned Nemotron (dense GQA, squared-ReLU). [arXiv:2407.14679]"""
from repro.configs.base import ModelConfig, register


@register("minitron-4b")
def minitron_4b() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b",
        family="dense",
        num_layers=32,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        d_ff=9216,
        vocab_size=256000,
        activation="relu2",
        norm="layernorm",
        rope=True,
        serve_window=4096,
        citation="arXiv:2407.14679",
    )
