"""Nemotron-4-340B — dense GQA with squared-ReLU MLP. [arXiv:2402.16819]"""
from repro.configs.base import ModelConfig, register


@register("nemotron-4-340b")
def nemotron_4_340b() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b",
        family="dense",
        num_layers=96,
        d_model=18432,
        num_heads=96,
        num_kv_heads=8,
        d_ff=73728,
        vocab_size=256000,
        activation="relu2",        # squared ReLU
        norm="layernorm",
        rope=True,
        serve_window=4096,         # sliding-window serving variant for long_500k
        citation="arXiv:2402.16819",
    )
