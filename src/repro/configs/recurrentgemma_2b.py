"""RecurrentGemma-2B — hybrid RG-LRU + local attention, 1 attn : 2
recurrent. [arXiv:2402.19427]

26 layers with cyclic pattern (R, R, L): two RG-LRU recurrent blocks then
one local (sliding-window 2048) attention block; 26 = 8x3 + 2 so the last
two layers form an unrolled (R, R) tail.
"""
from repro.configs.base import ModelConfig, register


@register("recurrentgemma-2b")
def recurrentgemma_2b() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        num_layers=26,
        d_model=2560,
        num_heads=10,
        num_kv_heads=1,            # MQA
        head_dim=256,
        d_ff=7680,
        vocab_size=256000,
        activation="geglu",
        norm="rmsnorm",
        rope=True,
        block_pattern=("R", "R", "L"),
        window=2048,
        lru_width=2560,
        conv1d_width=4,
        citation="arXiv:2402.19427",
    )
