"""Roofline report: formats the dry-run sweep JSONs into the
EXPERIMENTS.md §Roofline table. (The sweeps themselves are produced by
``python -m repro.launch.dryrun --all [--multi-pod] --json ...`` — they
need a fresh process with 512 forced host devices.)"""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load(name):
    path = os.path.join(RESULTS, name)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def fmt_table(results, log=print):
    log(f"| {'arch':24s} | {'shape':11s} | {'compute_s':>10s} | "
        f"{'memory_s':>10s} | {'collective_s':>12s} | {'dominant':10s} | "
        f"{'useful':>6s} |")
    log("|" + "-" * 26 + "|" + "-" * 13 + "|" + "-" * 12 + "|" + "-" * 12
        + "|" + "-" * 14 + "|" + "-" * 12 + "|" + "-" * 8 + "|")
    for r in results:
        if "skipped" in r:
            log(f"| {r['arch']:24s} | {r['shape']:11s} | "
                f"{'SKIP (' + r['skipped'][:40] + ')':>64s} |")
            continue
        if "error" in r:
            log(f"| {r['arch']:24s} | {r['shape']:11s} | ERROR |")
            continue
        rf = r["roofline"]
        log(f"| {r['arch']:24s} | {r['shape']:11s} | {rf['compute_s']:10.4f} | "
            f"{rf['memory_s']:10.4f} | {rf['collective_s']:12.4f} | "
            f"{rf['dominant'][:-2]:10s} | {rf['useful_flop_frac']:6.3f} |")


def main(log=print):
    ok = True
    for name, label in (("dryrun_singlepod.json", "single-pod 16x16"),
                        ("dryrun_multipod.json", "multi-pod 2x16x16")):
        rs = load(name)
        if rs is None:
            log(f"(no {name} — run the dryrun sweep first)")
            continue
        errs = sum("error" in r for r in rs)
        log(f"\n== Roofline: {label} — {len(rs)} combos, {errs} errors ==")
        fmt_table(rs, log=log)
        ok &= errs == 0
    return ok


if __name__ == "__main__":
    main()
