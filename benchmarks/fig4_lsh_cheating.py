"""Paper Fig. 4: LSH-cheating attack — attackers forge LSH codes to match
a target client. Reported metric (mechanism-level, robust at reduced
scale): the rate at which attackers are ADMITTED INTO DISTILLATION by
honest clients, with vs without §3.5 verification — the quantity whose
collapse Fig. 4's accuracy curves reflect. Honest-cohort accuracy is
reported alongside (synthetic-data caveat in EXPERIMENTS.md §Repro).
"""
from __future__ import annotations

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import setup
from repro.core import attacks, evaluate, init_state, make_wpfed_round

TARGET = 0
ATTACK_START = 3


def run(dataset="mnist", seed=0, rounds=8, log=print):
    """Both arms use similarity-driven selection (use_rank=False) so the
    §3.5 verification filter is the isolated variable: fully-corrupt
    attackers are ALSO blocked by the rank-score defense (demonstrated
    in fig5); Fig. 4's subject is the LSH-verification layer."""
    out = {}
    for label, overrides in (("with_verification",
                              {"use_rank": False}),
                             ("without_verification",
                              {"use_rank": False,
                               "lsh_verification": False})):
        ctx = setup(dataset, seed, fed_overrides=overrides)
        m = ctx["fed"].num_clients
        attacker = jnp.arange(m) >= m // 2
        honest = (~attacker).astype(jnp.float32)
        state = init_state(ctx["apply_fn"], ctx["init_fn"], ctx["opt"],
                           ctx["fed"], jax.random.PRNGKey(seed))
        round_fn = jax.jit(make_wpfed_round(ctx["apply_fn"], ctx["opt"],
                                            ctx["fed"]))
        accs, admit = [], []
        for r in range(rounds):
            if r >= ATTACK_START:
                state = attacks.corrupt_params(
                    state, attacker, ctx["init_fn"],
                    jax.random.fold_in(jax.random.PRNGKey(seed + 31), r))
                state = attacks.forge_lsh_codes(state, attacker, TARGET)
            state, met = round_fn(state, ctx["data"])
            ev = evaluate(ctx["apply_fn"], state, ctx["data"],
                          honest_mask=honest)
            accs.append(float(ev["mean_acc"]))
            if r >= ATTACK_START:
                ids = met["neighbor_ids"]                  # (M,N)
                valid = met["valid_mask"]
                att_sel = jnp.take(attacker, ids)          # (M,N) bool
                hon_rows = ~attacker
                admitted = jnp.sum(att_sel & valid, axis=1) \
                    / jnp.maximum(jnp.sum(valid, axis=1), 1)
                admit.append(float(jnp.sum(admitted * hon_rows)
                                   / jnp.sum(hon_rows)))
        out[label] = {"honest_accs": accs,
                      "attacker_admission_rate":
                          float(np.mean(admit)) if admit else 0.0}
        log(f"fig4 {label}: attacker admission "
            f"{out[label]['attacker_admission_rate']:.3f}, "
            f"final honest acc {accs[-1]:.4f}")
    return out


def main():
    out = run()
    print(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    main()
