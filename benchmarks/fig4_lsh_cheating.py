"""Paper Fig. 4: LSH-cheating attack — attackers forge LSH codes to match
a target client. Reported metric (mechanism-level, robust at reduced
scale): the rate at which attackers are ADMITTED INTO DISTILLATION by
honest clients, with vs without §3.5 verification — the quantity whose
collapse Fig. 4's accuracy curves reflect. Honest-cohort accuracy is
reported alongside (synthetic-data caveat in EXPERIMENTS.md §Repro).

The attack is an in-graph `core.adversary.ThreatModel` (corrupt params
+ forge codes toward the target, every round from ATTACK_START), so the
run goes through the round-program engine like every clean method —
`--reselect-every G` gossips between reselections with the attackers
still firing inside the compiled segments (DESIGN.md §9). The admission
rate is the engine's own in-graph telemetry (attacker_admission_rate).
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import run_method
from repro.core import resolve_attack, threat_model

TARGET = 0
ATTACK_START = 3


def _lsh_cheat_threat(ctx, seed):
    """§4.7 threat: the top half of the pool corrupts its params and
    republishes the target's LSH code, every round from ATTACK_START."""
    m = ctx["fed"].num_clients
    return threat_model(
        [resolve_attack("corrupt", init_fn=ctx["init_fn"],
                        start_round=ATTACK_START),
         resolve_attack("forge_codes", target_id=TARGET,
                        start_round=ATTACK_START)],
        jnp.arange(m) >= m // 2,
        key=jax.random.PRNGKey(seed + 31), name="lsh-cheat")


def run(dataset="mnist", seed=0, rounds=8, reselect_every=1, log=print):
    """Both arms use similarity-driven selection (use_rank=False) so the
    §3.5 verification filter is the isolated variable: fully-corrupt
    attackers are ALSO blocked by the rank-score defense (demonstrated
    in fig5); Fig. 4's subject is the LSH-verification layer."""
    out = {}
    for label, overrides in (("with_verification",
                              {"use_rank": False}),
                             ("without_verification",
                              {"use_rank": False,
                               "lsh_verification": False})):
        res = run_method("wpfed", dataset, seed, rounds=rounds,
                         fed_overrides=overrides,
                         threat=lambda ctx: _lsh_cheat_threat(ctx, seed),
                         reselect_every=reselect_every)
        admit = [h["attacker_admission_rate"] for h in res["history"]
                 if h["round"] >= ATTACK_START]
        out[label] = {"honest_accs": res["accs"],
                      "attacker_admission_rate":
                          float(np.mean(admit)) if admit else 0.0}
        log(f"fig4 {label}: attacker admission "
            f"{out[label]['attacker_admission_rate']:.3f}, "
            f"final honest acc {res['accs'][-1]:.4f}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--reselect-every", type=int, default=1,
                    help="gossip period G (1 = the paper's sync rounds)")
    args = ap.parse_args(argv)
    out = run(reselect_every=args.reselect_every)
    print(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    main()
