"""Shared benchmark harness: method runners + experiment loop.

Scale note (DESIGN.md §2): the paper runs 4xV100 for hundreds of rounds;
this container is a single CPU core, so the benchmarks run the same
protocol at reduced scale (fewer clients / rounds / samples) against
synthetic stand-ins with the paper's partition statistics. The target is
the paper's *orderings* (WPFed > baselines; robustness under attack),
not absolute accuracies.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import (FedConfig, PAPER_FED_OPTIMA,
                                        aecg_tcn, mnist_cnn, seeg_tcn)
from repro.core import (Schedule, ThreatModel, evaluate, init_state,
                        instrument_program, make_program, run_rounds)
from repro.data import DATASETS
from repro.models import apply_client_model, init_client_model
from repro.optim import adam

MODEL_FOR = {"mnist": mnist_cnn, "aecg": aecg_tcn, "seeg": seeg_tcn}

# reduced-scale experiment defaults (CPU budget). Local data is kept
# SCARCE and noisy — the paper's regime (SILO 0.877 on MNIST) is one
# where a client cannot solve the task alone; collaboration and
# *selection* only carry signal away from the local ceiling.
BENCH_CLIENTS = {"mnist": 8, "aecg": 8, "seeg": 8}
BENCH_DATA_KW = {"mnist": {"per_client": 90, "noise": 1.0},
                 "aecg": {"per_subject": 40},
                 "seeg": {"per_subject": 40}}
BENCH_ROUNDS = 8
BENCH_SEEDS = (0, 1)


def setup(dataset: str, seed: int, num_clients: int = 0,
          fed_overrides: Optional[dict] = None):
    n_clients = num_clients or BENCH_CLIENTS[dataset]
    ds = DATASETS[dataset](num_clients=n_clients, seed=seed,
                           **BENCH_DATA_KW[dataset])
    n_opt, alpha, gamma = PAPER_FED_OPTIMA[dataset]
    # the paper's N (8-12) is tuned for 35-40 clients; at the reduced
    # client count keep N << M-1 or every client selects everyone and
    # selection carries no signal (N ~ M/3, the paper's ratio).
    n_nb = min(n_opt, max(2, ds.num_clients // 3))
    fed = FedConfig(num_clients=ds.num_clients, num_neighbors=n_nb,
                    alpha=alpha, gamma=gamma, local_steps=3,
                    top_k=max(2, n_nb - 1), lsh_bits=128)
    if fed_overrides:
        fed = dataclasses.replace(fed, **fed_overrides)
    mcfg = MODEL_FOR[dataset]()
    apply_fn = functools.partial(apply_client_model, mcfg)
    init_fn = lambda k: init_client_model(mcfg, k)
    opt = adam(fed.lr)
    data = {k: jnp.asarray(v) for k, v in ds.stacked().items()}
    return {"ds": ds, "fed": fed, "apply_fn": apply_fn, "init_fn": init_fn,
            "opt": opt, "data": data}


def make_fed_program(method: str, ctx):
    """RoundProgram for `method`, resolved in one place
    (core.rounds.make_program) with ctx-specific extras bound."""
    kw = {}
    if method == "fedmd":
        kw["shared_ref_x"] = jnp.asarray(ctx["ds"].shared_ref_x)
    return make_program(method, ctx["apply_fn"], ctx["opt"], ctx["fed"],
                        **kw)


def run_method(method: str, dataset: str, seed: int, rounds: int = 0,
               fed_overrides: Optional[dict] = None,
               threat: Union[ThreatModel, Callable, None] = None,
               honest_mask=None, reselect_every: int = 1) -> Dict:
    """Train `method` for `rounds`; returns the accuracy trajectory plus
    the full per-round scalar history.

    EVERY run — clean or adversarial — goes through the round-program
    engine (core.rounds.run_rounds): per-round evaluation stays inside
    the compiled segment and reselect_every>1 runs gossip epochs
    between reselections (DESIGN.md §8). `threat` is a
    `core.adversary.ThreatModel` (or a builder `ctx -> ThreatModel`,
    for threats that need the run's init_fn / client count); attacks
    are spliced in-graph via `instrument_program`, so adversarial runs
    compile, scan, and gossip exactly like clean ones — the per-round
    host attack loop is gone (DESIGN.md §9). Under a threat the
    in-graph telemetry (attacker_admission_rate, rank_score_*) lands in
    the history, and evaluation defaults to the honest cohort.
    """
    ctx = setup(dataset, seed, fed_overrides=fed_overrides)
    rounds = rounds or BENCH_ROUNDS
    program = make_fed_program(method, ctx)
    tm = threat(ctx) if callable(threat) else threat
    if tm is not None:
        program = instrument_program(program, tm)
        if honest_mask is None:
            honest_mask = (~tm.attacker_mask).astype(jnp.float32)
    state = init_state(ctx["apply_fn"], ctx["init_fn"], ctx["opt"],
                       ctx["fed"], jax.random.PRNGKey(seed))
    t0 = time.time()
    eval_fn = lambda st, d: {"acc": evaluate(
        ctx["apply_fn"], st, d, honest_mask=honest_mask)["mean_acc"]}
    state, history = run_rounds(
        program, state, ctx["data"], rounds=rounds,
        schedule=Schedule(reselect_every), eval_fn=eval_fn)
    accs = [h["acc"] for h in history]
    return {"method": method, "dataset": dataset, "seed": seed,
            "accs": accs, "final_acc": accs[-1], "history": history,
            "wall_s": time.time() - t0}


def mean_std(results: List[Dict]) -> Dict:
    finals = [r["final_acc"] for r in results]
    return {"mean": float(np.mean(finals)), "std": float(np.std(finals))}
