"""Shared benchmark harness: method runners + experiment loop.

Scale note (DESIGN.md §2): the paper runs 4xV100 for hundreds of rounds;
this container is a single CPU core, so the benchmarks run the same
protocol at reduced scale (fewer clients / rounds / samples) against
synthetic stand-ins with the paper's partition statistics. The target is
the paper's *orderings* (WPFed > baselines; robustness under attack),
not absolute accuracies.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import (FedConfig, PAPER_FED_OPTIMA,
                                        aecg_tcn, mnist_cnn, seeg_tcn)
from repro.core import (Schedule, evaluate, init_state, make_program,
                        program_round, run_rounds)
from repro.data import DATASETS
from repro.models import apply_client_model, init_client_model
from repro.optim import adam

MODEL_FOR = {"mnist": mnist_cnn, "aecg": aecg_tcn, "seeg": seeg_tcn}

# reduced-scale experiment defaults (CPU budget). Local data is kept
# SCARCE and noisy — the paper's regime (SILO 0.877 on MNIST) is one
# where a client cannot solve the task alone; collaboration and
# *selection* only carry signal away from the local ceiling.
BENCH_CLIENTS = {"mnist": 8, "aecg": 8, "seeg": 8}
BENCH_DATA_KW = {"mnist": {"per_client": 90, "noise": 1.0},
                 "aecg": {"per_subject": 40},
                 "seeg": {"per_subject": 40}}
BENCH_ROUNDS = 8
BENCH_SEEDS = (0, 1)


def setup(dataset: str, seed: int, num_clients: int = 0,
          fed_overrides: Optional[dict] = None):
    n_clients = num_clients or BENCH_CLIENTS[dataset]
    ds = DATASETS[dataset](num_clients=n_clients, seed=seed,
                           **BENCH_DATA_KW[dataset])
    n_opt, alpha, gamma = PAPER_FED_OPTIMA[dataset]
    # the paper's N (8-12) is tuned for 35-40 clients; at the reduced
    # client count keep N << M-1 or every client selects everyone and
    # selection carries no signal (N ~ M/3, the paper's ratio).
    n_nb = min(n_opt, max(2, ds.num_clients // 3))
    fed = FedConfig(num_clients=ds.num_clients, num_neighbors=n_nb,
                    alpha=alpha, gamma=gamma, local_steps=3,
                    top_k=max(2, n_nb - 1), lsh_bits=128)
    if fed_overrides:
        fed = dataclasses.replace(fed, **fed_overrides)
    mcfg = MODEL_FOR[dataset]()
    apply_fn = functools.partial(apply_client_model, mcfg)
    init_fn = lambda k: init_client_model(mcfg, k)
    opt = adam(fed.lr)
    data = {k: jnp.asarray(v) for k, v in ds.stacked().items()}
    return {"ds": ds, "fed": fed, "apply_fn": apply_fn, "init_fn": init_fn,
            "opt": opt, "data": data}


def make_fed_program(method: str, ctx):
    """RoundProgram for `method`, resolved in one place
    (core.rounds.make_program) with ctx-specific extras bound."""
    kw = {}
    if method == "fedmd":
        kw["shared_ref_x"] = jnp.asarray(ctx["ds"].shared_ref_x)
    return make_program(method, ctx["apply_fn"], ctx["opt"], ctx["fed"],
                        **kw)


def make_round(method: str, ctx) -> Callable:
    """Classic round_fn(state, data) -> (state, metrics) for `method` —
    the program_round adapter over the same one-place registry."""
    return program_round(make_fed_program(method, ctx))


def run_method(method: str, dataset: str, seed: int, rounds: int = 0,
               fed_overrides: Optional[dict] = None,
               attack_hook: Optional[Callable] = None,
               honest_mask=None, reselect_every: int = 1) -> Dict:
    """Train `method` for `rounds`; returns accuracy trajectory.

    Without an attack hook the rounds run through the round-program
    engine (core.rounds.run_rounds — per-round evaluation stays inside
    the compiled segment; reselect_every>1 runs gossip epochs between
    reselections, DESIGN.md §8). Attack hooks mutate state on the host
    every round, so that path keeps the per-round Python loop and
    rejects reselect_every>1 rather than silently running sync.
    """
    if attack_hook is not None and reselect_every != 1:
        raise ValueError("attack_hook runs the per-round host loop; "
                         "reselect_every>1 is not supported there")
    ctx = setup(dataset, seed, fed_overrides=fed_overrides)
    rounds = rounds or BENCH_ROUNDS
    state = init_state(ctx["apply_fn"], ctx["init_fn"], ctx["opt"],
                       ctx["fed"], jax.random.PRNGKey(seed))
    t0 = time.time()
    if attack_hook is None:
        eval_fn = lambda st, d: {"acc": evaluate(
            ctx["apply_fn"], st, d, honest_mask=honest_mask)["mean_acc"]}
        state, history = run_rounds(
            make_fed_program(method, ctx), state, ctx["data"],
            rounds=rounds, schedule=Schedule(reselect_every),
            eval_fn=eval_fn)
        accs = [h["acc"] for h in history]
    else:
        round_fn = jax.jit(make_round(method, ctx))
        accs = []
        for r in range(rounds):
            state = attack_hook(state, r, ctx)
            state, _ = round_fn(state, ctx["data"])
            ev = evaluate(ctx["apply_fn"], state, ctx["data"],
                          honest_mask=honest_mask)
            accs.append(float(ev["mean_acc"]))
    return {"method": method, "dataset": dataset, "seed": seed,
            "accs": accs, "final_acc": accs[-1],
            "wall_s": time.time() - t0}


def mean_std(results: List[Dict]) -> Dict:
    finals = [r["final_acc"] for r in results]
    return {"mean": float(np.mean(finals)), "std": float(np.std(finals))}
