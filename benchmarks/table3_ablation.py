"""Paper Table 3: ablation of LSH-similarity and rank-score selection.
Variants: full WPFed, w/o LSH, w/o Rank, w/o both (random selection)."""
from __future__ import annotations

import json

from benchmarks.common import BENCH_SEEDS, mean_std, run_method

VARIANTS = {
    "wpfed": {},
    "wo_lsh": {"use_lsh": False},
    "wo_rank": {"use_rank": False},
    "wo_lsh_rank": {"use_lsh": False, "use_rank": False},
}


def run(dataset="mnist", seeds=BENCH_SEEDS, rounds=0, log=print):
    table = {}
    for name, overrides in VARIANTS.items():
        results = [run_method("wpfed", dataset, seed, rounds=rounds,
                              fed_overrides=overrides)
                   for seed in seeds]
        table[name] = mean_std(results)
        log(f"table3 {dataset} {name:12s} {table[name]['mean']:.4f} "
            f"± {table[name]['std']:.4f}")
    base = table["wpfed"]["mean"]
    for name in ("wo_lsh", "wo_rank", "wo_lsh_rank"):
        table[name]["delta_vs_full"] = round(table[name]["mean"] - base, 4)
    return table


def main():
    table = run()
    print(json.dumps(table, indent=1))
    return table


if __name__ == "__main__":
    main()
