"""Continuous-service benchmark (DESIGN.md §13) — the serving front's
throughput/latency for batched inference across per-client PERSONALIZED
models, plus the service driver's period cadence and durable-state
costs. Writes benchmarks/BENCH_service.json.

Timing discipline matches the kernel benches: every number is a median
over repeated reps after discarded warmups, with the per-rep spread
recorded next to it. All wall times are CPU times on this container —
the point is the RELATIVE shape (batching gain across the bucket
ladder, checkpoint cost vs period cost), not absolute hardware truth.

Usage: PYTHONPATH=src python benchmarks/service_bench.py [--smoke]
"""
import argparse
import functools
import json
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import ClientModelConfig, FedConfig
from repro.core import init_state
from repro.core.faults import FaultPlan
from repro.models import apply_client_model, init_client_model
from repro.optim import adam
from repro.service import (BulletinTransport, PersonalizedServer,
                           ServiceConfig, init_service_state,
                           resume_service, run_service)
from repro.service.driver import checkpoint_service
from repro.core.chain import Blockchain

OUT = os.path.join(os.path.dirname(__file__), "BENCH_service.json")


def build(m=8, d=16, classes=3, seed=0):
    rs = np.random.RandomState(seed)
    mcfg = ClientModelConfig("bench-mlp", "mlp", (d,), classes,
                             hidden=(32,))
    fed = FedConfig(num_clients=m, num_neighbors=3, top_k=2,
                    local_steps=3, local_batch=16, lsh_bits=128, lr=1e-2)
    centers = rs.randn(classes, d) * 2.5
    data = {}
    for split, n in (("train", 40), ("ref", 12), ("test", 64)):
        y = rs.choice(classes, size=(m, n))
        x = centers[y] + rs.randn(m, n, d)
        data[f"x_{split}"] = jnp.asarray(x.astype("f"))
        data[f"y_{split}"] = jnp.asarray(y.astype("i4"))
    apply_fn = functools.partial(apply_client_model, mcfg)
    init_fn = lambda k: init_client_model(mcfg, k)
    return fed, apply_fn, init_fn, adam(fed.lr), data


def timed(fn, reps, warmup=2):  # analysis: host-ok — benchmark timing
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(reps):
        t0 = time.time()
        fn()
        times.append(time.time() - t0)
    med = float(np.median(times))
    return {"median_s": med, "spread_s": float(np.ptp(times)),
            "reps": reps}


def bench_serving(apply_fn, params, data, m, reps):
    """Throughput/latency across the bucket ladder: one flush of B
    requests, requests spread over all M personalized models."""
    rows = []
    for batch in (1, 4, 16, 64, 256):
        server = PersonalizedServer(apply_fn, params)

        def flush_batch():
            for r in range(batch):
                cid = r % m
                server.submit(cid, data["x_test"][cid, r % 64])
            server.flush()

        t = timed(flush_batch, reps)
        rows.append({
            "batch": batch,
            "requests_per_s": batch / t["median_s"],
            "flush_median_ms": t["median_s"] * 1e3,
            "flush_spread_ms": t["spread_s"] * 1e3,
            "reps": t["reps"],
        })
        print(f"serve batch {batch:4d}: "
              f"{rows[-1]['requests_per_s']:9.0f} req/s  "
              f"p50 {rows[-1]['flush_median_ms']:7.2f} ms")
    return rows


def bench_driver(fed, apply_fn, init_fn, opt, data, reps):
    """Period cadence (compile vs warm) + durable-state costs."""
    svc = ServiceConfig(reselect_every=3, keep_last_k=2)
    state = init_service_state(
        init_state(apply_fn, init_fn, opt, fed, jax.random.PRNGKey(0)),
        svc)
    t0 = time.time()
    state, chain, _ = run_service(apply_fn, opt, fed, svc, state, data,
                                  periods=1)
    compile_s = time.time() - t0
    # warm periods: the driver reuses ONE compiled segment for every
    # period, so steady-state cadence excludes compilation entirely
    # (continue from period 1 so the ledger keeps covering the state's
    # round counter — resume_service refuses a lagging ledger)
    t0 = time.time()
    state, chain, _ = run_service(apply_fn, opt, fed, svc, state, data,
                                  periods=reps + 1, chain=chain,
                                  start_period=1)
    warm_period_s = (time.time() - t0) / reps
    with tempfile.TemporaryDirectory() as tmp:
        save = timed(lambda: checkpoint_service(
            tmp, 0, state, chain, keep_last_k=2), reps)
        resume = timed(lambda: resume_service(tmp, state), reps)
    return {
        "reselect_every": svc.reselect_every,
        "first_period_s_with_compile": compile_s,
        "warm_period_s": warm_period_s,
        "warm_round_s": warm_period_s / svc.reselect_every,
        "checkpoint_save_median_s": save["median_s"],
        "resume_median_s": resume["median_s"],
    }


def bench_transport(fed, apply_fn, init_fn, opt, data, reps):
    """Cost of the hardened transport (DESIGN.md §15): warm period time
    on the fault-free path with NO plan vs a ZERO-rate plan (the full
    fault machinery engaged, injecting nothing — its pure overhead) vs
    LIGHT chaos (faults actually firing). Backoff sleeps are no-ops so
    the chaos column times the degraded-mode compute (verdicts, masking,
    merge_delivery, checksums), not simulated network latency."""
    svc = ServiceConfig(reselect_every=3, keep_last_k=2)
    # light chaos, seed-checked: no retry budget exhausts and no period
    # loses every announcement through 12 periods
    chaos = FaultPlan(seed=0, drop=0.05, delay=0.05, duplicate=0.1,
                      corrupt=0.05, straggle=0.1, publish_fail=0.2,
                      fetch_fail=0.1)
    modes = (("no_plan", None), ("zero_rate_plan", FaultPlan(seed=0)),
             ("light_chaos", chaos))
    out = {}
    for name, plan in modes:
        state = init_service_state(
            init_state(apply_fn, init_fn, opt, fed,
                       jax.random.PRNGKey(0)), svc)
        xp = BulletinTransport(Blockchain(), plan=plan,
                               sleep=lambda s: None)
        # stamp each period boundary inside ONE driver call: the single
        # compile lands before the first stamp, so the diffs are pure
        # warm-period times
        stamps = []
        run_service(apply_fn, opt, fed, svc, state, data,
                    periods=reps + 2, transport=xp,
                    log=lambda _msg: stamps.append(time.time()))
        out[name] = {"warm_period_s": float(np.median(np.diff(stamps))),
                     "warm_periods_timed": len(stamps) - 1,
                     "fault_trace": xp.trace.snapshot()}
        print(f"transport {name:15s}: warm period "
              f"{out[name]['warm_period_s'] * 1e3:8.1f} ms  "
              f"trace {out[name]['fault_trace']}")
    base = out["no_plan"]["warm_period_s"]
    out["fault_free_overhead_frac"] = \
        out["zero_rate_plan"]["warm_period_s"] / base - 1.0
    out["light_chaos_overhead_frac"] = \
        out["light_chaos"]["warm_period_s"] / base - 1.0
    print(f"transport fault-free overhead "
          f"{out['fault_free_overhead_frac'] * 100:+.1f}%  "
          f"light chaos {out['light_chaos_overhead_frac'] * 100:+.1f}%")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer reps (CI)")
    ap.add_argument("--clients", type=int, default=8)
    args = ap.parse_args()
    reps = 3 if args.smoke else 10
    fed, apply_fn, init_fn, opt, data = build(m=args.clients)

    # serve TRAINED personalized models: run a short service first so
    # the benched params are the system's real output, not init noise
    svc = ServiceConfig(reselect_every=3)
    state = init_service_state(
        init_state(apply_fn, init_fn, opt, fed, jax.random.PRNGKey(0)),
        svc)
    state, _, hist = run_service(apply_fn, opt, fed, svc, state, data,
                                 periods=2)

    out = {
        "note": "CPU wall times (median over reps, warmups discarded); "
                "relative shape is the signal, not absolute hardware "
                "truth. Serving batches requests ACROSS per-client "
                "personalized models through one vmapped forward per "
                "bucket (repro.service.serving).",
        "num_models": fed.num_clients,
        "model": "bench-mlp (16 -> 32 -> 3)",
        "trained_rounds": len(hist),
        "serving": bench_serving(apply_fn, state.fed.params, data,
                                 fed.num_clients, reps),
        "driver": bench_driver(fed, apply_fn, init_fn, opt, data,
                               max(2, reps // 2)),
        "transport": bench_transport(fed, apply_fn, init_fn, opt, data,
                                     max(2, reps // 2)),
    }
    with open(OUT, "w") as fh:
        json.dump(out, fh, indent=1)
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
