"""Paper Fig. 5: poison attacks with 25/50% dishonest clients (paper:
20/40/60% of 35-40 clients; the reduced pool quantizes fractions).
Mechanism metrics: (a) crowd-sourced ranking score of poisoned vs honest
clients — WPFed's selection signal; (b) poisoned-client admission rate
into honest clients' distillation — WPFed vs ProxyFL (no selection);
plus honest-cohort accuracy (synthetic-data caveat in EXPERIMENTS.md).

The poison is an in-graph `core.adversary.ThreatModel` ("poison" =
periodic re-initialization, §4.8), so both methods run through the
round-program engine — `--reselect-every G` poisons inside the gossip
scan too — and the rank-score / admission metrics are the engine's own
in-graph telemetry (DESIGN.md §9).
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from benchmarks.common import run_method
from repro.core import attacker_mask_tail, resolve_attack, threat_model

ATTACK_START = 3
EVERY = 2


def _poison_threat(ctx, frac, seed):
    m = ctx["fed"].num_clients
    return threat_model(
        [resolve_attack("poison", init_fn=ctx["init_fn"],
                        start_round=ATTACK_START, every=EVERY)],
        attacker_mask_tail(m, frac),
        key=jax.random.PRNGKey(seed + 77),
        name=f"poison{int(frac * 100)}")


def run(dataset="mnist", seed=0, rounds=8, fracs=(0.25, 0.5),
        reselect_every=1, log=print):
    out = {}
    for frac in fracs:
        for method in ("wpfed", "proxyfl"):
            res = run_method(
                method, dataset, seed, rounds=rounds,
                threat=lambda ctx: _poison_threat(ctx, frac, seed),
                reselect_every=reselect_every)
            accs = res["accs"]
            key = f"{method}@{int(frac * 100)}%"
            out[key] = {"honest_accs": accs}
            if method == "wpfed":
                # in-graph telemetry: rank scores + admission, averaged
                # over post-warm-up rounds (selection carries signal)
                post = [h for h in res["history"]
                        if h["round"] > ATTACK_START]

                def post_mean(k):
                    return float(np.mean([h[k] for h in post])) \
                        if post else 0.0

                out[key].update({
                    "rank_score_honest": post_mean("rank_score_honest"),
                    "rank_score_poisoned": post_mean("rank_score_attacker"),
                    "poisoned_admission_rate":
                        post_mean("attacker_admission_rate"),
                })
                log(f"fig5 {key}: rank honest "
                    f"{out[key]['rank_score_honest']:.3f} vs poisoned "
                    f"{out[key]['rank_score_poisoned']:.3f}, admission "
                    f"{out[key]['poisoned_admission_rate']:.3f}, "
                    f"final acc {accs[-1]:.4f}")
            else:
                log(f"fig5 {key}: final honest acc {accs[-1]:.4f} "
                    f"(no selection — every poisoned peer may be gossiped)")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--reselect-every", type=int, default=1,
                    help="gossip period G (1 = the paper's sync rounds)")
    args = ap.parse_args(argv)
    out = run(reselect_every=args.reselect_every)
    print(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    main()
