"""Paper Fig. 5: poison attacks with 25/50% dishonest clients (paper:
20/40/60% of 35-40 clients; the reduced pool quantizes fractions).
Mechanism metrics: (a) crowd-sourced ranking score of poisoned vs honest
clients — WPFed's selection signal; (b) poisoned-client admission rate
into honest clients' distillation — WPFed vs ProxyFL (no selection);
plus honest-cohort accuracy (synthetic-data caveat in EXPERIMENTS.md).
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import make_round, setup
from repro.core import attacks, evaluate, init_state, make_wpfed_round

ATTACK_START = 3
EVERY = 2


def run(dataset="mnist", seed=0, rounds=8, fracs=(0.25, 0.5), log=print):
    out = {}
    for frac in fracs:
        for method in ("wpfed", "proxyfl"):
            ctx = setup(dataset, seed)
            m = ctx["fed"].num_clients
            n_bad = int(m * frac)
            attacker = jnp.arange(m) >= (m - n_bad)
            honest = (~attacker).astype(jnp.float32)
            state = init_state(ctx["apply_fn"], ctx["init_fn"], ctx["opt"],
                               ctx["fed"], jax.random.PRNGKey(seed))
            round_fn = jax.jit(make_round(method, ctx))
            accs, scores_h, scores_b, admit = [], [], [], []
            for r in range(rounds):
                if r >= ATTACK_START and (r - ATTACK_START) % EVERY == 0:
                    state = attacks.corrupt_params(
                        state, attacker, ctx["init_fn"],
                        jax.random.fold_in(jax.random.PRNGKey(seed + 77), r))
                state, met = round_fn(state, ctx["data"])
                accs.append(float(evaluate(ctx["apply_fn"], state,
                                           ctx["data"],
                                           honest_mask=honest)["mean_acc"]))
                if method == "wpfed" and r > ATTACK_START:
                    s = met["ranking_scores"]
                    scores_h.append(float(jnp.sum(s * honest)
                                          / jnp.sum(honest)))
                    scores_b.append(float(jnp.sum(s * attacker)
                                          / jnp.maximum(jnp.sum(attacker),
                                                        1)))
                    ids, valid = met["neighbor_ids"], met["valid_mask"]
                    att_sel = jnp.take(attacker, ids)
                    adm = jnp.sum(att_sel & valid, axis=1) \
                        / jnp.maximum(jnp.sum(valid, axis=1), 1)
                    admit.append(float(jnp.sum(adm * honest)
                                       / jnp.sum(honest)))
            key = f"{method}@{int(frac * 100)}%"
            out[key] = {"honest_accs": accs}
            if method == "wpfed":
                out[key].update({
                    "rank_score_honest": float(np.mean(scores_h)),
                    "rank_score_poisoned": float(np.mean(scores_b)),
                    "poisoned_admission_rate": float(np.mean(admit)),
                })
                log(f"fig5 {key}: rank honest "
                    f"{out[key]['rank_score_honest']:.3f} vs poisoned "
                    f"{out[key]['rank_score_poisoned']:.3f}, admission "
                    f"{out[key]['poisoned_admission_rate']:.3f}, "
                    f"final acc {accs[-1]:.4f}")
            else:
                log(f"fig5 {key}: final honest acc {accs[-1]:.4f} "
                    f"(no selection — every poisoned peer may be gossiped)")
    return out


def main():
    out = run()
    print(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    main()
