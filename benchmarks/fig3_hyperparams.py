"""Paper Fig. 3: sensitivity to alpha (local/collab trade-off) and gamma
(LSH-similarity weighting)."""
from __future__ import annotations

import json

from benchmarks.common import run_method

ALPHAS = (0.2, 0.6, 1.0)
GAMMAS = (0.01, 1.0, 1000.0)


def run(dataset="mnist", seed=0, rounds=0, log=print):
    out = {"alpha": {}, "gamma": {}}
    for a in ALPHAS:
        r = run_method("wpfed", dataset, seed, rounds=rounds,
                       fed_overrides={"alpha": a})
        out["alpha"][str(a)] = r["final_acc"]
        log(f"fig3 alpha={a}: {r['final_acc']:.4f}")
    for g in GAMMAS:
        r = run_method("wpfed", dataset, seed, rounds=rounds,
                       fed_overrides={"gamma": g})
        out["gamma"][str(g)] = r["final_acc"]
        log(f"fig3 gamma={g}: {r['final_acc']:.4f}")
    return out


def main():
    out = run()
    print(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    main()
