"""Paper Table 2: WPFed vs SILO / FedMD / ProxyFL / KD-PDFL on the three
(synthetic stand-in) datasets. Target: the paper's ordering — WPFed best,
SILO worst under non-IID.

All five methods run through the one round-program engine entry point
(core.rounds.run_rounds via benchmarks.common.run_method); pass
--reselect-every G to score the gossip schedule (DESIGN.md §8) instead
of the per-round sync protocol.
"""
from __future__ import annotations

import argparse
import json

from benchmarks.common import BENCH_SEEDS, mean_std, run_method

METHODS = ("silo", "fedmd", "proxyfl", "kdpdfl", "wpfed")


def run(datasets=("mnist", "aecg", "seeg"), seeds=BENCH_SEEDS, rounds=0,
        reselect_every=1, log=print):
    table = {}
    for ds in datasets:
        table[ds] = {}
        for method in METHODS:
            results = [run_method(method, ds, seed, rounds=rounds,
                                  reselect_every=reselect_every)
                       for seed in seeds]
            table[ds][method] = mean_std(results)
            log(f"table2 {ds:6s} {method:8s} "
                f"{table[ds][method]['mean']:.4f} "
                f"± {table[ds][method]['std']:.4f}")
    return table


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=0,
                    help="0 = benchmark default")
    ap.add_argument("--reselect-every", type=int, default=1,
                    help="gossip period G (1 = sync, the paper)")
    args = ap.parse_args(argv)
    table = run(rounds=args.rounds, reselect_every=args.reselect_every)
    print(json.dumps(table, indent=1))
    # paper's key ordering claims
    for ds, row in table.items():
        assert row["wpfed"]["mean"] >= row["silo"]["mean"] - 0.03, \
            f"{ds}: WPFed should not lose to SILO"
    return table


if __name__ == "__main__":
    main()
