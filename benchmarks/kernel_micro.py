"""Kernel micro-benchmarks: LSH projection + Hamming (interpret-mode
wall time is NOT TPU time — the derived column is the analytic TPU-v5e
estimate from FLOP/byte counts; see EXPERIMENTS.md)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.lsh_projection import CHUNK

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def bench_lsh(n_params=1 << 20, bits=256, iters=3):
    x = jax.random.normal(jax.random.PRNGKey(0), (n_params,))
    fn = jax.jit(lambda v: ref.lsh_project_sums_ref(v, 3, bits=bits))
    fn(x).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        fn(x).block_until_ready()
    us = (time.time() - t0) / iters * 1e6
    flops = 2.0 * n_params * bits
    tpu_est_us = max(flops / PEAK_FLOPS, n_params * 4 / HBM_BW) * 1e6
    return us, tpu_est_us


def bench_hamming(m=128, words=8, iters=3):
    bits = jax.random.bernoulli(jax.random.PRNGKey(1), 0.5, (m, words * 32))
    codes = ops.pack_bits(jnp.where(bits, 1.0, -1.0))
    fn = jax.jit(lambda c: ops.hamming_matrix(c, use_kernel=False))
    fn(codes).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        fn(codes).block_until_ready()
    us = (time.time() - t0) / iters * 1e6
    tpu_est_us = max(m * m * words * 8 / (PEAK_FLOPS / 16),
                     m * words * 4 / HBM_BW) * 1e6
    return us, tpu_est_us


def main(log=print):
    rows = []
    for n in (1 << 18, 1 << 20, 1 << 22):
        us, est = bench_lsh(n)
        rows.append(("lsh_project_" + str(n), us, est))
    for m in (64, 256):
        us, est = bench_hamming(m)
        rows.append((f"hamming_{m}x{m}", us, est))
    for name, us, est in rows:
        log(f"{name},{us:.1f},{est:.3f}")
    return rows


if __name__ == "__main__":
    main()
