"""Kernel micro-benchmarks: LSH projection (single + batched), Hamming,
and the fused selection path (interpret-mode wall time is NOT TPU time —
the derived column is the analytic TPU-v5e estimate from FLOP/byte
counts; see EXPERIMENTS.md).

The selection rows time the two *jnp* implementations the round can
actually run on CPU: the fused oracle (popcount + discrete-domain exp
LUT -> top-N; the bit-exact CPU twin of the Pallas kernel's Gram-matmul
form, DESIGN.md §4) against the unfused composition (hamming ->
normalized_distance -> selection_weights -> top_k). The measured
speedup is the fused path's win in the distance/weight stages (LUT
gather instead of M^2 transcendentals, no (M, M) intermediate
materializations); lax.top_k is a shared fixed cost. `python
benchmarks/kernel_micro.py` writes the machine-readable baseline to
benchmarks/BENCH_selection.json.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core import lsh, neighbor
from repro.kernels import ops, ref
from repro.kernels.lsh_projection import CHUNK, lsh_project_sums_batched
from repro.kernels.selection import fused_select

PEAK_FLOPS = 197e12
HBM_BW = 819e9
BENCH_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "BENCH_selection.json")


def _time(fn, *args, iters=3):
    """Best-of-iters wall time in us (min filters scheduler noise,
    which at sub-ms scales otherwise dominates the comparison)."""
    fn(*args)  # compile + warm
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.time()
        jax.block_until_ready(fn(*args))
        best = min(best, time.time() - t0)
    return best * 1e6


def bench_lsh(n_params=1 << 20, bits=256, iters=3):
    x = jax.random.normal(jax.random.PRNGKey(0), (n_params,))
    us = _time(jax.jit(lambda v: ref.lsh_project_sums_ref(v, 3, bits=bits)),
               x, iters=iters)
    flops = 2.0 * n_params * bits
    tpu_est_us = max(flops / PEAK_FLOPS, n_params * 4 / HBM_BW) * 1e6
    return us, tpu_est_us


def bench_batched_lsh(m=64, n_params=1 << 16, bits=256, iters=3,
                      with_kernel=False):
    """Batched (M, P) projection: per-client-oracle vmap (the old
    stacked path) vs the batched kernel's analytic TPU estimate. The
    interpret-mode kernel wall time is reported only when requested
    (it measures the interpreter, not the kernel)."""
    x = jax.random.normal(jax.random.PRNGKey(1), (m, n_params))
    oracle_us = _time(
        jax.jit(lambda v: ops.batched_lsh_codes(v, 3, bits=bits,
                                                use_kernel=False)),
        x, iters=iters)
    kernel_us = None
    if with_kernel:
        kernel_us = _time(
            jax.jit(lambda v: ops.batched_lsh_codes(v, 3, bits=bits,
                                                    use_kernel=True)),
            x, iters=iters)
    flops = 2.0 * m * n_params * bits
    tpu_est_us = max(flops / PEAK_FLOPS, m * n_params * 4 / HBM_BW) * 1e6
    return oracle_us, kernel_us, tpu_est_us


def bench_hamming(m=128, words=8, iters=3):
    bits = jax.random.bernoulli(jax.random.PRNGKey(1), 0.5, (m, words * 32))
    codes = ops.pack_bits(jnp.where(bits, 1.0, -1.0))
    fn = jax.jit(lambda c: ops.hamming_matrix(c, use_kernel=False))
    us = _time(fn, codes, iters=iters)
    tpu_est_us = max(m * m * words * 8 / (PEAK_FLOPS / 16),
                     m * words * 4 / HBM_BW) * 1e6
    return us, tpu_est_us


def _unfused_select(codes, scores, bits, gamma, n):
    d = lsh.distance_matrix(codes, use_kernel=False)
    d_norm = lsh.normalized_distance(d, bits)
    w = neighbor.selection_weights(scores, d_norm, gamma)
    return neighbor.select_neighbors(w, n)


def bench_fused_selection(m=256, bits=256, n=16, gamma=1.0, iters=10):
    """Fused oracle vs unfused composition at federation scale M."""
    words = bits // 32
    key = jax.random.PRNGKey(m)
    raw = jax.random.bernoulli(key, 0.5, (m, bits))
    codes = ops.pack_bits(jnp.where(raw, 1.0, -1.0))
    scores = jax.random.uniform(jax.random.fold_in(key, 1), (m,))

    unfused_us = _time(
        jax.jit(lambda c, s: _unfused_select(c, s, bits, gamma, n)),
        codes, scores, iters=iters)
    fused_us = _time(
        jax.jit(lambda c, s: ref.fused_select_ref(
            c, s, bits=bits, gamma=gamma, num_neighbors=n)),
        codes, scores, iters=iters)
    # TPU estimate: Gram matmul dominates; code + score reads are tiny.
    tpu_est_us = max(2.0 * m * m * bits / PEAK_FLOPS,
                     2 * m * words * 4 / HBM_BW) * 1e6
    return {"m": m, "bits": bits, "n": n,
            "unfused_us": round(unfused_us, 1),
            "fused_us": round(fused_us, 1),
            "speedup": round(unfused_us / fused_us, 2),
            "tpu_est_us": round(tpu_est_us, 3)}


def main(argv=None, log=print):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes / single iteration (CI budget)")
    ap.add_argument("--json-out", default=BENCH_JSON,
                    help="selection-baseline path ('' disables)")
    args = ap.parse_args(argv)
    iters = 1 if args.smoke else 3

    rows = []
    lsh_sizes = (1 << 16,) if args.smoke else (1 << 18, 1 << 20, 1 << 22)
    for nparams in lsh_sizes:
        us, est = bench_lsh(nparams, iters=iters)
        rows.append((f"lsh_project_{nparams}", us, est))
    bm, bp = (8, 1 << 13) if args.smoke else (64, 1 << 16)
    o_us, _, est = bench_batched_lsh(bm, bp, iters=iters)
    rows.append((f"lsh_batched_{bm}x{bp}", o_us, est))
    for m in ((64,) if args.smoke else (64, 256)):
        us, est = bench_hamming(m, iters=iters)
        rows.append((f"hamming_{m}x{m}", us, est))

    sel_ms = (64,) if args.smoke else (256, 512, 1024)
    sel_rows = [bench_fused_selection(m, iters=iters) for m in sel_ms]
    for r in sel_rows:
        rows.append((f"select_unfused_{r['m']}", r["unfused_us"],
                     r["tpu_est_us"]))
        rows.append((f"select_fused_{r['m']}", r["fused_us"],
                     r["tpu_est_us"]))
        log(f"# fused selection speedup @ M={r['m']}: {r['speedup']}x")
    for name, us, est in rows:
        log(f"{name},{us:.1f},{est:.3f}")

    if args.json_out and not args.smoke:
        best = max(sel_rows, key=lambda r: r["speedup"])
        with open(args.json_out, "w") as f:
            json.dump({"selection": sel_rows,
                       "measured_speedup": best["speedup"],
                       "at_m": best["m"],
                       "note": "CPU jnp wall times (fused oracle vs "
                               "unfused composition). lax.top_k is a "
                               "shared fixed cost that compresses the "
                               "end-to-end ratio at small M; the fused "
                               "win is in the distance/weight stages. "
                               "tpu_est_us is the analytic v5e bound "
                               "for the fused kernel"},
                      f, indent=1)
        log(f"# wrote {args.json_out}")
    return rows


if __name__ == "__main__":
    main()
