"""Kernel micro-benchmarks: LSH projection (single + batched), Hamming,
the fused selection path, and the fused all-in-one exchange
(interpret-mode wall time is NOT TPU time — the derived column is the
analytic TPU-v5e estimate from FLOP/byte counts; see EXPERIMENTS.md).

The selection and exchange rows time the two *jnp* implementations the
round can actually run on CPU: the fused oracles (the bit-exact CPU
twins of the Pallas kernels, DESIGN.md §4 / §7) against the unfused
compositions. For selection that is hamming -> normalized_distance ->
selection_weights -> top_k (the fused win is the distance/weight
stages; lax.top_k is a shared fixed cost). For exchange it is the three
scattered round calls — vmapped cross_entropy, lsh_verification_mask,
aggregate_neighbor_outputs — whose three separate log-softmax passes
over the same (M, N, R, C) logit tensor the fused form collapses into
one. `python benchmarks/kernel_micro.py` writes the machine-readable
baselines to benchmarks/BENCH_selection.json and
benchmarks/BENCH_exchange.json.

The rounds row benches the round-program engine (DESIGN.md §8): the
per-round Python loop vs scan-driven reselection segments at
reselect_every in {1, 4} on a tiny MLP federation — the schedule win
is (a) G-1 of every G rounds skipping re-code/re-selection/announce
and (b) one host dispatch per period instead of per round. Always
writes benchmarks/BENCH_rounds.json (smoke included — CI tracks it).

The adversary row prices the first-class threat-model API (DESIGN.md
§9): the same G=4 segment clean vs instrumented with the §4.8 poison
ThreatModel (lax.cond-gated re-init + in-graph telemetry) — the
overhead an adversarial run pays for compiling its attacks into the
segment instead of mutating state on the host. Always writes
benchmarks/BENCH_adversary.json.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core import distill, lsh, neighbor, verify
from repro.kernels import ops, ref
from repro.kernels.lsh_projection import CHUNK, lsh_project_sums_batched
from repro.kernels.selection import fused_select

PEAK_FLOPS = 197e12
HBM_BW = 819e9
BENCH_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "BENCH_selection.json")
BENCH_EXCHANGE_JSON = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "BENCH_exchange.json")
BENCH_ROUNDS_JSON = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "BENCH_rounds.json")
BENCH_ADVERSARY_JSON = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "BENCH_adversary.json")


def _time(fn, *args, iters=3):
    """Best-of-iters wall time in us (min filters scheduler noise,
    which at sub-ms scales otherwise dominates the comparison)."""
    fn(*args)  # compile + warm
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.time()
        jax.block_until_ready(fn(*args))
        best = min(best, time.time() - t0)
    return best * 1e6


def bench_lsh(n_params=1 << 20, bits=256, iters=3):
    x = jax.random.normal(jax.random.PRNGKey(0), (n_params,))
    us = _time(jax.jit(lambda v: ref.lsh_project_sums_ref(v, 3, bits=bits)),
               x, iters=iters)
    flops = 2.0 * n_params * bits
    tpu_est_us = max(flops / PEAK_FLOPS, n_params * 4 / HBM_BW) * 1e6
    return us, tpu_est_us


def bench_batched_lsh(m=64, n_params=1 << 16, bits=256, iters=3,
                      with_kernel=False):
    """Batched (M, P) projection: per-client-oracle vmap (the old
    stacked path) vs the batched kernel's analytic TPU estimate. The
    interpret-mode kernel wall time is reported only when requested
    (it measures the interpreter, not the kernel)."""
    x = jax.random.normal(jax.random.PRNGKey(1), (m, n_params))
    oracle_us = _time(
        jax.jit(lambda v: ops.batched_lsh_codes(v, 3, bits=bits,
                                                use_kernel=False)),
        x, iters=iters)
    kernel_us = None
    if with_kernel:
        kernel_us = _time(
            jax.jit(lambda v: ops.batched_lsh_codes(v, 3, bits=bits,
                                                    use_kernel=True)),
            x, iters=iters)
    flops = 2.0 * m * n_params * bits
    tpu_est_us = max(flops / PEAK_FLOPS, m * n_params * 4 / HBM_BW) * 1e6
    return oracle_us, kernel_us, tpu_est_us


def bench_hamming(m=128, words=8, iters=3):
    bits = jax.random.bernoulli(jax.random.PRNGKey(1), 0.5, (m, words * 32))
    codes = ops.pack_bits(jnp.where(bits, 1.0, -1.0))
    fn = jax.jit(lambda c: ops.hamming_matrix(c, use_kernel=False))
    us = _time(fn, codes, iters=iters)
    tpu_est_us = max(m * m * words * 8 / (PEAK_FLOPS / 16),
                     m * words * 4 / HBM_BW) * 1e6
    return us, tpu_est_us


def _unfused_select(codes, scores, bits, gamma, n):
    d = lsh.distance_matrix(codes, use_kernel=False)
    d_norm = lsh.normalized_distance(d, bits)
    w = neighbor.selection_weights(scores, d_norm, gamma)
    return neighbor.select_neighbors(w, n)


def bench_fused_selection(m=256, bits=256, n=16, gamma=1.0, iters=10):
    """Fused oracle vs unfused composition at federation scale M."""
    words = bits // 32
    key = jax.random.PRNGKey(m)
    raw = jax.random.bernoulli(key, 0.5, (m, bits))
    codes = ops.pack_bits(jnp.where(raw, 1.0, -1.0))
    scores = jax.random.uniform(jax.random.fold_in(key, 1), (m,))

    unfused_us = _time(
        jax.jit(lambda c, s: _unfused_select(c, s, bits, gamma, n)),
        codes, scores, iters=iters)
    fused_us = _time(
        jax.jit(lambda c, s: ref.fused_select_ref(
            c, s, bits=bits, gamma=gamma, num_neighbors=n)),
        codes, scores, iters=iters)
    # TPU estimate: Gram matmul dominates; code + score reads are tiny.
    tpu_est_us = max(2.0 * m * m * bits / PEAK_FLOPS,
                     2 * m * words * 4 / HBM_BW) * 1e6
    return {"m": m, "bits": bits, "n": n,
            "unfused_us": round(unfused_us, 1),
            "fused_us": round(fused_us, 1),
            "speedup": round(unfused_us / fused_us, 2),
            "tpu_est_us": round(tpu_est_us, 3)}


def _unfused_exchange(own, nb, y, sel):
    l_ij = jax.vmap(lambda yl, yy: jax.vmap(
        lambda l: distill.cross_entropy(l, yy))(yl))(nb, y)
    valid = jax.vmap(verify.lsh_verification_mask)(own, nb, sel)
    target, has = jax.vmap(distill.aggregate_neighbor_outputs)(nb, valid)
    return l_ij, valid, target, has


def bench_fused_exchange(m=128, n=8, r=32, c=10, iters=10):
    """Fused exchange oracle vs the three scattered round calls."""
    key = jax.random.PRNGKey(m + n)
    own = jax.random.normal(key, (m, r, c)) * 3
    nb = jax.random.normal(jax.random.fold_in(key, 1), (m, n, r, c)) * 3
    y = jax.random.randint(jax.random.fold_in(key, 2), (m, r), 0, c)
    sel = jax.random.bernoulli(jax.random.fold_in(key, 3), 0.8, (m, n))

    unfused_us = _time(jax.jit(_unfused_exchange), own, nb, y, sel,
                       iters=iters)
    fused_us = _time(jax.jit(ref.all_in_one_exchange_ref), own, nb, y, sel,
                     iters=iters)
    # TPU estimate: the neighbor-logit tensor dominates both terms —
    # ~1 fused read (vs 3 unfused) at ~10 VPU flops/element for the
    # shared log-softmax + CE/KL/mean derivations.
    elems = m * n * r * c
    tpu_est_us = max(10.0 * elems / PEAK_FLOPS, elems * 4 / HBM_BW) * 1e6
    return {"m": m, "n": n, "r": r, "c": c,
            "unfused_us": round(unfused_us, 1),
            "fused_us": round(fused_us, 1),
            "speedup": round(unfused_us / fused_us, 2),
            "tpu_est_us": round(tpu_est_us, 3)}


def _tiny_mlp_federation(m):
    """Shared tiny-MLP WPFed setup (16-dim, 3 classes) for the rounds
    and adversary rows."""
    import functools
    from repro.configs.paper_models import ClientModelConfig, FedConfig
    from repro.core import init_state, wpfed_program
    from repro.models import apply_client_model, init_client_model
    from repro.optim import adam

    mcfg = ClientModelConfig("bench-mlp", "mlp", (16,), 3, hidden=(32,))
    fed = FedConfig(num_clients=m, num_neighbors=3, top_k=2, local_steps=2,
                    local_batch=16, lsh_bits=128, lr=1e-2)
    key = jax.random.PRNGKey(0)
    data = {
        "x_train": jax.random.normal(key, (m, 32, 16)),
        "y_train": jax.random.randint(jax.random.fold_in(key, 1),
                                      (m, 32), 0, 3),
        "x_ref": jax.random.normal(jax.random.fold_in(key, 2), (m, 8, 16)),
        "y_ref": jax.random.randint(jax.random.fold_in(key, 3),
                                    (m, 8), 0, 3),
    }
    apply_fn = functools.partial(apply_client_model, mcfg)
    init_fn = lambda k: init_client_model(mcfg, k)
    opt = adam(fed.lr)
    state = init_state(apply_fn, init_fn, opt, fed, key)
    return {"state": state, "data": data, "init_fn": init_fn,
            "program": wpfed_program(apply_fn, opt, fed)}


def bench_rounds(m=8, rounds=4, iters=3):
    """Round-program engine vs the per-round Python loop on a tiny MLP
    federation (16-dim, 3 classes): wall time per round for (a) the
    classic jit(round_fn) Python loop, (b) engine segments at G=1
    (sync — one segment per round), (c) G=4 (one global round + 3
    gossip epochs in one compiled scan segment)."""
    from repro.core import make_segment_fn
    from repro.core.rounds import program_round

    f = _tiny_mlp_federation(m)
    program, state, data = f["program"], f["state"], f["data"]

    loop_fn = jax.jit(program_round(program))
    seg1 = jax.jit(make_segment_fn(program, 1))
    seg4 = jax.jit(make_segment_fn(program, 4))

    def run_loop(st):
        for _ in range(rounds):
            st, _m = loop_fn(st, data)
        return st

    def run_g1(st):
        for _ in range(rounds):
            st, _m = seg1(st, data)
        return st

    g4_rounds = (rounds // 4) * 4
    assert g4_rounds > 0, "bench_rounds needs rounds >= 4"

    def run_g4(st):
        for _ in range(rounds // 4):
            st, _m = seg4(st, data)
        return st

    loop_us = _time(run_loop, state, iters=iters) / rounds
    g1_us = _time(run_g1, state, iters=iters) / rounds
    g4_us = _time(run_g4, state, iters=iters) / g4_rounds
    return {"m": m, "rounds": rounds,
            "loop_us_per_round": round(loop_us, 1),
            "g1_us_per_round": round(g1_us, 1),
            "g4_us_per_round": round(g4_us, 1),
            "g4_speedup_vs_loop": round(loop_us / g4_us, 2)}


def bench_adversary(m=8, iters=3):
    """Instrumented-vs-clean segment cost (DESIGN.md §9): one G=4 WPFed
    reselection period, clean vs wrapped by `instrument_program` with
    the §4.8 poison ThreatModel (25% attackers, lax.cond-gated re-init
    active on alternating rounds, in-graph telemetry included) — the
    price of compiling the adversary into the segment."""
    from repro.core import instrument_program, make_segment_fn, resolve_threat

    f = _tiny_mlp_federation(m)
    tm = resolve_threat("poison", num_clients=m, attacker_frac=0.25,
                        init_fn=f["init_fn"], key=jax.random.PRNGKey(7),
                        start_round=1, every=2)
    seg_clean = jax.jit(make_segment_fn(f["program"], 4))
    seg_inst = jax.jit(make_segment_fn(
        instrument_program(f["program"], tm), 4))
    clean_us = _time(seg_clean, f["state"], f["data"], iters=iters) / 4
    inst_us = _time(seg_inst, f["state"], f["data"], iters=iters) / 4
    return {"m": m, "reselect_every": 4,
            "clean_us_per_round": round(clean_us, 1),
            "instrumented_us_per_round": round(inst_us, 1),
            "overhead": round(inst_us / clean_us, 3)}


def main(argv=None, log=print):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes / single iteration (CI budget)")
    ap.add_argument("--json-out", default=BENCH_JSON,
                    help="selection-baseline path ('' disables)")
    ap.add_argument("--exchange-json-out", default=BENCH_EXCHANGE_JSON,
                    help="exchange-baseline path ('' disables)")
    ap.add_argument("--rounds-json-out", default=BENCH_ROUNDS_JSON,
                    help="rounds-baseline path ('' disables); written in "
                         "smoke mode too — CI tracks the engine")
    ap.add_argument("--adversary-json-out", default=BENCH_ADVERSARY_JSON,
                    help="adversary-baseline path ('' disables); written "
                         "in smoke mode too — CI tracks the threat API")
    args = ap.parse_args(argv)
    iters = 1 if args.smoke else 3

    rows = []
    lsh_sizes = (1 << 16,) if args.smoke else (1 << 18, 1 << 20, 1 << 22)
    for nparams in lsh_sizes:
        us, est = bench_lsh(nparams, iters=iters)
        rows.append((f"lsh_project_{nparams}", us, est))
    bm, bp = (8, 1 << 13) if args.smoke else (64, 1 << 16)
    o_us, _, est = bench_batched_lsh(bm, bp, iters=iters)
    rows.append((f"lsh_batched_{bm}x{bp}", o_us, est))
    for m in ((64,) if args.smoke else (64, 256)):
        us, est = bench_hamming(m, iters=iters)
        rows.append((f"hamming_{m}x{m}", us, est))

    sel_ms = (64,) if args.smoke else (256, 512, 1024)
    sel_rows = [bench_fused_selection(m, iters=iters) for m in sel_ms]
    for r in sel_rows:
        rows.append((f"select_unfused_{r['m']}", r["unfused_us"],
                     r["tpu_est_us"]))
        rows.append((f"select_fused_{r['m']}", r["fused_us"],
                     r["tpu_est_us"]))
        log(f"# fused selection speedup @ M={r['m']}: {r['speedup']}x")

    exc_shapes = ((32, 4, 8, 10),) if args.smoke else \
        ((64, 8, 32, 10), (128, 8, 32, 10), (256, 16, 32, 10))
    exc_rows = [bench_fused_exchange(m, n, r, c, iters=iters)
                for m, n, r, c in exc_shapes]
    for r in exc_rows:
        tag = f"{r['m']}x{r['n']}x{r['r']}x{r['c']}"
        rows.append((f"exchange_unfused_{tag}", r["unfused_us"],
                     r["tpu_est_us"]))
        rows.append((f"exchange_fused_{tag}", r["fused_us"],
                     r["tpu_est_us"]))
        log(f"# fused exchange speedup @ {tag}: {r['speedup']}x")

    rounds_row = bench_rounds(m=4 if args.smoke else 8,
                              rounds=4 if args.smoke else 8, iters=iters)
    for k in ("loop", "g1", "g4"):
        rows.append((f"rounds_{k}_m{rounds_row['m']}",
                     rounds_row[f"{k}_us_per_round"], 0.0))
    log(f"# rounds engine G=4 speedup vs loop: "
        f"{rounds_row['g4_speedup_vs_loop']}x")
    if args.rounds_json_out:
        with open(args.rounds_json_out, "w") as f:
            json.dump(
                {"rounds": rounds_row, "smoke": bool(args.smoke),
                 "note": "CPU wall us per federation round: per-round "
                         "jit Python loop vs engine segments at "
                         "reselect_every 1 and 4. Scheduler noise at "
                         "the ms scale is large on this container "
                         "(ratios move ~30%+ run to run; loop-vs-g1 "
                         "differences are pure noise). The durable "
                         "claim is structural: at G=4, 3 of 4 rounds "
                         "skip LSH re-code/top-N re-selection/announce "
                         "and run inside one lax.scan segment with one "
                         "host dispatch per period (DESIGN.md §8)"},
                f, indent=1)
        log(f"# wrote {args.rounds_json_out}")

    adv_row = bench_adversary(m=4 if args.smoke else 8, iters=iters)
    rows.append((f"segment_clean_m{adv_row['m']}",
                 adv_row["clean_us_per_round"], 0.0))
    rows.append((f"segment_instrumented_m{adv_row['m']}",
                 adv_row["instrumented_us_per_round"], 0.0))
    log(f"# adversary instrumentation overhead @ G=4: "
        f"{adv_row['overhead']}x")
    if args.adversary_json_out:
        with open(args.adversary_json_out, "w") as f:
            json.dump(
                {"adversary": adv_row, "smoke": bool(args.smoke),
                 "note": "CPU wall us per federation round for one G=4 "
                         "WPFed segment, clean vs instrumented with the "
                         "§4.8 poison ThreatModel (core.adversary): "
                         "lax.cond-gated attacker re-init on alternating "
                         "rounds + in-graph admission/rank telemetry. "
                         "ms-scale scheduler noise on this container is "
                         "~30%+; the durable claim is structural — the "
                         "adversarial run compiles into the same scanned "
                         "segment as a clean one instead of paying a "
                         "per-round host loop (DESIGN.md §9)"},
                f, indent=1)
        log(f"# wrote {args.adversary_json_out}")

    for name, us, est in rows:
        log(f"{name},{us:.1f},{est:.3f}")

    if args.json_out and not args.smoke:
        best = max(sel_rows, key=lambda r: r["speedup"])
        with open(args.json_out, "w") as f:
            json.dump({"selection": sel_rows,
                       "measured_speedup": best["speedup"],
                       "at_m": best["m"],
                       "note": "CPU jnp wall times (fused oracle vs "
                               "unfused composition). lax.top_k is a "
                               "shared fixed cost that compresses the "
                               "end-to-end ratio at small M; the fused "
                               "win is in the distance/weight stages. "
                               "tpu_est_us is the analytic v5e bound "
                               "for the fused kernel"},
                      f, indent=1)
        log(f"# wrote {args.json_out}")
    if args.exchange_json_out and not args.smoke:
        best = max(exc_rows, key=lambda r: r["speedup"])
        with open(args.exchange_json_out, "w") as f:
            json.dump({"exchange": exc_rows,
                       "measured_speedup": best["speedup"],
                       "at": {k: best[k] for k in ("m", "n", "r", "c")},
                       "note": "CPU jnp wall times (fused exchange "
                               "oracle vs the three scattered round "
                               "calls). The fused win is the single "
                               "shared log-softmax pass over the "
                               "(M, N, R, C) neighbor logits vs three. "
                               "tpu_est_us is the analytic v5e bound "
                               "for the fused kernel"},
                      f, indent=1)
        log(f"# wrote {args.exchange_json_out}")
    return rows


if __name__ == "__main__":
    main()
