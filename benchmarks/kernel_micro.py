"""Kernel micro-benchmarks: LSH projection (single + batched), Hamming,
the fused selection path, and the fused all-in-one exchange
(interpret-mode wall time is NOT TPU time — the derived column is the
analytic TPU-v5e estimate from FLOP/byte counts; see EXPERIMENTS.md).

The selection and exchange rows time the two *jnp* implementations the
round can actually run on CPU: the fused oracles (the bit-exact CPU
twins of the Pallas kernels, DESIGN.md §4 / §7) against the unfused
compositions. For selection that is hamming -> normalized_distance ->
selection_weights -> top_k (the fused win is the distance/weight
stages; lax.top_k is a shared fixed cost). For exchange it is the three
scattered round calls — vmapped cross_entropy, lsh_verification_mask,
aggregate_neighbor_outputs — whose three separate log-softmax passes
over the same (M, N, R, C) logit tensor the fused form collapses into
one. `python benchmarks/kernel_micro.py` writes the machine-readable
baselines to benchmarks/BENCH_selection.json and
benchmarks/BENCH_exchange.json.

Timing discipline: every number is a MEDIAN over repeated reps after
discarded warmups, and the per-rep spread is recorded next to it in
the emitted JSONs (`Timing`) — single-shot wall times on this
container move ~30% run to run, which made the old best-of-3 numbers
unusable as baselines.

The §10 scale sweeps (`tiled_scale` in both JSONs) price the
VMEM-tiled kernels: tiled-vs-oneshot at the shapes both can hold
(selection bit-exact, asserted in the bench itself) plus the analytic
per-program VMEM table out to M=65536 / C=32768 — the shapes where
`auto` resolution (core.backends.resolve_tiling) hands the round to
the tiled path because the one-shot working set exceeds the budget.

The rounds row benches the round-program engine (DESIGN.md §8): the
per-round Python loop vs scan-driven reselection segments at
reselect_every in {1, 4} on a tiny MLP federation — the schedule win
is (a) G-1 of every G rounds skipping re-code/re-selection/announce
and (b) one host dispatch per period instead of per round. Always
writes benchmarks/BENCH_rounds.json (smoke included — CI tracks it).

The adversary row prices the first-class threat-model API (DESIGN.md
§9): the same G=4 segment clean vs instrumented with the §4.8 poison
ThreatModel (lax.cond-gated re-init + in-graph telemetry) — the
overhead an adversarial run pays for compiling its attacks into the
segment instead of mutating state on the host. Always writes
benchmarks/BENCH_adversary.json.
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import ann, backends, distill, lsh, neighbor, verify
from repro.kernels import ops, ref
from repro.kernels.lsh_projection import CHUNK, lsh_project_sums_batched
from repro.kernels.selection import (fused_select, fused_select_ann,
                                     fused_select_tiled)

PEAK_FLOPS = 197e12
HBM_BW = 819e9
BENCH_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "BENCH_selection.json")
BENCH_EXCHANGE_JSON = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "BENCH_exchange.json")
BENCH_ROUNDS_JSON = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "BENCH_rounds.json")
BENCH_ADVERSARY_JSON = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "BENCH_adversary.json")


class Timing(NamedTuple):
    """Median-of-k wall time plus the per-rep spread the JSONs record
    (single-shot numbers on this container move ~30% run to run — see
    the BENCH_rounds/BENCH_adversary notes — so a point estimate
    without its spread is unusable as a baseline)."""
    us: float           # median over reps
    best_us: float
    worst_us: float
    reps: int
    spread_pct: float   # (worst - best) / median * 100


def _time(fn, *args, iters=5, warmup=2):
    """Median-of-iters wall time after `warmup` discarded reps (the
    first rep pays compilation; the median filters scheduler noise
    without the min's bias toward lucky outliers)."""
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(max(iters, 1)):
        t0 = time.time()
        jax.block_until_ready(fn(*args))
        samples.append((time.time() - t0) * 1e6)
    med = statistics.median(samples)
    return Timing(med, min(samples), max(samples), len(samples),
                  100.0 * (max(samples) - min(samples)) / max(med, 1e-9))


def bench_lsh(n_params=1 << 20, bits=256, iters=3):
    x = jax.random.normal(jax.random.PRNGKey(0), (n_params,))
    t = _time(jax.jit(lambda v: ref.lsh_project_sums_ref(v, 3, bits=bits)),
              x, iters=iters)
    flops = 2.0 * n_params * bits
    tpu_est_us = max(flops / PEAK_FLOPS, n_params * 4 / HBM_BW) * 1e6
    return t, tpu_est_us


def bench_batched_lsh(m=64, n_params=1 << 16, bits=256, iters=3,
                      with_kernel=False):
    """Batched (M, P) projection: per-client-oracle vmap (the old
    stacked path) vs the batched kernel's analytic TPU estimate. The
    interpret-mode kernel wall time is reported only when requested
    (it measures the interpreter, not the kernel)."""
    x = jax.random.normal(jax.random.PRNGKey(1), (m, n_params))
    oracle_t = _time(
        jax.jit(lambda v: ops.batched_lsh_codes(v, 3, bits=bits,
                                                use_kernel=False)),
        x, iters=iters)
    kernel_t = None
    if with_kernel:
        kernel_t = _time(
            jax.jit(lambda v: ops.batched_lsh_codes(v, 3, bits=bits,
                                                    use_kernel=True)),
            x, iters=iters)
    flops = 2.0 * m * n_params * bits
    tpu_est_us = max(flops / PEAK_FLOPS, m * n_params * 4 / HBM_BW) * 1e6
    return oracle_t, kernel_t, tpu_est_us


def bench_hamming(m=128, words=8, iters=3):
    bits = jax.random.bernoulli(jax.random.PRNGKey(1), 0.5, (m, words * 32))
    codes = ops.pack_bits(jnp.where(bits, 1.0, -1.0))
    fn = jax.jit(lambda c: ops.hamming_matrix(c, use_kernel=False))
    t = _time(fn, codes, iters=iters)
    tpu_est_us = max(m * m * words * 8 / (PEAK_FLOPS / 16),
                     m * words * 4 / HBM_BW) * 1e6
    return t, tpu_est_us


def _unfused_select(codes, scores, bits, gamma, n):
    d = lsh.distance_matrix(codes, use_kernel=False)
    d_norm = lsh.normalized_distance(d, bits)
    w = neighbor.selection_weights(scores, d_norm, gamma)
    return neighbor.select_neighbors(w, n)


def bench_fused_selection(m=256, bits=256, n=16, gamma=1.0, iters=10):
    """Fused oracle vs unfused composition at federation scale M."""
    words = bits // 32
    key = jax.random.PRNGKey(m)
    raw = jax.random.bernoulli(key, 0.5, (m, bits))
    codes = ops.pack_bits(jnp.where(raw, 1.0, -1.0))
    scores = jax.random.uniform(jax.random.fold_in(key, 1), (m,))

    unfused_t = _time(
        jax.jit(lambda c, s: _unfused_select(c, s, bits, gamma, n)),
        codes, scores, iters=iters)
    fused_t = _time(
        jax.jit(lambda c, s: ref.fused_select_ref(
            c, s, bits=bits, gamma=gamma, num_neighbors=n)),
        codes, scores, iters=iters)
    # TPU estimate: Gram matmul dominates; code + score reads are tiny.
    tpu_est_us = max(2.0 * m * m * bits / PEAK_FLOPS,
                     2 * m * words * 4 / HBM_BW) * 1e6
    return {"m": m, "bits": bits, "n": n,
            "unfused_us": round(unfused_t.us, 1),
            "fused_us": round(fused_t.us, 1),
            "unfused_spread_pct": round(unfused_t.spread_pct, 1),
            "fused_spread_pct": round(fused_t.spread_pct, 1),
            "reps": fused_t.reps,
            "speedup": round(unfused_t.us / fused_t.us, 2),
            "tpu_est_us": round(tpu_est_us, 3)}


def _unfused_exchange(own, nb, y, sel):
    l_ij = jax.vmap(lambda yl, yy: jax.vmap(
        lambda l: distill.cross_entropy(l, yy))(yl))(nb, y)
    valid = jax.vmap(verify.lsh_verification_mask)(own, nb, sel)
    target, has = jax.vmap(distill.aggregate_neighbor_outputs)(nb, valid)
    return l_ij, valid, target, has


def bench_fused_exchange(m=128, n=8, r=32, c=10, iters=10):
    """Fused exchange oracle vs the three scattered round calls."""
    key = jax.random.PRNGKey(m + n)
    own = jax.random.normal(key, (m, r, c)) * 3
    nb = jax.random.normal(jax.random.fold_in(key, 1), (m, n, r, c)) * 3
    y = jax.random.randint(jax.random.fold_in(key, 2), (m, r), 0, c)
    sel = jax.random.bernoulli(jax.random.fold_in(key, 3), 0.8, (m, n))

    unfused_t = _time(jax.jit(_unfused_exchange), own, nb, y, sel,
                      iters=iters)
    fused_t = _time(jax.jit(ref.all_in_one_exchange_ref), own, nb, y, sel,
                    iters=iters)
    # TPU estimate: the neighbor-logit tensor dominates both terms —
    # ~1 fused read (vs 3 unfused) at ~10 VPU flops/element for the
    # shared log-softmax + CE/KL/mean derivations.
    elems = m * n * r * c
    tpu_est_us = max(10.0 * elems / PEAK_FLOPS, elems * 4 / HBM_BW) * 1e6
    return {"m": m, "n": n, "r": r, "c": c,
            "unfused_us": round(unfused_t.us, 1),
            "fused_us": round(fused_t.us, 1),
            "unfused_spread_pct": round(unfused_t.spread_pct, 1),
            "fused_spread_pct": round(fused_t.spread_pct, 1),
            "reps": fused_t.reps,
            "speedup": round(unfused_t.us / fused_t.us, 2),
            "tpu_est_us": round(tpu_est_us, 3)}


def bench_tiled_selection(ms, bits=256, n=16, iters=3):
    """One-shot vs column-tiled selection kernels, interpret mode, at
    shapes BOTH can hold (DESIGN.md §10): wall time is interpreter
    time, not TPU time — the durable claim is that ids/weights are
    bit-identical (asserted here) while VMEM per program drops from
    O(M) to O(tile). Pair with `selection_vmem_sweep` for the shapes
    only the tiled kernel can reach."""
    words = bits // 32
    rows = []
    for m in ms:
        key = jax.random.PRNGKey(m)
        raw = jax.random.bernoulli(key, 0.5, (m, bits))
        codes = ops.pack_bits(jnp.where(raw, 1.0, -1.0))
        scores = jax.random.uniform(jax.random.fold_in(key, 1), (m,))
        kw = dict(bits=bits, gamma=1.0, num_neighbors=min(n, m - 1))
        one_t = _time(lambda c, s: fused_select(c, s, **kw),
                      codes, scores, iters=iters)
        til_t = _time(lambda c, s: fused_select_tiled(c, s, **kw),
                      codes, scores, iters=iters)
        ids_o, w_o = fused_select(codes, scores, **kw)
        ids_t, w_t = fused_select_tiled(codes, scores, **kw)
        assert bool(jnp.all(ids_o == ids_t)) and bool(jnp.all(w_o == w_t))
        rows.append({"m": m, "bits": bits,
                     "oneshot_interpret_us": round(one_t.us, 1),
                     "tiled_interpret_us": round(til_t.us, 1),
                     "oneshot_spread_pct": round(one_t.spread_pct, 1),
                     "tiled_spread_pct": round(til_t.spread_pct, 1),
                     "reps": til_t.reps, "bit_exact": True,
                     "tiled_vs_oneshot":
                         round(one_t.us / til_t.us, 2)})
    return rows


def selection_vmem_sweep(ms=(256, 1024, 4096, 16384, 65536), bits=256):
    """Analytic per-program VMEM across the client sweep: where the
    one-shot kernel blows the budget, `auto` resolves to tiled."""
    return [{"m": m,
             "oneshot_vmem_bytes": backends.selection_vmem_bytes(m, bits),
             "tiled_vmem_bytes": backends.selection_tiled_vmem_bytes(bits),
             "auto": backends.resolve_tiling(
                 "auto", backends.selection_vmem_bytes(m, bits))}
            for m in ms]


def bench_tiled_exchange(cs, m=8, n=8, r=16, iters=3):
    """One-shot oracle vs streaming twin (both CPU jnp, both jitted —
    the twin runs inside the jitted round on the oracle+tiled path, so
    eager dispatch must not pollute the comparison; its tile loop
    compiles during warmup) across the class-count sweep. Agreement is
    tolerance-bounded per the §10 contract (asserted on the §3.5
    mask). Pair with `exchange_vmem_sweep` for the kernel-side VMEM
    story."""
    rows = []
    for c in cs:
        key = jax.random.PRNGKey(c)
        own = jax.random.normal(key, (m, r, c)) * 3
        nb = jax.random.normal(jax.random.fold_in(key, 1),
                               (m, n, r, c)) * 3
        y = jax.random.randint(jax.random.fold_in(key, 2), (m, r), 0, c)
        sel = jax.random.bernoulli(jax.random.fold_in(key, 3), 0.8, (m, n))
        one_t = _time(jax.jit(ref.all_in_one_exchange_ref), own, nb, y, sel,
                      iters=iters)
        twin_t = _time(jax.jit(ref.streamed_exchange_ref), own, nb, y, sel,
                       iters=iters)
        out_o = ref.all_in_one_exchange_ref(own, nb, y, sel)
        out_t = ref.streamed_exchange_ref(own, nb, y, sel)
        assert bool(jnp.all(out_o[1] == out_t[1]))     # §3.5 mask
        rows.append({"m": m, "n": n, "r": r, "c": c,
                     "oneshot_oracle_us": round(one_t.us, 1),
                     "streamed_twin_us": round(twin_t.us, 1),
                     "oneshot_spread_pct": round(one_t.spread_pct, 1),
                     "streamed_spread_pct": round(twin_t.spread_pct, 1),
                     "reps": twin_t.reps,
                     "mask_equal": True,
                     "streamed_vs_oneshot":
                         round(one_t.us / twin_t.us, 2)})
    return rows


def exchange_vmem_sweep(cs=(1024, 4096, 32768), n=16, r=64):
    """Analytic per-program VMEM across the vocab sweep (the §10
    motivating shape: N=16, R=64 holds ~17 MB one-shot at C=1024)."""
    return [{"n": n, "r": r, "c": c,
             "oneshot_vmem_bytes": backends.exchange_vmem_bytes(n, r, c),
             "tiled_vmem_bytes": backends.exchange_tiled_vmem_bytes(n),
             "auto": backends.resolve_tiling(
                 "auto", backends.exchange_vmem_bytes(n, r, c))}
            for c in cs]


def _clustered_codes(m, bits, n_clusters, flip=0.02, seed=0):
    """Cluster centers + per-client bit flips — the structured regime
    the §11 bucket index is built for (a converging federation:
    similar models agree on ~98% of code bits)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    centers = jax.random.bernoulli(k1, 0.5, (n_clusters, bits))
    assign = jax.random.randint(k2, (m,), 0, n_clusters)
    flips = jax.random.bernoulli(k3, flip, (m, bits))
    raw = jnp.logical_xor(centers[assign], flips)
    return ops.pack_bits(jnp.where(raw, 1.0, -1.0))


def _ann_recall(exact_ids, ann_ids):
    import numpy as np
    e, a = np.asarray(exact_ids), np.asarray(ann_ids)
    hits = sum(len(set(e[i]) & set(a[i])) for i in range(e.shape[0]))
    return hits / float(e.size)


def _ann_prefix_for(m):
    """Sweep discipline: bucket count scales with M at B = M/32 —
    matching the sweep's cluster scale (M/32 clusters), so buckets
    absorb whole clusters without overflow (at B = M/16 cluster-pair
    collisions overflow the cap and recall drops below the bar). The
    per-bucket cap, and with it K, stays near-constant across the
    sweep and the exact/ann FLOP ratio grows like M/K."""
    return max(4, m.bit_length() - 1 - 5)


def bench_ann_selection(ms=(512, 1024, 2048, 4096), bits=256, n=12,
                        gamma=1.0, iters=3):
    """The §11 sub-quadratic selection story, measured end to end on
    clustered codes with concentrated ranking scores (the distance-
    dominated Eq. 8 regime; score-dispersed regimes are intrinsically
    non-local — see DESIGN.md §11 — and the probe-curve section
    records one so the limitation is a number, not a footnote).

    Per sweep point: CPU wall time of the exact fused oracle vs the
    jitted ann twin (candidate generation INCLUDED — the bucketing is
    part of the price), recall@N vs the exact oracle, candidate-set
    size K, and per-bucket occupancy stats so the speedup is
    attributable to a measured candidate count. `crossover_m` is the
    smallest sweep M where the ann path wins on wall time."""
    rows = []
    for m in ms:
        pb = _ann_prefix_for(m)
        codes = _clustered_codes(m, bits, m // 32, seed=m)
        scores = 0.75 + 0.25 * jax.random.uniform(
            jax.random.PRNGKey(m + 1), (m,))
        kw = dict(bits=bits, gamma=gamma, num_neighbors=n)

        exact_fn = jax.jit(lambda c, s: ref.fused_select_ref(c, s, **kw))

        def ann_fn(c, s, _pb=pb):
            cand = ann.ann_candidates(c, s, seed=3, prefix_bits=_pb,
                                      probes=_pb, num_neighbors=n)
            return ref.ann_select_ref(c, s, cand.ids, **kw)

        ann_jit = jax.jit(ann_fn)
        exact_t = _time(exact_fn, codes, scores, iters=iters)
        ann_t = _time(ann_jit, codes, scores, iters=iters)
        ids_e, _ = exact_fn(codes, scores)
        ids_a, _ = ann_jit(codes, scores)
        cand = ann.ann_candidates(codes, scores, seed=3, prefix_bits=pb,
                                  probes=pb, num_neighbors=n)
        occ = ann.occupancy_stats(cand)
        k = occ["k"]
        rows.append({
            "m": m, "bits": bits, "n": n, "prefix_bits": pb, "probes": pb,
            "exact_us": round(exact_t.us, 1),
            "ann_us": round(ann_t.us, 1),
            "exact_spread_pct": round(exact_t.spread_pct, 1),
            "ann_spread_pct": round(ann_t.spread_pct, 1),
            "reps": ann_t.reps,
            "speedup": round(exact_t.us / ann_t.us, 2),
            "recall_at_n": round(_ann_recall(ids_e, ids_a), 4),
            "occupancy": occ,
            "exact_flops": backends.selection_flops(m, bits),
            "ann_flops": backends.ann_selection_flops(m, bits, k),
            "flop_ratio": round(backends.selection_flops(m, bits)
                                / backends.ann_selection_flops(m, bits, k),
                                2),
        })
    crossover = next((r["m"] for r in rows if r["speedup"] > 1.0), None)
    return rows, crossover


def bench_ann_probe_curve(m=1024, bits=256, n=12, gamma=1.0,
                          probes_list=(0, 1, 2, 4, 6)):
    """Recall@N vs probe count — the multi-probe recall knob priced at
    a fixed federation size, in BOTH score regimes: concentrated
    (distance-dominated, the §11 design point) and uniform (score-
    dispersed, the documented hard case). Candidate-set sizes ride
    along per probe count."""
    pb = 6
    codes = _clustered_codes(m, bits, m // 32, seed=7)
    ks = jax.random.uniform(jax.random.PRNGKey(8), (m,))
    curves = {}
    for regime, scores in [("concentrated", 0.75 + 0.25 * ks),
                           ("uniform", ks)]:
        ids_e, _ = ref.fused_select_ref(codes, scores, bits=bits,
                                        gamma=gamma, num_neighbors=n)
        pts = []
        for p in probes_list:
            cand = ann.ann_candidates(codes, scores, seed=3,
                                      prefix_bits=pb, probes=p,
                                      num_neighbors=n)
            ids_a, _ = ref.ann_select_ref(codes, scores, cand.ids,
                                          bits=bits, gamma=gamma,
                                          num_neighbors=n)
            occ = ann.occupancy_stats(cand)
            pts.append({"probes": p, "k": occ["k"],
                        "mean_occupancy": occ["mean_occupancy"],
                        "max_occupancy": occ["max_occupancy"],
                        "dropped_candidates": occ["dropped_candidates"],
                        "recall_at_n": round(_ann_recall(ids_e, ids_a), 4)})
        curves[regime] = pts
    return {"m": m, "bits": bits, "n": n, "prefix_bits": pb,
            "curves": curves}


def bench_ann_kernel_interpret(ms=(256, 512), bits=256, n=12, gamma=1.0,
                               iters=3):
    """Interpret-mode ann kernel vs the exact column-tiled kernel at
    shapes both can hold: wall time is interpreter time, not TPU time
    (the ann kernel runs ~K/M times fewer Gram FLOPs but more, smaller
    grid programs — the analytic FLOP ratio in the sweep rows is the
    TPU-side claim). The durable assertions: the kernel is bit-exact
    vs the ann twin on the same candidates, and the prefix_bits=0
    one-bucket fallback is bit-exact vs `fused_select` (acceptance
    pin)."""
    rows = []
    for m in ms:
        pb = _ann_prefix_for(m)
        codes = _clustered_codes(m, bits, m // 32, seed=m)
        scores = 0.75 + 0.25 * jax.random.uniform(
            jax.random.PRNGKey(m + 1), (m,))
        kw = dict(bits=bits, gamma=gamma, num_neighbors=n)
        cand = ann.ann_candidates(codes, scores, seed=3, prefix_bits=pb,
                                  probes=pb, num_neighbors=n)
        tiled_t = _time(lambda c, s: fused_select_tiled(c, s, **kw),
                        codes, scores, iters=iters)
        ann_t = _time(lambda c, s, ci: fused_select_ann(
            c, s, ci, block_m=128, **kw), codes, scores, cand.ids,
            iters=iters)
        ids_k, w_k = fused_select_ann(codes, scores, cand.ids,
                                      block_m=128, **kw)
        ids_r, w_r = ref.ann_select_ref(codes, scores, cand.ids, **kw)
        assert bool(jnp.all(ids_k == ids_r)) and bool(jnp.all(w_k == w_r))
        # one-bucket fallback: bit-exact vs the exact one-shot kernel
        cand0 = ann.ann_candidates(codes, scores, seed=3, prefix_bits=0,
                                   probes=0, num_neighbors=n)
        ids_0, w_0 = fused_select_ann(codes, scores, cand0.ids, **kw)
        ids_x, w_x = fused_select(codes, scores, **kw)
        assert bool(jnp.all(ids_0 == ids_x)) and bool(jnp.all(w_0 == w_x))
        rows.append({"m": m, "bits": bits, "prefix_bits": pb,
                     "k": int(cand.ids.shape[1]),
                     "tiled_interpret_us": round(tiled_t.us, 1),
                     "ann_interpret_us": round(ann_t.us, 1),
                     "tiled_spread_pct": round(tiled_t.spread_pct, 1),
                     "ann_spread_pct": round(ann_t.spread_pct, 1),
                     "reps": ann_t.reps,
                     "kernel_bit_exact_vs_twin": True,
                     "one_bucket_bit_exact_vs_fused_select": True})
    return rows


def _tiny_mlp_federation(m):
    """Shared tiny-MLP WPFed setup (16-dim, 3 classes) for the rounds
    and adversary rows."""
    import functools
    from repro.configs.paper_models import ClientModelConfig, FedConfig
    from repro.core import init_state, wpfed_program
    from repro.models import apply_client_model, init_client_model
    from repro.optim import adam

    mcfg = ClientModelConfig("bench-mlp", "mlp", (16,), 3, hidden=(32,))
    fed = FedConfig(num_clients=m, num_neighbors=3, top_k=2, local_steps=2,
                    local_batch=16, lsh_bits=128, lr=1e-2)
    key = jax.random.PRNGKey(0)
    data = {
        "x_train": jax.random.normal(key, (m, 32, 16)),
        "y_train": jax.random.randint(jax.random.fold_in(key, 1),
                                      (m, 32), 0, 3),
        "x_ref": jax.random.normal(jax.random.fold_in(key, 2), (m, 8, 16)),
        "y_ref": jax.random.randint(jax.random.fold_in(key, 3),
                                    (m, 8), 0, 3),
    }
    apply_fn = functools.partial(apply_client_model, mcfg)
    init_fn = lambda k: init_client_model(mcfg, k)
    opt = adam(fed.lr)
    state = init_state(apply_fn, init_fn, opt, fed, key)
    return {"state": state, "data": data, "init_fn": init_fn,
            "program": wpfed_program(apply_fn, opt, fed)}


def bench_rounds(m=8, rounds=4, iters=3):
    """Round-program engine vs the per-round Python loop on a tiny MLP
    federation (16-dim, 3 classes): wall time per round for (a) the
    classic jit(round_fn) Python loop, (b) engine segments at G=1
    (sync — one segment per round), (c) G=4 (one global round + 3
    gossip epochs in one compiled scan segment)."""
    from repro.core import make_segment_fn
    from repro.core.rounds import program_round

    f = _tiny_mlp_federation(m)
    program, state, data = f["program"], f["state"], f["data"]

    loop_fn = jax.jit(program_round(program))
    seg1 = jax.jit(make_segment_fn(program, 1))
    seg4 = jax.jit(make_segment_fn(program, 4))

    def run_loop(st):
        for _ in range(rounds):
            st, _m = loop_fn(st, data)
        return st

    def run_g1(st):
        for _ in range(rounds):
            st, _m = seg1(st, data)
        return st

    g4_rounds = (rounds // 4) * 4
    assert g4_rounds > 0, "bench_rounds needs rounds >= 4"

    def run_g4(st):
        for _ in range(rounds // 4):
            st, _m = seg4(st, data)
        return st

    loop_t = _time(run_loop, state, iters=iters)
    g1_t = _time(run_g1, state, iters=iters)
    g4_t = _time(run_g4, state, iters=iters)
    loop_us, g1_us = loop_t.us / rounds, g1_t.us / rounds
    g4_us = g4_t.us / g4_rounds
    return {"m": m, "rounds": rounds, "reps": loop_t.reps,
            "loop_us_per_round": round(loop_us, 1),
            "g1_us_per_round": round(g1_us, 1),
            "g4_us_per_round": round(g4_us, 1),
            "loop_spread_pct": round(loop_t.spread_pct, 1),
            "g1_spread_pct": round(g1_t.spread_pct, 1),
            "g4_spread_pct": round(g4_t.spread_pct, 1),
            "g4_speedup_vs_loop": round(loop_us / g4_us, 2)}


def bench_adversary(m=8, iters=3):
    """Instrumented-vs-clean segment cost (DESIGN.md §9): one G=4 WPFed
    reselection period, clean vs wrapped by `instrument_program` with
    the §4.8 poison ThreatModel (25% attackers, lax.cond-gated re-init
    active on alternating rounds, in-graph telemetry included) — the
    price of compiling the adversary into the segment."""
    from repro.core import instrument_program, make_segment_fn, resolve_threat

    f = _tiny_mlp_federation(m)
    tm = resolve_threat("poison", num_clients=m, attacker_frac=0.25,
                        init_fn=f["init_fn"], key=jax.random.PRNGKey(7),
                        start_round=1, every=2)
    seg_clean = jax.jit(make_segment_fn(f["program"], 4))
    seg_inst = jax.jit(make_segment_fn(
        instrument_program(f["program"], tm), 4))
    clean_t = _time(seg_clean, f["state"], f["data"], iters=iters)
    inst_t = _time(seg_inst, f["state"], f["data"], iters=iters)
    clean_us, inst_us = clean_t.us / 4, inst_t.us / 4
    return {"m": m, "reselect_every": 4, "reps": clean_t.reps,
            "clean_us_per_round": round(clean_us, 1),
            "instrumented_us_per_round": round(inst_us, 1),
            "clean_spread_pct": round(clean_t.spread_pct, 1),
            "instrumented_spread_pct": round(inst_t.spread_pct, 1),
            "overhead": round(inst_us / clean_us, 3)}


def main(argv=None, log=print):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes / single iteration (CI budget)")
    ap.add_argument("--json-out", default=BENCH_JSON,
                    help="selection-baseline path ('' disables)")
    ap.add_argument("--exchange-json-out", default=BENCH_EXCHANGE_JSON,
                    help="exchange-baseline path ('' disables)")
    ap.add_argument("--rounds-json-out", default=BENCH_ROUNDS_JSON,
                    help="rounds-baseline path ('' disables); written in "
                         "smoke mode too — CI tracks the engine")
    ap.add_argument("--adversary-json-out", default=BENCH_ADVERSARY_JSON,
                    help="adversary-baseline path ('' disables); written "
                         "in smoke mode too — CI tracks the threat API")
    args = ap.parse_args(argv)
    iters = 1 if args.smoke else 5

    rows = []
    lsh_sizes = (1 << 16,) if args.smoke else (1 << 18, 1 << 20, 1 << 22)
    for nparams in lsh_sizes:
        t, est = bench_lsh(nparams, iters=iters)
        rows.append((f"lsh_project_{nparams}", t.us, est, t.spread_pct))
    bm, bp = (8, 1 << 13) if args.smoke else (64, 1 << 16)
    o_t, _, est = bench_batched_lsh(bm, bp, iters=iters)
    rows.append((f"lsh_batched_{bm}x{bp}", o_t.us, est, o_t.spread_pct))
    for m in ((64,) if args.smoke else (64, 256)):
        t, est = bench_hamming(m, iters=iters)
        rows.append((f"hamming_{m}x{m}", t.us, est, t.spread_pct))

    sel_ms = (64,) if args.smoke else (256, 512, 1024)
    sel_rows = [bench_fused_selection(m, iters=iters) for m in sel_ms]
    for r in sel_rows:
        rows.append((f"select_unfused_{r['m']}", r["unfused_us"],
                     r["tpu_est_us"], r["unfused_spread_pct"]))
        rows.append((f"select_fused_{r['m']}", r["fused_us"],
                     r["tpu_est_us"], r["fused_spread_pct"]))
        log(f"# fused selection speedup @ M={r['m']}: {r['speedup']}x")

    exc_shapes = ((32, 4, 8, 10),) if args.smoke else \
        ((64, 8, 32, 10), (128, 8, 32, 10), (256, 16, 32, 10))
    exc_rows = [bench_fused_exchange(m, n, r, c, iters=iters)
                for m, n, r, c in exc_shapes]
    for r in exc_rows:
        tag = f"{r['m']}x{r['n']}x{r['r']}x{r['c']}"
        rows.append((f"exchange_unfused_{tag}", r["unfused_us"],
                     r["tpu_est_us"], r["unfused_spread_pct"]))
        rows.append((f"exchange_fused_{tag}", r["fused_us"],
                     r["tpu_est_us"], r["fused_spread_pct"]))
        log(f"# fused exchange speedup @ {tag}: {r['speedup']}x")

    # §10 scale sweeps: tiled-vs-oneshot parity where both run, plus
    # the analytic VMEM table to the shapes only the tiled path reaches
    tiled_sel_rows = bench_tiled_selection(
        (64,) if args.smoke else (256, 512, 1024), iters=iters)
    for r in tiled_sel_rows:
        rows.append((f"select_tiled_{r['m']}", r["tiled_interpret_us"],
                     0.0, r["tiled_spread_pct"]))
        log(f"# tiled selection interpret ratio @ M={r['m']}: "
            f"{r['tiled_vs_oneshot']}x (bit-exact)")
    tiled_exc_rows = bench_tiled_exchange(
        (512,) if args.smoke else (1024, 8192, 32768),
        m=4 if args.smoke else 8, iters=iters)
    for r in tiled_exc_rows:
        rows.append((f"exchange_streamed_c{r['c']}", r["streamed_twin_us"],
                     0.0, r["streamed_spread_pct"]))
        log(f"# streamed exchange CPU ratio @ C={r['c']}: "
            f"{r['streamed_vs_oneshot']}x")

    # §11 ANN selection: wall-time sweep + recall/probe curve +
    # interpret-mode kernel parity (incl. the one-bucket acceptance pin)
    ann_rows, ann_crossover = bench_ann_selection(
        (128, 256) if args.smoke else (512, 1024, 2048, 4096),
        iters=iters)
    for r in ann_rows:
        rows.append((f"select_ann_{r['m']}", r["ann_us"], 0.0,
                     r["ann_spread_pct"]))
        log(f"# ann selection @ M={r['m']} (pb={r['prefix_bits']}, "
            f"K={r['occupancy']['k']}): {r['speedup']}x vs exact, "
            f"recall@{r['n']}={r['recall_at_n']}, "
            f"flop_ratio={r['flop_ratio']}x")
    log(f"# ann crossover-M (wall-time win vs exact oracle): "
        f"{ann_crossover}")
    ann_curve = bench_ann_probe_curve(m=256 if args.smoke else 1024,
                                      probes_list=(0, 2) if args.smoke
                                      else (0, 1, 2, 4, 6))
    ann_kernel_rows = bench_ann_kernel_interpret(
        (64,) if args.smoke else (256, 512), iters=iters)
    for r in ann_kernel_rows:
        rows.append((f"select_ann_kernel_{r['m']}", r["ann_interpret_us"],
                     0.0, r["ann_spread_pct"]))
        log(f"# ann kernel interpret @ M={r['m']}: bit-exact vs twin; "
            f"one-bucket fallback bit-exact vs fused_select")

    rounds_row = bench_rounds(m=4 if args.smoke else 8,
                              rounds=4 if args.smoke else 8, iters=iters)
    for k in ("loop", "g1", "g4"):
        rows.append((f"rounds_{k}_m{rounds_row['m']}",
                     rounds_row[f"{k}_us_per_round"], 0.0,
                     rounds_row[f"{k}_spread_pct"]))
    log(f"# rounds engine G=4 speedup vs loop: "
        f"{rounds_row['g4_speedup_vs_loop']}x")
    if args.rounds_json_out:
        with open(args.rounds_json_out, "w") as f:
            json.dump(
                {"rounds": rounds_row, "smoke": bool(args.smoke),
                 "note": "CPU wall us per federation round: per-round "
                         "jit Python loop vs engine segments at "
                         "reselect_every 1 and 4. Scheduler noise at "
                         "the ms scale is large on this container "
                         "(ratios move ~30%+ run to run; loop-vs-g1 "
                         "differences are pure noise). The durable "
                         "claim is structural: at G=4, 3 of 4 rounds "
                         "skip LSH re-code/top-N re-selection/announce "
                         "and run inside one lax.scan segment with one "
                         "host dispatch per period (DESIGN.md §8)"},
                f, indent=1)
        log(f"# wrote {args.rounds_json_out}")

    adv_row = bench_adversary(m=4 if args.smoke else 8, iters=iters)
    rows.append((f"segment_clean_m{adv_row['m']}",
                 adv_row["clean_us_per_round"], 0.0,
                 adv_row["clean_spread_pct"]))
    rows.append((f"segment_instrumented_m{adv_row['m']}",
                 adv_row["instrumented_us_per_round"], 0.0,
                 adv_row["instrumented_spread_pct"]))
    log(f"# adversary instrumentation overhead @ G=4: "
        f"{adv_row['overhead']}x")
    if args.adversary_json_out:
        with open(args.adversary_json_out, "w") as f:
            json.dump(
                {"adversary": adv_row, "smoke": bool(args.smoke),
                 "note": "CPU wall us per federation round for one G=4 "
                         "WPFed segment, clean vs instrumented with the "
                         "§4.8 poison ThreatModel (core.adversary): "
                         "lax.cond-gated attacker re-init on alternating "
                         "rounds + in-graph admission/rank telemetry. "
                         "ms-scale scheduler noise on this container is "
                         "~30%+; the durable claim is structural — the "
                         "adversarial run compiles into the same scanned "
                         "segment as a clean one instead of paying a "
                         "per-round host loop (DESIGN.md §9)"},
                f, indent=1)
        log(f"# wrote {args.adversary_json_out}")

    for name, us, est, spread in rows:
        log(f"{name},{us:.1f},{est:.3f},{spread:.1f}%")

    if args.json_out and not args.smoke:
        best = max(sel_rows, key=lambda r: r["speedup"])
        with open(args.json_out, "w") as f:
            json.dump({"selection": sel_rows,
                       "measured_speedup": best["speedup"],
                       "at_m": best["m"],
                       "tiled_scale": {
                           "measured": tiled_sel_rows,
                           "vmem_sweep": selection_vmem_sweep()},
                       "ann": {
                           "sweep": ann_rows,
                           "crossover_m": ann_crossover,
                           "probe_curve": ann_curve,
                           "kernel_interpret": ann_kernel_rows,
                           "note": "DESIGN.md §11: exact fused oracle "
                                   "vs the jitted ann path (candidate "
                                   "generation included) on clustered "
                                   "codes (98% within-cluster bit "
                                   "agreement) with concentrated "
                                   "ranking scores — the distance-"
                                   "dominated Eq. 8 regime bucketing "
                                   "is built for. crossover_m is the "
                                   "smallest sweep M where ann wins "
                                   "on CPU wall time; flop_ratio "
                                   "(2M^2b / 2MKb) is the TPU-side "
                                   "claim. probe_curve records recall "
                                   "vs probes in BOTH score regimes — "
                                   "uniform scores are intrinsically "
                                   "non-local and recall saturates "
                                   "below the concentrated curve; the "
                                   "occupancy columns make every "
                                   "speedup attributable to a "
                                   "measured candidate count"},
                       "note": "CPU jnp wall times (fused oracle vs "
                               "unfused composition), median-of-reps "
                               "with per-rep spread recorded. lax.top_k "
                               "is a shared fixed cost that compresses "
                               "the end-to-end ratio at small M; the "
                               "fused win is in the distance/weight "
                               "stages. tpu_est_us is the analytic v5e "
                               "bound for the fused kernel. tiled_scale "
                               "(DESIGN.md §10): the column-tiled "
                               "kernel is bit-exact at every measured "
                               "shape (interpret wall times measure the "
                               "interpreter, not the TPU); the VMEM "
                               "sweep shows the one-shot kernel blowing "
                               "the per-program budget past M ~ 10^4 "
                               "while the tiled working set stays "
                               "constant — the shapes only the tiled "
                               "path can run"},
                      f, indent=1)
        log(f"# wrote {args.json_out}")
    if args.exchange_json_out and not args.smoke:
        best = max(exc_rows, key=lambda r: r["speedup"])
        with open(args.exchange_json_out, "w") as f:
            json.dump({"exchange": exc_rows,
                       "measured_speedup": best["speedup"],
                       "at": {k: best[k] for k in ("m", "n", "r", "c")},
                       "tiled_scale": {
                           "measured": tiled_exc_rows,
                           "vmem_sweep": exchange_vmem_sweep()},
                       "note": "CPU jnp wall times (fused exchange "
                               "oracle vs the three scattered round "
                               "calls), median-of-reps with per-rep "
                               "spread recorded. The fused win is the "
                               "single shared log-softmax pass over the "
                               "(M, N, R, C) neighbor logits vs three. "
                               "tpu_est_us is the analytic v5e bound "
                               "for the fused kernel. tiled_scale "
                               "(DESIGN.md §10): one-shot oracle vs the "
                               "streaming twin across the vocab sweep "
                               "(§3.5 masks asserted equal; l_ij/target "
                               "tolerance-bounded); the VMEM sweep "
                               "shows where auto resolution hands the "
                               "kernel path to the streamed variant — "
                               "at C=32768 the one-shot tile would need "
                               "~48x the budget"},
                      f, indent=1)
        log(f"# wrote {args.exchange_json_out}")
    return rows


if __name__ == "__main__":
    main()
