"""Benchmark entrypoint: one function per paper table/figure. Prints
``name,us_per_call,derived`` CSV rows for micro-benches and summary lines
for the experiment tables.

    PYTHONPATH=src python -m benchmarks.run             # full suite
    PYTHONPATH=src python -m benchmarks.run --only table2,kernels --fast
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: kernels,roofline,table2,table3,"
                         "fig3,fig4,fig5")
    ap.add_argument("--fast", action="store_true",
                    help="fewer rounds/seeds (CI budget)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    os.makedirs(RESULTS_DIR, exist_ok=True)
    outputs = {}

    def want(name):
        return only is None or name in only

    t0 = time.time()

    if want("kernels"):
        print("# kernel micro-benchmarks "
              "(name,us_per_call,tpu_est_us,spread_pct)")
        from benchmarks import kernel_micro
        # explicit argv: kernel_micro must not re-parse run.py's flags,
        # and its selection baseline goes to RESULTS_DIR — only a direct
        # kernel_micro invocation rewrites the committed baseline.
        rounds_out = ["--rounds-json-out",
                      os.path.join(RESULTS_DIR, "BENCH_rounds.json")]
        outputs["kernels"] = kernel_micro.main(
            (["--smoke"] if args.fast else
             ["--json-out", os.path.join(RESULTS_DIR,
                                         "BENCH_selection.json")])
            + rounds_out)

    if want("roofline"):
        print("\n# roofline (from dry-run sweeps)")
        from benchmarks import roofline
        roofline.main()

    seeds = (0,) if args.fast else (0, 1)
    rounds = 5 if args.fast else 8

    if want("table2"):
        print("\n# Table 2 — performance comparison")
        from benchmarks import table2_performance
        outputs["table2"] = table2_performance.run(seeds=seeds,
                                                   rounds=rounds)

    if want("table3"):
        print("\n# Table 3 — ablation (LSH / Rank)")
        from benchmarks import table3_ablation
        outputs["table3"] = table3_ablation.run(seeds=seeds, rounds=rounds)

    if want("fig3"):
        print("\n# Fig. 3 — alpha / gamma sensitivity")
        from benchmarks import fig3_hyperparams
        outputs["fig3"] = fig3_hyperparams.run(rounds=rounds)

    if want("fig4"):
        print("\n# Fig. 4 — LSH-cheating attack")
        from benchmarks import fig4_lsh_cheating
        outputs["fig4"] = fig4_lsh_cheating.run(rounds=rounds)

    if want("fig5"):
        print("\n# Fig. 5 — poison attack")
        from benchmarks import fig5_poison
        outputs["fig5"] = fig5_poison.run(rounds=rounds)

    path = os.path.join(RESULTS_DIR, "bench_results.json")
    with open(path, "w") as f:
        json.dump(outputs, f, indent=1, default=str)
    print(f"\n# done in {time.time() - t0:.0f}s -> {path}")


if __name__ == "__main__":
    main()
