#!/usr/bin/env bash
# One-command verify recipe: tier-1 tests + kernel micro-benchmark
# (smoke mode — covers LSH projection, Hamming, fused selection, the
# fused all-in-one exchange AND the round-program engine, which emits
# benchmarks/BENCH_rounds.json). Usage: scripts/ci.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q "$@"

echo "== kernel micro-benchmark (smoke) =="
python benchmarks/kernel_micro.py --smoke

echo "CI OK"
