#!/usr/bin/env bash
# One-command verify recipe: tier-1 tests + kernel micro-benchmark
# (smoke mode — covers LSH projection, Hamming, fused selection, the
# fused all-in-one exchange, the round-program engine and the adversary
# instrumentation, emitting benchmarks/BENCH_rounds.json +
# BENCH_adversary.json) + a reduced-scale run of the attack-resilience
# example (the in-graph ThreatModel path end-to-end, attacks firing
# inside a gossip segment). Usage: scripts/ci.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q "$@"

echo "== kernel micro-benchmark (smoke) =="
python benchmarks/kernel_micro.py --smoke

echo "== attack-resilience example (smoke) =="
python examples/attack_resilience.py --clients 6 --rounds 3 \
    --per-client 48 --reselect-every 3

echo "CI OK"
