#!/usr/bin/env bash
# One-command verify recipe: tier-1 tests + kernel micro-benchmark
# (smoke mode — covers LSH projection, Hamming, fused selection, the
# fused all-in-one exchange, the round-program engine and the adversary
# instrumentation, emitting benchmarks/BENCH_rounds.json +
# BENCH_adversary.json) + the VMEM-tiled kernel smoke (DESIGN.md §10:
# tiled selection/exchange in interpret mode at shapes whose one-shot
# working set exceeds the VMEM budget) + a reduced-scale run of the
# attack-resilience example (the in-graph ThreatModel path end-to-end,
# attacks firing inside a gossip segment) + the §11 ANN selection
# smoke (sub-quadratic candidate path at M=16384 — beyond the exact
# kernels' comfortable range — plus recall and the one-bucket
# bit-exact fallback) + the §13 continuous-service smoke (3 churned
# reselection periods, kill after 2, bit-exact resume + ledger
# verification across the restart, batched personalized serving)
# + the §15 chaos soak (every fault kind of a seeded FaultPlan firing
# against the hardened transport: degraded rounds within tolerance of
# fault-free, crash + truncated snapshot + forked ledger recovered
# bitwise, identical fault traces for the same seed)
# + a 1024-client dryrun on the tiled backend
# (the 10^4-client scaling path lowered under sharding, in a fresh
# process because jax locks the device count at first init).
# The static-analysis gate (DESIGN.md §12/§14) runs FIRST: kernel
# contracts + trace lint + the privacy-taint verifier are cheap (no
# kernel executes) and catch the §10/§4 bug classes — and any
# disclosure-boundary leak — before the test tiers spend minutes. The
# gate's wall-time is recorded in benchmarks/ANALYSIS_report.json. The
# seeded-leak fixtures are then each asserted to FAIL the strict gate:
# a verifier that stops flagging planted leaks is itself broken.
# Usage: scripts/ci.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== static analysis: contracts + lint + privacy taint (strict) =="
python -m repro.analysis --strict --json benchmarks/ANALYSIS_report.json

echo "== seeded-leak fixtures must fail the strict gate =="
for leak in tests/analysis_fixtures/leak_announce_field.py \
            tests/analysis_fixtures/leak_metric_tap.py \
            tests/analysis_fixtures/leak_served_private.py; do
    if python -m repro.analysis --strict "$leak" >/dev/null 2>&1; then
        echo "FATAL: $leak passed the strict gate (planted leak missed)"
        exit 1
    fi
    echo "ok: $leak rejected"
done

echo "== tier-1: pytest =="
python -m pytest -x -q "$@"

echo "== kernel micro-benchmark (smoke) =="
python benchmarks/kernel_micro.py --smoke

echo "== tiled kernels beyond the one-shot VMEM budget (smoke) =="
python scripts/tiled_smoke.py

echo "== sub-quadratic ANN selection smoke (DESIGN.md §11) =="
python scripts/ann_smoke.py

echo "== continuous federation service: churn + kill/resume (DESIGN.md §13) =="
python scripts/service_smoke.py

echo "== chaos soak: faults + degraded mode + crash/fork recovery (DESIGN.md §15) =="
python scripts/chaos_smoke.py

echo "== attack-resilience example (smoke) =="
python examples/attack_resilience.py --clients 6 --rounds 3 \
    --per-client 48 --reselect-every 3

echo "== 1024-client dryrun on the tiled backend =="
XLA_FLAGS="--xla_force_host_platform_device_count=512" \
    python -m repro.launch.fed --dryrun --clients 1024 \
    --ref-mode public --tiling tiled

echo "CI OK"
