"""CI smoke for the VMEM-tiled kernels (DESIGN.md §10): run both tiled
paths in interpret mode at shapes whose ONE-SHOT per-program working
set exceeds the VMEM budget — i.e. shapes the one-shot kernels cannot
hold on TPU — and hold them to their §10 contracts (selection:
bit-exact vs the jnp oracle; exchange: §3.5 mask equal, l_ij/target
tolerance-bounded vs the streaming twin and the one-shot oracle).

Usage: PYTHONPATH=src python scripts/tiled_smoke.py
"""
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backends
from repro.kernels import ops, ref
from repro.kernels.exchange import fused_exchange_streamed
from repro.kernels.selection import fused_select_tiled


def smoke_selection(m=16384, bits=256, n=16):
    est = backends.selection_vmem_bytes(m, bits)
    assert est > backends.VMEM_BUDGET_BYTES, (est, "not beyond one-shot")
    assert backends.resolve_tiling("auto", est) == "tiled"
    raw = jax.random.bernoulli(jax.random.PRNGKey(0), 0.5, (m, bits))
    codes = ops.pack_bits(jnp.where(raw, 1.0, -1.0))
    scores = jax.random.uniform(jax.random.PRNGKey(1), (m,))
    kw = dict(bits=bits, gamma=1.0, num_neighbors=n)
    t0 = time.time()
    ids_t, w_t = jax.block_until_ready(fused_select_tiled(
        codes, scores, **kw, block_m=512, block_k=2048))
    t1 = time.time()
    ids_o, w_o = jax.block_until_ready(jax.jit(functools.partial(
        ref.fused_select_ref, **kw))(codes, scores))
    assert bool(jnp.all(ids_t == ids_o)) and bool(jnp.all(w_t == w_o)), \
        "tiled selection diverged from the oracle"
    print(f"selection M={m}: one-shot est {est >> 20} MiB > budget; "
          f"tiled interpret {t1 - t0:.1f}s, bit-exact OK")


def smoke_exchange(m=4, n=8, r=16, c=8192):
    est = backends.exchange_vmem_bytes(n, r, c)
    assert est > backends.VMEM_BUDGET_BYTES, (est, "not beyond one-shot")
    assert backends.resolve_tiling("auto", est) == "tiled"
    k = jax.random.PRNGKey(2)
    own = jax.random.normal(k, (m, r, c)) * 3
    nb = jax.random.normal(jax.random.fold_in(k, 1), (m, n, r, c)) * 3
    y = jax.random.randint(jax.random.fold_in(k, 2), (m, r), 0, c)
    sel = jax.random.bernoulli(jax.random.fold_in(k, 3), 0.8, (m, n))
    t0 = time.time()
    out_s = jax.block_until_ready(fused_exchange_streamed(own, nb, y, sel))
    t1 = time.time()
    for other, tag in ((ref.streamed_exchange_ref(own, nb, y, sel), "twin"),
                       (ref.all_in_one_exchange_ref(own, nb, y, sel),
                        "one-shot oracle")):
        np.testing.assert_allclose(np.asarray(out_s[0]),
                                   np.asarray(other[0]),
                                   rtol=2e-5, atol=1e-5, err_msg=tag)
        assert bool(jnp.all(out_s[1] == other[1])), f"mask vs {tag}"
        np.testing.assert_allclose(np.asarray(out_s[2]),
                                   np.asarray(other[2]),
                                   rtol=2e-5, atol=1e-5, err_msg=tag)
    print(f"exchange C={c}: one-shot est {est >> 20} MiB > budget; "
          f"streamed interpret {t1 - t0:.1f}s, contract OK")


if __name__ == "__main__":
    smoke_selection()
    smoke_exchange()
    print("tiled smoke OK")
