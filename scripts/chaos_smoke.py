"""CI chaos soak for the hardened federation service (DESIGN.md §15):
the ISSUE-10 acceptance scenario end-to-end, at fixture scale.

Runs the service_smoke fixture federation under a seeded FaultPlan
with EVERY fault kind active — drop, delay, duplicate, corrupt,
stragglers, flaky publish/fetch, a scheduled crash-restart, and a
forked ledger view — and asserts the degraded-mode invariants:

  A. fault-free reference run (hardened transport, no plan);
  F. the full fault plan minus the crash, straight through;
  F2. the SAME plan again — fault traces and all state/metrics must
      reproduce bit-for-bit (determinism is the whole point);
  K. the same plan WITH the crash: the driver dies mid-period, the
     newest snapshot is deliberately truncated (crash-mid-write), the
     canonical ledger is replaced by a rolled-back view (the true
     history surviving only as chain.fork1.json) — resume must fall
     back to the previous retained snapshot, recover the longest
     valid ledger view, replay the lost periods (re-publishes dedupe
     idempotently against the recovered chain), and land bitwise
     equal to F.

Acceptance: every fault kind fired at least once; the faulted run's
final accuracy is within tolerance of the fault-free run (the plan is
eventually delivering, so degraded rounds slow learning, they don't
break it); kill/resume stays bitwise; same seed -> identical traces.

Usage: PYTHONPATH=src python scripts/chaos_smoke.py
"""
import dataclasses
import json
import os
import sys
import tempfile
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from service_smoke import build  # noqa: E402  (the shared CI fixture)

from repro.core import evaluate, init_state  # noqa: E402
from repro.core.chain import Blockchain, save_chain  # noqa: E402
from repro.core.faults import FaultPlan  # noqa: E402
from repro.service import (BulletinTransport, CrashInjected,  # noqa: E402
                           ServiceConfig, init_service_state,
                           resume_service, run_service)
from repro.service.transport import (recover_chain,  # noqa: E402
                                     rollback_view, write_fork_view)

PERIODS = 4
CRASH_PERIOD = 2
ACC_TOLERANCE = 0.25

# every fault kind active, rates tuned so a 6-client x 4-period run
# exercises each at least once while staying eventually-delivering
PLAN = FaultPlan(seed=21, drop=0.12, delay=0.12, duplicate=0.18,
                 corrupt=0.12, straggle=0.18, publish_fail=0.3,
                 fetch_fail=0.2, crash_periods=(CRASH_PERIOD,),
                 fork_at=1)


def main():
    fed, apply_fn, init_fn, opt, data = build()
    svc = ServiceConfig(reselect_every=3, keep_last_k=2)
    assert PLAN.eventually_delivering(), "soak plan must converge"
    plan_nc = dataclasses.replace(PLAN, crash_periods=())

    def fresh():
        return init_service_state(
            init_state(apply_fn, init_fn, opt, fed,
                       jax.random.PRNGKey(0)), svc)

    def eval_fn(st, d):
        return {"acc": evaluate(
            apply_fn, st.fed, d,
            honest_mask=st.active.astype(jnp.float32))["mean_acc"]}

    def soak(state, *, plan, ckpt_dir, chain=None, start_period=0):
        """One service run through an explicit transport (so the test
        can read back its fault trace)."""
        xp = BulletinTransport(chain if chain is not None
                               else Blockchain(), plan=plan)
        result = run_service(
            apply_fn, opt, fed, svc, state, data, periods=PERIODS,
            ckpt_dir=ckpt_dir, start_period=start_period,
            eval_fn=eval_fn, transport=xp)
        return result, xp

    t0 = time.time()
    with tempfile.TemporaryDirectory() as tmp:
        dirs = {k: os.path.join(tmp, k) for k in ("a", "f", "f2", "k")}

        # A: fault-free reference (hardened transport, no plan)
        (s_a, chain_a, hist_a), _ = soak(fresh(), plan=None,
                                         ckpt_dir=dirs["a"])
        acc_a = hist_a[-1]["acc"]

        # F: every fault kind, no crash — the uninterrupted chaos run
        (s_f, chain_f, hist_f), xp_f = soak(fresh(), plan=plan_nc,
                                            ckpt_dir=dirs["f"])
        acc_f = hist_f[-1]["acc"]
        fired = xp_f.trace.snapshot()
        for kind in ("drop", "delay", "duplicate", "corrupt", "straggle",
                     "publish_fail", "fetch_fail"):
            assert fired.get(kind, 0) > 0, \
                f"fault kind {kind!r} never fired (trace: {fired}) — " \
                f"retune PLAN rates/seed"
        degraded = sum(h.get("degraded_round", 0) for h in hist_f)
        assert degraded > 0, "no degraded rounds under the chaos plan"
        assert abs(acc_f - acc_a) < ACC_TOLERANCE, \
            f"chaos acceptance diverged: fault-free {acc_a:.3f} vs " \
            f"faulted {acc_f:.3f} (tolerance {ACC_TOLERANCE})"
        assert chain_f.verify_chain(), "faulted ledger broken"

        # F2: the same plan reproduces the identical fault trace and
        # the identical run, bit for bit
        (s_f2, chain_f2, hist_f2), xp_f2 = soak(fresh(), plan=plan_nc,
                                                ckpt_dir=dirs["f2"])
        assert xp_f2.trace.events == xp_f.trace.events, \
            "same FaultPlan seed produced a different fault trace"
        assert hist_f2 == hist_f, "same plan, different metrics"
        for a, b in zip(jax.tree.leaves(s_f), jax.tree.leaves(s_f2)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), \
                "same plan, different final state"

        # K: crash + truncated snapshot + forked ledger, full recovery
        try:
            run_service(apply_fn, opt, fed, svc, fresh(), data,
                        periods=PERIODS, ckpt_dir=dirs["k"],
                        eval_fn=eval_fn, faults=PLAN)
            raise AssertionError("scheduled crash never fired")
        except CrashInjected as e:
            assert e.period == CRASH_PERIOD
        # sabotage 1: the newest snapshot (period 1) truncates as if
        # the process died mid-write
        snaps = sorted(f for f in os.listdir(dirs["k"])
                       if f.endswith(".npz"))
        newest = os.path.join(dirs["k"], snaps[-1])
        blob = open(newest, "rb").read()
        with open(newest, "wb") as fh:
            fh.write(blob[:len(blob) // 3])
        # sabotage 2: the canonical ledger rolls back one block; the
        # true history survives only as a fork view
        true_chain = recover_chain(dirs["k"])
        save_chain(os.path.join(dirs["k"], "chain.json"),
                   rollback_view(true_chain, 1))
        write_fork_view(dirs["k"], true_chain, idx=1)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            s_r, chain_r, p0 = resume_service(dirs["k"], fresh())
        assert any("falling back" in str(w.message) for w in caught), \
            "truncated-snapshot fallback did not warn"
        assert p0 == 1, \
            f"expected fallback resume at period 1 (period-0 snapshot), " \
            f"got {p0}"
        assert chain_r.head_round() == true_chain.head_round(), \
            "fork recovery did not pick the longest valid view"
        # replay the lost periods; crash_periods stays scheduled but the
        # replay of period 2 is identical either way (fault hashes don't
        # read crash_periods), so replay WITHOUT the crash to finish
        s_k, chain_k, hist_k = run_service(
            apply_fn, opt, fed, svc, s_r, data, periods=PERIODS,
            chain=chain_r, ckpt_dir=dirs["k"], start_period=p0,
            eval_fn=eval_fn, faults=plan_nc)
        # bitwise equivalence with the uninterrupted faulted run
        for a, b in zip(jax.tree.leaves(s_k), jax.tree.leaves(s_f)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), \
                "crash/fork-recovered state not bitwise equal to the " \
                "uninterrupted faulted run"
        assert [b.payload for b in chain_k.blocks] == \
            [b.payload for b in chain_f.blocks], \
            "recovered ledger recorded different protocol content"
        tail = hist_f[-len(hist_k):]
        assert hist_k == tail, "resumed metrics diverged under faults"

        print(json.dumps({
            "acc_fault_free": round(float(acc_a), 4),
            "acc_faulted": round(float(acc_f), 4),
            "fault_trace": fired,
            "degraded_rounds": int(degraded),
            "crash_period": CRASH_PERIOD,
            "resume_period": p0,
            "wall_s": round(time.time() - t0, 1),
        }, indent=1))
        print("chaos smoke OK: all fault kinds fired, acceptance within "
              f"{ACC_TOLERANCE} of fault-free, kill/resume bitwise, fork "
              "recovered, trace reproduced")


if __name__ == "__main__":
    main()
