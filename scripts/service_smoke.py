"""CI smoke for the continuous federation service (DESIGN.md §13):
the ISSUE-8 acceptance scenario end-to-end, at fixture scale.

Runs a 3-period churned service (1 leave at period 1, 1 rejoin at
period 2) twice:

  A. straight through, and
  B. killed after period 2 — a FRESH process-equivalent resume
     (template state, everything else restored from disk via
     `resume_service`) finishes period 3.

Asserts the acceptance criteria:

  * per-round metrics of B are IDENTICAL (==, not approximately) to A;
  * the final ServiceState of B is bitwise equal to A's;
  * `verify_chain` holds across the restart boundary, and the two
    ledgers record the same protocol content (payloads; hashes differ
    by wall-clock timestamps);
  * checkpoint retention pruned to keep_last_k snapshots;
  * the serving front answers batched requests from the live
    per-client personalized models, matching direct application.

Usage: PYTHONPATH=src python scripts/service_smoke.py
"""
import functools
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import ClientModelConfig, FedConfig
from repro.core import init_state
from repro.models import apply_client_model, init_client_model
from repro.optim import adam
from repro.service import (ChurnEvent, PersonalizedServer, ServiceConfig,
                           init_service_state, resume_service, run_service)


def build(seed=0, m=6, d=16, classes=3):
    rs = np.random.RandomState(seed)
    mcfg = ClientModelConfig("smoke-mlp", "mlp", (d,), classes,
                             hidden=(32,))
    fed = FedConfig(num_clients=m, num_neighbors=3, top_k=2,
                    local_steps=3, local_batch=16, lsh_bits=128, lr=1e-2)
    centers = rs.randn(classes, d) * 2.5

    def gen(n, props):
        y = rs.choice(classes, size=n, p=props)
        return (centers[y] + rs.randn(n, d)).astype("f"), y.astype("i4")

    packs = {k: [] for k in ("x_train", "y_train", "x_ref", "y_ref",
                             "x_test", "y_test")}
    for _ in range(m):
        props = rs.dirichlet(np.ones(classes) * 0.8)
        props = 0.7 * props + 0.3 / classes
        for split, (n, p) in {"train": (40, props),
                              "ref": (12, np.ones(classes) / classes),
                              "test": (20, props)}.items():
            x, y = gen(n, p)
            packs[f"x_{split}"].append(x)
            packs[f"y_{split}"].append(y)
    data = {k: jnp.asarray(np.stack(v)) for k, v in packs.items()}
    apply_fn = functools.partial(apply_client_model, mcfg)
    init_fn = lambda k: init_client_model(mcfg, k)
    return fed, apply_fn, init_fn, adam(fed.lr), data


def main():
    fed, apply_fn, init_fn, opt, data = build()
    svc = ServiceConfig(reselect_every=3, keep_last_k=2)
    events = [ChurnEvent(1, "leave", 4), ChurnEvent(2, "join", 4)]

    def fresh():
        return init_service_state(
            init_state(apply_fn, init_fn, opt, fed,
                       jax.random.PRNGKey(0)), svc)

    with tempfile.TemporaryDirectory() as tmp:
        dir_a, dir_b = os.path.join(tmp, "a"), os.path.join(tmp, "b")
        t0 = time.time()
        s_a, chain_a, hist_a = run_service(
            apply_fn, opt, fed, svc, fresh(), data, periods=3,
            events=events, ckpt_dir=dir_a, log=print)
        assert chain_a.verify_chain(), "uninterrupted ledger broken"

        # run B: kill after period 2, resume from disk, finish
        run_service(apply_fn, opt, fed, svc, fresh(), data, periods=2,
                    events=events, ckpt_dir=dir_b)
        s_r, chain_r, p0 = resume_service(dir_b, fresh())
        assert p0 == 2, f"expected resume at period 2, got {p0}"
        s_b, chain_b, hist_tail = run_service(
            apply_fn, opt, fed, svc, s_r, data, periods=3,
            events=events, chain=chain_r, ckpt_dir=dir_b,
            start_period=p0, log=print)

        # acceptance: metric continuity, IDENTICAL not approximate
        tail_a = hist_a[-svc.reselect_every:]
        assert hist_tail == tail_a, "resumed metrics diverged"
        for a, b in zip(jax.tree.leaves(s_a), jax.tree.leaves(s_b)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), \
                "resumed final state not bitwise equal"
        assert chain_b.verify_chain(), \
            "ledger fails verification across the restart boundary"
        assert [blk.payload for blk in chain_a.blocks] == \
            [blk.payload for blk in chain_b.blocks], \
            "resumed ledger recorded different protocol content"
        snaps = sorted(f for f in os.listdir(dir_b)
                       if f.endswith(".npz"))
        assert len(snaps) == svc.keep_last_k, \
            f"retention kept {snaps}, wanted {svc.keep_last_k}"

        # churn actually happened (period 1 ran 5/6 active)
        fracs = [h["active_frac"] for h in hist_a]
        assert fracs[0] == 1.0 and fracs[svc.reselect_every] < 1.0 \
            and fracs[-1] == 1.0, f"churn not visible: {fracs}"

        # the serving front, on the final personalized models
        server = PersonalizedServer(apply_fn, s_b.fed.params)
        for r in range(12):
            cid = r % fed.num_clients
            server.submit(cid, data["x_test"][cid, r % 20])
        got = server.flush()
        direct = apply_fn(
            jax.tree.map(lambda p: p[2], s_b.fed.params),
            data["x_test"][2, 2][None])[0]
        assert np.allclose(got[2], np.asarray(direct), atol=1e-5), \
            "served logits diverge from direct application"
        stats = server.throughput()
        print(f"serving: {stats['requests']:.0f} requests, "
              f"{stats['requests_per_s']:.0f} req/s, "
              f"p50 {stats['p50_latency_s'] * 1e3:.2f} ms")
        print(f"service smoke OK ({time.time() - t0:.1f}s): "
              "churned kill/resume run identical to uninterrupted, "
              "ledger verified across restart")


if __name__ == "__main__":
    main()
