"""CI smoke for the §11 sub-quadratic ANN selection path: run
`select_partners` with selection_backend="ann" at an M far beyond the
exact kernels' comfortable range (the exact Gram at M=16384 is 2.7e8
weight entries per pass; the ann candidate path prices M*K with
K << M), and hold the path to its contracts:

  * determinism — same seed, same partners (the protocol threads
    state.round, so reselection must be reproducible);
  * invariants at scale — self-mask, all-True sel_mask, ids in range;
  * recall@N >= 0.9 vs the exact oracle on clustered codes at a
    mid-size M where the oracle still runs;
  * the prefix_bits=0 one-bucket fallback bit-exact vs the exact
    one-shot kernel (interpret mode) AND its oracle.

Usage: PYTHONPATH=src python scripts/ann_smoke.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import FedConfig
from repro.core import ann, backends, neighbor
from repro.kernels import ops, ref
from repro.kernels.selection import fused_select


def _clustered_codes(m, bits, n_clusters, flip=0.02, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    centers = jax.random.bernoulli(k1, 0.5, (n_clusters, bits))
    assign = jax.random.randint(k2, (m,), 0, n_clusters)
    flips = jax.random.bernoulli(k3, flip, (m, bits))
    raw = jnp.logical_xor(centers[assign], flips)
    return ops.pack_bits(jnp.where(raw, 1.0, -1.0))


def smoke_scale(m=16384, bits=256, n=12, prefix_bits=8, probes=6):
    """ANN selection at M=16384 — a shape whose exact path would build
    a 16384^2 weight matrix (1 GiB f32) per round."""
    fed = FedConfig(num_clients=m, num_neighbors=n, lsh_bits=bits,
                    ann_prefix_bits=prefix_bits, ann_probes=probes)
    codes = _clustered_codes(m, bits, m // 32, seed=1)
    scores = 0.75 + 0.25 * jax.random.uniform(jax.random.PRNGKey(2), (m,))
    k = ann.candidate_count(m, prefix_bits, probes, n, bits)
    t0 = time.time()
    ids, mask = jax.block_until_ready(neighbor.select_partners(
        codes, scores, fed, backend="ann", seed=4))
    t1 = time.time()
    ids2, _ = neighbor.select_partners(codes, scores, fed, backend="ann",
                                       seed=4)
    assert bool(jnp.all(ids == ids2)), "ann reselection not deterministic"
    assert bool(jnp.all(mask)), "teaser must keep every row served"
    row = jnp.arange(m, dtype=jnp.int32)[:, None]
    assert not bool(jnp.any(ids == row)), "self selected"
    assert bool(jnp.all((ids >= 0) & (ids < m))), "id out of range"
    print(f"ann selection M={m}: K={k} (vs exact M={m}), "
          f"{t1 - t0:.1f}s, invariants OK")


def smoke_recall(m=2048, bits=256, n=12):
    codes = _clustered_codes(m, bits, m // 32, seed=3)
    scores = 0.75 + 0.25 * jax.random.uniform(jax.random.PRNGKey(5), (m,))
    ids_e, _ = ref.fused_select_ref(codes, scores, bits=bits, gamma=1.0,
                                    num_neighbors=n)
    cand = ann.ann_candidates(codes, scores, seed=6, prefix_bits=7,
                              probes=7, num_neighbors=n)
    ids_a, _ = ref.ann_select_ref(codes, scores, cand.ids, bits=bits,
                                  gamma=1.0, num_neighbors=n)
    e, a = np.asarray(ids_e), np.asarray(ids_a)
    hits = sum(len(set(e[i]) & set(a[i])) for i in range(m))
    recall = hits / float(m * n)
    assert recall >= 0.9, f"recall@{n} = {recall:.3f} < 0.9"
    print(f"ann recall M={m}: recall@{n}={recall:.3f} "
          f"(K={cand.ids.shape[1]}) OK")


def smoke_one_bucket(m=256, bits=128, n=12):
    """prefix_bits=0 -> one bucket -> the ann path must be bit-exact
    vs the exact kernels, through the public select_partners API."""
    codes = _clustered_codes(m, bits, m // 32, seed=7)
    scores = jax.random.uniform(jax.random.PRNGKey(8), (m,))
    fed = FedConfig(num_clients=m, num_neighbors=n, lsh_bits=bits,
                    ann_prefix_bits=0, ann_probes=0)
    ids, _ = neighbor.select_partners(codes, scores, fed, backend="ann",
                                      seed=9)
    kw = dict(bits=bits, gamma=fed.gamma, num_neighbors=n)
    ids_k, _ = fused_select(codes, scores, interpret=True, **kw)
    ids_o, _ = ref.fused_select_ref(codes, scores, **kw)
    assert bool(jnp.all(ids == ids_k)), "one-bucket != fused_select"
    assert bool(jnp.all(ids == ids_o)), "one-bucket != oracle"
    print(f"ann one-bucket fallback M={m}: bit-exact vs exact kernels OK")


if __name__ == "__main__":
    assert backends.resolve_selection(
        "ann", 2, exact_flops=1.0, ann_flops=1.0) == "ann"
    smoke_one_bucket()
    smoke_recall()
    smoke_scale()
    print("ANN smoke OK")
