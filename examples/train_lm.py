"""Train a ~100M-parameter LM from the zoo for a few hundred steps on
the synthetic token stream (deliverable b's end-to-end training driver at
transformer scale).

xlstm-350m's reduced() variant is upsized here to ~100M so the run is a
genuine multi-million-param training while staying CPU-feasible.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="xlstm-350m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    # ~100M params: widen the reduced config
    base = get_config(args.arch)
    cfg = dataclasses.replace(
        base.reduced(), name=base.name + "-100m",
        num_layers=4, d_model=768, num_heads=8, num_kv_heads=8,
        head_dim=96, vocab_size=32768)
    n = cfg.param_count()
    print(f"training {cfg.name}: {n / 1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    import repro.launch.train as T
    import repro.configs as C
    # register the custom config so the driver can find it
    C.base._REGISTRY[cfg.name] = lambda: cfg
    _, history = train(cfg.name, steps=args.steps, batch=args.batch,
                       seq=args.seq, lr=6e-4, reduced=False,
                       log_every=max(args.steps // 10, 1))
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
