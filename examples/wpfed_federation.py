"""End-to-end driver (deliverable b): a full WPFed federation with a
~100M-parameter aggregate model pool — 24 CNN clients x ~420k params
trained for a few hundred aggregate local steps on synthetic non-IID
MNIST, with the blockchain ledger recording every round's announcements.

    PYTHONPATH=src python examples/wpfed_federation.py [--rounds 12]
"""
import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import FedConfig, mnist_cnn
from repro.core import evaluate, init_state, make_wpfed_round
from repro.core.chain import Blockchain, lsh_code_hex, sha256_commit
from repro.data import make_mnist_federated
from repro.models import apply_client_model, init_client_model
from repro.optim import adam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--clients", type=int, default=24)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "kernel", "oracle"],
                    help="selection + exchange backend (DESIGN.md §4, §7)")
    ap.add_argument("--ref-mode", default="personal",
                    choices=["personal", "public"],
                    help="public: shared reference set, M forwards per "
                         "exchange instead of M*N (DESIGN.md §7)")
    args = ap.parse_args()

    fed = FedConfig(num_clients=args.clients, num_neighbors=6, top_k=4,
                    local_steps=args.local_steps, lsh_bits=256,
                    selection_backend=args.backend,
                    exchange_backend=args.backend, ref_mode=args.ref_mode)
    ds = make_mnist_federated(num_clients=args.clients, per_client=200,
                              ref_per_client=32)
    data = {k: jnp.asarray(v) for k, v in ds.stacked().items()}
    mcfg = mnist_cnn()
    apply_fn = functools.partial(apply_client_model, mcfg)
    opt = adam(fed.lr)
    state = init_state(apply_fn, lambda k: init_client_model(mcfg, k), opt,
                       fed, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"{args.clients} clients x "
          f"{n_params // args.clients:,} params = {n_params:,} total; "
          f"{args.rounds} rounds x {fed.local_steps} local steps")

    chain = Blockchain()
    round_fn = jax.jit(make_wpfed_round(apply_fn, opt, fed))
    for r in range(args.rounds):
        t0 = time.time()
        state, metrics = round_fn(state, data)
        # publish this round's announcements on the ledger
        ann = {i: {"lsh": lsh_code_hex(np.asarray(state.codes[i])),
                   "commit": sha256_commit(np.asarray(state.rankings[i]))}
               for i in range(args.clients)}
        reveals = {i: [int(x) for x in np.asarray(state.rankings[i])]
                   for i in range(args.clients)}
        chain.publish_round(r + 1, ann, reveals=reveals)
        ev = evaluate(apply_fn, state, data)
        print(f"round {r:3d}: acc {float(ev['mean_acc']):.4f} "
              f"loss {float(metrics['mean_loss']):.4f} "
              f"verified {float(metrics['valid_neighbor_frac']):.2f} "
              f"({time.time() - t0:.1f}s)", flush=True)
    assert chain.verify_chain(), "ledger integrity violated"
    print(f"ledger: {len(chain.blocks)} blocks, chain verified OK")


if __name__ == "__main__":
    main()
