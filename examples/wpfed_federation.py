"""End-to-end driver (deliverable b): a full WPFed federation with a
~100M-parameter aggregate model pool — 24 CNN clients x ~420k params
trained for a few hundred aggregate local steps on synthetic non-IID
MNIST, with the blockchain ledger recording every round's announcements.

    PYTHONPATH=src python examples/wpfed_federation.py [--rounds 12] \
        [--schedule gossip --reselect-every 4]
"""
import argparse
import functools

import jax
import jax.numpy as jnp

from repro.configs.paper_models import (FedConfig, mnist_cnn,
                                        recommended_dedupe)
from repro.core import (evaluate, init_state, resolve_schedule, run_rounds,
                        wpfed_program)
from repro.core.chain import Blockchain
from repro.data import make_mnist_federated
from repro.launch.fed import chain_publisher
from repro.models import apply_client_model, init_client_model
from repro.optim import adam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--clients", type=int, default=24)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "kernel", "oracle"],
                    help="selection + exchange backend (DESIGN.md §4, §7)")
    ap.add_argument("--ref-mode", default="personal",
                    choices=["personal", "public"],
                    help="public: shared reference set, M forwards per "
                         "exchange instead of M*N (DESIGN.md §7); also "
                         "enables the Eq. 7 duplicate-evidence dedupe")
    ap.add_argument("--tiling", default="auto",
                    choices=["auto", "oneshot", "tiled"],
                    help="kernel VMEM regime for selection + exchange "
                         "(DESIGN.md §10)")
    ap.add_argument("--schedule", default="sync",
                    choices=["sync", "gossip"],
                    help="gossip: re-select every --reselect-every rounds, "
                         "cheap peer epochs in between (DESIGN.md §8)")
    ap.add_argument("--reselect-every", type=int, default=0,
                    help="gossip period G (0 = schedule default)")
    args = ap.parse_args()
    sched = resolve_schedule(args.schedule, args.reselect_every)

    fed = FedConfig(num_clients=args.clients, num_neighbors=6, top_k=4,
                    local_steps=args.local_steps, lsh_bits=256,
                    selection_backend=args.backend,
                    exchange_backend=args.backend, ref_mode=args.ref_mode,
                    selection_tiling=args.tiling,
                    exchange_tiling=args.tiling,
                    dedupe_rankings=recommended_dedupe(args.ref_mode))
    ds = make_mnist_federated(num_clients=args.clients, per_client=200,
                              ref_per_client=32)
    data = {k: jnp.asarray(v) for k, v in ds.stacked().items()}
    mcfg = mnist_cnn()
    apply_fn = functools.partial(apply_client_model, mcfg)
    opt = adam(fed.lr)
    state = init_state(apply_fn, lambda k: init_client_model(mcfg, k), opt,
                       fed, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"{args.clients} clients x "
          f"{n_params // args.clients:,} params = {n_params:,} total; "
          f"{args.rounds} rounds x {fed.local_steps} local steps")

    # the engine drives whole reselection periods (gossip epochs under
    # lax.scan) and publishes each reselection's announcements +
    # reveals on the host ledger (DESIGN.md §8)
    chain = Blockchain()
    state, history = run_rounds(
        wpfed_program(apply_fn, opt, fed), state, data,
        rounds=args.rounds, schedule=sched,
        eval_fn=lambda st, d: {"acc": evaluate(apply_fn, st, d)["mean_acc"]},
        on_reselect=chain_publisher(chain, args.clients),
        log=lambda line: print(line, flush=True))
    last = history[-1]
    print(f"final: acc {last['acc']:.4f} "
          f"verified {last['valid_neighbor_frac']:.2f}")
    assert chain.verify_chain(), "ledger integrity violated"
    print(f"ledger: {len(chain.blocks)} blocks "
          f"({sched.reselect_every}-round periods), chain verified OK")


if __name__ == "__main__":
    main()
