"""Quickstart: 60 seconds with the repro framework.

1. WPFed federation round on synthetic non-IID data (the paper's core).
2. LSH codes + Hamming similarity with the Pallas kernels.
3. A reduced transformer from the 10-arch zoo: one train step + decode.

    PYTHONPATH=src python examples/quickstart.py
"""
import functools

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------- 1. WPFed
from repro.configs.paper_models import FedConfig, mnist_cnn
from repro.core import evaluate, init_state, make_wpfed_round
from repro.data import make_mnist_federated
from repro.models import apply_client_model, init_client_model
from repro.optim import adam

print("== 1. one WPFed round (8 clients, non-IID synthetic MNIST) ==")
fed = FedConfig(num_clients=8, num_neighbors=3, top_k=3, local_steps=2,
                lsh_bits=128)
ds = make_mnist_federated(num_clients=8, per_client=80, ref_per_client=16)
data = {k: jnp.asarray(v) for k, v in ds.stacked().items()}
mcfg = mnist_cnn()
apply_fn = functools.partial(apply_client_model, mcfg)
opt = adam(fed.lr)
state = init_state(apply_fn, lambda k: init_client_model(mcfg, k), opt, fed,
                   jax.random.PRNGKey(0))
round_fn = jax.jit(make_wpfed_round(apply_fn, opt, fed))
state, metrics = round_fn(state, data)
print(f"  mean loss {float(metrics['mean_loss']):.3f}, "
      f"LSH-verified neighbor fraction "
      f"{float(metrics['valid_neighbor_frac']):.2f}")
print(f"  accuracy after 1 round: "
      f"{float(evaluate(apply_fn, state, data)['mean_acc']):.3f}")

# ------------------------------------------------- 2. LSH + Hamming kernels
from repro.kernels import ops

print("== 2. LSH codes (Pallas kernel, interpret mode on CPU) ==")
p_a = {"w": jax.random.normal(jax.random.PRNGKey(1), (4096,))}
p_b = jax.tree.map(lambda x: x + 0.02 * jax.random.normal(
    jax.random.PRNGKey(2), x.shape), p_a)     # near-copy
p_c = {"w": jax.random.normal(jax.random.PRNGKey(3), (4096,))}
codes = jnp.stack([ops.lsh_code(p, seed=5, bits=256)
                   for p in (p_a, p_b, p_c)])
d = ops.hamming_matrix(codes)
print(f"  Hamming(similar)={int(d[0, 1])}/256  "
      f"Hamming(unrelated)={int(d[0, 2])}/256")

# --------------------------------------------- 3. transformer zoo (reduced)
from repro.configs import get_config
from repro.models import init_params
from repro.optim import adamw
from repro.train import init_train_state, make_train_step, make_serve_step
from repro.models.transformer import prefill

print("== 3. reduced phi3 config: train step + prefill/decode ==")
cfg = get_config("phi3-medium-14b").reduced()
opt2 = adamw(1e-3)
params, opt_state = init_train_state(cfg, opt2, jax.random.PRNGKey(4))
toks = jax.random.randint(jax.random.PRNGKey(5), (2, 32), 0, cfg.vocab_size)
batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
step = jax.jit(make_train_step(cfg, opt2, remat="none"))
params, opt_state, m = step(params, opt_state, batch)
print(f"  train loss {float(m['loss']):.3f}")
logits, cache = prefill(cfg, params, toks, cache_len=40)
serve = jax.jit(make_serve_step(cfg))
tok = jnp.argmax(logits, -1).astype(jnp.int32)
out = [int(tok[0])]
for i in range(4):
    tok, _, cache = serve(params, cache, tok, jnp.int32(32 + i))
    out.append(int(tok[0]))
print(f"  greedy continuation: {out}")
print("quickstart OK")
