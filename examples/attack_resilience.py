"""Attack-resilience demo (paper §4.7-4.8): the LSH-cheating attack
against WPFed, with and without the trust-free defenses — expressed as
an in-graph `core.adversary.ThreatModel` and run through the
round-program engine (DESIGN.md §8-§9), so the adversarial run compiles
into the same segments as a clean one and `--reselect-every G` gossips
between reselections with the attack still firing inside the scan.

    PYTHONPATH=src python examples/attack_resilience.py
    PYTHONPATH=src python examples/attack_resilience.py \
        --clients 6 --rounds 3 --per-client 48   # reduced (CI smoke)
"""
import argparse
import functools

import jax
import jax.numpy as jnp

from repro.configs.paper_models import FedConfig, mnist_cnn
from repro.core import (Schedule, evaluate, init_state, instrument_program,
                        resolve_attack, run_rounds, threat_model,
                        wpfed_program)
from repro.data import make_mnist_federated
from repro.models import apply_client_model, init_client_model
from repro.optim import adam


def run(lsh_verification: bool, *, clients=8, rounds=6, attack_at=2,
        per_client=100, reselect_every=1):
    n_nb = min(4, clients - 1)
    fed = FedConfig(num_clients=clients, num_neighbors=n_nb,
                    top_k=max(2, n_nb - 1), local_steps=2, lsh_bits=128,
                    lsh_verification=lsh_verification)
    ds = make_mnist_federated(num_clients=clients, per_client=per_client,
                              ref_per_client=16)
    data = {k: jnp.asarray(v) for k, v in ds.stacked().items()}
    mcfg = mnist_cnn()
    apply_fn = functools.partial(apply_client_model, mcfg)
    init_fn = lambda k: init_client_model(mcfg, k)
    opt = adam(fed.lr)
    state = init_state(apply_fn, init_fn, opt, fed, jax.random.PRNGKey(0))

    # half the pool corrupts its params and forges the target's LSH
    # code, every round from attack_at — scheduled in-graph
    tm = threat_model(
        [resolve_attack("corrupt", init_fn=init_fn, start_round=attack_at),
         resolve_attack("forge_codes", target_id=0, start_round=attack_at)],
        jnp.arange(clients) >= clients // 2,
        key=jax.random.PRNGKey(9), name="lsh-cheat")
    program = instrument_program(wpfed_program(apply_fn, opt, fed), tm)
    honest = (~tm.attacker_mask).astype(jnp.float32)
    eval_fn = lambda st, d: {"acc": evaluate(
        apply_fn, st, d, honest_mask=honest)["mean_acc"]}
    _state, history = run_rounds(program, state, data, rounds=rounds,
                                 schedule=Schedule(reselect_every),
                                 eval_fn=eval_fn)
    return [h["acc"] for h in history]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--attack-at", type=int, default=2)
    ap.add_argument("--per-client", type=int, default=100)
    ap.add_argument("--reselect-every", type=int, default=1,
                    help="gossip period G (attacks fire inside the "
                         "compiled gossip scan too)")
    args = ap.parse_args(argv)
    kw = dict(clients=args.clients, rounds=args.rounds,
              attack_at=args.attack_at, per_client=args.per_client,
              reselect_every=args.reselect_every)
    print("LSH-cheating attack from round", args.attack_at)
    with_v = run(lsh_verification=True, **kw)
    without_v = run(lsh_verification=False, **kw)
    print(f"{'round':>5s} {'WPFed (verified)':>18s} {'no verification':>16s}")
    for r, (a, b) in enumerate(zip(with_v, without_v)):
        mark = "  <- attack on" if r >= args.attack_at else ""
        print(f"{r:5d} {a:18.4f} {b:16.4f}{mark}")
    print(f"\nfinal honest-client accuracy: verified={with_v[-1]:.4f} "
          f"vs unverified={without_v[-1]:.4f}")


if __name__ == "__main__":
    main()
