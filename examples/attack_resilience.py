"""Attack-resilience demo (paper §4.7-4.8): LSH-cheating and poison
attacks against WPFed, with and without the trust-free defenses.

    PYTHONPATH=src python examples/attack_resilience.py
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs.paper_models import FedConfig, mnist_cnn
from repro.core import attacks, evaluate, init_state, make_wpfed_round
from repro.data import make_mnist_federated
from repro.models import apply_client_model, init_client_model
from repro.optim import adam

M, ROUNDS, ATTACK_AT = 8, 6, 2


def run(lsh_verification: bool):
    fed = FedConfig(num_clients=M, num_neighbors=4, top_k=3, local_steps=2,
                    lsh_bits=128, lsh_verification=lsh_verification)
    ds = make_mnist_federated(num_clients=M, per_client=100,
                              ref_per_client=16)
    data = {k: jnp.asarray(v) for k, v in ds.stacked().items()}
    mcfg = mnist_cnn()
    apply_fn = functools.partial(apply_client_model, mcfg)
    init_fn = lambda k: init_client_model(mcfg, k)
    opt = adam(fed.lr)
    state = init_state(apply_fn, init_fn, opt, fed, jax.random.PRNGKey(0))
    round_fn = jax.jit(make_wpfed_round(apply_fn, opt, fed))
    attacker = jnp.arange(M) >= M // 2          # half the pool, forging
    honest = (~attacker).astype(jnp.float32)
    accs = []
    for r in range(ROUNDS):
        if r >= ATTACK_AT:
            state = attacks.corrupt_params(
                state, attacker, init_fn,
                jax.random.fold_in(jax.random.PRNGKey(9), r))
            state = attacks.forge_lsh_codes(state, attacker, target_id=0)
        state, m = round_fn(state, data)
        ev = evaluate(apply_fn, state, data, honest_mask=honest)
        accs.append(float(ev["mean_acc"]))
    return accs


def main():
    print("LSH-cheating attack from round", ATTACK_AT)
    with_v = run(lsh_verification=True)
    without_v = run(lsh_verification=False)
    print(f"{'round':>5s} {'WPFed (verified)':>18s} {'no verification':>16s}")
    for r, (a, b) in enumerate(zip(with_v, without_v)):
        mark = "  <- attack on" if r >= ATTACK_AT else ""
        print(f"{r:5d} {a:18.4f} {b:16.4f}{mark}")
    print(f"\nfinal honest-client accuracy: verified={with_v[-1]:.4f} "
          f"vs unverified={without_v[-1]:.4f}")


if __name__ == "__main__":
    main()
