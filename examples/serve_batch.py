"""Batched serving demo: prefill a batch of prompts, decode with a KV
cache, report tokens/s — including the sliding-window serving variant
used by the long_500k dry-run shape.

    PYTHONPATH=src python examples/serve_batch.py
"""
from repro.launch.serve import serve

for arch, window in (("phi3-medium-14b", 0),
                     ("phi3-medium-14b", 16),     # sliding-window variant
                     ("recurrentgemma-2b", 0),    # hybrid: ring + RG-LRU
                     ("whisper-small", 0)):       # enc-dec cross-attn
    res = serve(arch, batch=4, prompt_len=24, max_new=12, reduced=True,
                window_override=window)
    label = f"{arch}" + (f" (window={window})" if window else "")
    print(f"{label:40s} prefill {res['prefill_s']:.2f}s   "
          f"decode {res['decode_tok_per_s']:7.1f} tok/s   "
          f"sample {res['generated'][0][:6]}")
