"""Sharding-rule consistency: spec trees must mirror param/cache trees,
and specs must actually bind on a mesh (host 1x1 mesh keeps this on CPU)."""
import functools

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ALL_ARCHS, get_config
from repro.launch.mesh import make_host_mesh
from repro.models import init_cache, init_params
from repro.models.transformer import param_specs
from repro.optim import adamw
from repro.sharding import cache_specs, named, opt_state_specs


def _treedefs_match(tree_a, tree_b):
    ta = jax.tree.structure(tree_a)
    tb = jax.tree.structure(
        tree_b, is_leaf=lambda x: isinstance(x, P))
    return ta == tb


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_specs_mirror_params(arch):
    cfg = get_config(arch).reduced()
    params = jax.eval_shape(functools.partial(init_params, cfg),
                            jax.random.PRNGKey(0))
    specs = param_specs(cfg)
    assert _treedefs_match(params, specs), arch
    # every spec has rank <= param rank
    for leaf, spec in zip(
            jax.tree.leaves(params),
            jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
        assert len(spec) <= len(leaf.shape), (arch, leaf.shape, spec)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_opt_specs_mirror_state(arch):
    cfg = get_config(arch).reduced()
    params = jax.eval_shape(functools.partial(init_params, cfg),
                            jax.random.PRNGKey(0))
    opt = adamw(1e-3)
    state = jax.eval_shape(opt.init, params)
    specs = opt_state_specs(cfg)
    assert _treedefs_match(state, specs), arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_cache_specs_mirror_cache(arch):
    cfg = get_config(arch).reduced()
    mesh = make_host_mesh()
    params = init_params(cfg, jax.random.PRNGKey(0))
    extra = None
    if cfg.is_encdec:
        extra = {"audio": jnp.zeros((2, cfg.encoder_seq_len, cfg.d_model))}
    if cfg.vision_tokens:
        extra = {"vision": jnp.zeros((2, cfg.vision_tokens, cfg.vision_dim))}
    cache = jax.eval_shape(
        functools.partial(init_cache, cfg, batch=2, cache_len=8),
        params, extra=extra)
    specs = cache_specs(cfg, mesh)
    assert _treedefs_match(cache, specs), arch


def test_specs_bind_on_mesh():
    """NamedSharding construction + jit with in_shardings on a 1x1 mesh."""
    cfg = get_config("phi3-medium-14b").reduced()
    mesh = make_host_mesh()
    params = init_params(cfg, jax.random.PRNGKey(0))
    shardings = named(mesh, param_specs(cfg))
    placed = jax.device_put(params, shardings)
    from repro.models import forward
    tokens = jnp.zeros((2, 8), jnp.int32)
    with mesh:
        logits, _ = jax.jit(lambda p, t: forward(cfg, p, t))(placed, tokens)
    assert logits.shape == (2, 8, cfg.vocab_size)
