"""Round-program engine (core.rounds, DESIGN.md §8).

The load-bearing guarantees:
  * Schedule(reselect_every=1) through the engine is BIT-EXACT with the
    pre-engine sync compositions — for WPFed and all four baselines the
    legacy round bodies are copied verbatim into this module as oracles,
    so any numeric drift in the re-expression fails here.
  * Gossip epochs reuse the reselection's SelectResult: codes, rankings
    and commitments are frozen between reselections while params train.
  * run_rounds syncs with the host once per reselection (the Blockchain
    publishing point) and reports per-round scalar history.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FedState, Schedule, announce_phase, evaluate,
                        exchange_phase, init_state, make_program,
                        make_segment_fn, run_rounds, select_phase,
                        update_phase, wpfed_program)
from repro.core.chain import Blockchain
from repro.core.protocol import batched_local_update
from repro.core.rounds import (RoundProgram, program_round,
                               resolve_schedule)
from repro.core import verify


def _bitwise_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype and x.shape == y.shape
        assert x.tobytes() == y.tobytes()


@pytest.fixture(scope="module")
def ctx(tiny_fed):
    f = dict(tiny_fed)
    f["state0"] = init_state(f["apply_fn"], f["init_fn"], f["opt"],
                             f["fed"], jax.random.PRNGKey(0))
    return f


# ---------------------------------------------------------------------------
# legacy oracles: the pre-engine round bodies, verbatim
# ---------------------------------------------------------------------------
def _legacy_wpfed_round(apply_fn, optimizer, fed):
    def round_fn(state, data):
        rng, rng_sel, rng_upd = jax.random.split(state.rng, 3)
        sel = select_phase(state, fed, rng=rng_sel)
        exch = exchange_phase(apply_fn, fed, state.params, data, sel)
        params, opt_state, train_metrics = update_phase(
            apply_fn, optimizer, fed, state.params, state.opt_state,
            data, exch, rng_upd)
        ann = announce_phase(fed, params, sel, exch, state.round)
        n_sel = jnp.sum(sel.sel_mask.astype(jnp.float32))
        metrics = {
            "mean_loss": jnp.mean(train_metrics["loss"]),
            "mean_neighbor_loss": (
                jnp.sum(jnp.where(sel.sel_mask, exch.l_ij, 0.0))
                / jnp.maximum(n_sel, 1.0)),
            "valid_neighbor_frac": jnp.mean(
                exch.valid_mask.astype(jnp.float32)),
        }
        new_state = FedState(params, opt_state, ann.codes, ann.rankings,
                             ann.commitments, rng, state.round + 1)
        return new_state, metrics
    return round_fn


def _legacy_silo_round(apply_fn, optimizer, fed):
    m = fed.num_clients

    def round_fn(state, data):
        rng, rng_upd = jax.random.split(state.rng)
        upd_keys = jax.vmap(
            lambda i: jax.random.fold_in(rng_upd, i))(jnp.arange(m))
        dummy = jnp.zeros_like(
            jax.vmap(apply_fn)(state.params, data["x_ref"]))
        data_per = {k: data[k] for k in
                    ("x_train", "y_train", "x_ref", "y_ref")}
        params, opt_state, tm = batched_local_update(
            apply_fn, optimizer, fed, state.params, state.opt_state,
            data_per, dummy, jnp.zeros((m,), bool), upd_keys)
        return state._replace(params=params, opt_state=opt_state, rng=rng,
                              round=state.round + 1), \
            {"mean_loss": jnp.mean(tm["loss"])}
    return round_fn


def _legacy_fedmd_round(apply_fn, optimizer, fed, shared_ref_x):
    m = fed.num_clients

    def round_fn(state, data):
        rng, rng_upd = jax.random.split(state.rng)
        logits = jax.vmap(apply_fn, in_axes=(0, None))(
            state.params, shared_ref_x)
        consensus = jnp.mean(logits, axis=0)
        upd_keys = jax.vmap(
            lambda i: jax.random.fold_in(rng_upd, i))(jnp.arange(m))
        data_per = {k: data[k] for k in ("x_train", "y_train")}
        data_per["x_ref"] = jnp.broadcast_to(
            shared_ref_x[None], (m,) + shared_ref_x.shape)
        data_per["y_ref"] = jnp.zeros((m, shared_ref_x.shape[0]), jnp.int32)
        params, opt_state, tm = batched_local_update(
            apply_fn, optimizer, fed, state.params, state.opt_state,
            data_per, jnp.broadcast_to(consensus[None], logits.shape),
            jnp.ones((m,), bool), upd_keys)
        return state._replace(params=params, opt_state=opt_state, rng=rng,
                              round=state.round + 1), \
            {"mean_loss": jnp.mean(tm["loss"])}
    return round_fn


def _legacy_proxyfl_round(apply_fn, optimizer, fed, num_peers=3):
    m = fed.num_clients

    def round_fn(state, data):
        rng, rng_pick, rng_upd = jax.random.split(state.rng, 3)
        ids = jax.vmap(
            lambda k: jax.random.choice(k, m, (num_peers,), replace=False)
        )(jnp.stack(list(jax.random.split(rng_pick, m))))
        nb_params = jax.tree.map(lambda p: p[ids], state.params)
        y_web = jax.vmap(jax.vmap(apply_fn, in_axes=(0, None)))(
            nb_params, data["x_ref"])
        target = jnp.mean(y_web, axis=1)
        upd_keys = jax.vmap(
            lambda i: jax.random.fold_in(rng_upd, i))(jnp.arange(m))
        data_per = {k: data[k] for k in
                    ("x_train", "y_train", "x_ref", "y_ref")}
        params, opt_state, tm = batched_local_update(
            apply_fn, optimizer, fed, state.params, state.opt_state,
            data_per, target, jnp.ones((m,), bool), upd_keys)
        return state._replace(params=params, opt_state=opt_state, rng=rng,
                              round=state.round + 1), \
            {"mean_loss": jnp.mean(tm["loss"])}
    return round_fn


def _legacy_kdpdfl_round(apply_fn, optimizer, fed):
    m = fed.num_clients
    n = min(fed.num_neighbors, m - 1)

    def round_fn(state, data):
        rng, rng_upd = jax.random.split(state.rng)
        y_all = jax.vmap(
            jax.vmap(apply_fn, in_axes=(0, None))
        )(jax.tree.map(
            lambda p: jnp.broadcast_to(p[None], (m,) + p.shape),
            state.params), data["x_ref"])
        own = jax.vmap(apply_fn)(state.params, data["x_ref"])
        kls = jax.vmap(lambda o, ys: jax.vmap(
            lambda y: verify.kl_divergence(o, y))(ys))(own, y_all)
        kls = jnp.where(jnp.eye(m, dtype=bool), jnp.inf, kls)
        _, ids = jax.lax.top_k(-kls, n)
        picked = jnp.take_along_axis(
            y_all, ids[:, :, None, None], axis=1)
        target = jnp.mean(picked, axis=1)
        upd_keys = jax.vmap(
            lambda i: jax.random.fold_in(rng_upd, i))(jnp.arange(m))
        data_per = {k: data[k] for k in
                    ("x_train", "y_train", "x_ref", "y_ref")}
        params, opt_state, tm = batched_local_update(
            apply_fn, optimizer, fed, state.params, state.opt_state,
            data_per, target, jnp.ones((m,), bool), upd_keys)
        return state._replace(params=params, opt_state=opt_state, rng=rng,
                              round=state.round + 1), \
            {"mean_loss": jnp.mean(tm["loss"])}
    return round_fn


_LEGACY = {"wpfed": _legacy_wpfed_round, "silo": _legacy_silo_round,
           "fedmd": _legacy_fedmd_round, "proxyfl": _legacy_proxyfl_round,
           "kdpdfl": _legacy_kdpdfl_round}


# ---------------------------------------------------------------------------
# Schedule / resolve_schedule
# ---------------------------------------------------------------------------
def test_schedule_segments_partition_rounds():
    assert list(Schedule(4).segments(10)) == [(0, 4), (4, 4), (8, 2)]
    assert list(Schedule(1).segments(3)) == [(0, 1), (1, 1), (2, 1)]
    assert list(Schedule(5).segments(3)) == [(0, 3)]
    assert list(Schedule(2).segments(0)) == []


def test_schedule_validates():
    with pytest.raises(ValueError):
        Schedule(0)
    with pytest.raises(ValueError):
        Schedule(-1)


def test_resolve_schedule_one_place():
    assert resolve_schedule() == Schedule(1)
    assert resolve_schedule("sync", 1) == Schedule(1)
    assert resolve_schedule("gossip") == Schedule(4)       # default period
    assert resolve_schedule("gossip", 2) == Schedule(2)
    assert resolve_schedule("gossip", 1) == Schedule(1)
    with pytest.raises(ValueError):
        resolve_schedule("async")
    with pytest.raises(ValueError):
        resolve_schedule("sync", 4)      # not silently ignored


def test_make_program_registry(ctx):
    f = ctx
    for name in ("wpfed", "silo", "proxyfl", "kdpdfl"):
        prog = make_program(name, f["apply_fn"], f["opt"], f["fed"])
        assert prog.name == name and prog.gossip_round is not None
    prog = make_program("fedmd", f["apply_fn"], f["opt"], f["fed"],
                        shared_ref_x=f["data"]["x_ref"][0])
    assert prog.name == "fedmd"
    with pytest.raises(KeyError):
        make_program("dsgd", f["apply_fn"], f["opt"], f["fed"])


def test_segment_fn_rejects_gossip_without_body(ctx):
    prog = RoundProgram("global-only",
                        wpfed_program(ctx["apply_fn"], ctx["opt"],
                                      ctx["fed"]).global_round, None)
    make_segment_fn(prog, 1)                               # fine
    with pytest.raises(ValueError):
        make_segment_fn(prog, 2)
    with pytest.raises(ValueError):
        make_segment_fn(prog, 0)


# ---------------------------------------------------------------------------
# Schedule(reselect_every=1) == the pre-engine sync rounds, bitwise
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("method", list(_LEGACY))
def test_engine_sync_bitexact_vs_legacy(ctx, method):
    f = ctx
    kw = {"shared_ref_x": f["data"]["x_ref"][0]} if method == "fedmd" else {}
    legacy = jax.jit(_LEGACY[method](f["apply_fn"], f["opt"], f["fed"], *kw.values()))
    st_legacy = f["state0"]
    for _ in range(3):
        st_legacy, _m = legacy(st_legacy, f["data"])

    prog = make_program(method, f["apply_fn"], f["opt"], f["fed"], **kw)
    st_engine, history = run_rounds(prog, f["state0"], f["data"], rounds=3,
                                    schedule=Schedule(1))
    _bitwise_equal(st_legacy, st_engine)
    assert [h["round"] for h in history] == [0, 1, 2]
    assert np.isfinite(history[-1]["mean_loss"])


def test_program_round_adapter_matches_global(ctx):
    f = ctx
    prog = wpfed_program(f["apply_fn"], f["opt"], f["fed"])
    st_a, _cache, _m = jax.jit(prog.global_round)(f["state0"], f["data"])
    st_b, _m2 = jax.jit(program_round(prog))(f["state0"], f["data"])
    _bitwise_equal(st_a, st_b)


# ---------------------------------------------------------------------------
# gossip epochs: selection cache reuse
# ---------------------------------------------------------------------------
def test_gossip_freezes_codes_rankings_commitments(ctx):
    f = ctx
    prog = wpfed_program(f["apply_fn"], f["opt"], f["fed"])
    st_g, _cache, _m = jax.jit(prog.global_round)(f["state0"], f["data"])
    st, _hist = run_rounds(prog, f["state0"], f["data"], rounds=3,
                           schedule=Schedule(3))
    # announcements frozen across the period's gossip epochs...
    _bitwise_equal((st_g.codes, st_g.rankings, st_g.commitments),
                   (st.codes, st.rankings, st.commitments))
    # ...while the models keep training and the round index advances
    assert int(st.round) == 3
    p_g, p = jax.tree.leaves(st_g.params)[0], jax.tree.leaves(st.params)[0]
    assert not np.array_equal(np.asarray(p_g), np.asarray(p))


def test_gossip_metrics_reuse_cached_neighbor_ids(ctx):
    f = ctx
    prog = wpfed_program(f["apply_fn"], f["opt"], f["fed"])
    seg = jax.jit(make_segment_fn(prog, 3))
    _st, metrics = seg(f["state0"], f["data"])
    ids = np.asarray(metrics["neighbor_ids"])               # (3, M, N)
    assert ids.shape[0] == 3
    assert np.array_equal(ids[1], ids[0])
    assert np.array_equal(ids[2], ids[0])
    assert np.asarray(metrics["round"]).tolist() == [0, 1, 2]


def test_reselection_changes_partners_across_segments(ctx):
    """After a full period the global round re-codes and re-selects:
    codes must differ across reselections (per-round LSH seed rotation)."""
    f = ctx
    prog = wpfed_program(f["apply_fn"], f["opt"], f["fed"])
    st1, _ = run_rounds(prog, f["state0"], f["data"], rounds=2,
                        schedule=Schedule(2))
    st2, _ = run_rounds(prog, st1, f["data"], rounds=2, schedule=Schedule(2))
    assert not bool(jnp.all(st1.codes == st2.codes))


@pytest.mark.parametrize("method", ["silo", "fedmd", "proxyfl", "kdpdfl"])
def test_baseline_gossip_epochs_run(ctx, method):
    f = ctx
    kw = {"shared_ref_x": f["data"]["x_ref"][0]} if method == "fedmd" else {}
    prog = make_program(method, f["apply_fn"], f["opt"], f["fed"], **kw)
    st, hist = run_rounds(prog, f["state0"], f["data"], rounds=4,
                          schedule=Schedule(2))
    assert int(st.round) == 4
    assert all(np.isfinite(h["mean_loss"]) for h in hist)


def test_proxyfl_gossip_reuses_peer_draw(ctx):
    f = ctx
    prog = make_program("proxyfl", f["apply_fn"], f["opt"], f["fed"])
    st, ids, _m = jax.jit(prog.global_round)(f["state0"], f["data"])
    st2, ids2, _m2 = jax.jit(prog.gossip_round)(st, f["data"], ids)
    assert np.array_equal(np.asarray(ids), np.asarray(ids2))
    assert int(st2.round) == 2


# ---------------------------------------------------------------------------
# run_rounds driver: host sync, history, ledger
# ---------------------------------------------------------------------------
def test_on_reselect_fires_once_per_period(ctx):
    f = ctx
    prog = wpfed_program(f["apply_fn"], f["opt"], f["fed"])
    calls = []
    st, hist = run_rounds(prog, f["state0"], f["data"], rounds=5,
                          schedule=Schedule(2),
                          on_reselect=lambda r0, s: calls.append(
                              (r0, int(s.round))))
    assert calls == [(0, 2), (2, 4), (4, 5)]               # short tail period
    assert [h["round"] for h in hist] == [0, 1, 2, 3, 4]


def test_history_carries_eval_and_scalars_only(ctx):
    f = ctx
    prog = wpfed_program(f["apply_fn"], f["opt"], f["fed"])
    eval_fn = lambda st, d: {"acc": evaluate(f["apply_fn"], st, d)["mean_acc"]}
    _st, hist = run_rounds(prog, f["state0"], f["data"], rounds=2,
                           schedule=Schedule(2), eval_fn=eval_fn)
    for h in hist:
        assert 0.0 <= h["acc"] <= 1.0
        assert "neighbor_ids" not in h                     # arrays stay out
        assert isinstance(h["round"], int)


def test_engine_publishes_verifiable_ledger(ctx):
    """Blockchain wiring end-to-end: one block per reselection, chain
    verifies, and each block's commitments match the revealed rankings
    (Eq. 9-10 commit-and-reveal on the host ledger)."""
    from repro.core.chain import verify_reveal
    from repro.launch.fed import chain_publisher
    f = ctx
    m = f["fed"].num_clients
    prog = wpfed_program(f["apply_fn"], f["opt"], f["fed"])
    chain = Blockchain()
    _st, _hist = run_rounds(prog, f["state0"], f["data"], rounds=4,
                            schedule=Schedule(2),
                            on_reselect=chain_publisher(chain, m))
    assert chain.verify_chain()
    assert len(chain.blocks) == 3                          # genesis + 2
    for blk in chain.blocks[1:]:
        for i, reveal in blk.payload["reveals"].items():
            assert verify_reveal(
                blk.payload["announcements"][i]["commit"],
                np.asarray(reveal, np.int64))
    # tamper -> detected
    chain.blocks[1].payload["reveals"]["0"] = [0, 0, 0]
    assert not chain.verify_chain()
