"""Unit tests for the dry-run analysis helpers (pure functions — no
device-count forcing needed): HLO collective parsing, spec sanitizing,
model-FLOPs accounting, input specs."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P


# import via module path without triggering the XLA_FLAGS side effect?
# dryrun sets XLA_FLAGS at import — harmless here because jax is already
# initialized with 1 device in the test process (flag is ignored after
# first init), and the helpers under test are pure.
from repro.launch import dryrun as dr
from repro.configs import SHAPES, get_config


def test_collective_stats_parses_ops():
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(%x), replica_groups={}
  %ar = f32[512]{0} all-reduce(%y), to_apply=%add
  %rs = (bf16[8,64]{1,0}, bf16[8,64]{1,0}) reduce-scatter(%a, %b)
  %aa = s32[4,4]{1,0} all-to-all(%c)
  %cp = bf16[2,2]{1,0} collective-permute(%d)
  %ags = bf16[32]{0} all-gather-start(%e)
  %dot = f32[8,8]{1,0} dot(%p, %q)
"""
    st = dr.collective_stats(hlo)
    assert st["num_collectives"] == 6
    kinds = st["bytes_by_kind"]
    assert kinds["all-gather"] == 16 * 1024 * 2 + 32 * 2
    assert kinds["all-reduce"] == 512 * 4
    assert kinds["reduce-scatter"] == 2 * 8 * 64 * 2
    assert kinds["all-to-all"] == 16 * 4
    assert kinds["collective-permute"] == 4 * 2
    assert st["total_bytes"] == sum(kinds.values())


def test_collective_stats_ignores_non_collectives():
    st = dr.collective_stats("%dot = f32[128,128]{1,0} dot(%a, %b)")
    assert st["num_collectives"] == 0
    assert st["total_bytes"] == 0


def test_sanitize_drops_indivisible_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # pretend a 16-wide model axis via a fake mesh is hard on 1 device;
    # test the divisibility logic directly with the 1x1 mesh (every dim
    # divides 1, so specs pass through)
    sds = jax.ShapeDtypeStruct((51865, 64), jnp.float32)
    spec = P("model", None)
    out = dr._sanitize(spec, sds, mesh)
    assert out == spec


def test_sanitize_mixed_tree():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {"a": P("data", None), "b": P(("data", "model"), None)}
    sds = {"a": jax.ShapeDtypeStruct((4, 2), jnp.float32),
           "b": jax.ShapeDtypeStruct((8, 2), jnp.float32)}
    out = dr._sanitize(tree, sds, mesh)
    assert out["a"] == P("data", None)


def test_model_flops_modes():
    cfg = get_config("phi3-medium-14b")
    n = cfg.active_param_count()
    tr = dr.model_flops(cfg, SHAPES["train_4k"])
    pf = dr.model_flops(cfg, SHAPES["prefill_32k"])
    dc = dr.model_flops(cfg, SHAPES["decode_32k"])
    assert tr == 6.0 * n * 256 * 4096
    assert pf == 2.0 * n * 32 * 32768
    assert dc == 2.0 * n * 128


def test_model_flops_moe_uses_active():
    kimi = get_config("kimi-k2-1t-a32b")
    tr = dr.model_flops(kimi, SHAPES["train_4k"])
    assert tr < 6.0 * kimi.param_count() * 256 * 4096 / 10  # 1T total


def test_input_specs_shapes():
    cfg = get_config("whisper-small")
    sp = dr.input_specs(cfg, SHAPES["train_4k"])
    assert sp["tokens"].shape == (256, 4096)
    assert sp["audio"].shape == (256, cfg.encoder_seq_len, cfg.d_model)
    sp_d = dr.input_specs(cfg, SHAPES["decode_32k"])
    assert sp_d["tokens"].shape == (128,)
    vlm = get_config("llama-3.2-vision-90b")
    sp_v = dr.input_specs(vlm, SHAPES["prefill_32k"])
    assert sp_v["vision"].shape == (32, vlm.vision_tokens, vlm.vision_dim)
