"""Sub-quadratic ANN selection (DESIGN.md §11).

Contracts:
  * the ANN kernel (`fused_select_ann`) is bit-exact vs its jnp twin
    (`ref.ann_select_ref`) on the same candidate sets — ragged M,
    every prefix/probe combination;
  * the one-bucket fallback (prefix_bits=0) is bit-exact vs the EXACT
    selection path (`fused_select` / `fused_select_ref`), including
    all-identical-codes degeneracy at any prefix length;
  * candidate generation is deterministic in the seed, scan-safe with
    a traced seed, and produces pairwise-distinct valid ids per row;
  * ragged/skewed buckets (one giant bucket, empty probe buckets) keep
    the N=M-1 clamp, self-mask, and all-True sel_mask invariants;
  * recall@N vs the exact oracle >= 0.95 on clustered codes at the
    paper's (bits=256, N=12) config;
  * `backends.resolve_selection` routes "auto" by the FLOP estimate
    and still rejects unknown strings; exchange keeps rejecting "ann";
  * the `lsh_cheat` ThreatModel's admission telemetry works under
    selection_backend="ann".
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import FedConfig
from repro.core import (ann, backends, init_state, instrument_program,
                        neighbor, resolve_threat, run_rounds, wpfed_program)
from repro.kernels import ops, ref
from repro.kernels.selection import fused_select, fused_select_ann

GAMMA = 1.0


def _codes(m, words, seed=0):
    raw = jax.random.bernoulli(jax.random.PRNGKey(seed), 0.5, (m, words * 32))
    return ops.pack_bits(jnp.where(raw, 1.0, -1.0))


def _scores(m, seed=1):
    return jax.random.uniform(jax.random.PRNGKey(seed), (m,))


def _clustered_codes(m, words, n_clusters, flip=0.05, seed=0):
    """Cluster centers + per-client bit flips: the structured regime
    ANN bucketing is designed for (close models agree on most bits)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    bits = words * 32
    centers = jax.random.bernoulli(k1, 0.5, (n_clusters, bits))
    assign = jax.random.randint(k2, (m,), 0, n_clusters)
    flips = jax.random.bernoulli(k3, flip, (m, bits))
    raw = jnp.logical_xor(centers[assign], flips)
    return ops.pack_bits(jnp.where(raw, 1.0, -1.0))


def _ann_pair(codes, scores, *, seed, prefix_bits, probes, n, bits):
    cand = ann.ann_candidates(codes, scores, seed=seed,
                              prefix_bits=prefix_bits, probes=probes,
                              num_neighbors=n)
    k = fused_select_ann(codes, scores, cand.ids, bits=bits, gamma=GAMMA,
                         num_neighbors=n, interpret=True)
    r = ref.ann_select_ref(codes, scores, cand.ids, bits=bits, gamma=GAMMA,
                           num_neighbors=n)
    return cand, k, r


# ---------------------------------------------------------------------------
# candidate generation: determinism, seeding, structure
# ---------------------------------------------------------------------------
def test_prefix_bit_indices_deterministic_and_seed_dependent():
    a = ann.prefix_bit_indices(256, 10, 3)
    b = ann.prefix_bit_indices(256, 10, 3)
    c = ann.prefix_bit_indices(256, 10, 4)
    assert bool(jnp.all(a == b))
    assert not bool(jnp.all(a == c))
    assert a.shape == (10,)
    # a valid permutation prefix: distinct in-range bit positions
    assert len(set(np.asarray(a).tolist())) == 10
    assert int(jnp.min(a)) >= 0 and int(jnp.max(a)) < 256


def test_bucket_table_properties():
    m, pb = 37, 3
    codes = _codes(m, 4, seed=5)
    bit_idx = ann.prefix_bit_indices(128, pb, 0)
    bucket = ann.bucket_ids(codes, bit_idx)
    cap = ann.bucket_cap(m, pb, 5)
    table, counts, rank = ann.build_bucket_table(bucket, m, 1 << pb, cap)
    assert int(jnp.sum(counts)) == m                 # counts partition M
    tb = np.asarray(table)
    for b in range(1 << pb):
        row = tb[b][tb[b] < m]
        # every stored id really lives in bucket b, ascending
        assert all(int(bucket[i]) == b for i in row)
        assert list(row) == sorted(row)
    # each client appears at most once across the whole table
    stored = tb[tb < m]
    assert len(stored) == len(set(stored.tolist()))


@pytest.mark.parametrize("m,pb,probes", [(13, 0, 0), (37, 2, 2),
                                         (64, 4, 3), (10, 6, 6)])
def test_candidates_distinct_and_static_shape(m, pb, probes):
    codes, scores = _codes(m, 4), _scores(m)
    cand = ann.ann_candidates(codes, scores, seed=7, prefix_bits=pb,
                              probes=probes, num_neighbors=5)
    assert cand.ids.shape == (m, ann.candidate_count(m, pb, probes, 5, 128))
    ids = np.asarray(cand.ids)
    for i in range(m):
        valid = ids[i][ids[i] < m]
        assert len(valid) == len(set(valid.tolist()))   # no duplicates
        assert i in valid                # own bucket always holds self


def test_candidate_seed_changes_buckets_traced_under_jit():
    codes, scores = _codes(64, 8), _scores(64)

    @jax.jit
    def gen(seed):
        return ann.ann_candidates(codes, scores, seed=seed, prefix_bits=4,
                                  probes=2, num_neighbors=5).ids

    a, b, c = gen(jnp.int32(3)), gen(jnp.int32(3)), gen(jnp.int32(9))
    assert bool(jnp.all(a == b))
    assert not bool(jnp.all(a == c))


# ---------------------------------------------------------------------------
# kernel vs twin bit-exactness; one-bucket fallback vs the exact path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,words,pb,probes,n", [
    (13, 2, 0, 0, 4), (37, 4, 2, 2, 5), (64, 8, 4, 3, 12),
    (130, 4, 6, 6, 12), (9, 2, 3, 1, 8)])
def test_ann_kernel_matches_twin_bit_exact(m, words, pb, probes, n):
    codes, scores = _codes(m, words, seed=m), _scores(m, seed=m + 1)
    _, (ids_k, w_k), (ids_r, w_r) = _ann_pair(
        codes, scores, seed=7, prefix_bits=pb, probes=probes, n=n,
        bits=words * 32)
    assert bool(jnp.all(ids_k == ids_r))
    assert bool(jnp.all(w_k == w_r))


@pytest.mark.parametrize("m,n", [(13, 4), (37, 12), (64, 5)])
def test_one_bucket_fallback_bit_exact_vs_exact(m, n):
    """prefix_bits=0 -> ONE bucket with cap=M -> candidates are all
    clients in ascending id order -> the ANN path must equal the exact
    kernels bit-for-bit, tie-breaking included (acceptance pin)."""
    bits = 128
    codes, scores = _codes(m, bits // 32, seed=m), _scores(m, seed=m + 2)
    _, (ids_k, w_k), (ids_r, w_r) = _ann_pair(
        codes, scores, seed=0, prefix_bits=0, probes=0, n=n, bits=bits)
    ids_f, w_f = fused_select(codes, scores, bits=bits, gamma=GAMMA,
                              num_neighbors=n, interpret=True)
    ids_o, w_o = ref.fused_select_ref(codes, scores, bits=bits, gamma=GAMMA,
                                      num_neighbors=n)
    for ids, w in [(ids_k, w_k), (ids_r, w_r)]:
        assert bool(jnp.all(ids == ids_f)) and bool(jnp.all(w == w_f))
        assert bool(jnp.all(ids == ids_o)) and bool(jnp.all(w == w_o))


def test_all_identical_codes_bit_exact_vs_exact():
    """Degenerate skew: every client in ONE giant bucket regardless of
    prefix. Distances are all 0, so Eq. 8 reduces to the score order —
    the teaser + shared bucket must reproduce the exact top-N."""
    m, bits, n = 24, 128, 6
    codes = jnp.broadcast_to(_codes(1, bits // 32, seed=3), (m, bits // 32))
    scores = _scores(m, seed=4)
    for pb, probes in [(0, 0), (4, 2), (6, 6)]:
        _, (ids_k, w_k), (ids_r, w_r) = _ann_pair(
            codes, scores, seed=11, prefix_bits=pb, probes=probes, n=n,
            bits=bits)
        ids_o, w_o = ref.fused_select_ref(codes, scores, bits=bits,
                                          gamma=GAMMA, num_neighbors=n)
        assert bool(jnp.all(ids_k == ids_o)) and bool(jnp.all(w_k == w_o))
        assert bool(jnp.all(ids_r == ids_o)) and bool(jnp.all(w_r == w_o))


def test_tiny_m_empty_probe_buckets_bit_exact_vs_exact():
    """M far below the bucket count (m=10, 64 buckets): most probes hit
    EMPTY buckets (all-sentinel tiles) — yet cap + teaser still cover
    every client, so the result stays exactly the exact top-N."""
    m, bits, n = 10, 128, 4
    codes, scores = _codes(m, bits // 32, seed=9), _scores(m, seed=10)
    _, (ids_k, w_k), (ids_r, w_r) = _ann_pair(
        codes, scores, seed=5, prefix_bits=6, probes=6, n=n, bits=bits)
    ids_o, w_o = ref.fused_select_ref(codes, scores, bits=bits, gamma=GAMMA,
                                      num_neighbors=n)
    assert bool(jnp.all(ids_k == ids_o)) and bool(jnp.all(w_k == w_o))
    assert bool(jnp.all(ids_r == ids_o)) and bool(jnp.all(w_r == w_o))


def test_ann_excludes_self_and_clamps_n():
    m = 6
    codes, scores = _codes(m, 4), _scores(m)
    fed = FedConfig(num_clients=m, num_neighbors=50, lsh_bits=128,
                    ann_prefix_bits=3, ann_probes=2)
    ids, mask = neighbor.select_partners(codes, scores, fed, backend="ann")
    assert ids.shape == (m, m - 1)                   # N=M-1 clamp
    assert bool(jnp.all(mask))                       # teaser: never dry
    row = jnp.arange(m, dtype=jnp.int32)[:, None]
    assert not bool(jnp.any(ids == row))             # self-mask
    assert bool(jnp.all((ids >= 0) & (ids < m)))     # real clients only


def test_ann_giant_bucket_skew_valid_selection():
    """One giant bucket (identical codes) + a few singletons: overflow
    drops candidates but every client still queries and gets N valid,
    distinct, non-self partners."""
    m, bits, n = 40, 128, 5
    shared = jnp.broadcast_to(_codes(1, 4, seed=1), (34, 4))
    codes = jnp.concatenate([shared, _codes(6, 4, seed=2)], axis=0)
    scores = _scores(m)
    fed = FedConfig(num_clients=m, num_neighbors=n, lsh_bits=bits,
                    ann_prefix_bits=5, ann_probes=3)
    ids, mask = neighbor.select_partners(codes, scores, fed, backend="ann")
    assert bool(jnp.all(mask))
    row = jnp.arange(m, dtype=jnp.int32)[:, None]
    assert not bool(jnp.any(ids == row))
    for i in range(m):                               # distinct partners
        sel = np.asarray(ids[i]).tolist()
        assert len(sel) == len(set(sel))


# ---------------------------------------------------------------------------
# recall vs the exact oracle
# ---------------------------------------------------------------------------
def test_recall_at_n_clustered_codes_paper_config():
    """Paper config (bits=256, N=12) on clustered codes (98% within-
    cluster bit agreement — a converging federation) with concentrated
    ranking scores (distance-dominated Eq. 8, the regime bucketing is
    built for): recall@N vs the exact oracle must clear the 0.95
    acceptance bar. Score-DISPERSED regimes are intrinsically
    non-local (a globally high-ranked client can enter any row's
    top-N); the benchmark records that recall curve separately rather
    than asserting it away."""
    m, bits, n = 512, 256, 12
    codes = _clustered_codes(m, bits // 32, n_clusters=16, flip=0.02,
                             seed=0)
    scores = 0.75 + 0.25 * _scores(m, seed=1)
    ids_o, _ = ref.fused_select_ref(codes, scores, bits=bits, gamma=GAMMA,
                                    num_neighbors=n)
    cand = ann.ann_candidates(codes, scores, seed=3, prefix_bits=5,
                              probes=5, num_neighbors=n)
    ids_a, _ = ref.ann_select_ref(codes, scores, cand.ids, bits=bits,
                                  gamma=GAMMA, num_neighbors=n)
    exact, approx = np.asarray(ids_o), np.asarray(ids_a)
    hits = sum(len(set(exact[i]) & set(approx[i])) for i in range(m))
    recall = hits / float(m * n)
    assert recall >= 0.95, f"recall@{n} = {recall:.3f}"


# ---------------------------------------------------------------------------
# backend resolution + dispatch
# ---------------------------------------------------------------------------
def test_resolve_selection_routing():
    flops = dict(exact_flops=100.0, ann_flops=1.0)
    assert backends.resolve_selection("ann", 10, **flops) == "ann"
    # "auto" needs BOTH the M floor and the FLOP ratio
    assert backends.resolve_selection(
        "auto", backends.ANN_AUTO_MIN_M, **flops) == "ann"
    assert backends.resolve_selection(
        "auto", backends.ANN_AUTO_MIN_M - 1, **flops) != "ann"
    assert backends.resolve_selection(
        "auto", backends.ANN_AUTO_MIN_M, exact_flops=100.0,
        ann_flops=99.0) != "ann"
    # explicit exact backends never reroute
    assert backends.resolve_selection("oracle", 10 ** 6, **flops) == "oracle"
    assert backends.resolve_selection("kernel", 10 ** 6, **flops) == "kernel"
    with pytest.raises(ValueError, match="unknown selection backend"):
        backends.resolve_selection("annn", 10, **flops)


def test_exchange_resolve_still_rejects_ann():
    with pytest.raises(ValueError, match="unknown backend"):
        backends.resolve("ann")


def test_select_partners_ann_scan_safe_with_traced_seed():
    """The protocol threads seed=state.round through lax.scan — the
    whole ann path must trace with a dynamic seed, and per-round
    reselection must actually change with it."""
    m, n = 32, 5
    codes, scores = _codes(m, 8, seed=6), _scores(m, seed=7)
    fed = FedConfig(num_clients=m, num_neighbors=n, lsh_bits=256,
                    ann_prefix_bits=5, ann_probes=1)

    def body(carry, seed):
        ids, _ = neighbor.select_partners(codes, scores, fed,
                                          backend="ann", seed=seed)
        return carry, ids

    _, out = jax.jit(lambda: jax.lax.scan(
        body, 0, jnp.arange(4, dtype=jnp.int32)))()
    assert out.shape == (4, m, n)
    _, out2 = jax.jit(lambda: jax.lax.scan(
        body, 0, jnp.arange(4, dtype=jnp.int32)))()
    assert bool(jnp.all(out == out2))                # deterministic


def test_select_partners_ann_matches_direct_twin():
    m, n, bits = 48, 6, 128
    codes, scores = _codes(m, bits // 32, seed=8), _scores(m, seed=9)
    fed = FedConfig(num_clients=m, num_neighbors=n, lsh_bits=bits,
                    ann_prefix_bits=4, ann_probes=2)
    ids, mask = neighbor.select_partners(codes, scores, fed, backend="ann",
                                         seed=5)
    cand = ann.ann_candidates(codes, scores, seed=5, prefix_bits=4,
                              probes=2, num_neighbors=n)
    ids_r, w_r = ref.ann_select_ref(codes, scores, cand.ids, bits=bits,
                                    gamma=fed.gamma, num_neighbors=n)
    assert bool(jnp.all(ids == ids_r))
    assert bool(jnp.all(mask == jnp.isfinite(w_r)))


# ---------------------------------------------------------------------------
# threat telemetry under "ann"
# ---------------------------------------------------------------------------
def test_lsh_cheat_admission_telemetry_under_ann(tiny_fed):
    """The §4.7 lsh_cheat threat instrumented over the round program
    must keep producing finite attacker-admission telemetry when
    selection runs through the ANN candidate path."""
    f = tiny_fed
    fed = dataclasses.replace(f["fed"], selection_backend="ann",
                              ann_prefix_bits=3, ann_probes=2)
    state = init_state(f["apply_fn"], f["init_fn"], f["opt"], fed,
                       jax.random.PRNGKey(1))
    tm = resolve_threat("lsh_cheat", num_clients=fed.num_clients,
                        attacker_frac=0.34, init_fn=f["init_fn"],
                        key=jax.random.PRNGKey(2), start_round=1)
    program = instrument_program(wpfed_program(f["apply_fn"], f["opt"], fed),
                                 tm)
    _, history = run_rounds(program, state, f["data"], rounds=3,
                            log=lambda *_a, **_k: None)
    assert len(history) == 3
    for h in history[1:]:                            # post-attack rounds
        assert "attacker_admission_rate" in h
        assert np.isfinite(h["attacker_admission_rate"])
        assert 0.0 <= h["attacker_admission_rate"] <= 1.0
