"""Fault-injection + degraded-mode protocol tests (DESIGN.md §15):
deterministic fault verdicts, retry/backoff, checksum rejection and
last-known-good fallback, the straggler == churn masking-equivalence
invariant, crash-safe checkpoint fallback, ledger rollback refusal,
and longest-valid-chain fork recovery."""
import dataclasses
import os
import types
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import init_state
from repro.core.chain import Blockchain, load_chain, save_chain
from repro.core.faults import (FaultPlan, fault_scalars, fault_u01,
                               leading_failures, parse_fault_spec,
                               period_faults)
from repro.service import (BulletinTransport, ChurnEvent, CrashInjected,
                           LedgerRollbackError, RetryPolicy, ServiceConfig,
                           TransportError, init_service_state, mask_stragglers,
                           resume_service, run_service)
from repro.service.transport import (announcement_checksum, divergent_view,
                                     recover_chain, rollback_view,
                                     write_fork_view)


@pytest.fixture(scope="module")
def svc_env(tiny_fed):
    svc = ServiceConfig(reselect_every=2, keep_last_k=2)
    state = init_service_state(
        init_state(tiny_fed["apply_fn"], tiny_fed["init_fn"],
                   tiny_fed["opt"], tiny_fed["fed"],
                   jax.random.PRNGKey(0)), svc)
    args = (tiny_fed["apply_fn"], tiny_fed["opt"], tiny_fed["fed"], svc)
    return {"svc": svc, "state": state, "args": args, **tiny_fed}


def _fake_state(m=6, words=4, n=3, seed=0):
    """The minimal state surface transport.collect reads."""
    rs = np.random.RandomState(seed)
    fed = types.SimpleNamespace(
        codes=rs.randint(0, 2**32, (m, words), dtype=np.uint32),
        rankings=rs.randint(0, m, (m, n)).astype(np.int32))
    return types.SimpleNamespace(fed=fed)


# ---------------------------------------------------------------------------
# the fault plan: typed, seeded, deterministic
# ---------------------------------------------------------------------------
def test_plan_validation_and_spec_parsing():
    with pytest.raises(ValueError, match="outside"):
        FaultPlan(drop=1.5)
    with pytest.raises(ValueError, match="crash_periods"):
        FaultPlan(crash_periods=(-1,))
    plan = parse_fault_spec(
        "seed=7, drop=0.1, straggle=0.2, publish_fail=0.3, "
        "crash=2, crash=5, fork=1")
    assert plan == FaultPlan(seed=7, drop=0.1, straggle=0.2,
                             publish_fail=0.3, crash_periods=(2, 5),
                             fork_at=1)
    assert plan.eventually_delivering()
    assert not FaultPlan(drop=1.0).eventually_delivering()
    with pytest.raises(ValueError, match="unknown fault spec key"):
        parse_fault_spec("dorp=0.1")
    with pytest.raises(ValueError, match="key=value"):
        parse_fault_spec("drop")


def test_verdicts_deterministic_and_seed_sensitive():
    plan = FaultPlan(seed=3, drop=0.5, delay=0.5, duplicate=0.5,
                     corrupt=0.5, straggle=0.5, publish_fail=0.5)
    a = period_faults(plan, 4, 32, 5)
    b = period_faults(plan, 4, 32, 5)
    for f in ("stragglers", "drop", "delay", "duplicate", "corrupt"):
        assert np.array_equal(getattr(a, f), getattr(b, f))
    assert a.publish_failures == b.publish_failures
    # a different seed is a different fault universe
    c = period_faults(dataclasses.replace(plan, seed=4), 4, 32, 5)
    assert any(not np.array_equal(getattr(a, f), getattr(c, f))
               for f in ("stragglers", "drop", "delay", "corrupt"))
    # draws are uniform-ish and stream-independent
    us = [fault_u01(0, "drop", p, client=c)
          for p in range(20) for c in range(20)]
    assert 0.4 < np.mean(us) < 0.6
    assert all(0.0 <= u < 1.0 for u in us)
    assert fault_u01(0, "drop", 1, client=2) != \
        fault_u01(0, "delay", 1, client=2)


def test_verdict_precedence_mutually_exclusive():
    plan = FaultPlan(seed=1, drop=1.0, delay=1.0, duplicate=1.0,
                     corrupt=1.0)
    pf = period_faults(plan, 0, 8, 5)
    assert pf.drop.all()
    # drop wins: nothing is simultaneously dropped and corrupt/delayed
    assert not (pf.drop & pf.corrupt).any()
    assert not (pf.drop & pf.delay).any()
    assert not (pf.drop & pf.duplicate).any()


def test_fault_scalars_count_announcing_only():
    plan = FaultPlan(seed=1, drop=1.0, straggle=0.0)
    pf = period_faults(plan, 0, 6, 5)
    announcing = np.array([True, True, False, False, False, False])
    s = fault_scalars(pf, announcing)
    assert s["fault_dropped"] == 2.0
    assert s["degraded_round"] == 1.0
    quiet = fault_scalars(period_faults(FaultPlan(seed=1), 0, 6, 5),
                          announcing)
    assert quiet["degraded_round"] == 0.0
    assert all(v == 0.0 for v in quiet.values())


# ---------------------------------------------------------------------------
# retry / backoff
# ---------------------------------------------------------------------------
def test_retry_policy_backoff_and_validation():
    rp = RetryPolicy(max_attempts=5, base_delay_s=0.02, max_delay_s=0.1,
                     jitter=0.25)
    # exponential until the cap, jitter bounded
    assert rp.delay_s(0, 0.5) == pytest.approx(0.02)
    assert rp.delay_s(1, 0.5) == pytest.approx(0.04)
    assert rp.delay_s(4, 0.5) == pytest.approx(0.1)  # capped
    assert rp.delay_s(0, 1.0) <= 0.02 * 1.25 + 1e-12
    assert rp.delay_s(0, 0.0) >= 0.02 * 0.75 - 1e-12
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError, match="jitter"):
        RetryPolicy(jitter=2.0)


def test_publish_retries_then_succeeds_with_backoff():
    # find a seed whose period-0 publish stream fails 1..3 leading
    # attempts — deterministic thereafter
    seed = next(s for s in range(200)
                if 1 <= leading_failures(FaultPlan(seed=s,
                                                   publish_fail=0.6),
                                         "publish_fail", 0, 5) <= 3)
    plan = FaultPlan(seed=seed, publish_fail=0.6)
    n_fail = leading_failures(plan, "publish_fail", 0, 5)
    sleeps = []
    xp = BulletinTransport(Blockchain(), plan=plan, sleep=sleeps.append)
    blk = xp.publish(0, 0, {0: {"lsh": "ab", "commit": "cd",
                                "sum": "ef"}}, {0: [1]})
    assert blk.payload["round"] == 0
    assert len(sleeps) == n_fail
    assert xp.trace.counters["publish_fail"] == n_fail
    assert all(d > 0 for d in sleeps)
    # replaying the same plan replays the identical retry trace
    sleeps2 = []
    xp2 = BulletinTransport(Blockchain(), plan=plan, sleep=sleeps2.append)
    xp2.publish(0, 0, {0: {"lsh": "ab", "commit": "cd", "sum": "ef"}},
                {0: [1]})
    assert sleeps2 == sleeps


def test_publish_exhaustion_raises_and_idempotent_republish():
    xp = BulletinTransport(Blockchain(),
                           plan=FaultPlan(seed=0, publish_fail=1.0),
                           sleep=lambda s: None)
    with pytest.raises(TransportError, match="publish of round 0"):
        xp.publish(0, 0, {}, {})
    # fault-free transport: publish twice -> one block, same object
    ok = BulletinTransport(Blockchain())
    b1 = ok.publish(0, 0, {0: {"lsh": "ab", "commit": "cd",
                               "sum": "ef"}}, {})
    b2 = ok.publish(1, 0, {}, {})
    assert b2 is b1
    assert len(ok.chain.blocks) == 2
    assert ok.fetch(1, 0) is b1
    with pytest.raises(TransportError, match="missing from the ledger"):
        ok.fetch(1, 7)


# ---------------------------------------------------------------------------
# the announcement link: checksum, drop, delay, duplicate
# ---------------------------------------------------------------------------
def test_checksum_travels_and_rejects_corruption():
    st = _fake_state()
    announcing = np.ones(6, bool)
    ok = BulletinTransport(Blockchain())
    ann, reveals, failed, delayed = ok.collect(0, announcing, st)
    assert sorted(ann) == list(range(6)) and not failed.any()
    for e in ann.values():
        assert e["sum"] == announcement_checksum(e)
    # corrupt=1.0: every delivery is damaged in transit and the board's
    # checksum rejects it — nothing poisoned, everything failed
    bad = BulletinTransport(Blockchain(),
                            plan=FaultPlan(seed=2, corrupt=1.0))
    ann2, _, failed2, _ = bad.collect(0, announcing, st)
    assert ann2 == {} and failed2.all()
    assert bad.trace.counters["corrupt"] == 6


def test_drop_delay_duplicate_semantics():
    st = _fake_state()
    announcing = np.ones(6, bool)
    drop = BulletinTransport(Blockchain(), plan=FaultPlan(seed=2, drop=1.0))
    ann, _, failed, delayed = drop.collect(0, announcing, st)
    assert ann == {} and failed.all() and not delayed.any()
    late = BulletinTransport(Blockchain(), plan=FaultPlan(seed=2, delay=1.0))
    ann2, _, failed2, delayed2 = late.collect(0, announcing, st)
    # delayed announcements LAND (fresh on the board), just late
    assert sorted(ann2) == list(range(6))
    assert not failed2.any() and delayed2.all()
    dup = BulletinTransport(Blockchain(),
                            plan=FaultPlan(seed=2, duplicate=1.0))
    ann3, _, failed3, _ = dup.collect(0, announcing, st)
    # byte-identical second copies dedupe to one entry each
    assert sorted(ann3) == list(range(6)) and not failed3.any()
    assert dup.trace.counters["duplicate"] == 6
    # non-announcing clients are untouched by any fault
    ann4, _, failed4, _ = drop.collect(1, np.zeros(6, bool), st)
    assert ann4 == {} and not failed4.any()


def test_corrupt_reverts_to_last_known_good_in_service(svc_env):
    """corrupt=1.0 for one period: the board keeps every client's
    previous codes — the driver's merged state must match (revert +
    age bump), not silently diverge from the ledger."""
    state, data = svc_env["state"], svc_env["data"]
    plan = FaultPlan(seed=5, corrupt=1.0)
    s_f, chain_f, hist = run_service(*svc_env["args"], state, data,
                                     periods=1, faults=plan)
    # nothing landed: the period's block carries zero announcements
    blk = chain_f.round_block(0)
    assert blk is not None and blk.payload["announcements"] == {}
    # device state reverted to the pre-segment codes, aged one period
    assert np.array_equal(np.asarray(s_f.fed.codes),
                          np.asarray(state.fed.codes))
    assert np.array_equal(np.asarray(s_f.fed.rankings),
                          np.asarray(state.fed.rankings))
    assert np.asarray(s_f.code_age).tolist() == [1] * 6
    assert hist[-1]["fault_corrupt"] == 6.0
    assert hist[-1]["degraded_round"] == 1.0
    # params still trained: corruption degrades announcements, not
    # the round's local work
    p0_old = jax.tree.leaves(state.fed.params)[0]
    p0_new = jax.tree.leaves(s_f.fed.params)[0]
    assert not np.array_equal(np.asarray(p0_old), np.asarray(p0_new))


def test_delay_marks_staleness_in_service(svc_env):
    state, data = svc_env["state"], svc_env["data"]
    s_f, chain_f, hist = run_service(*svc_env["args"], state, data,
                                     periods=1,
                                     faults=FaultPlan(seed=5, delay=1.0))
    # fresh codes DID land (board and device agree) ...
    assert not np.array_equal(np.asarray(s_f.fed.codes),
                              np.asarray(state.fed.codes))
    blk = chain_f.round_block(0)
    assert sorted(map(int, blk.payload["announcements"])) == list(range(6))
    # ... but they arrived past the deadline: staleness discount applies
    assert np.asarray(s_f.code_age).tolist() == [1] * 6
    assert hist[-1]["fault_delayed"] == 6.0


# ---------------------------------------------------------------------------
# the masking-equivalence invariant (straggler == one-period churn)
# ---------------------------------------------------------------------------
def test_straggler_round_bit_identical_to_churn_round(svc_env):
    """A round with k stragglers is BIT-IDENTICAL to a round where
    those same k clients are churn-inactive — the degraded-mode
    protocol is the churn protocol, not a second code path."""
    state, data = svc_env["state"], svc_env["data"]
    m = svc_env["fed"].num_clients
    # a seed whose period-0 straggler set is a proper non-empty subset
    seed = next(s for s in range(200) if 0 < period_faults(
        FaultPlan(seed=s, straggle=0.4), 0, m, 5).stragglers.sum() < m)
    plan = FaultPlan(seed=seed, straggle=0.4)
    strag = period_faults(plan, 0, m, 5).stragglers
    s_f, chain_f, hist_f = run_service(*svc_env["args"], state, data,
                                       periods=1, faults=plan)
    events = [ChurnEvent(0, "leave", int(i)) for i in np.nonzero(strag)[0]]
    s_c, chain_c, hist_c = run_service(*svc_env["args"], state, data,
                                       periods=1, events=events)
    # identical protocol state (the faulted run restores membership
    # after the segment; the churn run's leavers are still out)
    assert np.array_equal(
        np.asarray(s_f.active),
        np.asarray(s_c.active) | strag)
    for a, b in zip(jax.tree.leaves(s_f._replace(active=s_c.active)),
                    jax.tree.leaves(s_c)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # identical ledger content: stragglers announced nothing
    assert [b.payload for b in chain_f.blocks] == \
        [b.payload for b in chain_c.blocks]
    assert set(map(int, chain_f.round_block(0).payload["announcements"])
               ) == set(np.nonzero(~strag)[0].tolist())
    # identical per-round metrics (fault counters ride only on the
    # faulted run's entries)
    for hf, hc in zip(hist_f, hist_c):
        for k in hc:
            assert hf[k] == hc[k]
    assert hist_f[-1]["fault_stragglers"] == float(strag.sum())


def test_fault_free_plan_is_bitwise_noop(svc_env):
    """An all-zero-rate FaultPlan engages every hardened path (checksums,
    counter streaming, retry envelope) yet stays bit-identical to no
    plan at all."""
    state, data = svc_env["state"], svc_env["data"]
    s_a, chain_a, hist_a = run_service(*svc_env["args"], state, data,
                                       periods=1)
    s_b, chain_b, hist_b = run_service(*svc_env["args"], state, data,
                                       periods=1, faults=FaultPlan(seed=9))
    for a, b in zip(jax.tree.leaves(s_a), jax.tree.leaves(s_b)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert [b.payload for b in chain_a.blocks] == \
        [b.payload for b in chain_b.blocks]
    for ha, hb in zip(hist_a, hist_b):
        for k in ha:
            assert ha[k] == hb[k]
    assert hist_b[-1]["degraded_round"] == 0.0
    assert "degraded_round" not in hist_a[-1]


# ---------------------------------------------------------------------------
# crash-restart injection
# ---------------------------------------------------------------------------
def test_crash_injection_then_resume_bitwise(svc_env, tmp_path):
    state, data = svc_env["state"], svc_env["data"]
    plan = FaultPlan(seed=3, crash_periods=(1,))
    ck = str(tmp_path / "crash")
    with pytest.raises(CrashInjected, match="period 1"):
        run_service(*svc_env["args"], state, data, periods=2,
                    ckpt_dir=ck, faults=plan)
    # the crash fired after period 1's segment but BEFORE any durable
    # effect: only period 0 is on disk
    s_r, chain_r, p0 = resume_service(ck, state)
    assert p0 == 1
    assert chain_r.head_round() == 0
    # resume replays the crash period (no re-crash at start_period)
    s_k, chain_k, _ = run_service(*svc_env["args"], s_r, data, periods=2,
                                  chain=chain_r, ckpt_dir=ck,
                                  start_period=p0, faults=plan)
    s_u, chain_u, _ = run_service(
        *svc_env["args"], state, data, periods=2,
        ckpt_dir=str(tmp_path / "uninterrupted"),
        faults=dataclasses.replace(plan, crash_periods=()))
    for a, b in zip(jax.tree.leaves(s_k), jax.tree.leaves(s_u)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert [b.payload for b in chain_k.blocks] == \
        [b.payload for b in chain_u.blocks]


# ---------------------------------------------------------------------------
# crash-safe checkpoints (satellite: truncated-snapshot fallback)
# ---------------------------------------------------------------------------
def test_truncated_checkpoint_falls_back_with_warning(svc_env, tmp_path):
    state, data = svc_env["state"], svc_env["data"]
    ck = str(tmp_path / "trunc")
    run_service(*svc_env["args"], state, data, periods=2, ckpt_dir=ck)
    newest = os.path.join(ck, "step_00000001.npz")
    blob = open(newest, "rb").read()
    with open(newest, "wb") as fh:          # simulate a crash mid-write
        fh.write(blob[:len(blob) // 3])
    with pytest.warns(UserWarning, match="falling back"):
        s_r, chain_r, p0 = resume_service(ck, state)
    assert p0 == 1                          # the previous retained snapshot
    # the fallback state is the real period-0 state: continuing from it
    # reproduces the uninterrupted run bitwise
    s_c, chain_c, _ = run_service(*svc_env["args"], s_r, data, periods=2,
                                  chain=chain_r, ckpt_dir=ck,
                                  start_period=p0)
    s_u, chain_u, _ = run_service(*svc_env["args"], state, data,
                                  periods=2,
                                  ckpt_dir=str(tmp_path / "u2"))
    for a, b in zip(jax.tree.leaves(s_c), jax.tree.leaves(s_u)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert [b.payload for b in chain_c.blocks] == \
        [b.payload for b in chain_u.blocks]


def test_every_checkpoint_corrupt_raises(svc_env, tmp_path):
    state, data = svc_env["state"], svc_env["data"]
    ck = str(tmp_path / "allbad")
    run_service(*svc_env["args"], state, data, periods=2, ckpt_dir=ck)
    for f in os.listdir(ck):
        if f.endswith(".npz"):
            with open(os.path.join(ck, f), "wb") as fh:
                fh.write(b"not a zipfile")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with pytest.raises(ValueError, match="failed to load"):
            resume_service(ck, state)


# ---------------------------------------------------------------------------
# ledger rollback refusal + fork recovery (satellites)
# ---------------------------------------------------------------------------
def test_resume_refuses_rolled_back_ledger(svc_env, tmp_path):
    """A ledger that VERIFIES but is shorter than the checkpoint's
    round counter is a silent-rollback symptom — distinct, actionable
    refusal (not the tamper error)."""
    state, data = svc_env["state"], svc_env["data"]
    ck = str(tmp_path / "rb")
    _, chain, _ = run_service(*svc_env["args"], state, data, periods=2,
                              ckpt_dir=ck)
    rolled = rollback_view(chain, 1)
    assert rolled.verify_chain()            # valid — just missing history
    save_chain(os.path.join(ck, "chain.json"), rolled)
    with pytest.raises(LedgerRollbackError, match="behind the"):
        resume_service(ck, state)


def test_fork_recovery_prefers_longest_valid(svc_env, tmp_path):
    state, data = svc_env["state"], svc_env["data"]
    ck = str(tmp_path / "fork")
    _, chain, _ = run_service(*svc_env["args"], state, data, periods=2,
                              ckpt_dir=ck)
    full_head = chain.head_round()
    # the canonical file rolls back; the full history survives only as
    # a fork view — recovery must pick the longer fork and resume
    save_chain(os.path.join(ck, "chain.json"), rollback_view(chain, 1))
    write_fork_view(ck, chain, idx=1)
    s_r, chain_r, p0 = resume_service(ck, state)
    assert p0 == 2 and chain_r.head_round() == full_head
    # a same-length divergent fork NEVER beats the canonical file
    save_chain(os.path.join(ck, "chain.json"), chain)
    write_fork_view(ck, divergent_view(chain, 1), idx=1)
    chosen = recover_chain(ck)
    assert "fork" not in chosen.blocks[-1].payload
    # and an unreadable canonical file falls back to a valid fork
    with open(os.path.join(ck, "chain.json"), "w") as fh:
        fh.write("{corrupt")
    with pytest.warns(UserWarning, match="unreadable"):
        chosen2 = recover_chain(ck)
    assert chosen2.verify_chain()


def test_driver_writes_fork_view_at_fork_at(svc_env, tmp_path):
    state, data = svc_env["state"], svc_env["data"]
    ck = str(tmp_path / "forkat")
    run_service(*svc_env["args"], state, data, periods=2, ckpt_dir=ck,
                faults=FaultPlan(seed=4, fork_at=0))
    assert os.path.exists(os.path.join(ck, "chain.fork0.json"))
    # the injected competitor is the SHORTER view, so a normal resume
    # still picks chain.json
    s_r, chain_r, p0 = resume_service(ck, state)
    assert p0 == 2 and chain_r.head_round() == 2


def test_head_round():
    chain = Blockchain()
    assert chain.head_round() == -1
    chain.publish_round(0, {})
    chain.publish_round(3, {})
    assert chain.head_round() == 3
    assert rollback_view(chain, 1).head_round() == 0
    with pytest.raises(ValueError, match="drop_last"):
        rollback_view(chain, 3)


def test_mask_stragglers_is_churn_masking(svc_env):
    state = svc_env["state"]
    strag = np.array([False, True, False, False, True, False])
    masked = mask_stragglers(state, strag)
    assert np.asarray(masked.active).tolist() == \
        (~strag).tolist()
    # everything else untouched
    for a, b in zip(jax.tree.leaves(state._replace(active=masked.active)),
                    jax.tree.leaves(masked)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
