"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.hamming import hamming_all_pairs
from repro.kernels.lsh_projection import CHUNK, lsh_project_sums


@pytest.mark.parametrize("nchunks", [1, 2, 5])
@pytest.mark.parametrize("bits", [128, 256, 512])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lsh_kernel_matches_oracle(nchunks, bits, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(nchunks), (CHUNK * nchunks,))
         .astype(dtype).astype(jnp.float32))
    k = lsh_project_sums(x, 42, bits=bits, interpret=True)
    r = ref.lsh_project_sums_ref(x, 42, bits=bits)
    scale = 1 + float(jnp.max(jnp.abs(r)))
    assert float(jnp.max(jnp.abs(k - r))) < 1e-3 * scale


@pytest.mark.parametrize("m,n", [(32, 128), (64, 256), (128, 128)])
@pytest.mark.parametrize("words", [128, 256])
def test_hamming_kernel_matches_oracle(m, n, words):
    key = jax.random.PRNGKey(m * n)
    bits_a = jax.random.bernoulli(key, 0.5, (m, words * 32))
    bits_b = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5,
                                  (n, words * 32))
    a = ops.pack_bits(jnp.where(bits_a, 1.0, -1.0))
    b = ops.pack_bits(jnp.where(bits_b, 1.0, -1.0))
    k = hamming_all_pairs(a, b, interpret=True)
    r = ref.hamming_all_pairs_ref(a, b)
    assert bool(jnp.all(k == r))


def test_hamming_matrix_padding_path():
    """hamming_matrix pads M and word axes; results must match oracle."""
    key = jax.random.PRNGKey(7)
    bits = jax.random.bernoulli(key, 0.5, (10, 256))     # M=10, W=8
    codes = ops.pack_bits(jnp.where(bits, 1.0, -1.0))
    d_kernel = ops.hamming_matrix(codes, use_kernel=True)
    d_ref = ops.hamming_matrix(codes, use_kernel=False)
    assert bool(jnp.all(d_kernel == d_ref))
    assert bool(jnp.all(jnp.diag(d_kernel) == 0))
    assert bool(jnp.all(d_kernel == d_kernel.T))


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 8))
def test_pack_unpack_roundtrip(seed, words):
    bits = words * 32
    s = jax.random.normal(jax.random.PRNGKey(seed), (3, bits))
    packed = ops.pack_bits(s)
    assert packed.dtype == jnp.uint32 and packed.shape == (3, words)
    unpacked = ops.unpack_bits(packed, bits)
    assert bool(jnp.all(unpacked == (s > 0)))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.floats(0.001, 0.2))
def test_lsh_locality_property(seed, noise):
    """Hamming(code(p), code(p + small noise)) < Hamming(code(p), code(q))
    for independent q — the property WPFed's similarity relies on."""
    key = jax.random.PRNGKey(seed)
    p = jax.random.normal(key, (CHUNK,))
    p_near = p + noise * jax.random.normal(jax.random.fold_in(key, 1),
                                           (CHUNK,))
    q = jax.random.normal(jax.random.fold_in(key, 2), (CHUNK,))
    codes = jnp.stack([
        ops.pack_bits(ref.lsh_project_sums_ref(v, 9, bits=256))
        for v in (p, p_near, q)])
    d = ops.hamming_matrix(codes, use_kernel=False)
    assert int(d[0, 1]) < int(d[0, 2])


def test_flatten_params_padding():
    tree = {"a": jnp.ones((100,)), "b": jnp.ones((3, 7))}
    flat = ops.flatten_params(tree)
    assert flat.shape[0] % CHUNK == 0
    assert float(jnp.sum(flat)) == 121.0


# ---------------------------------------------------------------------------
# flash attention kernel
# ---------------------------------------------------------------------------
from repro.kernels.flash_attention import flash_attention


@pytest.mark.parametrize("n,sq,sk,dh", [(2, 256, 256, 128), (1, 512, 512, 64),
                                        (2, 256, 512, 128)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_oracle(n, sq, sk, dh, causal, dtype):
    if causal and sq != sk:
        pytest.skip("causal requires square")
    key = jax.random.PRNGKey(n * sq + dh)
    q = jax.random.normal(key, (n, sq, dh)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (n, sk, dh)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (n, sk, dh)).astype(dtype)
    o_k = flash_attention(q, k, v, causal=causal, interpret=True)
    o_r = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(o_k.astype(jnp.float32)
                                 - o_r.astype(jnp.float32)))) < tol


def test_gqa_flash_wrapper_matches_model_attention():
    """The GQA wrapper must agree with the model's own attention path."""
    from repro.configs import get_config
    from repro.models import attention as attn_mod
    from repro.models.attention import _naive_attn
    cfg = get_config("phi3-medium-14b").reduced()
    key = jax.random.PRNGKey(3)
    b, s, h, kv, dh = 2, 256, cfg.num_heads, cfg.num_kv_heads, 64
    q = jax.random.normal(key, (b, s, h, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, dh))
    o_flash = ops.gqa_flash_attention(q, k, v, causal=True)
    # model path (scores einsum) on the same tensors
    scores = attn_mod._gqa_scores(cfg, q, k)
    mask = jnp.arange(s)[:, None] >= jnp.arange(s)[None, :]
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    o_model = ctx.reshape(b, s, h, dh)
    assert float(jnp.max(jnp.abs(o_flash - o_model))) < 2e-5
