import functools
import sys
import types

import numpy as np
import jax
import jax.numpy as jnp
import pytest

# ---------------------------------------------------------------------------
# Optional-dependency shim: hypothesis is not installable in the offline
# environment. Several modules do `from hypothesis import given, settings,
# strategies as st` at import time; without this shim the whole module
# fails collection. The stub skips only the @given-decorated tests —
# plain tests in the same module still run.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")

    def _given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (offline environment)")(fn)
        return deco

    def _settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    def _strategy_stub(*_a, **_k):
        return None

    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    for _name in ("integers", "floats", "booleans", "lists", "tuples",
                  "sampled_from", "text", "composite", "just", "one_of"):
        setattr(_st, _name, _strategy_stub)
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

from repro.configs.paper_models import ClientModelConfig, FedConfig
from repro.models import apply_client_model, init_client_model
from repro.optim import adam


@pytest.fixture(scope="session")
def tiny_fed():
    """Small, fast federation fixture shared across protocol tests:
    6 MLP clients on 16-dim synthetic two-class data."""
    import numpy as np
    m, n_loc, n_ref, d, classes = 6, 40, 12, 16, 3
    rs = np.random.RandomState(0)
    mcfg = ClientModelConfig("test-mlp", "mlp", (d,), classes, hidden=(32,))
    fed = FedConfig(num_clients=m, num_neighbors=3, top_k=2, local_steps=3,
                    local_batch=16, lsh_bits=128, lr=1e-2)

    # class-structured data: FIXED global class centers (the task must be
    # learnable and consistent across train/ref/test); non-IID label skew
    # via per-client class proportions.
    centers = rs.randn(classes, d) * 2.5

    def gen(n, props):
        y = rs.choice(classes, size=n, p=props)
        x = centers[y] + rs.randn(n, d)
        return x.astype("f"), y.astype("i4")

    xs, ys, xr, yr, xt, yt = [], [], [], [], [], []
    for i in range(m):
        props = rs.dirichlet(np.ones(classes) * 0.8)      # label skew
        props = 0.7 * props + 0.3 / classes               # keep all classes
        x, y = gen(n_loc, props)
        xs.append(x); ys.append(y)
        x, y = gen(n_ref, np.ones(classes) / classes)     # shared-repo style
        xr.append(x); yr.append(y)
        x, y = gen(n_loc // 2, props)                     # test ~ local dist
        xt.append(x); yt.append(y)
    data = {"x_train": jnp.asarray(np.stack(xs)),
            "y_train": jnp.asarray(np.stack(ys)),
            "x_ref": jnp.asarray(np.stack(xr)),
            "y_ref": jnp.asarray(np.stack(yr)),
            "x_test": jnp.asarray(np.stack(xt)),
            "y_test": jnp.asarray(np.stack(yt))}

    apply_fn = functools.partial(apply_client_model, mcfg)
    init_fn = lambda k: init_client_model(mcfg, k)
    opt = adam(fed.lr)
    return {"fed": fed, "mcfg": mcfg, "apply_fn": apply_fn,
            "init_fn": init_fn, "opt": opt, "data": data}
