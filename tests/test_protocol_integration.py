"""Integration tests: full WPFed rounds, attacks, baselines, chain."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attacks, evaluate, init_state, make_wpfed_round
from repro.core.baselines import (make_fedmd_round, make_kdpdfl_round,
                                  make_proxyfl_round, make_silo_round)
from repro.core.chain import (Blockchain, lsh_code_hex, sha256_commit,
                              verify_reveal)
from repro.core.verify import verify_rankings_fnv


@pytest.fixture(scope="module")
def fed_run(tiny_fed):
    """Run 3 WPFed rounds once; several tests inspect the results."""
    f = tiny_fed
    state0 = init_state(f["apply_fn"], f["init_fn"], f["opt"], f["fed"],
                        jax.random.PRNGKey(0))
    round_fn = jax.jit(make_wpfed_round(f["apply_fn"], f["opt"], f["fed"]))
    acc0 = float(evaluate(f["apply_fn"], state0, f["data"])["mean_acc"])
    state, metrics = state0, None
    for _ in range(5):
        state, metrics = round_fn(state, f["data"])
    acc1 = float(evaluate(f["apply_fn"], state, f["data"])["mean_acc"])
    return {"state0": state0, "state": state, "metrics": metrics,
            "acc0": acc0, "acc1": acc1}


def test_wpfed_improves_accuracy(fed_run):
    assert fed_run["acc1"] > fed_run["acc0"]


def test_wpfed_reporters_all_honest(fed_run):
    assert float(fed_run["metrics"]["honest_reporter_frac"]) == 1.0


def test_wpfed_lsh_filter_keeps_upper_half(fed_run):
    # N=3 selected -> ceil(3/2)=2 pass -> 2/3 valid fraction
    assert abs(float(fed_run["metrics"]["valid_neighbor_frac"]) - 2 / 3) < 1e-6


def test_wpfed_neighbors_exclude_self(fed_run):
    ids = np.asarray(fed_run["metrics"]["neighbor_ids"])
    for i in range(ids.shape[0]):
        assert i not in ids[i]


def test_wpfed_announcements_change(fed_run):
    assert not bool(jnp.all(fed_run["state"].codes
                            == fed_run["state0"].codes))
    assert not bool(jnp.all(fed_run["state"].commitments
                            == fed_run["state0"].commitments))


def test_commit_reveal_catches_liar(tiny_fed, fed_run):
    state = fed_run["state"]
    liar = jnp.array([True, False, False, False, False, False])
    lied = attacks.lie_in_reveal(state, liar)
    det = verify_rankings_fnv(lied.rankings, lied.commitments)
    assert not bool(det[0])
    assert bool(jnp.all(det[1:]))


def test_lsh_cheat_filtered_by_verification(tiny_fed):
    """Forged codes raise selection likelihood, but §3.5 output-KL
    verification must exclude the attackers from distillation."""
    f = tiny_fed
    state = init_state(f["apply_fn"], f["init_fn"], f["opt"], f["fed"],
                       jax.random.PRNGKey(1))
    round_fn = jax.jit(make_wpfed_round(f["apply_fn"], f["opt"], f["fed"]))
    for _ in range(2):                      # let models differentiate
        state, _ = round_fn(state, f["data"])
    attacker = jnp.array([False, False, False, True, True, True])
    state = attacks.corrupt_params(state, attacker, f["init_fn"],
                                   jax.random.PRNGKey(2))
    state = attacks.forge_lsh_codes(state, attacker, target_id=0)
    state, m = round_fn(state, f["data"])
    ids = np.asarray(m["neighbor_ids"])
    # verification validity among client 0's selected neighbors:
    # attackers (corrupt params -> dissimilar outputs) should mostly fail
    valid_frac = float(m["valid_neighbor_frac"])
    assert valid_frac <= 2 / 3 + 1e-6


def test_silo_baseline_never_mixes(tiny_fed):
    f = tiny_fed
    state = init_state(f["apply_fn"], f["init_fn"], f["opt"], f["fed"],
                       jax.random.PRNGKey(3))
    silo = jax.jit(make_silo_round(f["apply_fn"], f["opt"], f["fed"]))
    s1, m = silo(state, f["data"])
    assert np.isfinite(float(m["mean_loss"]))
    # codes/rankings untouched by silo (no announcements)
    assert bool(jnp.all(s1.codes == state.codes))


@pytest.mark.parametrize("maker", [make_proxyfl_round, make_kdpdfl_round])
def test_gossip_baselines_run(tiny_fed, maker):
    f = tiny_fed
    state = init_state(f["apply_fn"], f["init_fn"], f["opt"], f["fed"],
                       jax.random.PRNGKey(4))
    fn = jax.jit(maker(f["apply_fn"], f["opt"], f["fed"]))
    s1, m = fn(state, f["data"])
    assert np.isfinite(float(m["mean_loss"]))


def test_fedmd_baseline_runs(tiny_fed):
    f = tiny_fed
    state = init_state(f["apply_fn"], f["init_fn"], f["opt"], f["fed"],
                       jax.random.PRNGKey(5))
    shared = f["data"]["x_ref"][0]
    fn = jax.jit(make_fedmd_round(f["apply_fn"], f["opt"], f["fed"], shared))
    s1, m = fn(state, f["data"])
    assert np.isfinite(float(m["mean_loss"]))


def test_blockchain_round_trip(fed_run):
    """Host-ledger integration: publish announcements from a real round,
    verify chain + commit-reveal."""
    state = fed_run["state"]
    bc = Blockchain()
    ann = {i: {"lsh": lsh_code_hex(state.codes[i]),
               "commit": sha256_commit(np.asarray(state.rankings[i]))}
           for i in range(state.codes.shape[0])}
    bc.publish_round(1, ann)
    reveals = {i: [int(x) for x in np.asarray(state.rankings[i])]
               for i in range(state.codes.shape[0])}
    bc.publish_round(2, {}, reveals=reveals)
    assert bc.verify_chain()
    blk = bc.round_block(1)
    for i, r in reveals.items():
        assert verify_reveal(blk.payload["announcements"][str(i)]["commit"],
                             np.asarray(r))
    # tamper -> detected
    blk.payload["announcements"]["0"]["commit"] = "00" * 32
    assert not bc.verify_chain()


def test_blockchain_stamps_real_timestamps(fed_run):
    """Regression: Block.timestamp was always 0.0 (lambda default).
    publish_round must stamp wall-clock time, the stamp must be
    hash-covered (tamper-evident), and genesis stays unstamped."""
    import time
    state = fed_run["state"]
    bc = Blockchain()
    t0 = time.time()
    blk = bc.publish_round(1, {0: {"lsh": lsh_code_hex(state.codes[0]),
                                   "commit": "00" * 32}})
    t1 = time.time()
    assert bc.blocks[0].timestamp == 0.0          # genesis
    assert t0 <= blk.timestamp <= t1
    assert bc.verify_chain()
    blk.timestamp += 60.0                         # backdate -> detected
    assert not bc.verify_chain()


def test_ablation_switches_alter_selection(tiny_fed):
    import dataclasses
    f = tiny_fed
    state = init_state(f["apply_fn"], f["init_fn"], f["opt"], f["fed"],
                       jax.random.PRNGKey(6))
    variants = {}
    for name, kw in {
        "full": {},
        "no_lsh": {"use_lsh": False},
        "no_rank": {"use_rank": False},
        "random": {"use_lsh": False, "use_rank": False},
    }.items():
        fed_v = dataclasses.replace(f["fed"], **kw)
        fn = jax.jit(make_wpfed_round(f["apply_fn"], f["opt"], fed_v))
        _, m = fn(state, f["data"])
        variants[name] = np.asarray(m["neighbor_ids"])
    assert not np.array_equal(variants["full"], variants["random"])
