"""Config registry + parameter-count sanity vs published sizes."""
import pytest

from repro.configs import ALL_ARCHS, SHAPES, get_config, list_archs
from repro.configs.base import supports_shape

EXPECTED_PARAMS_B = {
    "kimi-k2-1t-a32b": (950, 1150),
    "whisper-small": (0.2, 0.4),
    "nemotron-4-340b": (320, 360),
    "llama-3.2-vision-90b": (80, 95),
    "qwen1.5-32b": (30, 40),
    "recurrentgemma-2b": (2.0, 4.0),
    "minitron-4b": (3.5, 5.0),
    "grok-1-314b": (290, 330),
    "xlstm-350m": (0.2, 0.5),
    "phi3-medium-14b": (13, 16),
}


def test_all_archs_registered():
    assert set(ALL_ARCHS) <= set(list_archs())
    assert len(ALL_ARCHS) == 10


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_counts_in_published_band(arch):
    cfg = get_config(arch)
    lo, hi = EXPECTED_PARAMS_B[arch]
    count_b = cfg.param_count() / 1e9
    assert lo <= count_b <= hi, f"{arch}: {count_b:.2f}B not in [{lo},{hi}]"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_moe_active_less_than_total(arch):
    cfg = get_config(arch)
    if cfg.is_moe:
        assert cfg.active_param_count() < cfg.param_count()
    else:
        assert cfg.active_param_count() == cfg.param_count()


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_constraints(arch):
    r = get_config(arch).reduced()
    assert r.d_model <= 512
    assert r.num_layers <= 3
    assert r.num_experts <= 4
    assert r.vocab_size <= 1024
    assert r.num_heads % r.num_kv_heads == 0
    # reduced keeps every distinct block type of the family
    assert set(r.block_pattern) == set(get_config(arch).block_pattern) \
        or len(set(get_config(arch).block_pattern)) > 2


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_long_500k_support_policy():
    runs, skips = [], []
    for a in ALL_ARCHS:
        ok, why = supports_shape(get_config(a), SHAPES["long_500k"])
        (runs if ok else skips).append(a)
    assert "recurrentgemma-2b" in runs and "xlstm-350m" in runs
    # dense archs run via sliding-window serving variant
    for dense in ("nemotron-4-340b", "qwen1.5-32b", "minitron-4b",
                  "phi3-medium-14b"):
        assert dense in runs
    for full_attn in ("kimi-k2-1t-a32b", "grok-1-314b", "whisper-small",
                      "llama-3.2-vision-90b"):
        assert full_attn in skips
