"""Privacy-taint verifier (repro.analysis.taint, DESIGN.md §14).

Pins ISSUE 9's acceptance criteria: every HEAD target is clean, each
seeded-leak fixture produces EXACTLY its expected finding, and the
engine's scan/cond sub-jaxpr propagation and declassifier clearing
each have a dedicated test.
"""
import os

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import io_callback

from repro.analysis import privacy, taint
from repro.analysis.privacy import (DECLASSIFIERS, capture_declassifiers,
                                    declassifier, sink, tracing)
from repro.analysis.taint import (EMPTY, SRC_DATA, SRC_PARAMS, TaintTarget,
                                  capture_targets, check_target,
                                  check_targets, taint_target)

FIXDIR = os.path.join(os.path.dirname(__file__), "analysis_fixtures")


def _target(name, fn, args, labels):
    return TaintTarget(name=name, build=lambda: (fn, args, labels))


def _check(fn, args, labels, name="t"):
    return check_target(_target(name, fn, args, labels))


# ---------------------------------------------------------------------------
# HEAD is clean
# ---------------------------------------------------------------------------
def test_head_targets_clean():
    targets = taint.head_targets()
    names = {t.name for t in targets}
    # the protocol surface ISSUE 9 names: every phase, wpfed + all four
    # baselines, the tapped segment, instrumented round, service, serving
    for expect in ("phase-select", "phase-exchange", "phase-update",
                   "phase-announce", "wpfed-global-round",
                   "wpfed-gossip-round", "wpfed-segment-tapped",
                   "wpfed-instrumented-segment", "baseline-silo",
                   "baseline-fedmd", "baseline-proxyfl",
                   "baseline-kdpdfl", "service-global-round",
                   "service-segment-tapped", "serving-forward"):
        assert expect in names, f"missing HEAD taint target {expect}"
    findings = check_targets(targets)
    assert findings == [], [str(f) for f in findings]


def test_declassifier_registry_covers_paper_surface():
    # the paper's disclosure artifacts each have a registered
    # declassifier with a justification (importing protocol modules
    # populates the registry; head_targets above already did)
    for name in ("lsh-code", "rank-reveal", "rank-scores", "commitment",
                 "public-ref-logits", "round-telemetry", "served-logits"):
        assert name in DECLASSIFIERS, name
        entry = DECLASSIFIERS[name]
        assert entry.justification.strip()
        assert entry.paper_eq.strip()


# ---------------------------------------------------------------------------
# seeded-leak fixtures: exactly the expected finding each
# ---------------------------------------------------------------------------
LEAK_FIXTURES = [
    ("leak_announce_field.py", "taint-sink", "chain-announcement"),
    ("leak_metric_tap.py", "taint-callback", "io_callback"),
    ("leak_served_private.py", "taint-sink", "serving-response"),
]


@pytest.mark.parametrize("fname,rule,needle",
                         [pytest.param(*f, id=f[0]) for f in LEAK_FIXTURES])
def test_leak_fixture_exact_finding(fname, rule, needle):
    import importlib.util
    path = os.path.join(FIXDIR, fname)
    with capture_targets() as targets, capture_declassifiers():
        spec = importlib.util.spec_from_file_location(
            "_leak_" + fname[:-3], path)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
    assert len(targets) == 1
    findings = check_targets(targets)
    assert len(findings) == 1, [str(f) for f in findings]
    f = findings[0]
    assert f.rule == rule
    assert needle in f.message
    # the finding points INTO the fixture, not into the analysis layer
    assert os.path.basename(f.path) == fname
    assert f.line > 0


def test_leak_fixtures_fail_cli_strict():
    from repro.analysis.__main__ import run
    for fname, _, _ in LEAK_FIXTURES:
        assert run(["--strict", os.path.join(FIXDIR, fname)]) != 0, fname


# ---------------------------------------------------------------------------
# propagation mechanics
# ---------------------------------------------------------------------------
def test_scan_carry_propagation():
    # taint enters the scan through a closed-over invar, accumulates in
    # the carry, and reaches the sink after the loop
    def fn(p, x0):
        def body(c, _):
            return c + jnp.sum(p), None
        c, _ = jax.lax.scan(body, x0, None, length=3)
        return sink("metrics-tap", c)

    fs = _check(fn, (jnp.ones(3), jnp.zeros(())), (SRC_PARAMS, ""))
    assert [f.rule for f in fs] == ["taint-sink"]
    # clean carry stays clean through the same structure
    def fn2(p, x0):
        def body(c, _):
            return c + 1.0, None
        c, _ = jax.lax.scan(body, x0, None, length=3)
        return sink("metrics-tap", c), jnp.sum(p)

    assert _check(fn2, (jnp.ones(3), jnp.zeros(())),
                  (SRC_PARAMS, "")) == []


def test_scan_xs_to_ys_propagation():
    def fn(xs):
        def body(c, x):
            return c, x * 2.0
        _, ys = jax.lax.scan(body, jnp.zeros(()), xs)
        return sink("metrics-tap", ys)

    assert [f.rule for f in _check(fn, (jnp.ones(4),), (SRC_DATA,))] \
        == ["taint-sink"]


def test_cond_branch_and_pred_propagation():
    # taint through a branch output
    def fn(p):
        out = jax.lax.cond(True, lambda: jnp.sum(p), lambda: jnp.float32(0))
        return sink("metrics-tap", out)

    assert [f.rule for f in _check(fn, (jnp.ones(3),), (SRC_PARAMS,))] \
        == ["taint-sink"]

    # implicit flow: a clean payload selected by a TAINTED predicate is
    # tainted (the branch taken reveals one bit of the private value)
    def fn2(p):
        out = jax.lax.cond(jnp.sum(p) > 0,
                           lambda: jnp.float32(1), lambda: jnp.float32(0))
        return sink("metrics-tap", out)

    assert [f.rule for f in _check(fn2, (jnp.ones(3),), (SRC_DATA,))] \
        == ["taint-sink"]

    # clean pred + clean branches stay clean
    def fn3(p, flag):
        out = jax.lax.cond(flag > 0,
                           lambda: jnp.float32(1), lambda: jnp.float32(0))
        return sink("metrics-tap", out), jnp.sum(p)

    assert _check(fn3, (jnp.ones(3), jnp.zeros(())),
                  (SRC_PARAMS, "")) == []


def test_while_loop_propagation():
    def fn(p):
        out = jax.lax.while_loop(lambda c: c < 10.0,
                                 lambda c: c + jnp.sum(p), jnp.zeros(()))
        return sink("metrics-tap", out)

    assert [f.rule for f in _check(fn, (jnp.ones(3),), (SRC_PARAMS,))] \
        == ["taint-sink"]


def test_declassifier_clears_taint():
    from repro.core.chain import fnv1a_commit

    def ok(r):
        return sink("chain-announcement", fnv1a_commit(r))

    def bad(r):
        return sink("chain-announcement", r)

    args = (jnp.ones((2, 3), jnp.int32),)
    assert _check(ok, args, (SRC_PARAMS,)) == []
    assert [f.rule for f in _check(bad, args, (SRC_PARAMS,))] \
        == ["taint-sink"]


def test_declassifier_under_vmap():
    # announce_phase vmaps make_ranking: the marker primitive must
    # survive batching and still clear taint
    from repro.core.ranking import make_ranking

    def fn(losses, ids):
        rankings = jax.vmap(make_ranking)(ids, losses)
        return sink("chain-announcement", rankings)

    fs = _check(fn, (jnp.ones((4, 3)), jnp.zeros((4, 3), jnp.int32)),
                (SRC_DATA, ""))
    assert fs == [], [str(f) for f in fs]


def test_taint_survives_derived_ops_and_jit():
    # arbitrary elementwise/reduction chains keep taint, through pjit
    def fn(p):
        h = jax.jit(lambda v: jnp.tanh(v).mean() * 3.0)(p)
        return sink("serving-response", h)

    assert [f.rule for f in _check(fn, (jnp.ones(5),), (SRC_PARAMS,))] \
        == ["taint-sink"]


def test_pallas_call_conservative_propagation():
    from jax.experimental import pallas as pl

    def kern(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2.0

    def fn(p):
        out = pl.pallas_call(
            kern, out_shape=jax.ShapeDtypeStruct(p.shape, p.dtype),
            interpret=True)(p)
        return sink("serving-response", out)

    assert [f.rule for f in _check(fn, (jnp.ones(4),), (SRC_PARAMS,))] \
        == ["taint-sink"]


def test_io_callback_flagged_only_when_tainted():
    def tainted(p):
        io_callback(lambda s: None, None, jnp.mean(p), ordered=True)
        return p

    def clean(p, r):
        io_callback(lambda s: None, None, r, ordered=True)
        return jnp.sum(p)

    assert [f.rule for f in _check(tainted, (jnp.ones(3),),
                                   (SRC_PARAMS,))] == ["taint-callback"]
    assert _check(clean, (jnp.ones(3), jnp.zeros(())),
                  (SRC_PARAMS, "")) == []


def test_trace_error_is_a_finding():
    def boom(x):
        raise RuntimeError("nope")

    fs = _check(boom, (jnp.ones(2),), ("",), name="boom-target")
    assert [f.rule for f in fs] == ["taint-trace-error"]
    assert "boom-target" in fs[0].message


def test_label_arity_mismatch_is_a_finding():
    fs = _check(lambda a, b: a + b, (jnp.ones(2), jnp.ones(2)),
                (SRC_PARAMS,))
    assert [f.rule for f in fs] == ["taint-trace-error"]


# ---------------------------------------------------------------------------
# registry mechanics
# ---------------------------------------------------------------------------
def test_sink_rejects_unknown_name():
    with pytest.raises(ValueError, match="unknown sink"):
        sink("not-a-sink", jnp.zeros(()))


def test_declassifier_requires_justification():
    with pytest.raises(ValueError, match="justification"):
        declassifier(name="x", paper_eq="Eq. 0", justification="  ")


def test_declassifier_name_collision_rejected():
    with capture_declassifiers():
        @declassifier(name="collide-test", paper_eq="Eq. 0",
                      justification="first")
        def first(x):
            return x

        with pytest.raises(ValueError, match="already registered"):
            @declassifier(name="collide-test", paper_eq="Eq. 0",
                          justification="second")
            def second(x):
                return x


def test_markers_are_runtime_noops():
    # outside tracing() the wrappers are passthrough: no marker
    # primitives in ordinary jaxprs, zero graph overhead
    from repro.core.chain import fnv1a_commit
    r = jnp.arange(6, dtype=jnp.int32).reshape(2, 3)
    jaxpr = jax.make_jaxpr(fnv1a_commit)(r)
    prims = {e.primitive.name for e in jaxpr.jaxpr.eqns}
    assert "taint_declassify" not in prims
    with tracing():
        jaxpr2 = jax.make_jaxpr(fnv1a_commit)(r)
    prims2 = {e.primitive.name for e in jaxpr2.jaxpr.eqns}
    assert "taint_declassify" in prims2
    # and the marked computation still computes the same value
    assert (fnv1a_commit(r) == jax.jit(fnv1a_commit)(r)).all()


def test_round_telemetry_declassifier_rejects_nonscalars():
    from repro.core.rounds import release_round_telemetry
    with pytest.raises(ValueError, match="scalars only"):
        release_round_telemetry({"v": jnp.ones(3)})
    out = release_round_telemetry({"v": jnp.ones(())})
    assert out["v"].ndim == 0


def test_capture_targets_isolated():
    before = dict(taint.TARGETS)
    with capture_targets() as got:
        taint_target(name="tmp-target",
                     build=lambda: (lambda x: x, (jnp.ones(2),), ("",)))
    assert [t.name for t in got] == ["tmp-target"]
    assert taint.TARGETS == before
