"""Service-layer tests (DESIGN.md §13): churn invariants, staleness,
heterogeneous gossip budgets, kill/resume bit-exactness, ledger
persistence, and the personalized serving front."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import init_state
from repro.core.chain import Blockchain, load_chain, save_chain
from repro.core.neighbor import select_partners
from repro.core.protocol import select_phase, update_phase
from repro.service import (ChurnEvent, PersonalizedServer, ServiceConfig,
                           apply_events, init_service_state, join, leave,
                           parse_events, participation_mask, resume_service,
                           run_service, service_program, staleness_discount)
from repro.service.membership import validate_events


@pytest.fixture(scope="module")
def svc_state(tiny_fed):
    svc = ServiceConfig(reselect_every=3, keep_last_k=2)
    state = init_service_state(
        init_state(tiny_fed["apply_fn"], tiny_fed["init_fn"],
                   tiny_fed["opt"], tiny_fed["fed"],
                   jax.random.PRNGKey(0)), svc)
    return {"svc": svc, "state": state, **tiny_fed}


# ---------------------------------------------------------------------------
# churn invariants: the masks through selection
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["oracle", "kernel"])
def test_leaver_never_in_any_top_n(tiny_fed, backend):
    """A departed client is excluded from EVERY peer's top-N, whatever
    backend computes the selection."""
    fed = tiny_fed["fed"]
    m = fed.num_clients
    rs = np.random.RandomState(3)
    codes = jnp.asarray(
        rs.randint(0, 2**32, (m, fed.lsh_bits // 32), dtype=np.uint32))
    scores = jnp.asarray(rs.rand(m).astype(np.float32)) + 0.5
    active = jnp.ones((m,), bool).at[4].set(False)
    ids, mask = select_partners(codes, scores, fed, active=active,
                                backend=backend)
    chosen = np.asarray(ids)[np.asarray(mask)]
    assert 4 not in chosen
    # every ACTIVE row still fills its top-N from the remaining cohort
    sel_count = np.asarray(mask).sum(axis=1)
    n = min(fed.num_neighbors, m - 1)
    for i in range(m):
        if i != 4:
            assert sel_count[i] == min(n, m - 2)


def test_active_mask_requires_use_rank(tiny_fed):
    import dataclasses
    fed = dataclasses.replace(tiny_fed["fed"], use_rank=False)
    m = fed.num_clients
    codes = jnp.zeros((m, fed.lsh_bits // 32), jnp.uint32)
    with pytest.raises(ValueError, match="use_rank"):
        select_partners(codes, jnp.ones((m,)), fed,
                        active=jnp.ones((m,), bool))


def test_stale_joiner_selectable_leaver_not(svc_state):
    """The join/leave asymmetry: a re-joined client with code_age > 0
    keeps a FINITE (discounted) weight — with top-N wide enough to
    admit every finite candidate it appears in peers' selections —
    while a departed client's -inf weight keeps it out even then."""
    import dataclasses
    fed = dataclasses.replace(svc_state["fed"], num_neighbors=5)
    state = svc_state["state"]
    # client 5 rejoined two periods stale; client 3 departed
    st = join(leave(state, 5), 5)._replace(
        code_age=state.code_age.at[5].set(2),
        active=state.active.at[3].set(False))
    scale = staleness_discount(st.code_age,
                               svc_state["svc"].staleness_lambda)
    sel = select_phase(st.fed, fed, active=st.active, score_scale=scale)
    chosen = np.asarray(sel.ids)[np.asarray(sel.sel_mask)]
    assert 5 in chosen
    assert 3 not in chosen
    # active rows fill M-2 valid slots (everyone but self and the leaver)
    counts = np.asarray(sel.sel_mask).sum(axis=1)
    for i in range(6):
        if i != 3:
            assert counts[i] == 4


def test_all_but_one_departed_degrades_not_crashes(svc_state):
    """Two survivors -> each selects exactly the other; ONE survivor ->
    zero valid slots, and a full compiled period still runs (the
    exchange's has_target=False path)."""
    fed, svc = svc_state["fed"], svc_state["svc"]
    program = service_program(svc_state["apply_fn"], svc_state["opt"],
                              fed, svc)
    m = fed.num_clients
    two = svc_state["state"]._replace(
        active=jnp.zeros((m,), bool).at[0].set(True).at[2].set(True))
    new_state, sel, _ = jax.jit(program.global_round)(
        two, svc_state["data"])
    ids, mask = np.asarray(sel.ids), np.asarray(sel.sel_mask)
    assert mask[0].sum() == 1 and ids[0][mask[0]][0] == 2
    assert mask[2].sum() == 1 and ids[2][mask[2]][0] == 0
    jax.block_until_ready(new_state)

    from repro.core.rounds import make_segment_fn
    one = svc_state["state"]._replace(
        active=jnp.zeros((m,), bool).at[3].set(True))
    seg = jax.jit(make_segment_fn(program, svc.reselect_every))
    final, metrics = seg(one, svc_state["data"])
    jax.block_until_ready(metrics)
    sel2 = jax.jit(program.global_round)(one, svc_state["data"])[1]
    # the sole survivor has nobody valid to talk to (inactive rows
    # still compute a selection, but they are masked out of updates)
    assert np.asarray(sel2.sel_mask)[3].sum() == 0


def test_leave_freezes_update_and_announce(svc_state):
    """After a leave, the departed client's params, codes, rankings and
    commitments come back bitwise unchanged from a global round, and
    its code_age increments."""
    program = service_program(svc_state["apply_fn"], svc_state["opt"],
                              svc_state["fed"], svc_state["svc"])
    st = leave(svc_state["state"], 1)
    new_state, _, _ = jax.jit(program.global_round)(st, svc_state["data"])
    for old, new in zip(jax.tree.leaves(st.fed.params),
                        jax.tree.leaves(new_state.fed.params)):
        assert np.array_equal(np.asarray(old[1]), np.asarray(new[1]))
        # a participant's params DID move
        assert not np.array_equal(np.asarray(old[0]), np.asarray(new[0]))
    assert np.array_equal(np.asarray(st.fed.codes[1]),
                          np.asarray(new_state.fed.codes[1]))
    assert np.array_equal(np.asarray(st.fed.rankings[1]),
                          np.asarray(new_state.fed.rankings[1]))
    assert int(new_state.code_age[1]) == 1
    assert int(new_state.code_age[0]) == 0


# ---------------------------------------------------------------------------
# membership mechanics
# ---------------------------------------------------------------------------
def test_participation_mask_heterogeneous_g(svc_state):
    state = svc_state["state"]._replace(
        gossip_count=jnp.asarray([1, 2, 3, 3, 3, 3], jnp.int32),
        active=jnp.ones((6,), bool).at[5].set(False))
    # epoch 0 (first gossip epoch): G_i=1 already exhausted
    assert np.asarray(participation_mask(state, 0)).tolist() == \
        [False, True, True, True, True, False]
    assert np.asarray(participation_mask(state, 1)).tolist() == \
        [False, False, True, True, True, False]


def test_gossip_budget_freezes_mid_period(svc_state):
    """G_i = 1: client trains in the global round, then freezes for the
    period's gossip epochs while others keep moving."""
    fed, svc = svc_state["fed"], svc_state["svc"]
    program = service_program(svc_state["apply_fn"], svc_state["opt"],
                              fed, svc)
    st = svc_state["state"]._replace(
        gossip_count=jnp.asarray([1, 3, 3, 3, 3, 3], jnp.int32))
    g_round = jax.jit(program.global_round)
    after_global, sel, _ = g_round(st, svc_state["data"])
    after_gossip, _, _ = jax.jit(program.gossip_round)(
        after_global, svc_state["data"], sel)
    p0_before = jax.tree.leaves(after_global.fed.params)[0]
    p0_after = jax.tree.leaves(after_gossip.fed.params)[0]
    assert np.array_equal(np.asarray(p0_before[0]), np.asarray(p0_after[0]))
    assert not np.array_equal(np.asarray(p0_before[1]),
                              np.asarray(p0_after[1]))
    # optimizer state frozen too (bit-exact resume depends on it)
    for old, new in zip(jax.tree.leaves(after_global.fed.opt_state),
                        jax.tree.leaves(after_gossip.fed.opt_state)):
        assert np.array_equal(np.asarray(old[0]), np.asarray(new[0]))


def test_churn_event_plumbing():
    assert parse_events("1:leave:4, 2:join:5") == [
        ChurnEvent(1, "leave", 4), ChurnEvent(2, "join", 5)]
    with pytest.raises(ValueError, match="period:kind:client"):
        parse_events("1:leave")
    with pytest.raises(ValueError, match="kind"):
        validate_events([ChurnEvent(0, "lurk", 1)], 6)
    with pytest.raises(ValueError, match="client axis"):
        validate_events([ChurnEvent(0, "join", 6)], 6)


def test_apply_events_idempotent_and_ordered(svc_state):
    state = svc_state["state"]
    events = [ChurnEvent(0, "leave", 2), ChurnEvent(0, "join", 2),
              ChurnEvent(1, "leave", 3), ChurnEvent(0, "leave", 3)]
    s0 = apply_events(state, events, 0)
    # list order within a period: leave(2) then join(2) -> active
    assert bool(s0.active[2]) and not bool(s0.active[3])
    s1 = apply_events(s0, events, 1)
    assert not bool(s1.active[3])


def test_staleness_discount_ordering():
    ages = jnp.asarray([0, 1, 4], jnp.int32)
    d = np.asarray(staleness_discount(ages, 0.5))
    assert d[0] == 1.0 and d[0] > d[1] > d[2] > 0.0
    assert np.allclose(np.asarray(staleness_discount(ages, 0.0)), 1.0)


def test_service_config_validation():
    with pytest.raises(ValueError):
        ServiceConfig(reselect_every=0)
    with pytest.raises(ValueError):
        ServiceConfig(staleness_lambda=-0.1)
    with pytest.raises(ValueError):
        ServiceConfig(keep_last_k=0)


def test_update_phase_none_participate_is_default(svc_state):
    """participate=None must stay bit-exact with the pre-service
    update (the engine pins depend on it)."""
    fed = svc_state["fed"]
    program = service_program(svc_state["apply_fn"], svc_state["opt"],
                              fed, svc_state["svc"])
    st = svc_state["state"]
    sel = select_phase(st.fed, fed, active=st.active,
                       score_scale=staleness_discount(st.code_age, 0.5))
    from repro.core.protocol import exchange_phase
    exch = exchange_phase(svc_state["apply_fn"], fed, st.fed.params,
                          svc_state["data"], sel)
    rng = jax.random.PRNGKey(7)
    all_on = jnp.ones((fed.num_clients,), bool)
    base = update_phase(svc_state["apply_fn"], svc_state["opt"], fed,
                        st.fed.params, st.fed.opt_state,
                        svc_state["data"], exch, rng)
    masked = update_phase(svc_state["apply_fn"], svc_state["opt"], fed,
                          st.fed.params, st.fed.opt_state,
                          svc_state["data"], exch, rng,
                          participate=all_on)
    for a, b in zip(jax.tree.leaves(base[:2]), jax.tree.leaves(masked[:2])):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# kill/resume: the acceptance criterion
# ---------------------------------------------------------------------------
def test_kill_resume_bit_exact_with_churn(svc_state, tmp_path):
    """3 churned periods straight through vs killed-after-2 + resumed:
    identical per-round metrics, bitwise-equal final state, payload-
    equal ledgers, and verify_chain across the restart boundary."""
    fed, svc = svc_state["fed"], svc_state["svc"]
    args = (svc_state["apply_fn"], svc_state["opt"], fed, svc)
    events = [ChurnEvent(1, "leave", 4), ChurnEvent(2, "join", 4)]
    taps = []
    s_a, chain_a, hist_a = run_service(
        *args, svc_state["state"], svc_state["data"], periods=3,
        events=events, ckpt_dir=str(tmp_path / "a"),
        metrics_tap=taps.append)
    assert chain_a.verify_chain()
    assert len(hist_a) == 3 * svc.reselect_every
    # the ordered io_callback tap saw every round, in order
    assert len(taps) == len(hist_a)
    assert [t["round"] for t in taps] == [h["round"] for h in hist_a]
    assert all(t["mean_loss"] == h["mean_loss"]
               for t, h in zip(taps, hist_a))
    # churn is visible: period 1 runs with 5/6 active, period 2 with 6/6
    fracs = [h["active_frac"] for h in hist_a]
    assert fracs[0] == 1.0 and abs(fracs[3] - 5 / 6) < 1e-6 \
        and fracs[6] == 1.0

    ckpt_b = str(tmp_path / "b")
    run_service(*args, svc_state["state"], svc_state["data"], periods=2,
                events=events, ckpt_dir=ckpt_b)
    # "kill": fresh template, restore everything from disk
    s_r, chain_r, p0 = resume_service(ckpt_b, svc_state["state"])
    assert p0 == 2
    assert chain_r.verify_chain()
    s_c, chain_c, hist_tail = run_service(
        *args, s_r, svc_state["data"], periods=3, events=events,
        chain=chain_r, ckpt_dir=ckpt_b, start_period=p0)
    assert [h["round"] for h in hist_tail] == \
        [h["round"] for h in hist_a[-svc.reselect_every:]]
    for ha, hb in zip(hist_a[-svc.reselect_every:], hist_tail):
        assert ha == hb  # identical, not approximately equal
    for a, b in zip(jax.tree.leaves(s_a), jax.tree.leaves(s_c)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # hashes differ (wall-clock timestamps); the recorded protocol
    # content must not
    assert [b.payload for b in chain_a.blocks] == \
        [b.payload for b in chain_c.blocks]
    assert chain_c.verify_chain()
    # retention: keep_last_k=2 of 3 periods
    snaps = sorted(f for f in os.listdir(ckpt_b) if f.endswith(".npz"))
    assert snaps == ["step_00000001.npz", "step_00000002.npz"]


def test_resume_refuses_tampered_chain(svc_state, tmp_path):
    fed, svc = svc_state["fed"], svc_state["svc"]
    ckpt = str(tmp_path / "c")
    run_service(svc_state["apply_fn"], svc_state["opt"], fed, svc,
                svc_state["state"], svc_state["data"], periods=1,
                ckpt_dir=ckpt)
    path = os.path.join(ckpt, "chain.json")
    chain = load_chain(path)
    chain.blocks[1].payload["round"] = 999
    with open(path, "w") as fh:
        fh.write(chain.to_json())
    with pytest.raises(ValueError, match="verify_chain"):
        resume_service(ckpt, svc_state["state"])


def test_resume_without_checkpoint_raises(svc_state, tmp_path):
    with pytest.raises(FileNotFoundError):
        resume_service(str(tmp_path / "nope"), svc_state["state"])


def test_chain_json_roundtrip(tmp_path):
    chain = Blockchain()
    chain.publish_round(0, {0: {"lsh": "ab", "commit": "cd"}},
                        reveals={0: [1, 2]})
    chain.publish_round(3, {1: {"lsh": "ef", "commit": "01"}})
    path = str(tmp_path / "chain.json")
    save_chain(path, chain)
    loaded = load_chain(path)
    assert loaded.verify_chain()
    assert [b.hash for b in loaded.blocks] == [b.hash for b in chain.blocks]
    assert loaded.round_block(3).payload == chain.round_block(3).payload
    # tampering after the fact fails verification, not silently passes
    loaded.blocks[1].payload["reveals"]["0"] = [9, 9]
    assert not loaded.verify_chain()


# ---------------------------------------------------------------------------
# the serving front
# ---------------------------------------------------------------------------
def test_personalized_server_matches_direct_apply(svc_state):
    apply_fn = svc_state["apply_fn"]
    params = svc_state["state"].fed.params
    data = svc_state["data"]
    server = PersonalizedServer(apply_fn, params, batch_buckets=(4, 8))
    want = []
    for i, cid in enumerate([3, 0, 5, 3, 1]):  # cross-client batch, dup ids
        server.submit(cid, data["x_test"][cid, i])
        want.append(apply_fn(jax.tree.map(lambda p: p[cid], params),
                             data["x_test"][cid, i][None])[0])
    got = server.flush()
    assert len(got) == 5
    for g, w in zip(got, want):
        assert np.allclose(g, np.asarray(w), atol=1e-5)
    stats = server.throughput()
    assert stats["requests"] == 5
    # 5 requests pad into one bucket-8 batch: padding is accounted for
    assert stats["batches"] == 1 and stats["padded_slots"] == 3


def test_personalized_server_update_params(svc_state):
    apply_fn = svc_state["apply_fn"]
    params = svc_state["state"].fed.params
    data = svc_state["data"]
    server = PersonalizedServer(apply_fn, params)
    server.submit(2, data["x_test"][2, 0])
    before = server.flush()[0]
    server.update_params(jax.tree.map(lambda p: p * 0.5, params))
    server.submit(2, data["x_test"][2, 0])
    after = server.flush()[0]
    assert not np.allclose(before, after)
    with pytest.raises(ValueError, match="client axis"):
        server.update_params(
            jax.tree.map(lambda p: jnp.concatenate([p, p]), params))
    with pytest.raises(ValueError, match="client_id"):
        server.submit(99, data["x_test"][0, 0])
