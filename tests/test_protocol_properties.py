"""Hypothesis property tests on protocol invariants (fast, pure-jnp)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import lsh, neighbor, ranking
from repro.core.chain import fnv1a_commit
from repro.kernels import ops


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**16), st.integers(3, 12), st.integers(2, 6))
def test_distance_matrix_metric_properties(seed, m, words):
    """Hamming over packed codes: symmetric, zero diagonal, bounded,
    triangle inequality (it's a true metric)."""
    key = jax.random.PRNGKey(seed)
    bits = jax.random.bernoulli(key, 0.5, (m, words * 32))
    codes = ops.pack_bits(jnp.where(bits, 1.0, -1.0))
    d = np.asarray(lsh.distance_matrix(codes, use_kernel=False))
    assert (d == d.T).all()
    assert (np.diag(d) == 0).all()
    assert (d <= words * 32).all() and (d >= 0).all()
    for i in range(m):
        for j in range(m):
            assert (d[i] + d[j] >= d[i, j]).all()  # vectorized triangle


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**16), st.floats(0.1, 10.0))
def test_weights_monotone_in_distance(seed, gamma):
    """Equal rank scores -> closer peers always weigh more (Eq. 8)."""
    key = jax.random.PRNGKey(seed)
    m = 6
    d = jax.random.uniform(key, (m, m))
    d = (d + d.T) / 2 * (1 - jnp.eye(m))
    s = jnp.ones((m,))
    w = np.asarray(neighbor.selection_weights(s, d, gamma))
    dn = np.asarray(d)
    for i in range(m):
        js = [j for j in range(m) if j != i]
        order_w = sorted(js, key=lambda j: -w[i, j])
        order_d = sorted(js, key=lambda j: dn[i, j])
        assert order_w == order_d


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**16))
def test_ranking_scores_ignore_padding(seed):
    key = jax.random.PRNGKey(seed)
    r = jax.random.randint(key, (5, 3), 0, 6).astype(jnp.int32)
    s1 = ranking.ranking_scores(r, 6, top_k=2)
    padded = jnp.concatenate([r, -jnp.ones((5, 2), jnp.int32)], axis=1)
    s2 = ranking.ranking_scores(padded, 6, top_k=2)
    assert np.allclose(np.asarray(s1), np.asarray(s2))


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**16), st.integers(1, 8))
def test_commitment_distinguishes_orderings(seed, n):
    """Rankings are order-sensitive: any permutation that changes the
    sequence changes the commitment (Eq. 9 binding)."""
    key = jax.random.PRNGKey(seed)
    r = jax.random.permutation(key, jnp.arange(n + 1, dtype=jnp.int32))[None]
    c1 = fnv1a_commit(r)
    r2 = jnp.roll(r, 1, axis=1)
    if not bool(jnp.all(r == r2)):
        assert not bool(jnp.all(fnv1a_commit(r2) == c1))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**16))
def test_sharded_lsh_equals_full_projection(seed):
    """Beyond-paper sharded LSH: sum of per-shard partial projections ==
    projection of the full vector (linearity), asserted via the
    shard_map helper on a 1-device mesh."""
    from repro.compat import shard_map
    from repro.kernels.ref import lsh_project_sums_ref
    key = jax.random.PRNGKey(seed)
    n = 4096
    x = jax.random.normal(key, (n,))
    mesh = jax.make_mesh((1,), ("model",))
    fn = shard_map(
        lambda v: lsh.sharded_lsh_code(v, 7, 128, "model"),
        mesh=mesh, in_specs=jax.sharding.PartitionSpec("model"),
        out_specs=jax.sharding.PartitionSpec(), check_vma=False)
    code_sharded = fn(x)
    code_full = ops.pack_bits(lsh_project_sums_ref(x, 7, bits=128))
    assert bool(jnp.all(code_sharded == code_full))
