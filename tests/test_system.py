"""End-to-end behaviour tests for the WPFed system (paper-level claims
at reduced scale)."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attacks, evaluate, init_state, make_wpfed_round
from repro.core.baselines import make_silo_round


def _run(f, round_fn, state, rounds):
    m = None
    for _ in range(rounds):
        state, m = round_fn(state, f["data"])
    return state, m


def test_wpfed_beats_silo_on_noniid(tiny_fed):
    """The paper's core claim (Table 2): collaboration with personalized
    selection beats isolated training under non-IID data, at equal local
    step budget."""
    f = tiny_fed
    key = jax.random.PRNGKey(42)
    s_w = init_state(f["apply_fn"], f["init_fn"], f["opt"], f["fed"], key)
    s_s = init_state(f["apply_fn"], f["init_fn"], f["opt"], f["fed"], key)
    wp = jax.jit(make_wpfed_round(f["apply_fn"], f["opt"], f["fed"]))
    si = jax.jit(make_silo_round(f["apply_fn"], f["opt"], f["fed"]))
    s_w, _ = _run(f, wp, s_w, 4)
    s_s, _ = _run(f, si, s_s, 4)
    acc_w = float(evaluate(f["apply_fn"], s_w, f["data"])["mean_acc"])
    acc_s = float(evaluate(f["apply_fn"], s_s, f["data"])["mean_acc"])
    # collaboration must not hurt; tiny-scale margin kept loose
    assert acc_w >= acc_s - 0.02, (acc_w, acc_s)


def test_poison_attack_resilience(tiny_fed):
    """Fig. 5 mechanism: poisoned clients get low ranking scores and are
    deselected; honest-client accuracy keeps improving."""
    f = tiny_fed
    key = jax.random.PRNGKey(7)
    state = init_state(f["apply_fn"], f["init_fn"], f["opt"], f["fed"], key)
    round_fn = jax.jit(make_wpfed_round(f["apply_fn"], f["opt"], f["fed"]))
    honest = jnp.array([True, True, True, True, False, False])
    m = None
    # paper §4.8: attacks start AFTER a warm-up so rankings carry signal
    for r in range(6):
        state = attacks.poison_step(state, ~honest, f["init_fn"],
                                    jax.random.fold_in(key, r), r,
                                    start_round=3, every=2)
        state, m = round_fn(state, f["data"])
    ev = evaluate(f["apply_fn"], state, f["data"],
                  honest_mask=honest.astype(jnp.float32))
    assert float(ev["mean_acc"]) > 0.4
    # poisoned clients should have lower crowd-sourced ranking scores
    scores = np.asarray(m["ranking_scores"])
    assert scores[:4].mean() >= scores[4:].mean() - 1e-6


def test_verification_toggles_change_robustness(tiny_fed):
    """Disabling LSH verification admits forged-code attackers into
    distillation; enabling it filters them (Fig. 4 mechanism)."""
    f = tiny_fed
    key = jax.random.PRNGKey(9)
    attacker = jnp.array([False, False, False, True, True, True])

    def run(lsh_verification):
        fed_v = dataclasses.replace(f["fed"],
                                    lsh_verification=lsh_verification)
        state = init_state(f["apply_fn"], f["init_fn"], f["opt"], fed_v, key)
        fn = jax.jit(make_wpfed_round(f["apply_fn"], f["opt"], fed_v))
        state, _ = fn(state, f["data"])
        state = attacks.corrupt_params(state, attacker, f["init_fn"],
                                       jax.random.fold_in(key, 1))
        state = attacks.forge_lsh_codes(state, attacker, target_id=0)
        _, m = fn(state, f["data"])
        return float(m["valid_neighbor_frac"])

    frac_on = run(True)
    frac_off = run(False)
    assert frac_off > frac_on  # verification excludes neighbors
