"""Trainer / server loop tests: loss goes down, serving generates, the
fed driver improves accuracy, checkpoint/restore mid-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.serve import serve
from repro.launch.train import train


def test_lm_training_reduces_loss(tmp_path):
    _, history = train("xlstm-350m", steps=30, batch=4, seq=64,
                       lr=1e-3, reduced=True,
                       ckpt_dir=str(tmp_path / "ck"), ckpt_every=15)
    assert history[0]["loss"] > history[-1]["loss"]
    # checkpoint exists and restore path works (restart from latest)
    _, history2 = train("xlstm-350m", steps=31, batch=4, seq=64,
                        lr=1e-3, reduced=True, ckpt_dir=str(tmp_path / "ck"))
    assert history2[-1]["step"] == 30


def test_serving_generates_tokens():
    res = serve("phi3-medium-14b", batch=2, prompt_len=16, max_new=8,
                reduced=True)
    gen = res["generated"]
    assert gen.shape == (2, 8)
    assert gen.dtype == np.int32
    assert res["decode_tok_per_s"] > 0


def test_serving_enc_dec():
    res = serve("whisper-small", batch=2, prompt_len=8, max_new=4,
                reduced=True)
    assert res["generated"].shape == (2, 4)


def test_fed_driver_improves(tiny_fed):
    """run_federation over the synthetic mnist dataset, 2 rounds, tiny."""
    from repro.launch.fed import run_federation
    from repro.configs.paper_models import FedConfig
    fed = FedConfig(num_clients=5, num_neighbors=2, top_k=2, local_steps=2,
                    local_batch=32, lsh_bits=128)
    state, history = run_federation("mnist", rounds=2, num_clients=5,
                                    fed=fed, log=lambda *a, **k: None)
    assert history[-1]["acc"] >= history[0]["acc"] - 0.05
