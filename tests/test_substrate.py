"""Substrate tests: optimizer, schedules, checkpointing, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import checkpoint as ckpt
from repro.data import (TokenStream, make_aecg_federated,
                        make_mnist_federated, make_seeg_federated)
from repro.configs import get_config
from repro.optim import (adam, adamw, apply_updates, clip_by_global_norm,
                         cosine_decay, linear_warmup_cosine, sgd)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("opt_fn", [lambda: sgd(0.1),
                                    lambda: sgd(0.1, momentum=0.9),
                                    lambda: adam(0.1),
                                    lambda: adamw(0.1, weight_decay=0.01)])
def test_optimizer_minimizes_quadratic(opt_fn):
    opt = opt_fn()
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array([1.5])}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)
    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(loss(params)) < 0.05 * l0


def test_adamw_decay_shrinks_weights():
    opt = adamw(0.05, weight_decay=0.5)
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    zero_g = {"w": jnp.zeros((4,))}
    for _ in range(10):
        upd, state = opt.update(zero_g, state, params)
        params = apply_updates(params, upd)
    assert float(jnp.max(params["w"])) < 1.0


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    cn = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    assert abs(cn - 1.0) < 1e-5
    assert float(norm) > 1.0
    small = {"a": jnp.full((3,), 0.01)}
    kept, _ = clip_by_global_norm(small, 1.0)
    assert np.allclose(np.asarray(kept["a"]), 0.01)


def test_schedules():
    s = linear_warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.int32(0))) == 0.0
    assert abs(float(s(jnp.int32(10))) - 1.0) < 0.02
    assert float(s(jnp.int32(100))) < 0.2
    c = cosine_decay(1.0, 100)
    assert float(c(jnp.int32(0))) == 1.0


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"layers": ({"w": jnp.arange(6.0).reshape(2, 3)},
                       {"w": jnp.ones((4,), jnp.bfloat16)}),
            "step": jnp.int32(7)}
    d = str(tmp_path / "ck")
    ckpt.save(d, 3, tree)
    ckpt.save(d, 9, tree)
    assert ckpt.latest_step(d) == 9
    like = jax.tree.map(jnp.zeros_like, tree)
    restored = ckpt.restore(d, 9, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        assert np.allclose(np.asarray(a, np.float64),
                           np.asarray(b, np.float64))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, {"w": jnp.ones((3,))})
    with pytest.raises(ValueError):
        ckpt.restore(d, 1, {"w": jnp.ones((4,))})


def test_checkpoint_keep_last_k_prunes(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"w": jnp.ones((2,))}
    for step in range(5):
        ckpt.save(d, step, tree, keep_last_k=3)
    import os
    files = sorted(f for f in os.listdir(d) if f.endswith(".npz"))
    assert files == ["step_00000002.npz", "step_00000003.npz",
                     "step_00000004.npz"]
    assert ckpt.latest_step(d) == 4
    # pruning never touches non-snapshot files in the same directory
    # (the service keeps its chain.json next to the snapshots)
    (tmp_path / "ck" / "chain.json").write_text("{}")
    ckpt.save(d, 5, tree, keep_last_k=1)
    left = sorted(os.listdir(d))
    assert left == ["chain.json", "step_00000005.npz"]
    with pytest.raises(ValueError):
        ckpt.save(d, 6, tree, keep_last_k=0)


def test_checkpoint_mixed_pytree_bf16_roundtrip(tmp_path):
    """Tuple/list/dict mix + the bf16 -> f32 (npz) -> bf16 cast path:
    bf16 survives EXACTLY (f32 holds every bf16 value), and every
    other dtype round-trips bitwise."""
    tree = {
        "stack": [({"w": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
                    "b": jnp.float32(0.1)},
                   {"w": jnp.full((3,), 1.0 / 3.0, jnp.bfloat16)}),
                  {"ints": jnp.arange(4, dtype=jnp.int32)}],
        "mask": jnp.asarray([True, False, True]),
        "seed": jnp.asarray([7, 9], jnp.uint32),
    }
    d = str(tmp_path / "ck")
    ckpt.save(d, 0, tree)
    restored = ckpt.restore(d, 0, jax.tree.map(jnp.zeros_like, tree))
    assert (jax.tree.structure(tree) == jax.tree.structure(restored))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        assert np.array_equal(np.asarray(a, np.float64),
                              np.asarray(b, np.float64))


# ---------------------------------------------------------------------------
# federated data pipeline (paper §4.3 statistics)
# ---------------------------------------------------------------------------
def test_mnist_partition_statistics():
    ds = make_mnist_federated(num_clients=10, per_client=100,
                              ref_per_client=16)
    assert ds.num_clients == 10
    for c in ds.clients:
        # 7:3 split
        total = len(c.x_train) + len(c.x_test)
        assert abs(len(c.x_train) / total - 0.7) < 0.05
        assert c.x_ref.shape == (16, 28, 28, 1)
    # non-IID label skew: per-client class distributions differ
    props = np.stack([np.bincount(c.y_train, minlength=10)
                      / len(c.y_train) for c in ds.clients])
    assert float(props.std(axis=0).max()) > 0.01
    # reference sets are disjoint across clients
    refs = [c.x_ref.tobytes() for c in ds.clients]
    assert len(set(refs)) == len(refs)


@pytest.mark.parametrize("maker,n,classes", [(make_aecg_federated, 6, 2),
                                             (make_seeg_federated, 6, 3)])
def test_subject_datasets(maker, n, classes):
    ds = maker(num_clients=n)
    assert ds.num_clients == n
    st = ds.stacked()
    assert st["x_train"].shape[0] == n
    for c in ds.clients:
        assert set(np.unique(c.y_train)) <= set(range(classes))
    assert ds.shared_ref_x is not None


def test_token_stream_determinism_and_shapes():
    cfg = get_config("phi3-medium-14b").reduced()
    s1 = TokenStream(cfg, 4, 32, seed=1)
    s2 = TokenStream(cfg, 4, 32, seed=1)
    b1, b2 = s1.next_batch(), s2.next_batch()
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 32)
    assert b1["labels"].shape == (4, 32)
    # labels are next-token shifted
    assert np.array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    assert b1["tokens"].max() < cfg.vocab_size


def test_modality_stubs():
    from repro.data import modality_stub
    whisper = get_config("whisper-small").reduced()
    stub = modality_stub(whisper, 2)
    assert stub["audio"].shape == (2, whisper.encoder_seq_len,
                                   whisper.d_model)
    vlm = get_config("llama-3.2-vision-90b").reduced()
    stub = modality_stub(vlm, 2)
    assert stub["vision"].shape == (2, vlm.vision_tokens, vlm.vision_dim)
