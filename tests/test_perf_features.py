"""Tests for the §Perf features: sort-based MoE dispatch, shard_map MoE,
grouped dispatch, chunked attention, grad accumulation."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import forward, init_params
from repro.optim import adamw
from repro.train import init_train_state, make_train_step


@pytest.fixture(autouse=True)
def _reset_moe_globals():
    yield
    moe_mod.set_sharded_impl(None)
    moe_mod.set_dispatch_spec(None, num_groups=1)
    attn_mod.set_attn_impl("auto")


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 12), st.integers(16, 300))
def test_position_in_expert_matches_cumsum_oracle(seed, e, n):
    fe = jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, e)
    oh = jax.nn.one_hot(fe, e, dtype=jnp.int32)
    pos_ref = jnp.sum((jnp.cumsum(oh, 0) - oh) * oh, -1)
    assert bool(jnp.all(moe_mod._position_in_expert(fe) == pos_ref))


@pytest.mark.parametrize("arch", ["grok-1-314b", "kimi-k2-1t-a32b"])
def test_sharded_moe_matches_global(arch):
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              moe_capacity_factor=50.0)
    p = moe_mod.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    o_ref, aux_ref = moe_mod.apply_moe(cfg, p, x)
    mesh = make_host_mesh()
    moe_mod.set_sharded_impl(mesh, batch_axes=("data",))
    with mesh:
        o_sm, aux_sm = jax.jit(
            lambda p_, x_: moe_mod.moe_forward(cfg, p_, x_))(p, x)
    assert float(jnp.max(jnp.abs(o_ref - o_sm))) < 1e-4
    assert abs(float(aux_ref["load_balance"])
               - float(aux_sm["load_balance"])) < 1e-4


def test_grouped_dispatch_matches_global():
    cfg = dataclasses.replace(get_config("grok-1-314b").reduced(),
                              moe_capacity_factor=50.0)
    p = moe_mod.init_moe(cfg, jax.random.PRNGKey(2), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 32, cfg.d_model))
    moe_mod.set_dispatch_spec(None, num_groups=1)
    o1, _ = moe_mod.apply_moe(cfg, p, x)
    moe_mod.set_dispatch_spec(None, num_groups=4)
    o4, _ = moe_mod.apply_moe(cfg, p, x)
    assert float(jnp.max(jnp.abs(o1 - o4))) < 1e-5


def test_moe_capacity_drops_tokens():
    cfg = dataclasses.replace(get_config("grok-1-314b").reduced(),
                              moe_capacity_factor=0.25)
    p = moe_mod.init_moe(cfg, jax.random.PRNGKey(4), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 64, cfg.d_model))
    _, aux = moe_mod.apply_moe(cfg, p, x)
    assert float(aux["dropped_frac"]) > 0.0


def test_chunked_attention_engages_at_threshold():
    cfg = get_config("phi3-medium-14b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 4096), 0,
                              cfg.vocab_size)
    attn_mod.set_attn_impl("naive")
    l_n, _ = forward(cfg, params, toks)
    attn_mod.set_attn_impl("auto")  # 4096^2 > 2048^2 -> chunked
    l_a, _ = forward(cfg, params, toks)
    np.testing.assert_allclose(np.asarray(l_n), np.asarray(l_a),
                               atol=5e-5, rtol=5e-5)


def test_grad_accum_matches_full_batch():
    cfg = get_config("xlstm-350m").reduced()
    opt = adamw(1e-3)
    params, opt_state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    s1 = make_train_step(cfg, opt, remat="none", grad_accum=1)
    s4 = make_train_step(cfg, opt, remat="none", grad_accum=4)
    p1, _, m1 = s1(params, opt_state, batch)
    p4, _, m4 = s4(params, opt_state, batch)
    # f32 accumulation order differs between the chunked and full-batch
    # paths; Adam normalizes tiny grad differences up to ~lr scale.
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p4)
    assert max(jax.tree.leaves(diffs)) < 5e-4
