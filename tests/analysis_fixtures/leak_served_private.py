"""Seeded-leak fixture: `taint-sink` — a serving response that returns
a value derived from a PRIVATE TRAINING BATCH (not just the requested
model's logits on the request input). The served output mixes in the
client's local data mean, so the response sink receives client-data
taint (ISSUE 9: "private-batch served output")."""
import jax.numpy as jnp

from repro.analysis.privacy import sink
from repro.analysis.taint import SRC_DATA, taint_target


def leaky_serve(x_request, x_train):
    # BUG: the response blends in statistics of the private batch
    out = x_request * 2.0 + jnp.mean(x_train)
    return sink("serving-response", out)


taint_target(
    name="leak-served-private",
    build=lambda: (leaky_serve,
                   (jnp.ones((2, 8), jnp.float32),
                    jnp.ones((16, 8), jnp.float32)),
                   ("", SRC_DATA)))
