"""Seeded-leak fixture: `taint-sink` — an announcement that publishes
a RAW PARAMETER leaf to the chain. The codes/commitment fields are
properly declassified; the third field is a slice of the client's own
parameters, exactly the refactor-regression the trust-free verifier
exists to catch (ISSUE 9: "raw-param announce")."""
import jax.numpy as jnp

from repro.analysis.privacy import sink
from repro.analysis.taint import SRC_PARAMS, taint_target
from repro.core.chain import fnv1a_commit
from repro.core.lsh import stacked_lsh_codes


def leaky_announce(params_vec):
    # stacked_lsh_codes / fnv1a_commit are registered declassifiers —
    # these two fields are fine
    codes = stacked_lsh_codes(params_vec, seed=1, bits=32,
                              backend="oracle")
    commit = fnv1a_commit(params_vec.astype(jnp.int32), salt=0)
    # BUG: the third announced field is the raw parameter row itself
    return sink("chain-announcement", (codes, commit, params_vec[0]))


taint_target(
    name="leak-announce-field",
    build=lambda: (leaky_announce,
                   (jnp.ones((4, 8), jnp.float32),),
                   (SRC_PARAMS,)))
