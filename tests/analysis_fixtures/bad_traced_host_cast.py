"""Seeded-bad fixture: `traced-host-cast` — float() on a traced
reduction inside a jitted function (crashes at trace time in the real
world; the lint catches it without tracing)."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("scale",))
def scale_by_mean(x, *, scale: float = 2.0):
    total = float(jnp.sum(x))           # BUG: host cast on a tracer
    return x * (total * scale)
