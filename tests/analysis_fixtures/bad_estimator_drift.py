"""Seeded-bad fixture: `estimator-drift` — the declared VMEM estimator
claims 4x the bytes the captured BlockSpecs imply (the §10 drift bug
class: a retuned kernel whose budget formula was not updated)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis.registry import kernel_contract


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def _lying_estimator(m: int, n: int) -> int:
    return 4 * (2 * 8 * n * 4)          # BUG: 4x the real working set


@kernel_contract(
    name="fixture_estimator_drift", sites=1, oracle=None,
    estimator=_lying_estimator, exactness="bit_exact", out_revisit=(),
    points=({"m": 32, "n": 128},),
    make_args=lambda pt: (
        (jax.ShapeDtypeStruct((pt["m"], pt["n"]), jnp.float32),), {}),
    estimator_kwargs=lambda pt: {"m": pt["m"], "n": pt["n"]},
    slack=0.10)
def drift(x):
    m, n = x.shape
    return pl.pallas_call(
        _copy_kernel,
        grid=(m // 8,),
        in_specs=[pl.BlockSpec((8, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x)
