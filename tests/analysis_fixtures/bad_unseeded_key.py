"""Seeded-bad fixture: `unseeded-key` — a constant PRNGKey built
inside a jitted function, so the "random" draw is identical every
round (PR 1's dead-seed bug class)."""
import jax


@jax.jit
def add_noise(x):
    key = jax.random.PRNGKey(0)         # BUG: round-independent key
    return x + jax.random.normal(key, x.shape)
