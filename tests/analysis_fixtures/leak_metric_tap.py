"""Seeded-leak fixture: `taint-callback` — a metrics tap that streams
a parameter-derived value to the host through io_callback WITHOUT the
`round-telemetry` declassifier. The engine flags the tainted callback
operand even though the value is a mere scalar mean (ISSUE 9:
"undeclassified io_callback tap")."""
import jax.numpy as jnp
from jax.experimental import io_callback

from repro.analysis.taint import SRC_PARAMS, taint_target


def leaky_tap(params_vec):
    mean = jnp.mean(params_vec)
    # BUG: device->host crossing with no declassifier on the path
    io_callback(lambda s: None, None, mean, ordered=True)
    return mean


taint_target(
    name="leak-metric-tap",
    build=lambda: (leaky_tap,
                   (jnp.ones((4, 8), jnp.float32),),
                   (SRC_PARAMS,)))
