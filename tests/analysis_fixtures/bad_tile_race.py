"""Seeded-bad fixture: `tile-race` — the output index map collapses
pairs of grid points onto the same block with no declared revisit
axis, so two programs race on every written block (and the collapsed
mapping also leaves the tail blocks unwritten)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis.registry import kernel_contract


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


@kernel_contract(
    name="fixture_tile_race", sites=1, oracle=None, estimator=None,
    exactness="bit_exact", out_revisit=(),    # no axis declared
    points=({"m": 32},),
    make_args=lambda pt: (
        (jax.ShapeDtypeStruct((pt["m"], 128), jnp.float32),), {}))
def race(x):
    m, n = x.shape
    return pl.pallas_call(
        _copy_kernel,
        grid=(m // 8,),
        in_specs=[pl.BlockSpec((8, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, n), lambda i: (i // 2, 0)),  # BUG
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x)
