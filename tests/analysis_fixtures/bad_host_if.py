"""Seeded-bad fixture: `host-if` — a Python `if` on a traced boolean
inside a jitted function (freezes the branch at trace time or raises
TracerBoolConversionError; the lint catches it statically)."""
import jax


@jax.jit
def positive_part(x):
    if x.sum() > 0:                     # BUG: branch on a tracer
        return x
    return -x
