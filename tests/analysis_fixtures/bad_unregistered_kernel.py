"""Seeded-bad fixture: `unregistered-kernel` — a module that launches
`pl.pallas_call` with NO `kernel_contract` registration. The
completeness walk counts call sites per file against the declared
contract totals, so a kernel added outside kernels/ (or without its
registry entry) fails the gate instead of silently skipping every
contract check."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _double_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


@jax.jit
def double(x):
    # BUG: no kernel_contract entry declares this launch site
    return pl.pallas_call(
        _double_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True)(x)
