"""Seeded-bad fixture: `tile-gap` — the grid stops at half the row
blocks, so the lower half of the output is never written."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis.registry import kernel_contract


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


@kernel_contract(
    name="fixture_tile_gap", sites=1, oracle=None, estimator=None,
    exactness="bit_exact", out_revisit=(),
    points=({"m": 32},),
    make_args=lambda pt: (
        (jax.ShapeDtypeStruct((pt["m"], 128), jnp.float32),), {}))
def gap(x):
    m, n = x.shape
    return pl.pallas_call(
        _copy_kernel,
        grid=(m // 8 // 2,),        # BUG: half the row blocks
        in_specs=[pl.BlockSpec((8, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x)
