"""Seeded-bad fixture: `block-mismatch` — the in_spec's block is
rank-1 against a rank-2 operand, and the kernel body takes three refs
while the launch binds 1 input + 1 output = 2."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis.registry import kernel_contract


def _bad_kernel(x_ref, y_ref, o_ref):   # BUG: launch binds only 2 refs
    o_ref[...] = x_ref[...]


@kernel_contract(
    name="fixture_block_mismatch", sites=1, oracle=None, estimator=None,
    exactness="bit_exact", out_revisit=(),
    points=({"m": 32},),
    make_args=lambda pt: (
        (jax.ShapeDtypeStruct((pt["m"], 128), jnp.float32),), {}))
def mismatch(x):
    m, n = x.shape
    return pl.pallas_call(
        _bad_kernel,
        grid=(m // 8,),
        in_specs=[pl.BlockSpec((n,), lambda i: (0,))],   # BUG: rank 1
        out_specs=pl.BlockSpec((8, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x)
