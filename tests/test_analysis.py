"""Tests for the `repro.analysis` static-analysis gate (DESIGN.md §12).

Three layers of coverage:
  * HEAD is clean — every registered kernel contract verifies and the
    trace lint finds nothing un-exempted in core/kernels/launch;
  * each seeded-bad fixture under tests/analysis_fixtures/ trips
    exactly the rule its header names (and fails the strict CLI);
  * the registry is complete (every `pallas_call(` site anywhere under
    src/repro is declared by some entry — AST walk, not grep) and the
    five VMEM estimators in core.backends are each cross-validated at
    >= 3 representative shape points.

Taint-verifier coverage lives in tests/test_taint.py; this file covers
the report schema, the completeness walk, and the host-ok inventory.
"""
import os

import pytest

from repro.analysis import __main__ as analysis_main
from repro.analysis.exemptions import EXPECTED_HOST_OK
from repro.analysis.kernel_contracts import (check_entries, check_entry,
                                             completeness_findings,
                                             head_entries,
                                             pallas_call_lines)
from repro.analysis.trace_lint import (collect_host_ok, lint_paths,
                                       lint_source)
from repro.core import backends

FIXTURES = os.path.join(os.path.dirname(__file__), "analysis_fixtures")


def _fixture(name: str) -> str:
    return os.path.join(FIXTURES, name)


# ---------------------------------------------------------------------------
# HEAD is clean
# ---------------------------------------------------------------------------
def test_head_kernel_contracts_clean():
    entries = head_entries()
    assert len(entries) == 9
    findings = check_entries(entries)
    assert findings == [], "\n".join(str(f) for f in findings)


def test_head_trace_lint_clean():
    findings = lint_paths(analysis_main._default_lint_paths())
    assert findings == [], "\n".join(str(f) for f in findings)


def test_cli_strict_head_clean_and_writes_json(tmp_path):
    report = tmp_path / "report.json"
    assert analysis_main.run(["--strict", "--json", str(report)]) == 0
    import json
    payload = json.loads(report.read_text())
    assert payload["schema_version"] == 2
    assert payload["clean"] is True
    assert payload["total"] == 0
    assert payload["findings"] == []
    assert len(payload["kernel_entries"]) == 9
    # the full HEAD taint surface rides in the same report
    assert len(payload["taint_targets"]) == 16
    assert "wpfed-global-round" in payload["taint_targets"]
    assert "service-degraded-round" in payload["taint_targets"]
    assert payload["host_ok"]["count"] == EXPECTED_HOST_OK
    assert len(payload["host_ok"]["sites"]) == EXPECTED_HOST_OK
    assert payload["wall_time_s"] > 0


# ---------------------------------------------------------------------------
# seeded-bad fixtures: one per rule
# ---------------------------------------------------------------------------
CONTRACT_FIXTURES = [
    ("bad_tile_gap.py", "tile-gap"),
    ("bad_tile_race.py", "tile-race"),
    ("bad_block_mismatch.py", "block-mismatch"),
    ("bad_estimator_drift.py", "estimator-drift"),
]
LINT_FIXTURES = [
    ("bad_traced_host_cast.py", "traced-host-cast"),
    ("bad_unseeded_key.py", "unseeded-key"),
    ("bad_host_if.py", "host-if"),
]


SITE_FIXTURES = [
    ("bad_unregistered_kernel.py", "unregistered-kernel"),
]


@pytest.mark.parametrize("name,rule", CONTRACT_FIXTURES)
def test_contract_fixture_trips_rule(name, rule):
    findings = analysis_main._check_fixture_file(_fixture(name))
    assert rule in {f.rule for f in findings}, \
        "\n".join(str(f) for f in findings)


@pytest.mark.parametrize("name,rule", LINT_FIXTURES)
def test_lint_fixture_trips_rule(name, rule):
    findings = lint_paths([_fixture(name)])
    assert rule in {f.rule for f in findings}, \
        "\n".join(str(f) for f in findings)


@pytest.mark.parametrize("name,rule",
                         CONTRACT_FIXTURES + LINT_FIXTURES + SITE_FIXTURES)
def test_cli_strict_fails_on_fixture(name, rule, capsys):
    assert analysis_main.run(["--strict", _fixture(name)]) != 0
    assert rule in capsys.readouterr().out


def test_fixture_dir_covers_at_least_six_rules():
    rules = {r for _, r in
             CONTRACT_FIXTURES + LINT_FIXTURES + SITE_FIXTURES}
    assert len(rules) >= 7


# ---------------------------------------------------------------------------
# registry completeness: no unregistered pallas_call sites in src/repro
# ---------------------------------------------------------------------------
def test_every_pallas_call_site_is_registered():
    # the src/repro-wide AST walk finds nothing undeclared on HEAD
    findings = completeness_findings(head_entries())
    assert findings == [], "\n".join(str(f) for f in findings)


def test_pallas_call_lines_counts_ast_call_nodes():
    import repro.kernels.lsh_projection as mod
    lines = pallas_call_lines(mod.__file__)
    assert len(lines) >= 1 and all(
        isinstance(n, int) and n > 0 for n in lines)
    # registry.py ASSIGNS pl.pallas_call (capture shim) but never calls
    # it — the AST counter must not miscount that as a launch site
    import repro.analysis.registry as reg
    assert pallas_call_lines(reg.__file__) == []
    # and the seeded fixture has exactly one site
    assert len(pallas_call_lines(
        _fixture("bad_unregistered_kernel.py"))) == 1


def test_completeness_flags_undeclared_site():
    findings = completeness_findings(
        head_entries(),
        src_root=os.path.dirname(_fixture("bad_unregistered_kernel.py")))
    flagged = [f for f in findings if f.rule == "unregistered-kernel"]
    assert any("bad_unregistered_kernel.py" in f.path for f in flagged), \
        "\n".join(str(f) for f in findings)


# ---------------------------------------------------------------------------
# host-ok exemption inventory (satellite: every exemption is visible)
# ---------------------------------------------------------------------------
def test_host_ok_inventory_matches_pin():
    sites = collect_host_ok(analysis_main._default_lint_paths())
    assert len(sites) == EXPECTED_HOST_OK, (
        f"{len(sites)} host-ok exemptions found, pin says "
        f"{EXPECTED_HOST_OK} — update src/repro/analysis/exemptions.py "
        f"alongside the new/removed exemption")
    for path, line, why in sites:
        assert line > 0 and why, (path, line, why)


def test_host_ok_drift_is_a_strict_failure(monkeypatch, capsys):
    import repro.analysis.exemptions as ex
    monkeypatch.setattr(ex, "EXPECTED_HOST_OK", EXPECTED_HOST_OK + 1)
    assert analysis_main.run(["--strict"]) != 0
    assert "host-ok-drift" in capsys.readouterr().out
    # without --strict a warning-severity drift does not gate
    monkeypatch.undo()


# ---------------------------------------------------------------------------
# estimator truthfulness: all five backends estimators, >= 3 points
# ---------------------------------------------------------------------------
def test_all_vmem_estimators_cross_validated():
    entries = head_entries()
    by_estimator = {e.estimator: e for e in entries
                    if isinstance(e.estimator, str)}
    assert set(by_estimator) == set(backends.VMEM_ESTIMATORS)
    for name, entry in sorted(by_estimator.items()):
        assert len(entry.points) >= 3, name
        bad = [f for f in check_entry(entry)
               if f.rule.startswith("estimator")]
        assert bad == [], f"{name}: " + "\n".join(str(f) for f in bad)


# ---------------------------------------------------------------------------
# consolidated backend/tiling rejection formatter (core.backends)
# ---------------------------------------------------------------------------
_BAD_STRINGS = ["", "Auto", "kernel ", "oracel", "tiled1", "none",
                "ANN", "oneshot-ish"]


@pytest.mark.parametrize("bad", _BAD_STRINGS)
@pytest.mark.parametrize("resolver,field,accepted", [
    (backends.resolve, "backend", backends.BACKENDS),
    (lambda b: backends.resolve_selection(
        b, 64, exact_flops=1.0, ann_flops=1.0),
     "selection backend", backends.SELECTION_BACKENDS),
    (lambda b: backends.resolve_tiling(b, 0),
     "tiling", backends.TILINGS),
], ids=["resolve", "resolve_selection", "resolve_tiling"])
def test_rejections_name_field_value_and_accepted_set(
        resolver, field, accepted, bad):
    with pytest.raises(ValueError) as ei:
        resolver(bad)
    msg = str(ei.value)
    assert f"unknown {field}:" in msg
    assert repr(bad) in msg
    assert str(tuple(accepted)) in msg


def test_accepted_strings_do_not_raise():
    for b in backends.BACKENDS:
        assert backends.resolve(b) in ("kernel", "oracle")
    for b in backends.SELECTION_BACKENDS:
        assert backends.resolve_selection(
            b, 64, exact_flops=1.0, ann_flops=1.0) in (
                "kernel", "oracle", "ann")
    for t in backends.TILINGS:
        assert backends.resolve_tiling(t, 0) in ("oneshot", "tiled")


# ---------------------------------------------------------------------------
# lint mechanics: exemption scopes + traced-context discovery
# ---------------------------------------------------------------------------
def test_host_ok_exemption_scopes():
    src = """\
import numpy as np

def same_line(x):
    return np.asarray(x.data)  # analysis: host-ok (telemetry)

def line_above(x):
    # analysis: host-ok (telemetry)
    return np.asarray(x.data)

def def_scope(x):  # analysis: host-ok
    a = np.asarray(x.data)
    return float(a.sum())

def flagged(x):
    return np.asarray(x.data)
"""
    findings = lint_source(src, "mem.py")
    assert [f.rule for f in findings] == ["host-sync"]
    assert findings[0].line == 15


def test_scan_body_is_a_traced_context():
    src = """\
import jax
import jax.numpy as jnp

def outer(xs):
    def body(carry, x):
        if carry > 0:
            carry = carry + 1.0
        return carry, float(jnp.sum(x))
    return jax.lax.scan(body, 0.0, xs)
"""
    rules = {f.rule for f in lint_source(src, "mem.py")}
    assert rules == {"host-if", "traced-host-cast"}


def test_static_argnames_are_not_traced():
    src = """\
import functools
import jax

@functools.partial(jax.jit, static_argnames=("n",))
def f(x, *, n):
    m = int(n * 2)          # static: fine
    k = x.shape[0]
    if n > k:               # static + shape: fine
        return x
    return x * m
"""
    assert lint_source(src, "mem.py") == []
