"""Tests for the `repro.analysis` static-analysis gate (DESIGN.md §12).

Three layers of coverage:
  * HEAD is clean — every registered kernel contract verifies and the
    trace lint finds nothing un-exempted in core/kernels/launch;
  * each seeded-bad fixture under tests/analysis_fixtures/ trips
    exactly the rule its header names (and fails the strict CLI);
  * the registry is complete (every `pl.pallas_call(` site in
    src/repro/kernels is declared by some entry) and the five VMEM
    estimators in core.backends are each cross-validated at >= 3
    representative shape points.
"""
import glob
import os
import re

import pytest

from repro.analysis import __main__ as analysis_main
from repro.analysis.kernel_contracts import (check_entries, check_entry,
                                             head_entries)
from repro.analysis.trace_lint import lint_paths, lint_source
from repro.core import backends

FIXTURES = os.path.join(os.path.dirname(__file__), "analysis_fixtures")


def _fixture(name: str) -> str:
    return os.path.join(FIXTURES, name)


# ---------------------------------------------------------------------------
# HEAD is clean
# ---------------------------------------------------------------------------
def test_head_kernel_contracts_clean():
    entries = head_entries()
    assert len(entries) == 9
    findings = check_entries(entries)
    assert findings == [], "\n".join(str(f) for f in findings)


def test_head_trace_lint_clean():
    findings = lint_paths(analysis_main._default_lint_paths())
    assert findings == [], "\n".join(str(f) for f in findings)


def test_cli_strict_head_clean_and_writes_json(tmp_path):
    report = tmp_path / "report.json"
    assert analysis_main.run(["--strict", "--json", str(report)]) == 0
    import json
    payload = json.loads(report.read_text())
    assert payload["clean"] is True
    assert payload["total"] == 0
    assert len(payload["kernel_entries"]) == 9


# ---------------------------------------------------------------------------
# seeded-bad fixtures: one per rule
# ---------------------------------------------------------------------------
CONTRACT_FIXTURES = [
    ("bad_tile_gap.py", "tile-gap"),
    ("bad_tile_race.py", "tile-race"),
    ("bad_block_mismatch.py", "block-mismatch"),
    ("bad_estimator_drift.py", "estimator-drift"),
]
LINT_FIXTURES = [
    ("bad_traced_host_cast.py", "traced-host-cast"),
    ("bad_unseeded_key.py", "unseeded-key"),
    ("bad_host_if.py", "host-if"),
]


@pytest.mark.parametrize("name,rule", CONTRACT_FIXTURES)
def test_contract_fixture_trips_rule(name, rule):
    findings = analysis_main._check_module_file(_fixture(name))
    assert rule in {f.rule for f in findings}, \
        "\n".join(str(f) for f in findings)


@pytest.mark.parametrize("name,rule", LINT_FIXTURES)
def test_lint_fixture_trips_rule(name, rule):
    findings = lint_paths([_fixture(name)])
    assert rule in {f.rule for f in findings}, \
        "\n".join(str(f) for f in findings)


@pytest.mark.parametrize("name,rule",
                         CONTRACT_FIXTURES + LINT_FIXTURES)
def test_cli_strict_fails_on_fixture(name, rule, capsys):
    assert analysis_main.run(["--strict", _fixture(name)]) != 0
    assert rule in capsys.readouterr().out


def test_fixture_dir_covers_at_least_six_rules():
    rules = {r for _, r in CONTRACT_FIXTURES + LINT_FIXTURES}
    assert len(rules) >= 6


# ---------------------------------------------------------------------------
# registry completeness: no unregistered pallas_call sites
# ---------------------------------------------------------------------------
def test_every_pallas_call_site_is_registered():
    import repro.kernels
    sites_by_module = {}
    for e in head_entries():
        sites_by_module[e.module] = \
            sites_by_module.get(e.module, 0) + e.sites
    kernels_dir = os.path.dirname(repro.kernels.__file__)
    seen_any = False
    for path in sorted(glob.glob(os.path.join(kernels_dir, "*.py"))):
        with open(path, "r", encoding="utf-8") as fh:
            n_sites = len(re.findall(r"pl\.pallas_call\(", fh.read()))
        mod = "repro.kernels." + \
            os.path.splitext(os.path.basename(path))[0]
        assert sites_by_module.get(mod, 0) == n_sites, (
            f"{mod} launches {n_sites} pallas_call site(s) but the "
            f"registry declares {sites_by_module.get(mod, 0)} — add or "
            f"fix a @kernel_contract entry")
        seen_any = seen_any or n_sites > 0
    assert seen_any  # the grep actually found the kernels


# ---------------------------------------------------------------------------
# estimator truthfulness: all five backends estimators, >= 3 points
# ---------------------------------------------------------------------------
def test_all_vmem_estimators_cross_validated():
    entries = head_entries()
    by_estimator = {e.estimator: e for e in entries
                    if isinstance(e.estimator, str)}
    assert set(by_estimator) == set(backends.VMEM_ESTIMATORS)
    for name, entry in sorted(by_estimator.items()):
        assert len(entry.points) >= 3, name
        bad = [f for f in check_entry(entry)
               if f.rule.startswith("estimator")]
        assert bad == [], f"{name}: " + "\n".join(str(f) for f in bad)


# ---------------------------------------------------------------------------
# consolidated backend/tiling rejection formatter (core.backends)
# ---------------------------------------------------------------------------
_BAD_STRINGS = ["", "Auto", "kernel ", "oracel", "tiled1", "none",
                "ANN", "oneshot-ish"]


@pytest.mark.parametrize("bad", _BAD_STRINGS)
@pytest.mark.parametrize("resolver,field,accepted", [
    (backends.resolve, "backend", backends.BACKENDS),
    (lambda b: backends.resolve_selection(
        b, 64, exact_flops=1.0, ann_flops=1.0),
     "selection backend", backends.SELECTION_BACKENDS),
    (lambda b: backends.resolve_tiling(b, 0),
     "tiling", backends.TILINGS),
], ids=["resolve", "resolve_selection", "resolve_tiling"])
def test_rejections_name_field_value_and_accepted_set(
        resolver, field, accepted, bad):
    with pytest.raises(ValueError) as ei:
        resolver(bad)
    msg = str(ei.value)
    assert f"unknown {field}:" in msg
    assert repr(bad) in msg
    assert str(tuple(accepted)) in msg


def test_accepted_strings_do_not_raise():
    for b in backends.BACKENDS:
        assert backends.resolve(b) in ("kernel", "oracle")
    for b in backends.SELECTION_BACKENDS:
        assert backends.resolve_selection(
            b, 64, exact_flops=1.0, ann_flops=1.0) in (
                "kernel", "oracle", "ann")
    for t in backends.TILINGS:
        assert backends.resolve_tiling(t, 0) in ("oneshot", "tiled")


# ---------------------------------------------------------------------------
# lint mechanics: exemption scopes + traced-context discovery
# ---------------------------------------------------------------------------
def test_host_ok_exemption_scopes():
    src = """\
import numpy as np

def same_line(x):
    return np.asarray(x.data)  # analysis: host-ok (telemetry)

def line_above(x):
    # analysis: host-ok (telemetry)
    return np.asarray(x.data)

def def_scope(x):  # analysis: host-ok
    a = np.asarray(x.data)
    return float(a.sum())

def flagged(x):
    return np.asarray(x.data)
"""
    findings = lint_source(src, "mem.py")
    assert [f.rule for f in findings] == ["host-sync"]
    assert findings[0].line == 15


def test_scan_body_is_a_traced_context():
    src = """\
import jax
import jax.numpy as jnp

def outer(xs):
    def body(carry, x):
        if carry > 0:
            carry = carry + 1.0
        return carry, float(jnp.sum(x))
    return jax.lax.scan(body, 0.0, xs)
"""
    rules = {f.rule for f in lint_source(src, "mem.py")}
    assert rules == {"host-if", "traced-host-cast"}


def test_static_argnames_are_not_traced():
    src = """\
import functools
import jax

@functools.partial(jax.jit, static_argnames=("n",))
def f(x, *, n):
    m = int(n * 2)          # static: fine
    k = x.shape[0]
    if n > k:               # static + shape: fine
        return x
    return x * m
"""
    assert lint_source(src, "mem.py") == []
