"""All-in-one exchange subsystem (DESIGN.md §7).

Bit-exactness contracts:
  * fused exchange kernel vs jnp oracle: losses, valid mask, aggregated
    targets identical in interpret mode (incl. the M-padding path);
  * oracle vs the unfused composition the round used to run
    (distill.cross_entropy -> verify.lsh_verification_mask ->
    distill.aggregate_neighbor_outputs): identical, so the refactored
    round's metrics are unchanged by construction;
  * all_in_one_exchange backends agree and the protocol round is
    exchange-backend-invariant end to end.

Semantics regressions for §3.5 and the two reference regimes:
  upper-half keep count, masked neighbors never passing, the
  all-invalid fallback to local-only loss, and personal-vs-public
  ref_mode equivalence when every client holds the same reference set.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import FedConfig
from repro.core import (all_in_one_exchange, distill, exchange_phase,
                        init_state, make_wpfed_round, select_phase, verify)
from repro.core.exchange import ExchangeResult
from repro.kernels import ref
from repro.kernels.exchange import BM_EXC, fused_exchange


def _inputs(m, n, r, c, seed=0, sel_p=0.7):
    k = jax.random.PRNGKey(seed)
    own = jax.random.normal(k, (m, r, c)) * 3
    nb = jax.random.normal(jax.random.fold_in(k, 1), (m, n, r, c)) * 3
    y = jax.random.randint(jax.random.fold_in(k, 2), (m, r), 0, c)
    sel = jax.random.bernoulli(jax.random.fold_in(k, 3), sel_p, (m, n))
    return own, nb, y, sel


# ---------------------------------------------------------------------------
# kernel vs oracle vs unfused composition
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,n,r,c", [
    (6, 3, 12, 3), (7, 5, 8, 10), (1, 4, 4, 5), (9, 1, 3, 4), (16, 8, 16, 7)])
@pytest.mark.parametrize("lsh_verification", [True, False])
def test_exchange_kernel_matches_oracle(m, n, r, c, lsh_verification):
    """m=7/9/1 exercise the BM_EXC padding path."""
    own, nb, y, sel = _inputs(m, n, r, c, seed=m * n)
    out_k = fused_exchange(own, nb, y, sel,
                           lsh_verification=lsh_verification)
    out_o = ref.all_in_one_exchange_ref(own, nb, y, sel,
                                        lsh_verification=lsh_verification)
    for a, b, name in zip(out_k, out_o,
                          ("l_ij", "valid", "target", "has_target")):
        assert a.dtype == b.dtype and a.shape == b.shape, name
        assert bool(jnp.all(a == b)), name


@pytest.mark.parametrize("m,n,r,c", [(6, 3, 12, 3), (7, 5, 8, 10)])
def test_exchange_oracle_matches_unfused_composition(m, n, r, c):
    """The oracle is bit-identical to the three scattered calls the
    round ran before the fusion (acceptance: round metrics unchanged)."""
    own, nb, y, sel = _inputs(m, n, r, c, seed=m + n)
    l_legacy = jax.vmap(lambda yl, yy: jax.vmap(
        lambda l: distill.cross_entropy(l, yy))(yl))(nb, y)
    v_legacy = jax.vmap(verify.lsh_verification_mask)(own, nb, sel)
    t_legacy, h_legacy = jax.vmap(distill.aggregate_neighbor_outputs)(
        nb, v_legacy)
    l_o, v_o, t_o, h_o = ref.all_in_one_exchange_ref(own, nb, y, sel)
    assert bool(jnp.all(l_legacy == l_o))
    assert bool(jnp.all(v_legacy == v_o))
    assert bool(jnp.all(t_legacy == t_o))
    assert bool(jnp.all(h_legacy == h_o))


# ---------------------------------------------------------------------------
# §3.5 semantics regressions (both backends)
# ---------------------------------------------------------------------------
def _fed(m=6, **kw):
    base = dict(num_clients=m, num_neighbors=4, top_k=2, lsh_bits=128)
    base.update(kw)
    return FedConfig(**base)


@pytest.mark.parametrize("backend", ["kernel", "oracle"])
def test_exchange_upper_half_keep_count(backend):
    """ceil(n_valid / 2) of the selected neighbors pass, per client."""
    own, nb, y, sel = _inputs(8, 5, 6, 4, seed=11, sel_p=0.6)
    res = all_in_one_exchange(own, nb, y, sel, _fed(8), backend=backend)
    n_valid = np.asarray(jnp.sum(sel, axis=1))
    kept = np.asarray(jnp.sum(res.valid_mask, axis=1))
    assert (kept == (n_valid + 1) // 2).all()


@pytest.mark.parametrize("backend", ["kernel", "oracle"])
def test_exchange_masked_neighbors_never_pass(backend):
    own, nb, y, sel = _inputs(8, 5, 6, 4, seed=13, sel_p=0.4)
    res = all_in_one_exchange(own, nb, y, sel, _fed(8), backend=backend)
    assert not bool(jnp.any(res.valid_mask & ~sel))


@pytest.mark.parametrize("backend", ["kernel", "oracle"])
def test_exchange_all_invalid_falls_back_to_local_only(backend):
    """No selected neighbors -> zero target, has_target False, and the
    combined loss reduces to the local CE term (Alg. 1's fallback)."""
    own, nb, y, _ = _inputs(5, 3, 4, 3, seed=17)
    sel = jnp.zeros((5, 3), bool)
    res = all_in_one_exchange(own, nb, y, sel, _fed(5), backend=backend)
    assert not bool(jnp.any(res.valid_mask))
    assert not bool(jnp.any(res.has_target))
    assert bool(jnp.all(res.target_ref == 0.0))
    # distill.combined_loss zeroes the ref term when has_target is False
    apply_fn = lambda p, x: x @ p
    p = jnp.eye(3)
    batch = {"x": own[0, :, :3], "y": y[0, :4] % 3}
    _, (_, l_ref) = distill.combined_loss(
        apply_fn, p, batch, own[0], res.target_ref[0],
        res.has_target[0], alpha=0.5)
    assert float(l_ref) == 0.0


@pytest.mark.parametrize("backend", ["kernel", "oracle"])
def test_exchange_verification_off_passes_all_selected(backend):
    own, nb, y, sel = _inputs(6, 4, 5, 3, seed=19, sel_p=0.5)
    fed = _fed(6, lsh_verification=False)
    res = all_in_one_exchange(own, nb, y, sel, fed, backend=backend)
    assert bool(jnp.all(res.valid_mask == sel))


# ---------------------------------------------------------------------------
# all_in_one_exchange entry point
# ---------------------------------------------------------------------------
def test_exchange_backends_agree_via_entry_point():
    own, nb, y, sel = _inputs(10, 4, 6, 5, seed=23)
    fed = _fed(10)
    res_k = all_in_one_exchange(own, nb, y, sel, fed, backend="kernel")
    res_o = all_in_one_exchange(own, nb, y, sel, fed, backend="oracle")
    for a, b, name in zip(res_k, res_o, ExchangeResult._fields):
        assert bool(jnp.all(a == b)), name


def test_exchange_rejects_unknown_backend():
    own, nb, y, sel = _inputs(4, 2, 3, 3)
    with pytest.raises(ValueError):
        all_in_one_exchange(own, nb, y, sel,
                            _fed(4, exchange_backend="cuda"))


def test_exchange_degenerate_no_neighbors():
    """M=1 federation: N=0 — no kernel launch, zeros fallback."""
    own = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 3))
    nb = jnp.zeros((1, 0, 4, 3))
    res = all_in_one_exchange(own, nb, jnp.zeros((1, 4), jnp.int32),
                              jnp.zeros((1, 0), bool), _fed(1))
    assert res.l_ij.shape == (1, 0) and res.valid_mask.shape == (1, 0)
    assert res.target_ref.shape == (1, 4, 3)
    assert not bool(res.has_target[0])


# ---------------------------------------------------------------------------
# protocol integration: backend invariance, phases, metrics, ref modes
# ---------------------------------------------------------------------------
def test_round_exchange_backend_invariant(tiny_fed):
    f = tiny_fed
    out = {}
    for backend in ("oracle", "kernel"):
        fed = dataclasses.replace(f["fed"], exchange_backend=backend)
        state = init_state(f["apply_fn"], f["init_fn"], f["opt"], fed,
                           jax.random.PRNGKey(0))
        round_fn = jax.jit(make_wpfed_round(f["apply_fn"], f["opt"], fed))
        s1, m1 = round_fn(state, f["data"])
        s2, m2 = round_fn(s1, f["data"])
        out[backend] = (s2, m2)
    s_o, m_o = out["oracle"]
    s_k, m_k = out["kernel"]
    assert bool(jnp.all(s_o.codes == s_k.codes))
    assert bool(jnp.all(s_o.rankings == s_k.rankings))
    assert bool(jnp.all(m_o["valid_mask"] == m_k["valid_mask"]))
    np.testing.assert_array_equal(np.asarray(m_o["mean_neighbor_loss"]),
                                  np.asarray(m_k["mean_neighbor_loss"]))


def test_round_metrics_match_phase_composition(tiny_fed):
    """round_fn is exactly select -> exchange -> update -> announce; the
    (fixed) mean_neighbor_loss averages over SELECTED slots only."""
    f = tiny_fed
    state = init_state(f["apply_fn"], f["init_fn"], f["opt"], f["fed"],
                       jax.random.PRNGKey(3))
    round_fn = jax.jit(make_wpfed_round(f["apply_fn"], f["opt"], f["fed"]))
    _, metrics = round_fn(state, f["data"])

    _, rng_sel, _ = jax.random.split(state.rng, 3)
    sel = select_phase(state, f["fed"], rng=rng_sel)
    exch = exchange_phase(f["apply_fn"], f["fed"], state.params,
                          f["data"], sel)
    n_sel = float(jnp.sum(sel.sel_mask))
    expect = float(jnp.sum(jnp.where(sel.sel_mask, exch.l_ij, 0.0))
                   / max(n_sel, 1.0))
    assert np.isclose(float(metrics["mean_neighbor_loss"]), expect,
                      rtol=0, atol=0)
    assert bool(jnp.all(metrics["neighbor_ids"] == sel.ids))
    assert bool(jnp.all(metrics["valid_mask"] == exch.valid_mask))


def test_mean_neighbor_loss_ignores_unselected_slots():
    """Regression for the biased metric: zeros in unselected slots must
    not dilute the average (old code divided by M*N, not the count)."""
    own, nb, y, _ = _inputs(4, 3, 5, 3, seed=29)
    sel = jnp.array([[True, False, False]] * 4)
    res = all_in_one_exchange(own, nb, y, sel, _fed(4), backend="oracle")
    biased = float(jnp.mean(jnp.where(sel, res.l_ij, 0.0)))
    fixed = float(jnp.sum(jnp.where(sel, res.l_ij, 0.0))
                  / jnp.sum(sel.astype(jnp.float32)))
    assert np.isclose(fixed, float(jnp.mean(res.l_ij[:, 0])))
    assert fixed > biased          # losses are positive; bias was downward


def test_ref_mode_public_equals_personal_on_identical_refs(tiny_fed):
    """The abstract's public-reference regime: when every client already
    holds the same reference set, the M-forward public exchange must
    reproduce the M*N-forward personal one."""
    f = tiny_fed
    data = dict(f["data"])
    data["x_ref"] = jnp.broadcast_to(data["x_ref"][:1],
                                     data["x_ref"].shape)
    data["y_ref"] = jnp.broadcast_to(data["y_ref"][:1],
                                     data["y_ref"].shape)
    out = {}
    for mode in ("personal", "public"):
        fed = dataclasses.replace(f["fed"], ref_mode=mode)
        state = init_state(f["apply_fn"], f["init_fn"], f["opt"], fed,
                           jax.random.PRNGKey(1))
        round_fn = jax.jit(make_wpfed_round(f["apply_fn"], f["opt"], fed))
        s1, m1 = round_fn(state, data)
        s2, m2 = round_fn(s1, data)
        out[mode] = (s2, m2)
    s_p, m_p = out["personal"]
    s_u, m_u = out["public"]
    assert bool(jnp.all(m_p["neighbor_ids"] == m_u["neighbor_ids"]))
    assert bool(jnp.all(m_p["valid_mask"] == m_u["valid_mask"]))
    np.testing.assert_allclose(np.asarray(m_p["mean_neighbor_loss"]),
                               np.asarray(m_u["mean_neighbor_loss"]),
                               rtol=1e-6)
    leaves_p = jax.tree.leaves(s_p.params)
    leaves_u = jax.tree.leaves(s_u.params)
    for a, b in zip(leaves_p, leaves_u):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-6)


def test_ref_mode_rejects_unknown(tiny_fed):
    f = tiny_fed
    fed = dataclasses.replace(f["fed"], ref_mode="shared")
    state = init_state(f["apply_fn"], f["init_fn"], f["opt"], fed,
                       jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        make_wpfed_round(f["apply_fn"], f["opt"], fed)(state, f["data"])


# ---------------------------------------------------------------------------
# launcher wiring
# ---------------------------------------------------------------------------
def test_dryrun_threads_clients_and_ref_mode(monkeypatch):
    """Regression: `--dryrun` used to silently ignore `--clients`."""
    from repro.launch import fed as fed_launch
    calls = {}

    def fake_dryrun(num_clients=256, arch="phi3-medium-14b",
                    backend="kernel", ref_mode="personal", tiling="auto",
                    reselect_every=1, attack="none", attack_frac=0.5,
                    attack_start=-1):
        calls.update(num_clients=num_clients, backend=backend,
                     ref_mode=ref_mode, tiling=tiling,
                     reselect_every=reselect_every,
                     attack=attack, attack_frac=attack_frac,
                     attack_start=attack_start)

    monkeypatch.setattr(fed_launch, "dryrun_fed_round", fake_dryrun)
    monkeypatch.setenv("XLA_FLAGS",
                       "--xla_force_host_platform_device_count=512")
    fed_launch.main(["--dryrun", "--clients", "32", "--ref-mode", "public"])
    assert calls == {"num_clients": 32, "backend": "kernel",
                     "ref_mode": "public", "tiling": "auto",
                     "reselect_every": 1,
                     "attack": "none", "attack_frac": 0.5,
                     "attack_start": -1}
    fed_launch.main(["--dryrun", "--backend", "oracle",
                     "--tiling", "tiled",
                     "--schedule", "gossip", "--reselect-every", "4",
                     "--attack", "poison", "--attack-frac", "0.25",
                     "--attack-start", "5"])
    assert calls == {"num_clients": 256, "backend": "oracle",
                     "ref_mode": "personal", "tiling": "tiled",
                     "reselect_every": 4,
                     "attack": "poison", "attack_frac": 0.25,
                     "attack_start": 5}
