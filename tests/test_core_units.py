"""Unit + property tests for the WPFed core primitives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import distill, neighbor, ranking, verify
from repro.core.chain import fnv1a_commit


# ---------------------------------------------------------------------------
# ranking (Eq. 7)
# ---------------------------------------------------------------------------
def test_make_ranking_orders_by_loss():
    ids = jnp.array([5, 2, 9, 1], jnp.int32)
    losses = jnp.array([0.9, 0.1, 0.5, 0.3])
    r = ranking.make_ranking(ids, losses)
    assert list(np.asarray(r)) == [2, 1, 9, 5]


def test_make_ranking_invalid_sink_to_minus_one():
    ids = jnp.array([5, 2, 9, 1], jnp.int32)
    losses = jnp.array([0.9, 0.1, 0.5, 0.3])
    mask = jnp.array([True, False, True, True])
    r = ranking.make_ranking(ids, losses, mask)
    assert list(np.asarray(r)) == [1, 9, 5, -1]


def test_ranking_scores_eq7():
    # 3 reporters, 4 clients; K=1
    rankings = jnp.array([[1, 2], [1, 3], [2, 1]], jnp.int32)
    s = ranking.ranking_scores(rankings, 4, top_k=1)
    # client 1 appears in 3 rankings, top-1 in two -> 2/3
    assert abs(float(s[1]) - 2 / 3) < 1e-6
    # client 2 appears twice, top-1 once -> 1/2
    assert abs(float(s[2]) - 0.5) < 1e-6
    # client 0 never ranked -> 0
    assert float(s[0]) == 0.0


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000))
def test_ranking_scores_bounded(seed):
    key = jax.random.PRNGKey(seed)
    m, n, c = 8, 4, 8
    rankings = jax.random.randint(key, (m, n), -1, c).astype(jnp.int32)
    s = ranking.ranking_scores(rankings, c, top_k=2)
    assert bool(jnp.all(s >= 0)) and bool(jnp.all(s <= 1))


def test_ranking_scores_excludes_bad_reporters():
    rankings = jnp.array([[1], [1], [1]], jnp.int32)
    s_all = ranking.ranking_scores(rankings, 3, top_k=1)
    s_some = ranking.ranking_scores(rankings, 3, top_k=1,
                                    reporter_mask=jnp.array([True, False,
                                                             False]))
    assert float(s_all[1]) == 1.0 and float(s_some[1]) == 1.0
    # with zero honest reporters the score collapses to 0 (no evidence)
    s_none = ranking.ranking_scores(rankings, 3, top_k=1,
                                    reporter_mask=jnp.zeros(3, bool))
    assert float(s_none[1]) == 0.0


# ---------------------------------------------------------------------------
# neighbor selection (Eq. 8)
# ---------------------------------------------------------------------------
def test_selection_weight_formula():
    scores = jnp.array([0.5, 1.0, 0.25])
    d = jnp.array([[0.0, 0.2, 0.8],
                   [0.2, 0.0, 0.5],
                   [0.8, 0.5, 0.0]], jnp.float32)
    w = neighbor.selection_weights(scores, d, gamma=2.0)
    assert np.isclose(float(w[0, 1]), 1.0 * np.exp(-0.4))
    assert np.isclose(float(w[0, 2]), 0.25 * np.exp(-1.6))
    assert not np.isfinite(float(w[0, 0]))            # self excluded


def test_selection_ablation_switches():
    scores = jnp.array([0.1, 0.9, 0.5])
    d = jnp.ones((3, 3)) * 0.3
    w_rank_only = neighbor.selection_weights(scores, d, 1.0, use_lsh=False)
    assert np.isclose(float(w_rank_only[0, 1]), 0.9)
    w_lsh_only = neighbor.selection_weights(scores, d, 1.0, use_rank=False)
    assert np.isclose(float(w_lsh_only[0, 1]), np.exp(-0.3))
    w_rand = neighbor.selection_weights(scores, d, 1.0, use_lsh=False,
                                        use_rank=False,
                                        rng=jax.random.PRNGKey(0))
    assert bool(jnp.all(jnp.isfinite(w_rand[~np.eye(3, dtype=bool)])))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 999), st.integers(2, 10))
def test_select_neighbors_topn_no_self(seed, m):
    key = jax.random.PRNGKey(seed)
    w = jax.random.uniform(key, (m, m))
    w = jnp.where(jnp.eye(m, dtype=bool), -jnp.inf, w)
    ids, mask = neighbor.select_neighbors(w, 3)
    for i in range(m):
        sel = np.asarray(ids[i])[np.asarray(mask[i])]
        assert i not in sel
        assert len(set(sel.tolist())) == len(sel)


# ---------------------------------------------------------------------------
# verification (§3.5, §3.6)
# ---------------------------------------------------------------------------
def _skewed(own, strength):
    """Boost class 0 by `strength` — changes the softmax (a constant
    shift would not)."""
    return own.at[:, 0].add(strength)


def test_lsh_verification_keeps_upper_half():
    own = jnp.tile(jnp.array([[1.0, 0.5, -0.5]]), (4, 1))
    near = jnp.stack([_skewed(own, 0.01), _skewed(own, 0.05),
                      _skewed(own, 5.0), _skewed(own, 9.0)])
    mask = jnp.ones((4,), bool)
    keep = verify.lsh_verification_mask(own, near, mask)
    assert list(np.asarray(keep)) == [True, True, False, False]


def test_lsh_verification_respects_selection_mask():
    own = jnp.tile(jnp.array([[1.0, 0.5, -0.5]]), (4, 1))
    near = jnp.stack([_skewed(own, 9.0), _skewed(own, 0.01),
                      _skewed(own, 0.02), _skewed(own, 0.03)])
    mask = jnp.array([True, True, False, False])
    keep = verify.lsh_verification_mask(own, near, mask)
    # only 2 valid -> keep 1 (upper half): the more-similar valid one (#1)
    assert list(np.asarray(keep)) == [False, True, False, False]


def test_kl_divergence_properties():
    a = jnp.array([[2.0, 0.0, -1.0]])
    assert float(verify.kl_divergence(a, a)) < 1e-9
    b = jnp.array([[0.0, 2.0, -1.0]])
    assert float(verify.kl_divergence(a, b)) > 0


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 500))
def test_fnv_commitment_binds(seed):
    key = jax.random.PRNGKey(seed)
    r = jax.random.randint(key, (5, 4), -1, 10).astype(jnp.int32)
    c = fnv1a_commit(r)
    assert bool(jnp.all(fnv1a_commit(r) == c))
    r2 = r.at[2, 1].add(1)
    assert not bool(jnp.all(fnv1a_commit(r2) == c))


# ---------------------------------------------------------------------------
# distillation (Eq. 2-4)
# ---------------------------------------------------------------------------
def test_aggregate_neighbor_outputs():
    nl = jnp.stack([jnp.ones((3, 2)), 3 * jnp.ones((3, 2)),
                    100 * jnp.ones((3, 2))])
    agg, has = distill.aggregate_neighbor_outputs(
        nl, jnp.array([True, True, False]))
    assert bool(has)
    assert np.allclose(np.asarray(agg), 2.0)
    agg0, has0 = distill.aggregate_neighbor_outputs(
        nl, jnp.zeros((3,), bool))
    assert not bool(has0)
    assert np.allclose(np.asarray(agg0), 0.0)


def test_combined_loss_alpha_extremes(tiny_fed):
    apply_fn = tiny_fed["apply_fn"]
    init_fn = tiny_fed["init_fn"]
    data = tiny_fed["data"]
    p = init_fn(jax.random.PRNGKey(0))
    batch = {"x": data["x_train"][0][:8], "y": data["y_train"][0][:8]}
    tgt = jnp.zeros((data["x_ref"].shape[1], 3))
    l1, (ll, lr) = distill.combined_loss(apply_fn, p, batch,
                                         data["x_ref"][0], tgt, True, 1.0)
    assert np.isclose(float(l1), float(ll))
    l0, (ll0, lr0) = distill.combined_loss(apply_fn, p, batch,
                                           data["x_ref"][0], tgt, True, 0.0)
    assert np.isclose(float(l0), float(lr0))
