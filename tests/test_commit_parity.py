"""Commit-and-reveal parity (ISSUE 9 satellite): the in-graph FNV-1a
fast-path commitment (`core.chain.fnv1a_commit` / `core.verify
.verify_rankings_fnv`) and the on-chain SHA-256 binding commitment
(`core.chain.sha256_commit` / `verify_reveal`) must agree on WHICH
reveals verify — over random announcements and tampered-reveal
negatives — and the agreement must hold end-to-end through a
`Blockchain` publish/reveal cycle.

Property-test style with plain seeded numpy loops (hypothesis is not
installable offline; conftest's stub would skip @given tests)."""
import jax.numpy as jnp
import numpy as np

from repro.core.chain import (Blockchain, fnv1a_commit, sha256_commit,
                              verify_reveal)
from repro.core.verify import verify_rankings_fnv

N_TRIALS = 50


def _random_rankings(rs, m, n):
    """Plausible announcement rankings: neighbor ids with -1 padding."""
    r = rs.randint(-1, 4 * m, size=(m, n)).astype(np.int32)
    return r


def _tamper(rs, rankings):
    """Flip one entry of one row; guaranteed to differ."""
    t = rankings.copy()
    i = rs.randint(t.shape[0])
    j = rs.randint(t.shape[1])
    t[i, j] += 1 + rs.randint(5)
    return t, i


def test_fnv_and_sha_agree_on_honest_and_tampered_reveals():
    rs = np.random.RandomState(0)
    for trial in range(N_TRIALS):
        m = int(rs.randint(2, 9))
        n = int(rs.randint(1, 6))
        salt = int(rs.randint(0, 1 << 16))
        rankings = _random_rankings(rs, m, n)
        commits_fnv = fnv1a_commit(jnp.asarray(rankings), salt=salt)
        commits_sha = [sha256_commit(rankings[i], salt=salt)
                       for i in range(m)]

        # honest reveals: both accept every row
        ok_fnv = np.asarray(verify_rankings_fnv(
            jnp.asarray(rankings), commits_fnv, salt=salt))
        ok_sha = np.array([verify_reveal(commits_sha[i], rankings[i],
                                         salt=salt) for i in range(m)])
        assert ok_fnv.all() and ok_sha.all(), trial

        # tampered reveal: both reject exactly the tampered row
        tampered, row = _tamper(rs, rankings)
        bad_fnv = np.asarray(verify_rankings_fnv(
            jnp.asarray(tampered), commits_fnv, salt=salt))
        bad_sha = np.array([verify_reveal(commits_sha[i], tampered[i],
                                          salt=salt) for i in range(m)])
        # full agreement vector, not just the tampered row
        np.testing.assert_array_equal(bad_fnv, bad_sha)
        assert not bad_fnv[row], trial


def test_fnv_salt_separates_commitments():
    rs = np.random.RandomState(1)
    for trial in range(N_TRIALS):
        rankings = _random_rankings(rs, int(rs.randint(2, 6)), 4)
        c0 = fnv1a_commit(jnp.asarray(rankings), salt=7)
        # verifying against the wrong salt must fail (both schemes)
        ok = np.asarray(verify_rankings_fnv(jnp.asarray(rankings), c0,
                                            salt=8))
        assert not ok.any(), trial
        sha7 = sha256_commit(rankings[0], salt=7)
        assert not verify_reveal(sha7, rankings[0], salt=8)


def test_parity_through_blockchain_end_to_end():
    """Publish SHA commitments on chain, reveal next round, and check
    the chain-side verdicts match the in-graph FNV verdicts — with one
    client revealing a tampered ranking."""
    rs = np.random.RandomState(2)
    m, n = 5, 3
    rankings = _random_rankings(rs, m, n)
    commits_fnv = fnv1a_commit(jnp.asarray(rankings), salt=0)

    chain = Blockchain()
    chain.publish_round(0, {
        i: {"lsh": "00", "commit": sha256_commit(rankings[i])}
        for i in range(m)})

    # round 1: everyone reveals; client 3 lies about its ranking
    revealed = rankings.copy()
    revealed[3, 0] += 2
    chain.publish_round(1, {i: {"lsh": "00", "commit": "x"}
                            for i in range(m)},
                        reveals={i: revealed[i] for i in range(m)})
    assert chain.verify_chain()

    blk0 = chain.round_block(0)
    blk1 = chain.round_block(1)
    on_chain_reveals = np.array(
        [blk1.payload["reveals"][str(i)] for i in range(m)], np.int32)
    verdict_sha = np.array([
        verify_reveal(blk0.payload["announcements"][str(i)]["commit"],
                      on_chain_reveals[i]) for i in range(m)])
    verdict_fnv = np.asarray(verify_rankings_fnv(
        jnp.asarray(on_chain_reveals), commits_fnv))
    np.testing.assert_array_equal(verdict_sha, verdict_fnv)
    np.testing.assert_array_equal(
        verdict_sha, np.array([True, True, True, False, True]))
