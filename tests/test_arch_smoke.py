"""Per-architecture smoke tests (deliverable f): a REDUCED variant of
each family runs one forward + one train step on CPU; output shapes and
finiteness asserted."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.data import modality_stub
from repro.models import forward, init_params
from repro.optim import adamw
from repro.train import init_train_state, make_train_step

B, S = 2, 16


def _batch(cfg, rs):
    batch = {
        "tokens": jnp.asarray(rs.randint(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rs.randint(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    batch.update({k: jnp.asarray(v)
                  for k, v in modality_stub(cfg, B, rs).items()})
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_forward_shapes_finite(arch):
    cfg = get_config(arch).reduced()
    rs = np.random.RandomState(0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rs)
    extra = {k: batch[k] for k in ("audio", "vision") if k in batch}
    logits, aux = forward(cfg, params, batch["tokens"], extra or None)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    if cfg.is_moe:  # avoid drop-nondeterminism in the loss assertion
        cfg = dataclasses.replace(cfg, moe_capacity_factor=2.0)
    rs = np.random.RandomState(1)
    opt = adamw(1e-3)
    params, opt_state = init_train_state(cfg, opt, jax.random.PRNGKey(1))
    step = jax.jit(make_train_step(cfg, opt, remat="none"))
    batch = _batch(cfg, rs)
    p1, o1, m1 = step(params, opt_state, batch)
    assert bool(jnp.isfinite(m1["loss"]))
    assert float(m1["grad_norm"]) > 0
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.sum(jnp.abs(a - b))), params, p1))
    assert delta > 0
    # a second step on the same batch reduces loss (sanity of gradient)
    p2, o2, m2 = step(p1, o1, batch)
    assert float(m2["loss"]) < float(m1["loss"]) + 0.1


@pytest.mark.parametrize("arch", ["recurrentgemma-2b", "xlstm-350m",
                                  "phi3-medium-14b"])
def test_remat_matches_no_remat(arch):
    cfg = get_config(arch).reduced()
    rs = np.random.RandomState(2)
    params = init_params(cfg, jax.random.PRNGKey(2))
    batch = _batch(cfg, rs)
    from repro.train.steps import lm_loss
    l0, _ = lm_loss(cfg, params, batch, remat="none")
    l1, _ = lm_loss(cfg, params, batch, remat="block")
    assert abs(float(l0) - float(l1)) < 1e-4


@pytest.mark.parametrize("arch", ["phi3-medium-14b", "recurrentgemma-2b",
                                  "whisper-small", "grok-1-314b"])
def test_unroll_matches_scan(arch):
    cfg = get_config(arch).reduced()
    rs = np.random.RandomState(3)
    params = init_params(cfg, jax.random.PRNGKey(3))
    batch = _batch(cfg, rs)
    extra = {k: batch[k] for k in ("audio", "vision") if k in batch}
    l_scan, _ = forward(cfg, params, batch["tokens"], extra or None,
                        unroll=False)
    l_unroll, _ = forward(cfg, params, batch["tokens"], extra or None,
                          unroll=True)
    np.testing.assert_allclose(np.asarray(l_scan), np.asarray(l_unroll),
                               atol=2e-5, rtol=2e-5)
