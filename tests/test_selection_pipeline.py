"""Batched LSH + fused selection pipeline (DESIGN.md §4).

Bit-exactness contracts:
  * batched LSH kernel vs per-client oracle: packed codes identical
    (projection sums to f32 tolerance — reduction order differs);
  * fused selection kernel vs jnp oracle vs the unfused
    hamming -> selection_weights -> top_k composition: ids and weights
    identical, including the Table-3 ablation switches;
  * select_partners backends agree, and the protocol round is
    backend-invariant end to end.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import FedConfig
from repro.core import init_state, lsh, make_wpfed_round, neighbor
from repro.kernels import ops, ref
from repro.kernels.lsh_projection import (BLOCK_M, CHUNK,
                                          lsh_project_sums_batched)
from repro.kernels.selection import fused_select


def _codes(m, words, seed=0):
    raw = jax.random.bernoulli(jax.random.PRNGKey(seed), 0.5, (m, words * 32))
    return ops.pack_bits(jnp.where(raw, 1.0, -1.0))


# ---------------------------------------------------------------------------
# batched LSH projection kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,nchunks,bits", [
    (8, 1, 128), (6, 2, 256), (13, 3, 128), (16, 1, 512), (1, 2, 128)])
def test_batched_lsh_codes_match_oracle(m, nchunks, bits):
    x = jax.random.normal(jax.random.PRNGKey(m * nchunks),
                          (m, CHUNK * nchunks))
    codes_k = ops.batched_lsh_codes(x, 11, bits=bits, use_kernel=True)
    codes_o = ops.batched_lsh_codes(x, 11, bits=bits, use_kernel=False)
    assert codes_k.shape == (m, bits // 32)
    assert bool(jnp.all(codes_k == codes_o))


@pytest.mark.parametrize("m", [3, 8, 9])
def test_batched_lsh_sums_close_to_oracle(m):
    """Sums agree to f32 tolerance (chunked accumulation vs one matmul);
    includes the M-padding path (m % BLOCK_M != 0)."""
    x = jax.random.normal(jax.random.PRNGKey(m), (m, CHUNK * 2))
    pm = (-m) % BLOCK_M
    sums_k = lsh_project_sums_batched(
        jnp.pad(x, ((0, pm), (0, 0))), 5, bits=128)[:m]
    sums_o = ref.lsh_project_sums_batched_ref(x, 5, bits=128)
    scale = 1 + float(jnp.max(jnp.abs(sums_o)))
    assert float(jnp.max(jnp.abs(sums_k - sums_o))) < 1e-3 * scale


def test_batched_lsh_rows_match_single_client_path():
    """Row i of the batched pipeline == the single-client Eq. 5 code of
    client i's pytree (flatten order + projection semantics agree)."""
    keys = jax.random.split(jax.random.PRNGKey(3), 5)
    trees = [{"w": jax.random.normal(k, (40, 30)),
              "b": jax.random.normal(jax.random.fold_in(k, 1), (17,))}
             for k in keys]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
    flat2d = ops.flatten_params_batched(stacked)
    batched = ops.batched_lsh_codes(flat2d, 9, bits=128, use_kernel=True)
    for i, tree in enumerate(trees):
        single = ops.lsh_code(tree, 9, bits=128, use_kernel=False)
        assert bool(jnp.all(batched[i] == single)), i


def test_batched_lsh_seed_changes_codes():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, CHUNK))
    a = ops.batched_lsh_codes(x, 0, bits=128)
    b = ops.batched_lsh_codes(x, 1, bits=128)
    assert not bool(jnp.all(a == b))


def test_batched_lsh_accepts_traced_seed():
    """The per-round seed is state.round + 1, a traced scalar under jit."""
    x = jax.random.normal(jax.random.PRNGKey(2), (4, CHUNK))
    fn = jax.jit(lambda s: ops.batched_lsh_codes(x, s, bits=128))
    assert bool(jnp.all(fn(jnp.int32(7))
                        == ops.batched_lsh_codes(x, 7, bits=128)))


# ---------------------------------------------------------------------------
# fused selection: kernel vs oracle vs unfused composition
# ---------------------------------------------------------------------------
def _unfused(codes, scores, bits, gamma, n, use_lsh=True, use_rank=True):
    d = lsh.distance_matrix(codes, use_kernel=False)
    d_norm = lsh.normalized_distance(d, bits)
    w = neighbor.selection_weights(scores, d_norm, gamma,
                                   use_lsh=use_lsh, use_rank=use_rank)
    ids, mask = neighbor.select_neighbors(w, n)
    top_w, _ = jax.lax.top_k(w, min(n, codes.shape[0] - 1))
    return ids, mask, top_w


@pytest.mark.parametrize("m,words,n", [
    (6, 4, 3), (10, 4, 9), (32, 8, 12), (37, 8, 5), (64, 16, 16), (9, 4, 8)])
def test_fused_selection_matches_oracle_and_unfused(m, words, n):
    codes = _codes(m, words, seed=m * words)
    scores = jax.random.uniform(jax.random.PRNGKey(m + n), (m,))
    kw = dict(bits=words * 32, gamma=1.0, num_neighbors=n)
    ids_k, w_k = fused_select(codes, scores, **kw)
    ids_o, w_o = ref.fused_select_ref(codes, scores, **kw)
    ids_u, mask_u, w_u = _unfused(codes, scores, words * 32, 1.0, n)
    assert bool(jnp.all(ids_k == ids_o)) and bool(jnp.all(w_k == w_o))
    assert bool(jnp.all(ids_k == ids_u)) and bool(jnp.all(w_k == w_u))
    assert bool(jnp.all(mask_u))


@pytest.mark.parametrize("use_lsh,use_rank", [(True, False), (False, True)])
@pytest.mark.parametrize("gamma", [0.1, 1.0, 10.0])
def test_fused_selection_ablation_switches(use_lsh, use_rank, gamma):
    m, words, n = 12, 4, 5
    codes = _codes(m, words, seed=42)
    scores = jax.random.uniform(jax.random.PRNGKey(1), (m,))
    kw = dict(bits=words * 32, gamma=gamma, num_neighbors=n,
              use_lsh=use_lsh, use_rank=use_rank)
    ids_k, w_k = fused_select(codes, scores, **kw)
    ids_o, w_o = ref.fused_select_ref(codes, scores, **kw)
    ids_u, _, w_u = _unfused(codes, scores, words * 32, gamma,
                             n, use_lsh=use_lsh, use_rank=use_rank)
    assert bool(jnp.all(ids_k == ids_o)) and bool(jnp.all(w_k == w_o))
    assert bool(jnp.all(ids_k == ids_u)) and bool(jnp.all(w_k == w_u))


@pytest.mark.parametrize("m", [5, 8, 9, 17])
def test_fused_selection_excludes_self_and_padding(m):
    """Self-exclusion plus the row/column padding edge: m deliberately
    not a BM_SEL multiple; padded columns must never be selected."""
    codes = _codes(m, 4, seed=m)
    scores = jnp.ones((m,))                       # uniform -> ties galore
    ids, w = fused_select(codes, scores, bits=128, gamma=1.0,
                          num_neighbors=m - 1)
    idn = np.asarray(ids)
    for i in range(m):
        assert i not in idn[i]
        assert set(idn[i]) == set(range(m)) - {i}   # all real, no padding
    assert bool(jnp.all(jnp.isfinite(w)))


def test_fused_selection_degenerate_single_client():
    """M=1 federation: no selectable peers -> empty (1, 0) outputs on
    both backends (the kernel path must not hit a zero-length stack)."""
    codes = _codes(1, 4, seed=0)
    scores = jnp.ones((1,))
    for fn in (fused_select, ref.fused_select_ref):
        ids, w = fn(codes, scores, bits=128, gamma=1.0, num_neighbors=3)
        assert ids.shape == (1, 0) and w.shape == (1, 0)


def test_fused_selection_tie_breaking_matches_top_k():
    """Identical codes + identical scores -> all weights tie; the fused
    iterative argmax must reproduce lax.top_k's ascending-index order."""
    m, n = 11, 4
    codes = jnp.tile(_codes(1, 4, seed=0), (m, 1))
    scores = jnp.full((m,), 0.5)
    ids_k, w_k = fused_select(codes, scores, bits=128, gamma=1.0,
                              num_neighbors=n)
    ids_u, _, w_u = _unfused(codes, scores, 128, 1.0, n)
    assert bool(jnp.all(ids_k == ids_u))
    assert bool(jnp.all(w_k == w_u))


# ---------------------------------------------------------------------------
# select_partners entry point
# ---------------------------------------------------------------------------
def _fed(m, **kw):
    base = dict(num_clients=m, num_neighbors=4, top_k=2, lsh_bits=128)
    base.update(kw)
    return FedConfig(**base)


def test_select_partners_backends_agree():
    m = 14
    codes = _codes(m, 4, seed=7)
    scores = jax.random.uniform(jax.random.PRNGKey(2), (m,))
    fed = _fed(m)
    ids_k, mask_k = neighbor.select_partners(codes, scores, fed,
                                             backend="kernel")
    ids_o, mask_o = neighbor.select_partners(codes, scores, fed,
                                             backend="oracle")
    assert bool(jnp.all(ids_k == ids_o))
    assert bool(jnp.all(mask_k == mask_o)) and bool(jnp.all(mask_k))


def test_select_partners_random_ablation_needs_rng():
    m = 8
    codes = _codes(m, 4, seed=3)
    scores = jnp.zeros((m,))
    fed = _fed(m, use_lsh=False, use_rank=False)
    ids, mask = neighbor.select_partners(codes, scores, fed,
                                         rng=jax.random.PRNGKey(0))
    idn = np.asarray(ids)
    for i in range(m):
        assert i not in idn[i][np.asarray(mask[i])]
    with pytest.raises(AssertionError):
        neighbor.select_partners(codes, scores, fed)


def test_select_partners_rejects_unknown_backend():
    fed = _fed(6, selection_backend="cuda")
    with pytest.raises(ValueError):
        neighbor.select_partners(_codes(6, 4), jnp.zeros((6,)), fed)


# ---------------------------------------------------------------------------
# protocol integration: backend invariance + per-round LSH seed
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def two_rounds(tiny_fed):
    f = tiny_fed
    out = {}
    for backend in ("oracle", "kernel"):
        fed = dataclasses.replace(f["fed"], selection_backend=backend)
        state = init_state(f["apply_fn"], f["init_fn"], f["opt"], fed,
                           jax.random.PRNGKey(0))
        round_fn = jax.jit(make_wpfed_round(f["apply_fn"], f["opt"], fed))
        s1, m1 = round_fn(state, f["data"])
        s2, m2 = round_fn(s1, f["data"])
        out[backend] = (state, s1, s2, m1, m2)
    return out


def test_round_backend_invariant(two_rounds):
    o, k = two_rounds["oracle"], two_rounds["kernel"]
    assert bool(jnp.all(o[0].codes == k[0].codes))          # init
    for r in (3, 4):                                        # metrics
        assert bool(jnp.all(o[r]["neighbor_ids"] == k[r]["neighbor_ids"]))
    assert bool(jnp.all(o[2].codes == k[2].codes))          # after 2 rounds


def test_round_threads_per_round_lsh_seed(two_rounds, tiny_fed):
    """Regression (ISSUE satellite): codes published at the end of round
    r hash with the shared per-round seed r+1 — not the dead seed=0 —
    and all clients use the same seed (distances stay comparable)."""
    fed = tiny_fed["fed"]
    _, s1, s2, _, _ = two_rounds["oracle"]
    for state, seed in ((s1, 1), (s2, 2)):
        expect = lsh.stacked_lsh_codes(state.params, seed=seed,
                                       bits=fed.lsh_bits, backend="oracle")
        assert bool(jnp.all(state.codes == expect))
    # the seed is actually consumed: seed-0 codes of the same params differ
    stale = lsh.stacked_lsh_codes(s1.params, seed=0, bits=fed.lsh_bits,
                                  backend="oracle")
    assert not bool(jnp.all(s1.codes == stale))
