"""First-class adversary API (core.adversary, DESIGN.md §9).

The load-bearing guarantees:
  * The in-graph attack path (instrument_program + run_rounds at
    Schedule(1)) is BIT-EXACT with the legacy per-round host loop —
    eager attack hook before each jitted round, the pre-PR4
    benchmarks.common.run_method composition, copied verbatim below —
    for WPFed and ProxyFL.
  * Attack scheduling (`start_round`/`every`) is scan-safe: attacks
    fire at the right rounds INSIDE a reselect_every=4 gossip segment,
    where the round index is a lax.scan tracer.
  * resolve_attack / threat_model / resolve_threat validate in one
    place (the repro.core.backends pattern).
  * §3.6 end-to-end: lie_in_reveal reporters are flagged by the
    engine's own per-round metrics.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Schedule, attacker_mask_tail, attacks, evaluate,
                        init_state, instrument_program, make_program,
                        make_segment_fn, resolve_attack, resolve_threat,
                        run_rounds, threat_model, wpfed_program)
from repro.core.adversary import ATTACKS, THREATS, Attack, attack_key
from repro.core.attacks import attack_active
from repro.core.rounds import program_round


def _bitwise_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype and x.shape == y.shape
        assert x.tobytes() == y.tobytes()


@pytest.fixture(scope="module")
def ctx(tiny_fed):
    f = dict(tiny_fed)
    f["state0"] = init_state(f["apply_fn"], f["init_fn"], f["opt"],
                             f["fed"], jax.random.PRNGKey(0))
    f["mask"] = jnp.arange(f["fed"].num_clients) >= 4   # last 2 of 6
    return f


# ---------------------------------------------------------------------------
# one-place validation: resolve_attack / threat_model / resolve_threat
# ---------------------------------------------------------------------------
def test_resolve_attack_validates():
    init_fn = lambda k: {"w": jnp.zeros((2,))}
    with pytest.raises(ValueError, match="unknown attack"):
        resolve_attack("dos")
    with pytest.raises(ValueError, match="init_fn"):
        resolve_attack("corrupt")
    with pytest.raises(ValueError, match="init_fn"):
        resolve_attack("poison")
    with pytest.raises(ValueError, match="target_id"):
        resolve_attack("forge_codes")
    with pytest.raises(ValueError, match="every"):
        resolve_attack("corrupt", init_fn=init_fn, every=0)
    with pytest.raises(ValueError, match="start_round"):
        resolve_attack("corrupt", init_fn=init_fn, start_round=-1)
    # §4.8 schedule defaults live on the registry entry
    a = resolve_attack("poison", init_fn=init_fn)
    assert (a.start_round, a.every) == (50, 3)
    b = resolve_attack("lie_in_reveal")
    assert (b.start_round, b.every) == (0, 1)
    assert set(ATTACKS) == {"forge_codes", "corrupt", "poison",
                            "lie_in_reveal"}


def test_threat_model_validates(ctx):
    lie = resolve_attack("lie_in_reveal")
    with pytest.raises(ValueError, match="at least one"):
        threat_model([], ctx["mask"])
    with pytest.raises(TypeError, match="resolve_attack"):
        threat_model([lambda s: s], ctx["mask"])
    with pytest.raises(ValueError, match="bool"):
        threat_model([lie], jnp.arange(6))          # int mask
    with pytest.raises(ValueError, match="1-D"):
        threat_model([lie], jnp.zeros((2, 3), bool))
    tm = threat_model([lie], ctx["mask"], name="liars")
    assert tm.name == "liars" and len(tm.attacks) == 1


def test_attacker_mask_tail():
    m = attacker_mask_tail(8, 0.25)
    assert m.tolist() == [False] * 6 + [True] * 2
    with pytest.raises(ValueError):
        attacker_mask_tail(8, 0.0)      # no attackers
    with pytest.raises(ValueError):
        attacker_mask_tail(8, 1.0)      # nobody honest


def test_resolve_threat_presets(ctx):
    with pytest.raises(ValueError, match="unknown threat"):
        resolve_threat("byzantine", num_clients=6)
    with pytest.raises(ValueError, match="init_fn"):
        resolve_threat("poison", num_clients=6)     # poison needs init_fn
    tm = resolve_threat("lsh_cheat", num_clients=6, attacker_frac=0.34,
                        init_fn=ctx["init_fn"], start_round=2)
    assert [a.name for a in tm.attacks] == ["corrupt", "forge_codes"]
    assert all(a.start_round == 2 for a in tm.attacks)
    assert int(jnp.sum(tm.attacker_mask)) == 2
    lie = resolve_threat("lie_in_reveal", num_clients=6)
    assert [a.name for a in lie.attacks] == ["lie_in_reveal"]
    assert set(THREATS) == {"lsh_cheat", "poison", "lie_in_reveal"}


# ---------------------------------------------------------------------------
# scan-safe scheduling
# ---------------------------------------------------------------------------
def test_attack_active_matches_host_gate_traced():
    active = jax.jit(jax.vmap(lambda r: attack_active(r, 3, 2)))(
        jnp.arange(10))
    expect = [(r >= 3) and ((r - 3) % 2 == 0) for r in range(10)]
    assert active.tolist() == expect


def test_poison_step_gates_under_jit_with_traced_round(ctx):
    """Regression (PR 4 satellite): the old host `if round_idx >=
    start_round` raised/mis-gated when round_idx was a tracer."""
    f = ctx
    step = jax.jit(lambda s, r: attacks.poison_step(
        s, f["mask"], f["init_fn"], jax.random.PRNGKey(3), r,
        start_round=1, every=2))
    w0 = np.asarray(f["state0"].params["w"][0])
    fired = np.asarray(step(f["state0"], jnp.asarray(1)).params["w"][0])
    idle = np.asarray(step(f["state0"], jnp.asarray(0)).params["w"][0])
    assert not np.array_equal(fired, w0)            # active round re-inits
    assert np.array_equal(fired[:4], w0[:4])        # honest rows untouched
    assert np.array_equal(idle, w0)                 # warm-up round is a no-op


def test_attack_fires_inside_gossip_scan(ctx):
    """A marker attack (rankings += 1) scheduled at start_round=1,
    every=2 must fire at gossip rounds 1 and 3 of a 4-round segment —
    where the round index is a lax.scan tracer. WPFed's gossip epoch
    never rewrites rankings, so the final state shows exactly the two
    scheduled firings on top of round 0's announcement."""
    f = ctx
    marker = Attack("marker",
                    lambda s, mask, r, k: s._replace(rankings=s.rankings + 1),
                    start_round=1, every=2)
    tm = threat_model([marker], f["mask"], name="marker")
    prog = wpfed_program(f["apply_fn"], f["opt"], f["fed"])
    st_clean, _c, _m = jax.jit(prog.global_round)(f["state0"], f["data"])
    seg = jax.jit(make_segment_fn(instrument_program(prog, tm), 4))
    st, _metrics = seg(f["state0"], f["data"])
    assert int(st.round) == 4
    np.testing.assert_array_equal(np.asarray(st.rankings),
                                  np.asarray(st_clean.rankings) + 2)


# ---------------------------------------------------------------------------
# in-graph path bit-exact vs the legacy per-round host loop (Schedule(1))
# ---------------------------------------------------------------------------
def _legacy_attack_loop(round_fn, hook, state, data, rounds):
    """Verbatim copy of the pre-PR4 benchmarks.common.run_method attack
    path: mutate state with an eager host hook, then run one jitted
    round, every round."""
    round_fn = jax.jit(round_fn)
    for r in range(rounds):
        state = hook(state, r)
        state, _m = round_fn(state, data)
    return state


@pytest.mark.parametrize("method", ["wpfed", "proxyfl"])
def test_in_graph_attacks_bitexact_vs_legacy_host_loop(ctx, method):
    f = ctx
    KEY = jax.random.PRNGKey(123)
    START, EVERY = 1, 2
    tm = threat_model(
        [resolve_attack("corrupt", init_fn=f["init_fn"],
                        start_round=START, every=EVERY),
         resolve_attack("forge_codes", target_id=0,
                        start_round=START, every=EVERY)],
        f["mask"], key=KEY, name="cheat")
    prog = make_program(method, f["apply_fn"], f["opt"], f["fed"])
    st_engine, history = run_rounds(
        instrument_program(prog, tm), f["state0"], f["data"], rounds=4,
        schedule=Schedule(1))

    def hook(state, r):                 # the legacy eager per-round hook
        if r >= START and (r - START) % EVERY == 0:
            state = attacks.corrupt_params(state, f["mask"], f["init_fn"],
                                           attack_key(KEY, 0, r))
            state = attacks.forge_lsh_codes(state, f["mask"], 0)
        return state

    st_legacy = _legacy_attack_loop(program_round(prog), hook,
                                    f["state0"], f["data"], 4)
    _bitwise_equal(st_legacy, st_engine)
    assert [h["round"] for h in history] == [0, 1, 2, 3]


def test_attacked_gossip_schedule_runs_whole_segments(ctx):
    """Acceptance: an adversarial run drives Schedule(reselect_every=4)
    through run_rounds — one compiled segment per period, attacks and
    threat telemetry included, no host loop."""
    f = ctx
    tm = resolve_threat("poison", num_clients=6, attacker_frac=0.34,
                        init_fn=f["init_fn"], key=jax.random.PRNGKey(5),
                        start_round=1, every=2)
    prog = instrument_program(
        wpfed_program(f["apply_fn"], f["opt"], f["fed"]), tm)
    segments = []
    st, hist = run_rounds(prog, f["state0"], f["data"], rounds=8,
                          schedule=Schedule(4),
                          on_reselect=lambda r0, s: segments.append(r0))
    assert segments == [0, 4]           # two periods, host sync per period
    assert int(st.round) == 8
    for h in hist:
        assert 0.0 <= h["attacker_admission_rate"] <= 1.0
        assert np.isfinite(h["rank_score_honest"])
        assert np.isfinite(h["rank_score_attacker"])


# ---------------------------------------------------------------------------
# §3.6 end-to-end: lying reporters flagged by the engine's own metrics
# ---------------------------------------------------------------------------
def test_lie_in_reveal_reporters_flagged_end_to_end(ctx):
    f = ctx
    tm = threat_model([resolve_attack("lie_in_reveal")], f["mask"],
                      name="liars")
    prog = instrument_program(
        wpfed_program(f["apply_fn"], f["opt"], f["fed"]), tm)
    _st, hist = run_rounds(prog, f["state0"], f["data"], rounds=2,
                           schedule=Schedule(1))
    # every round the liars reveal a ranking differing from their
    # commitment; the §3.6 check flags exactly the 2 liars of 6
    for h in hist:
        assert abs(h["honest_reporter_frac"] - 4 / 6) < 1e-6


def test_instrumented_metrics_absent_without_selection_arrays(ctx):
    """Baselines that expose no selection arrays gain no bogus
    telemetry — the augmentation is derived, not fabricated."""
    f = ctx
    tm = threat_model(
        [resolve_attack("corrupt", init_fn=f["init_fn"], start_round=1)],
        f["mask"], key=jax.random.PRNGKey(2), name="corrupt")
    prog = instrument_program(
        make_program("silo", f["apply_fn"], f["opt"], f["fed"]), tm)
    _st, hist = run_rounds(prog, f["state0"], f["data"], rounds=2,
                           schedule=Schedule(2))
    for h in hist:
        assert "attacker_admission_rate" not in h
        assert np.isfinite(h["mean_loss"])
