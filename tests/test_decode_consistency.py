"""Serving invariant: sequential decode == full forward; prefill -> decode
handoff is exact. Run for every architecture family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.models import decode_step, forward, init_cache, init_params, prefill

S = 10
TOL = 2e-4


def _setup(arch, key):
    cfg = get_config(arch).reduced()
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=100.0)
    params = init_params(cfg, key)
    tokens = jax.random.randint(key, (2, S), 0, cfg.vocab_size)
    extra = None
    if cfg.is_encdec:
        extra = {"audio": jax.random.normal(
            key, (2, cfg.encoder_seq_len, cfg.d_model)) * 0.1}
    if cfg.vision_tokens:
        extra = {"vision": jax.random.normal(
            key, (2, cfg.vision_tokens, cfg.vision_dim)) * 0.1}
    return cfg, params, tokens, extra


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_forward(arch):
    key = jax.random.PRNGKey(0)
    cfg, params, tokens, extra = _setup(arch, key)
    full, _ = forward(cfg, params, tokens, extra)
    cache = init_cache(cfg, params, 2, S, extra=extra)
    for pos in range(S):
        lg, cache = decode_step(cfg, params, cache, tokens[:, pos],
                                jnp.int32(pos))
        err = float(jnp.max(jnp.abs(lg - full[:, pos])))
        assert err < TOL, f"{arch} pos {pos}: {err}"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_handoff(arch):
    key = jax.random.PRNGKey(1)
    cfg, params, tokens, extra = _setup(arch, key)
    full, _ = forward(cfg, params, tokens, extra)
    lgp, cache = prefill(cfg, params, tokens[:, :S - 1], extra, cache_len=S)
    assert float(jnp.max(jnp.abs(lgp - full[:, S - 2]))) < TOL
    lg, _ = decode_step(cfg, params, cache, tokens[:, S - 1],
                        jnp.int32(S - 1))
    assert float(jnp.max(jnp.abs(lg - full[:, S - 1]))) < TOL


@pytest.mark.parametrize("arch", ["phi3-medium-14b", "nemotron-4-340b"])
def test_sliding_window_serving_variant(arch):
    """window_override decode must agree with the window-masked forward."""
    key = jax.random.PRNGKey(2)
    cfg, params, tokens, extra = _setup(arch, key)
    win = 4
    full, _ = forward(cfg, params, tokens, extra, window_override=win)
    cache = init_cache(cfg, params, 2, S, extra=extra, window_override=win)
    for pos in range(S):
        lg, cache = decode_step(cfg, params, cache, tokens[:, pos],
                                jnp.int32(pos), window_override=win)
        err = float(jnp.max(jnp.abs(lg - full[:, pos])))
        assert err < TOL, f"{arch} win pos {pos}: {err}"


def test_window_ring_buffer_bounded():
    """Ring cache never grows beyond the window size."""
    cfg = get_config("phi3-medium-14b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(3))
    win = 4
    cache = init_cache(cfg, params, 2, 64, window_override=win)
    kv = jax.tree.leaves(cache["layers"])[0]
    assert kv.shape[2] == win  # (reps, B, win, kv, dh)
