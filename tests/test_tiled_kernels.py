"""VMEM-tiled kernels: column-tiled two-pass selection and streamed
R/C-tiled exchange (DESIGN.md §10).

Contracts:
  * tiled selection is BIT-EXACT against `ref.fused_select_ref` and the
    one-shot kernel — ids and weights — at every M, including ragged
    shapes (M not a tile multiple), cross-tile ties, ablation switches
    and the N = M-1 clamp edge (exact-integer distances + shared
    elementwise exp + order-preserving merge-by-knockout);
  * streamed exchange is tolerance-bounded against the one-shot oracle
    and the streaming twin for l_ij / target_ref (the online softmax
    reorders reductions), while the §3.5 valid mask and has_target are
    pinned EQUAL (they only flip on exact kl ties);
  * `backends.resolve_tiling` picks one-shot vs tiled from the explicit
    VMEM estimate, and the subsystem entry points thread the tiling
    fields end to end.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import FedConfig
from repro.core import all_in_one_exchange, backends, neighbor, ranking
from repro.kernels import ops, ref
from repro.kernels.exchange import (fused_exchange, fused_exchange_streamed,
                                    streamed_tiles)
from repro.kernels.selection import fused_select, fused_select_tiled


def _codes(m, words, seed=0):
    raw = jax.random.bernoulli(jax.random.PRNGKey(seed), 0.5, (m, words * 32))
    return ops.pack_bits(jnp.where(raw, 1.0, -1.0))


def _exchange_inputs(m, n, r, c, seed=0, sel_p=0.7):
    k = jax.random.PRNGKey(seed)
    own = jax.random.normal(k, (m, r, c)) * 3
    nb = jax.random.normal(jax.random.fold_in(k, 1), (m, n, r, c)) * 3
    y = jax.random.randint(jax.random.fold_in(k, 2), (m, r), 0, c)
    sel = jax.random.bernoulli(jax.random.fold_in(k, 3), sel_p, (m, n))
    return own, nb, y, sel


# ---------------------------------------------------------------------------
# column-tiled selection: bit-exactness at ragged shapes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,words,n,bm,bk", [
    (6, 4, 3, 8, 128),        # single tile both axes
    (37, 8, 5, 8, 128),       # ragged M on both grids
    (9, 4, 8, 8, 128),        # N = M-1 clamp edge
    (130, 4, 7, 32, 128),     # ragged across two column tiles
    (300, 8, 16, 128, 128),   # three column tiles, ragged rows
    (257, 4, 12, 64, 128),    # one past a tile boundary
])
def test_tiled_selection_bit_exact_ragged(m, words, n, bm, bk):
    codes = _codes(m, words, seed=m * words)
    scores = jax.random.uniform(jax.random.PRNGKey(m + n), (m,))
    kw = dict(bits=words * 32, gamma=1.0, num_neighbors=n)
    ids_t, w_t = fused_select_tiled(codes, scores, **kw,
                                    block_m=bm, block_k=bk)
    ids_o, w_o = ref.fused_select_ref(codes, scores, **kw)
    ids_k, w_k = fused_select(codes, scores, **kw)
    assert bool(jnp.all(ids_t == ids_o)) and bool(jnp.all(w_t == w_o))
    assert bool(jnp.all(ids_t == ids_k)) and bool(jnp.all(w_t == w_k))


@pytest.mark.parametrize("use_lsh,use_rank", [(True, False), (False, True)])
def test_tiled_selection_ablation_switches(use_lsh, use_rank):
    m, words, n = 150, 4, 6
    codes = _codes(m, words, seed=42)
    scores = jax.random.uniform(jax.random.PRNGKey(1), (m,))
    kw = dict(bits=words * 32, gamma=0.5, num_neighbors=n,
              use_lsh=use_lsh, use_rank=use_rank)
    ids_t, w_t = fused_select_tiled(codes, scores, **kw,
                                    block_m=32, block_k=128)
    ids_o, w_o = ref.fused_select_ref(codes, scores, **kw)
    assert bool(jnp.all(ids_t == ids_o)) and bool(jnp.all(w_t == w_o))


def test_tiled_selection_cross_tile_ties():
    """Equal weights spanning column-tile boundaries must keep
    lax.top_k's ascending-index order: the running candidates hold
    strictly smaller global ids than the current tile, so putting them
    first in the merge preserves first-max tie-breaking."""
    m, n = 300, 12
    base = _codes(3, 4, seed=2)
    groups = jax.random.randint(jax.random.PRNGKey(1), (m,), 0, 3)
    codes = base[groups]                      # 3 distinct codes -> ties
    scores = jnp.round(jax.random.uniform(jax.random.PRNGKey(3),
                                          (m,)) * 4) / 4
    ids_t, w_t = fused_select_tiled(codes, scores, bits=128, gamma=1.0,
                                    num_neighbors=n, block_m=64,
                                    block_k=128)
    ids_o, w_o = ref.fused_select_ref(codes, scores, bits=128, gamma=1.0,
                                      num_neighbors=n)
    assert bool(jnp.all(ids_t == ids_o)) and bool(jnp.all(w_t == w_o))


def test_tiled_selection_degenerate_single_client():
    codes = _codes(1, 4, seed=0)
    ids, w = fused_select_tiled(codes, jnp.ones((1,)), bits=128, gamma=1.0,
                                num_neighbors=3)
    assert ids.shape == (1, 0) and w.shape == (1, 0)


@pytest.mark.parametrize("m", [1024, 4096])
def test_tiled_selection_bit_exact_large(m):
    """The scale the one-shot kernel was built for (1024) and a scale
    past its comfort zone (4096, ~4.3 MB/program one-shot): the tiled
    kernel stays bit-exact with default production tiles."""
    codes = _codes(m, 8, seed=m)
    scores = jax.random.uniform(jax.random.PRNGKey(m), (m,))
    kw = dict(bits=256, gamma=1.0, num_neighbors=16)
    ids_o, w_o = jax.jit(functools.partial(
        ref.fused_select_ref, **kw))(codes, scores)
    ids_t, w_t = fused_select_tiled(codes, scores, **kw)
    assert bool(jnp.all(ids_t == ids_o)) and bool(jnp.all(w_t == w_o))


def test_select_partners_tiling_paths_agree():
    m = 37
    codes = _codes(m, 4, seed=7)
    scores = jax.random.uniform(jax.random.PRNGKey(2), (m,))
    fed = FedConfig(num_clients=m, num_neighbors=5, top_k=2, lsh_bits=128)
    outs = {}
    for tiling in ("oneshot", "tiled", "auto"):
        outs[tiling] = neighbor.select_partners(
            codes, scores, fed, backend="kernel", tiling=tiling)
    for tiling in ("tiled", "auto"):
        assert bool(jnp.all(outs[tiling][0] == outs["oneshot"][0])), tiling
        assert bool(jnp.all(outs[tiling][1] == outs["oneshot"][1])), tiling


def test_select_partners_rejects_unknown_tiling():
    fed = FedConfig(num_clients=6, num_neighbors=3, top_k=2, lsh_bits=128,
                    selection_tiling="huge")
    with pytest.raises(ValueError):
        neighbor.select_partners(_codes(6, 4), jnp.zeros((6,)), fed)


# ---------------------------------------------------------------------------
# streamed exchange: tolerance contract at ragged shapes
# ---------------------------------------------------------------------------
def _assert_exchange_close(got, want, name):
    l_g, v_g, t_g, h_g = got
    l_w, v_w, t_w, h_w = want
    np.testing.assert_allclose(np.asarray(l_g), np.asarray(l_w),
                               rtol=2e-5, atol=1e-5, err_msg=name)
    assert bool(jnp.all(v_g == v_w)), f"{name}: valid mask"
    np.testing.assert_allclose(np.asarray(t_g), np.asarray(t_w),
                               rtol=2e-5, atol=1e-5, err_msg=name)
    assert bool(jnp.all(h_g == h_w)), f"{name}: has_target"


@pytest.mark.parametrize("m,n,r,c,br,bc", [
    (5, 3, 9, 17, 4, 128),     # ragged M, R; single C tile
    (7, 5, 12, 70, 8, 128),    # ragged everything
    (4, 2, 8, 513, 8, 128),    # one past a C-tile boundary
    (6, 4, 16, 40, 8, 128),    # two R tiles
    (3, 4, 5, 300, 8, 128),    # three C tiles, ragged R
    (9, 1, 3, 4, 8, 128),      # single-neighbor, tiny tail shapes
    (1, 4, 4, 5, 8, 128),      # single client block
])
@pytest.mark.parametrize("lsh_verification", [True, False])
def test_streamed_exchange_matches_contract(m, n, r, c, br, bc,
                                            lsh_verification):
    own, nb, y, sel = _exchange_inputs(m, n, r, c, seed=m * n + r)
    out_s = fused_exchange_streamed(own, nb, y, sel,
                                    lsh_verification=lsh_verification,
                                    block_r=br, block_c=bc)
    out_o = ref.all_in_one_exchange_ref(own, nb, y, sel,
                                        lsh_verification=lsh_verification)
    out_t = ref.streamed_exchange_ref(own, nb, y, sel,
                                      lsh_verification=lsh_verification,
                                      block_r=br, block_c=bc)
    _assert_exchange_close(out_s, out_o, "kernel vs one-shot oracle")
    _assert_exchange_close(out_s, out_t, "kernel vs streaming twin")
    for a, b, nm in zip(out_s, out_o, ("l_ij", "valid", "target", "has")):
        assert a.dtype == b.dtype and a.shape == b.shape, nm


def test_streamed_exchange_vocab_scale_smoke():
    """A C past the one-shot kernel's VMEM comfort zone (the §10
    motivation): est one-shot VMEM > budget, streamed stays O(tile)."""
    m, n, r, c = 4, 8, 16, 8192
    assert (backends.exchange_vmem_bytes(n, r, c)
            > backends.VMEM_BUDGET_BYTES)
    assert (backends.exchange_tiled_vmem_bytes(n)
            < backends.VMEM_BUDGET_BYTES)
    own, nb, y, sel = _exchange_inputs(m, n, r, c, seed=3)
    out_s = fused_exchange_streamed(own, nb, y, sel)
    out_t = ref.streamed_exchange_ref(own, nb, y, sel)
    _assert_exchange_close(out_s, out_t, "vocab-scale kernel vs twin")


def test_streamed_exchange_upper_half_keep_count():
    own, nb, y, sel = _exchange_inputs(8, 5, 6, 4, seed=11, sel_p=0.6)
    _, valid, _, _ = fused_exchange_streamed(own, nb, y, sel,
                                             block_r=4, block_c=128)
    n_valid = np.asarray(jnp.sum(sel, axis=1))
    kept = np.asarray(jnp.sum(valid, axis=1))
    assert (kept == (n_valid + 1) // 2).all()
    assert not bool(jnp.any(valid & ~sel))


def test_exchange_entry_point_tiling_paths():
    """all_in_one_exchange threads exchange_tiling end to end: tiled
    kernel and tiled oracle (the streaming twin) agree with the
    one-shot paths per the §10 contract."""
    own, nb, y, sel = _exchange_inputs(10, 4, 6, 5, seed=23)
    fed = FedConfig(num_clients=10, num_neighbors=4, top_k=2, lsh_bits=128)
    base = all_in_one_exchange(own, nb, y, sel, fed, backend="oracle",
                               tiling="oneshot")
    for backend in ("kernel", "oracle"):
        out = all_in_one_exchange(own, nb, y, sel, fed, backend=backend,
                                  tiling="tiled")
        _assert_exchange_close(tuple(out), tuple(base),
                               f"{backend}+tiled vs oracle+oneshot")
    auto = all_in_one_exchange(own, nb, y, sel, fed, backend="oracle")
    for a, b in zip(auto, base):          # tiny shape: auto == one-shot
        assert bool(jnp.all(a == b))


def test_exchange_entry_point_rejects_unknown_tiling():
    own, nb, y, sel = _exchange_inputs(4, 2, 3, 3)
    fed = FedConfig(num_clients=4, num_neighbors=2, top_k=2, lsh_bits=128,
                    exchange_tiling="mega")
    with pytest.raises(ValueError):
        all_in_one_exchange(own, nb, y, sel, fed)


# ---------------------------------------------------------------------------
# VMEM-estimate resolution
# ---------------------------------------------------------------------------
def test_resolve_tiling_auto_uses_budget():
    assert backends.resolve_tiling("auto", 0) == "oneshot"
    assert backends.resolve_tiling(
        "auto", backends.VMEM_BUDGET_BYTES) == "oneshot"
    assert backends.resolve_tiling(
        "auto", backends.VMEM_BUDGET_BYTES + 1) == "tiled"
    assert backends.resolve_tiling("auto", 100, budget_bytes=10) == "tiled"
    assert backends.resolve_tiling("oneshot", 1 << 60) == "oneshot"
    assert backends.resolve_tiling("tiled", 0) == "tiled"
    with pytest.raises(ValueError):
        backends.resolve_tiling("huge", 0)


def test_vmem_estimates_scale_as_documented():
    """One-shot grows linearly with the unbounded axis; tiled does not
    depend on it at all."""
    assert (backends.selection_vmem_bytes(1 << 16, 256)
            >= 3.9 * backends.selection_vmem_bytes(1 << 14, 256))
    assert (backends.exchange_vmem_bytes(16, 64, 1 << 15)
            >= 15.9 * backends.exchange_vmem_bytes(16, 64, 1 << 11))
    # the documented M ~ 10^4 / C ~ 10^3 ceilings fall out of the
    # estimates: one-shot selection at M=65536 and exchange at C=32768
    # blow the budget, their tiled twins stay comfortably inside it
    assert (backends.selection_vmem_bytes(1 << 16, 256)
            > backends.VMEM_BUDGET_BYTES)
    assert (backends.selection_tiled_vmem_bytes(256)
            < backends.VMEM_BUDGET_BYTES // 4)
    assert (backends.exchange_vmem_bytes(16, 64, 1 << 15)
            > backends.VMEM_BUDGET_BYTES)
    assert (backends.exchange_tiled_vmem_bytes(16)
            < backends.VMEM_BUDGET_BYTES // 4)


def test_streamed_tiles_clamps_small_shapes():
    br, pr, bc, pc = streamed_tiles(5, 17, 8, 512)
    assert br == 8 and (5 + pr) % br == 0
    assert bc == 128 and (17 + pc) % bc == 0
    br, pr, bc, pc = streamed_tiles(64, 4096, 8, 512)
    assert (br, bc) == (8, 512) and pr == 0 and pc == 0


# ---------------------------------------------------------------------------
# Eq. 7 duplicate-evidence dedupe (public-ref ranking correction)
# ---------------------------------------------------------------------------
def test_dedupe_counts_duplicate_rankings_once():
    """Three reporters revealing the same vector must count as one:
    with dedupe, scores equal the two-distinct-reporter scores."""
    dup = jnp.array([[2, 3], [2, 3], [2, 3], [0, 1]], jnp.int32)
    uniq = jnp.array([[2, 3], [0, 1]], jnp.int32)
    s_dup = ranking.ranking_scores(dup, 4, top_k=1, dedupe=True)
    s_uniq = ranking.ranking_scores(uniq, 4, top_k=1)
    np.testing.assert_array_equal(np.asarray(s_dup), np.asarray(s_uniq))
    # without dedupe the duplicated evidence inflates nothing here
    # (same ratio) but DOES dominate mixed tallies:
    mixed = jnp.array([[2, 3], [2, 3], [3, 2]], jnp.int32)
    s_no = ranking.ranking_scores(mixed, 4, top_k=1)
    s_yes = ranking.ranking_scores(mixed, 4, top_k=1, dedupe=True)
    assert float(s_no[2]) == pytest.approx(2 / 3)
    assert float(s_yes[2]) == pytest.approx(1 / 2)   # one vote per vector


def test_dedupe_respects_reporter_mask():
    """A duplicate of an EXCLUDED reporter is the first honest copy and
    must survive; duplicates of an honest reporter drop."""
    r = jnp.array([[1, 2], [1, 2], [1, 2]], jnp.int32)
    mask = jnp.array([False, True, True])
    kept = ranking.dedupe_reporter_mask(r, mask)
    np.testing.assert_array_equal(np.asarray(kept),
                                  np.array([False, True, False]))


def test_dedupe_noop_on_distinct_rankings():
    r = jnp.array([[1, 2], [2, 1], [0, 2]], jnp.int32)
    kept = ranking.dedupe_reporter_mask(r, jnp.ones((3,), bool))
    assert bool(jnp.all(kept))
    s_plain = ranking.ranking_scores(r, 3, top_k=1)
    s_dedup = ranking.ranking_scores(r, 3, top_k=1, dedupe=True)
    np.testing.assert_array_equal(np.asarray(s_plain), np.asarray(s_dedup))


def test_dedupe_public_personal_rank_agreement(tiny_fed):
    """Regression for the §7 duplicated-evidence caveat: on identical
    reference sets the public and personal regimes produce the same
    revealed rankings, so the deduped Eq. 7 scores — and the next
    round's rank ordering — agree between the modes."""
    from repro.core import init_state, make_wpfed_round
    f = tiny_fed
    data = dict(f["data"])
    data["x_ref"] = jnp.broadcast_to(data["x_ref"][:1], data["x_ref"].shape)
    data["y_ref"] = jnp.broadcast_to(data["y_ref"][:1], data["y_ref"].shape)
    scores = {}
    for mode in ("personal", "public"):
        fed = dataclasses.replace(f["fed"], ref_mode=mode,
                                  dedupe_rankings=True)
        state = init_state(f["apply_fn"], f["init_fn"], f["opt"], fed,
                           jax.random.PRNGKey(1))
        round_fn = jax.jit(make_wpfed_round(f["apply_fn"], f["opt"], fed))
        s1, _ = round_fn(state, data)
        _, m2 = round_fn(s1, data)          # round 2 scores use reveals
        scores[mode] = np.asarray(m2["ranking_scores"])
    np.testing.assert_allclose(scores["public"], scores["personal"],
                               rtol=1e-6, atol=1e-7)
    assert np.array_equal(np.argsort(-scores["public"]),
                          np.argsort(-scores["personal"]))


# ---------------------------------------------------------------------------
# protocol integration: tiled round is invariant
# ---------------------------------------------------------------------------
def test_round_selection_tiling_invariant(tiny_fed):
    """A full WPFed round with tiled selection is bit-identical to the
    one-shot round (the tiled kernel is bit-exact, so the tiling choice
    can never move protocol results)."""
    from repro.core import init_state, make_wpfed_round
    f = tiny_fed
    out = {}
    for tiling in ("oneshot", "tiled"):
        fed = dataclasses.replace(f["fed"], selection_backend="kernel",
                                  selection_tiling=tiling)
        state = init_state(f["apply_fn"], f["init_fn"], f["opt"], fed,
                           jax.random.PRNGKey(0))
        round_fn = jax.jit(make_wpfed_round(f["apply_fn"], f["opt"], fed))
        s1, m1 = round_fn(state, f["data"])
        s2, m2 = round_fn(s1, f["data"])
        out[tiling] = (s2, m2)
    s_o, m_o = out["oneshot"]
    s_t, m_t = out["tiled"]
    assert bool(jnp.all(m_o["neighbor_ids"] == m_t["neighbor_ids"]))
    assert bool(jnp.all(s_o.codes == s_t.codes))
    assert bool(jnp.all(s_o.rankings == s_t.rankings))
